"""End-to-end training driver: train SmolLM-135M (real config) for a few
hundred steps with checkpointing.  On this CPU container the default runs
the *reduced* config; pass --full on real hardware for the 135M model.

    PYTHONPATH=src python examples/train_smollm.py            # CPU smoke
    PYTHONPATH=src python examples/train_smollm.py --full     # 135M
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true")
ap.add_argument("--steps", type=int, default=0)
args = ap.parse_args()

if args.full:
    steps = args.steps or 300
    argv = ["--arch", "smollm-135m", "--steps", str(steps),
            "--batch", "8", "--seq", "512", "--lr", "3e-4",
            "--ckpt-dir", "/tmp/smollm_ckpt", "--ckpt-every", "50"]
else:
    steps = args.steps or 200
    argv = ["--arch", "smollm-135m", "--smoke", "--steps", str(steps),
            "--batch", "8", "--seq", "128", "--lr", "1e-3",
            "--ckpt-dir", "/tmp/smollm_smoke_ckpt", "--ckpt-every", "50"]

losses = train_main(argv)
print(f"\nfinal loss {losses[-1]:.4f} (start {losses[0]:.4f}) — "
      "resume any time with the same command plus --resume")
