"""Straggler drill: SLOTH watching a (simulated) 16×16 TPU pod.

A slow chip and a degraded ICI link are injected into per-step telemetry;
the pod detector localises both and the mitigation policy plans the
response (data-shard rebalance or checkpoint+exclude restart).

    PYTHONPATH=src python examples/straggler_drill.py
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.failures import FailSlow
from repro.distributed.telemetry import (MitigationPolicy, PodDetector,
                                         PodSimulator, PodTelemetryConfig)

cfg = PodTelemetryConfig(mesh_w=16, mesh_h=16)
detector = PodDetector(cfg)
policy = MitigationPolicy(n_shards=16)

# yi-34b-class training step: ~0.8 TFLOP/chip/step, ~0.5 GB collectives
pod = PodSimulator(cfg, step_flops=8e11, collective_bytes=128e9, seed=0)

print("== phase 1: healthy pod ==")
v = detector.analyse(pod.run_steps(32))
print(f"flagged={v.flagged}  action={v.action}")

print("\n== phase 2: chip (14,7) thermally throttled 5x ==")
chip = 7 * 16 + 14
pod.inject(FailSlow("core", chip, 0.0, 1e9, 5.0))
v = detector.analyse(pod.run_steps(32))
print(f"flagged={v.flagged} kind={v.kind} loc={v.location} "
      f"(injected chip {chip}) severity={v.severity:.1f}")
print("mitigation:", policy.plan(v))

print("\n== phase 3: degraded ICI link ==")
pod2 = PodSimulator(cfg, step_flops=8e11, collective_bytes=128e9, seed=1)
pod2.inject(FailSlow("link", 77, 0.0, 1e9, 8.0))
v = detector.analyse(pod2.run_steps(32))
u, w = detector.mesh.links[77]
print(f"flagged={v.flagged} kind={v.kind} loc={v.location} "
      f"(injected link 77 = chip{u}->chip{w})")
print("mitigation:", policy.plan(v))
