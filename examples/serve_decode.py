"""Serve a small model with batched requests (prefill + greedy decode).

    PYTHONPATH=src python examples/serve_decode.py
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, Request, ServeEngine

cfg = get_config("smollm-135m", smoke=True)
params = T.init_model(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
engine = ServeEngine(cfg, params, EngineConfig(batch=4, cache_len=128))

rng = np.random.default_rng(0)
t0 = time.perf_counter()
for i in range(10):
    prompt = rng.integers(0, cfg.vocab, size=int(rng.integers(4, 24)))
    engine.submit(Request(i, prompt.astype(np.int32), max_new=12))
done = engine.run()
wall = time.perf_counter() - t0

for r in sorted(done, key=lambda r: r.rid)[:4]:
    print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.out_tokens}")
tok = sum(len(r.out_tokens) for r in done)
print(f"\nserved {len(done)} requests, {tok} new tokens in {wall:.1f}s "
      f"({tok / wall:.0f} tok/s, CPU smoke config)")
print(f"mean decode step: {np.mean(engine.decode_times) * 1e3:.1f} ms "
      f"(mean prefill {np.mean(engine.prefill_times) * 1e3:.1f} ms)")
