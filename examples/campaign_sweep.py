"""Scenario-campaign sweep: reproduce the paper's aggregate metrics.

Runs a grid of fail-slow scenarios (workload × mesh × failure kind ×
severity × n_failures × replicate) through every requested detector
(``--detectors``: SLOTH and/or the five baselines, all judged on the same
traces by the same router-aware rule) and prints per-cell, per-detector
and campaign-level accuracy / FPR / top-k localisation / recall@k /
compression / probe overhead, with Wilson confidence intervals and
wall-time telemetry.

    PYTHONPATH=src python examples/campaign_sweep.py            # full grid
    PYTHONPATH=src python examples/campaign_sweep.py --tiny     # CI smoke
    PYTHONPATH=src python examples/campaign_sweep.py \\
        --tiny --executor process --n-failures 2                # multi-core
    PYTHONPATH=src python examples/campaign_sweep.py \\
        --mesh 12x12 --mesh 16x8 --executor process             # big meshes
    PYTHONPATH=src python examples/campaign_sweep.py \\
        --detectors sloth --detectors thres --detectors adr     # Table III
    PYTHONPATH=src python examples/campaign_sweep.py \\
        --tiny --kinds mixed --kinds none --n-failures 2 \\
        --severities linspace:1.5:3:4 --all-detectors   # mixed + sweep

``--kinds`` accepts the base kinds, ``mixed`` (per-failure kinds sampled
from the live core/link/router population) and ``core+link``-style
composites; ``--severities`` accepts plain slowdown factors and
``linspace:LO:HI:N`` sweep specs.

``--mesh`` / ``--topology`` entries share one fabric-spec grammar,
resolved through the topology registry (``core.routing``)::

    W | WxH            default mesh, e.g. --mesh 12x8
    name:WxH           registered fabric, e.g. --topology torus:8x8
    name:WxH:variant   fabric variant, e.g. --topology het:4x4:fast2slow1

Registered builtins: ``mesh`` (bidirectional 2D mesh, XY routing),
``torus`` (wrap links, shortest-direction DOR), ``systolic``
(unidirectional east/south dataflow with edge re-injection), ``het``
(mesh with a ``fast<A>slow<B>`` rate-class pattern).  Third-party
fabrics join via ``register_topology(name, cls)`` and are then valid in
the same specs.  Campaigns spanning more than one fabric print the
``per fabric`` accuracy/FPR table.  Campaigns with several severities
print the ``severity_curve()`` readout; mixed-kind campaigns print the
per-truth-kind recall split.

``--recorder-impl`` selects the SL-Recorder sketch path: ``ref`` (per-run
numpy oracle, the default), ``batched`` (on-device run-compressed JAX
scan with the drained-eviction stream) or ``both`` — which runs the
campaign once per impl and asserts scenario-for-scenario identical
verdicts and compression ratios (the recorder-parity smoke used in CI).
Compression ratios and pattern structure are integer-derived and always
bit-identical; verdict fields pass through thresholded float scores, and
the batched path keeps Stage-2 statistics in float32 vs the oracle's
float64 — so run ``both`` on decisively-failing grids (the CI smoke's
8× severity), not on near-threshold sweeps where a score within f32
rounding of a flag threshold could legitimately diverge.

``--streaming N`` runs SLOTH incrementally over each trace split into N
chunks (the always-on deployment mode): the campaign gains a
detection-latency column (first flagged chunk's stream time minus the
earliest failure onset), the summary prints the
``detection latency: ...`` aggregate, and — as a gate — a second,
post-hoc campaign is run and every streamed verdict is asserted
scenario-for-scenario identical to its one-shot counterpart (the
streaming-equivalence smoke used in CI).  Latency invariants are also
asserted: ``none``-kind scenarios carry no latency, flagged streamed
positives a finite one, unflagged streamed positives ``inf``.
Composes with ``--recorder-impl both`` (each impl gets its own
streamed-vs-post-hoc comparison).

``--mitigation NAME`` (repeatable) closes the detect → mitigate loop:
every detector × policy cell re-simulates the mitigated deployment and
the summary gains the recovered-throughput table.  The ``none`` control
is always included alongside the requested policies, and two gates run
(the mitigation smoke used in CI): the control's recovered fraction must
be exactly zero on every scenario, and on decisive core scenarios at
least one correct acted-on verdict must recover throughput.  With
``--streaming N`` the mitigation switches mid-stream at the first
flagged chunk instead of restarting from t=0:

    PYTHONPATH=src python examples/campaign_sweep.py \\
        --tiny --kinds core --kinds none --severities 10 \\
        --streaming 4 --mitigation remap
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.campaign import (CampaignGrid, _sev_str,  # noqa: E402
                                 run_campaign)
from repro.core.detectors import (DEFAULT_DETECTORS,  # noqa: E402
                                  available_detectors)
from repro.mitigate.policy import available_policies  # noqa: E402
from repro.core.recorder import RECORDER_IMPLS  # noqa: E402
from repro.core.sloth import SlothConfig  # noqa: E402


def make_grid(args) -> CampaignGrid:
    n_failures = tuple(args.n_failures) if args.n_failures else (1,)
    kinds = (tuple(args.kinds) if args.kinds
             else ("core", "link", "router", "none"))
    meshes = tuple(args.mesh or ()) + tuple(args.topology or ())
    if args.tiny:
        return CampaignGrid(workloads=("darknet19",),
                            meshes=meshes if meshes else (4,),
                            kinds=kinds,
                            severities=(tuple(args.severities)
                                        if args.severities else (8.0,)),
                            n_failures=n_failures,
                            reps=1, campaign_seed=args.seed)
    return CampaignGrid(
        workloads=("darknet19", "googlenet", "binary_tree"),
        meshes=meshes if meshes else (4, 6),
        kinds=kinds,
        severities=(tuple(args.severities) if args.severities
                    else (5.0, 10.0)),
        n_failures=n_failures,
        reps=2,
        campaign_seed=args.seed,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="minimal smoke grid (4 scenarios)")
    ap.add_argument("--seed", type=int, default=0, help="campaign seed")
    ap.add_argument("--workers", type=int, default=None,
                    help="pool width (default: cpu count)")
    ap.add_argument("--executor", choices=("thread", "process"),
                    default="thread",
                    help="scenario dispatch: GIL-bound thread pool or "
                         "true multi-core process pool (bit-identical "
                         "results either way)")
    ap.add_argument("--n-failures", type=int, action="append", default=None,
                    metavar="K", help="simultaneous-failure axis entry "
                    "(repeatable, e.g. --n-failures 1 --n-failures 2)")
    ap.add_argument("--mesh", action="append", default=None, metavar="WxH",
                    help="mesh axis entry, 'W' or 'WxH' "
                         "(repeatable, e.g. --mesh 12x12 --mesh 16x8)")
    ap.add_argument("--topology", action="append", default=None,
                    metavar="SPEC",
                    help="fabric axis entry, 'name:WxH[:variant]' with "
                         "name from the topology registry — mesh | torus "
                         "| systolic | het (repeatable, e.g. --topology "
                         "torus:8x8 --topology het:4x4:fast2slow1; "
                         "combines with --mesh entries on one axis)")
    ap.add_argument("--kinds", action="append", default=None, metavar="K",
                    help="failure-kind axis entry: core | link | router | "
                         "none | mixed | 'core+link'-style composite "
                         "(repeatable; default: all four base kinds)")
    ap.add_argument("--severities", action="append", default=None,
                    metavar="S", help="severity axis entry: a slowdown "
                    "factor or 'linspace:LO:HI:N' sweep spec (repeatable, "
                    "e.g. --severities 10 --severities linspace:1.5:3:4)")
    ap.add_argument("--detectors", action="append", default=None,
                    metavar="NAME", choices=available_detectors(),
                    help="detector to run on every scenario (repeatable; "
                         "default: sloth; see also --all-detectors)")
    ap.add_argument("--all-detectors", action="store_true",
                    help="shorthand for every registered detector "
                         "(SLOTH + the five baselines)")
    ap.add_argument("--streaming", type=int, default=0, metavar="N",
                    help="run SLOTH incrementally over N trace chunks per "
                         "scenario, report detection latency, and assert "
                         "streamed verdicts match a post-hoc campaign "
                         "(0 = post-hoc only, the default)")
    ap.add_argument("--mitigation", action="append", default=None,
                    metavar="NAME", choices=available_policies(),
                    help="mitigation policy to judge on every detector "
                         "verdict (repeatable; the 'none' control is "
                         "always added; default: no mitigation axis)")
    ap.add_argument("--recorder-impl", default="ref",
                    choices=RECORDER_IMPLS + ("both",),
                    help="SL-Recorder sketch path: per-run numpy oracle "
                         "(ref), on-device batched run-compressed scan "
                         "(batched), or 'both' to run the campaign twice "
                         "and assert identical verdicts + compression")
    args = ap.parse_args(argv)

    detectors = (DEFAULT_DETECTORS if args.all_detectors
                 else tuple(args.detectors) if args.detectors
                 else ("sloth",))
    # the 'none' control rides along whenever mitigation is requested, so
    # the recovered-throughput table always has its zero baseline
    pols = (tuple(dict.fromkeys(tuple(args.mitigation) + ("none",)))
            if args.mitigation else ())
    grid = make_grid(args)
    n = grid.n_scenarios()
    print(f"campaign: {len(grid.workloads)} workloads × "
          f"{len(grid.meshes)} meshes × {len(grid.kinds)} kinds × "
          f"{len(grid.severities)} severities × "
          f"{len(grid.n_failures)} n_failures × {grid.reps} reps "
          f"= {n} scenarios (seed {grid.campaign_seed}, "
          f"executor {args.executor}, detectors {', '.join(detectors)}, "
          f"recorder {args.recorder_impl}"
          + (f", streaming {args.streaming} chunks" if args.streaming
             else "")
          + (f", mitigation {', '.join(pols)}" if pols else "") + ")")

    done = []

    def progress(o):
        done.append(o)
        if len(done) % 10 == 0 or len(done) == n:
            print(f"  ... {len(done)}/{n} scenarios", flush=True)

    cfg = (None if args.recorder_impl in ("ref", "both")
           else SlothConfig(recorder_impl=args.recorder_impl))
    t0 = time.perf_counter()
    res = run_campaign(grid, workers=args.workers, executor=args.executor,
                       detectors=detectors, cfg=cfg, progress=progress,
                       streaming=args.streaming, mitigation=pols)
    wall = time.perf_counter() - t0

    # explicit raises, not asserts, throughout the gates below: these are
    # the CI parity smokes and must still fail under python -O
    def judged(d):
        return (d.detector, d.flagged, d.pred_kind, d.pred_location,
                d.matched, d.truth_rank, d.truth_ranks)

    def check_streaming(streamed, label, campaign_cfg):
        """Streamed verdicts must equal a post-hoc campaign's, and
        detection latencies must obey the streaming semantics."""
        posthoc = run_campaign(grid, workers=args.workers,
                               executor=args.executor, detectors=detectors,
                               cfg=campaign_cfg)
        for s, p in zip(streamed.outcomes, posthoc.outcomes):
            for ds, dp in zip(s.detector_results, p.detector_results):
                if judged(ds) != judged(dp):
                    raise SystemExit(
                        f"streaming equivalence FAILED ({label}): "
                        f"scenario {s.scenario_id} "
                        f"streamed={judged(ds)} post-hoc={judged(dp)}")
                lat = ds.detection_latency
                if s.kind == "none":
                    if lat is not None:
                        raise SystemExit(
                            f"latency invariant FAILED ({label}): "
                            f"scenario {s.scenario_id} is failure-free "
                            f"but has latency {lat}")
                elif ds.detector == "sloth":
                    if lat is None:
                        raise SystemExit(
                            f"latency invariant FAILED ({label}): "
                            f"streamed positive scenario "
                            f"{s.scenario_id} has no latency")
                    if ds.flagged != (lat != float("inf")):
                        raise SystemExit(
                            f"latency invariant FAILED ({label}): "
                            f"scenario {s.scenario_id} flagged="
                            f"{ds.flagged} but latency {lat}")
        print(f"streaming equivalence ({label}): chunked == post-hoc on "
              f"all {len(streamed.outcomes)} scenarios")

    if args.streaming:
        check_streaming(res, args.recorder_impl
                        if args.recorder_impl != "both" else "ref", cfg)

    if pols:
        # mitigation smoke: the control recovers exactly nothing, and on
        # decisive core scenarios a correct acted-on verdict recovers
        # throughput
        for o in res.outcomes:
            for mo in o.mitigation_results:
                if mo.policy == "none" and mo.recovered_frac != 0.0:
                    raise SystemExit(
                        f"mitigation control FAILED: scenario "
                        f"{o.scenario_id} policy 'none' recovered "
                        f"{mo.recovered_frac} (must be exactly 0.0)")
        decisive = [mo for o in res.outcomes if o.kind == "core"
                    for mo in o.mitigation_results
                    if mo.policy != "none" and mo.correct and mo.acted]
        if decisive:
            recovered = [mo for mo in decisive if mo.recovered_frac > 0.0]
            if not recovered:
                raise SystemExit(
                    "mitigation smoke FAILED: no correct acted-on core "
                    "verdict recovered throughput under "
                    f"{', '.join(p for p in pols if p != 'none')}")
            print(f"mitigation smoke: control exactly 0.0 on all "
                  f"{len(res.outcomes)} scenarios; {len(recovered)}/"
                  f"{len(decisive)} acted core mitigations recovered "
                  f"throughput")

    if args.recorder_impl == "both":
        cfg_b = SlothConfig(recorder_impl="batched")
        res_b = run_campaign(grid, workers=args.workers,
                             executor=args.executor, detectors=detectors,
                             cfg=cfg_b, streaming=args.streaming)
        if args.streaming:
            check_streaming(res_b, "batched", cfg_b)
        for a, b in zip(res.outcomes, res_b.outcomes):
            if a.compression_ratio != b.compression_ratio:
                raise SystemExit(
                    f"recorder parity FAILED: scenario {a.scenario_id} "
                    f"compression ref={a.compression_ratio} "
                    f"batched={b.compression_ratio}")
            for da, db in zip(a.detector_results, b.detector_results):
                ka = judged(da) + (da.detection_latency,)
                kb = judged(db) + (db.detection_latency,)
                if ka != kb:
                    raise SystemExit(
                        f"recorder parity FAILED: scenario "
                        f"{a.scenario_id} ref={ka} batched={kb}")
        print(f"\nrecorder parity: ref == batched on all "
              f"{len(res.outcomes)} scenarios (verdicts, ranks, "
              f"compression ratios"
              + (", detection latencies" if args.streaming else "") + ")")

    print(f"\n== per-cell (workload, fabric, kind, severity, "
          f"n_failures) ==")
    for (wl, w, h, kind, sev, nf, topo), m in res.cells.items():
        if kind == "none":
            stat = f"FPR {m.fpr.pct():6.2f}% ({m.fpr.successes}/{m.fpr.trials})"
        else:
            stat = (f"acc {m.accuracy.pct():6.2f}% "
                    f"({m.accuracy.successes}/{m.accuracy.trials}) "
                    f"top3 {m.topk_rate(3)*100:6.2f}% "
                    f"recall@3 {m.recall_at(3)*100:6.2f}%")
        print(f"  {wl:12s} {topo}:{w}x{h} {kind:9s} x{_sev_str(sev):<8s} "
              f"k={nf} {stat}")

    if len(detectors) > 1:
        print(f"\n== per-detector (accuracy / FPR / top-3 / recall@3) ==")
        for name, m in res.detector_metrics.items():
            print(f"  {name:8s} acc {m.accuracy.pct():6.2f}% "
                  f"({m.accuracy.successes}/{m.accuracy.trials})  "
                  f"FPR {m.fpr.pct():6.2f}% "
                  f"({m.fpr.successes}/{m.fpr.trials})  "
                  f"top3 {m.topk_rate(3)*100:6.2f}%  "
                  f"recall@3 {m.recall_at(3)*100:6.2f}%")

    print(f"\n== campaign aggregate ==")
    print(res.summary())
    print(f"\nwall time: {wall:.1f}s "
          f"({wall / max(n, 1):.2f}s/scenario)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
