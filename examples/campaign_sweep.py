"""Scenario-campaign sweep: reproduce the paper's aggregate metrics.

Runs a grid of fail-slow scenarios (workload × mesh × failure kind ×
severity × replicate) through the SLOTH pipeline and prints per-cell and
campaign-level accuracy / FPR / top-k localisation / compression / probe
overhead, with Wilson confidence intervals.

    PYTHONPATH=src python examples/campaign_sweep.py            # full grid
    PYTHONPATH=src python examples/campaign_sweep.py --tiny     # CI smoke
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.campaign import CampaignGrid, run_campaign  # noqa: E402


def make_grid(args) -> CampaignGrid:
    if args.tiny:
        return CampaignGrid(workloads=("darknet19",), meshes=(4,),
                            kinds=("core", "link", "router", "none"),
                            severities=(8.0,), reps=1,
                            campaign_seed=args.seed)
    return CampaignGrid(
        workloads=("darknet19", "googlenet", "binary_tree"),
        meshes=(4, 6),
        kinds=("core", "link", "router", "none"),
        severities=(5.0, 10.0),
        reps=2,
        campaign_seed=args.seed,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="minimal smoke grid (4 scenarios)")
    ap.add_argument("--seed", type=int, default=0, help="campaign seed")
    ap.add_argument("--workers", type=int, default=None,
                    help="thread-pool width (default: cpu count)")
    args = ap.parse_args(argv)

    grid = make_grid(args)
    n = grid.n_scenarios()
    print(f"campaign: {len(grid.workloads)} workloads × "
          f"{len(grid.meshes)} meshes × {len(grid.kinds)} kinds × "
          f"{len(grid.severities)} severities × {grid.reps} reps "
          f"= {n} scenarios (seed {grid.campaign_seed})")

    done = []

    def progress(o):
        done.append(o)
        if len(done) % 10 == 0 or len(done) == n:
            print(f"  ... {len(done)}/{n} scenarios", flush=True)

    t0 = time.perf_counter()
    res = run_campaign(grid, workers=args.workers, progress=progress)
    wall = time.perf_counter() - t0

    print(f"\n== per-cell (workload, mesh, kind, severity) ==")
    for (wl, w, h, kind, sev), m in res.cells.items():
        if kind == "none":
            stat = f"FPR {m.fpr.pct():6.2f}% ({m.fpr.successes}/{m.fpr.trials})"
        else:
            stat = (f"acc {m.accuracy.pct():6.2f}% "
                    f"({m.accuracy.successes}/{m.accuracy.trials}) "
                    f"top3 {m.topk_rate(3)*100:6.2f}%")
        print(f"  {wl:12s} {w}x{h} {kind:6s} x{sev:<5.1f} {stat}")

    print(f"\n== campaign aggregate ==")
    print(res.summary())
    print(f"\nwall time: {wall:.1f}s "
          f"({wall / max(n, 1):.2f}s/scenario)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
