"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from proptest import given, settings, st

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


# ---------------------------------------------------------------------------
# sketch_update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,m,H,L,n", [(1, 32, 2, 8, 300), (2, 64, 4, 16,
                                       700), (4, 128, 8, 32, 500)])
def test_sketch_kernel_matches_ref(d, m, H, L, n):
    from repro.core.sketch import SketchParams, split_key
    from repro.kernels.sketch_update import ops as O, ref as R
    p = SketchParams(d=d, m=m, H=H, L=L)
    rng = np.random.default_rng(d * 100 + m)
    keys = rng.integers(0, 80, size=n).astype(np.int64) * 0x9E3779B9
    lo, hi = split_key(keys)
    dur = rng.random(n).astype(np.float32)
    val = (rng.random(n) * 5).astype(np.float32)
    t = np.cumsum(rng.random(n)).astype(np.float32)
    args = tuple(jnp.asarray(x) for x in (lo, hi, dur, val, t))
    st_r = R.insert_batch(R.make_state(p), *args, H=p.H)
    st_p = O.insert(O.make_state(p), *args, params=p, impl="pallas",
                    block=128)
    for k in st_r:
        a, b = np.asarray(st_r[k]), np.asarray(st_p[k])
        if a.dtype.kind == "i":
            assert np.array_equal(a, b), k
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5,
                                       err_msg=k)


def test_sketch_kernel_matches_numpy_oracle():
    from repro.core.sketch import FailSlowSketch, SketchParams, split_key
    from repro.kernels.sketch_update import ops as O
    p = SketchParams(d=2, m=64, H=4, L=16)
    rng = np.random.default_rng(3)
    n = 400
    keys = rng.integers(0, 50, size=n).astype(np.int64) * 31337
    lo, hi = split_key(keys)
    dur = rng.random(n).astype(np.float32)
    oracle = FailSlowSketch(p)
    oracle.insert_stream(keys, dur, dur * 2, np.arange(n, dtype=float))
    st = O.insert(O.make_state(p), jnp.asarray(lo), jnp.asarray(hi),
                  jnp.asarray(dur), jnp.asarray(dur * 2),
                  jnp.asarray(np.arange(n, dtype=np.float32)),
                  params=p, impl="pallas")
    pats = {q.key & 0x7FFFFFFF: q for q in O.patterns(st)}
    exp = {int(k) & 0x7FFFFFFF: v for k, v in oracle.stage2.items()}
    assert set(pats) == set(exp)
    for k, q in pats.items():
        assert q.count == exp[k].count


@pytest.mark.parametrize("d,m,H,L,n", [
    (1, 32, 2, 8, 300),      # small tables, frequent Stage-2 FIFO evictions
    (2, 64, 4, 16, 700),
    (4, 16, 1, 4, 400),      # H=1: every record promotes; L=4: evict-heavy
    (3, 8, 2, 8, 500),       # heavy Stage-1 bucket collisions
])
def test_sketch_batched_matches_scan_ref(d, m, H, L, n):
    """The vectorized multi-record path is bit-identical to the
    sequential lax.scan reference on integer state."""
    from repro.core.sketch import SketchParams, split_key
    from repro.kernels.sketch_update import ops as O, ref as R
    p = SketchParams(d=d, m=m, H=H, L=L)
    rng = np.random.default_rng(7 * d + m)
    keys = rng.integers(0, 60, size=n).astype(np.int64) * 0x9E3779B9
    lo, hi = split_key(keys)
    dur = rng.random(n).astype(np.float32)
    val = (rng.random(n) * 5).astype(np.float32)
    t = np.cumsum(rng.random(n)).astype(np.float32)
    args = tuple(jnp.asarray(x) for x in (lo, hi, dur, val, t))
    st_r = R.insert_batch(R.make_state(p), *args, H=p.H)
    st_b = O.insert(O.make_state(p), *args, params=p, impl="batched")
    for k in st_r:
        a, b = np.asarray(st_r[k]), np.asarray(st_b[k])
        if a.dtype.kind == "i":
            assert np.array_equal(a, b), k
        else:
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6,
                                       err_msg=k)


@pytest.mark.parametrize("seed,d,m,H,L", [
    (0, 2, 64, 4, 16), (1, 1, 16, 2, 4), (2, 3, 32, 8, 8)])
def test_sketch_batched_matches_numpy_oracle(seed, d, m, H, L):
    """Algorithm-1 ground truth: random record streams through the numpy
    oracle vs the vectorized batch path — identical Stage-1 tables and
    identical live Stage-2 pattern sets (incl. FIFO-eviction victims)."""
    from repro.core.sketch import FailSlowSketch, SketchParams, split_key
    from repro.kernels.sketch_update import ops as O
    p = SketchParams(d=d, m=m, H=H, L=L)
    rng = np.random.default_rng(seed)
    n = 600
    keys = rng.integers(0, 40, size=n).astype(np.int64) * 31337
    lo, hi = split_key(keys)
    dur = rng.random(n).astype(np.float32)
    ts = np.arange(n, dtype=np.float32)
    oracle = FailSlowSketch(p)
    oracle.insert_stream(keys, dur, dur * 2, ts.astype(float))
    st = O.insert(O.make_state(p), jnp.asarray(lo), jnp.asarray(hi),
                  jnp.asarray(dur), jnp.asarray(dur * 2), jnp.asarray(ts),
                  params=p, impl="batched")
    # Stage-1 tables bit-identical
    assert np.array_equal(np.asarray(st["freq"]), oracle.freq)
    assert np.array_equal(np.asarray(st["valid"]),
                          oracle.valid.astype(np.int32))
    assert np.array_equal(np.asarray(st["keys_lo"]) * np.asarray(st["valid"]),
                          oracle.keys_lo * oracle.valid)
    # live Stage-2 patterns identical (key set, counts, arrival order)
    pats = {q.key: q for q in O.patterns(st)}
    assert set(pats) == set(int(k) for k in oracle.stage2)
    for k, q in pats.items():
        exp = oracle.stage2[k]
        assert q.count == exp.count
        assert q.arrival == exp.arrival
        assert q.sum_dur == pytest.approx(exp.sum_dur, rel=1e-5)
        assert q.min_dur == pytest.approx(exp.min_dur, rel=1e-6)
    if L <= 8:
        assert oracle.n_evicted > 0      # the stream exercised eviction


def test_sketch_batched_promotion_and_evict_edges():
    """Deterministic Stage-1/Stage-2 edge cases: promotion exactly at H,
    decrement-clear-claim of a contested bucket, FIFO eviction order."""
    from repro.core.sketch import FailSlowSketch, SketchParams, split_key
    from repro.kernels.sketch_update import ops as O
    p = SketchParams(d=1, m=1, H=3, L=2)     # one bucket: force the races
    # key 7 ×3 (promotes at freq 3), key 9 ×6 (3 decrements clear the
    # bucket, 3 claims re-promote), key 5 ×6 (same dance) → the third
    # Stage-2 pattern FIFO-evicts the oldest (key 7)
    keys = np.array([7] * 3 + [9] * 6 + [5] * 6, dtype=np.int64)
    lo, hi = split_key(keys)
    n = len(keys)
    dur = np.full(n, 0.5, np.float32)
    ts = np.arange(n, dtype=np.float32)
    oracle = FailSlowSketch(p)
    oracle.insert_stream(keys, dur, dur, ts.astype(float))
    st = O.insert(O.make_state(p), jnp.asarray(lo), jnp.asarray(hi),
                  jnp.asarray(dur), jnp.asarray(dur), jnp.asarray(ts),
                  params=p, impl="batched")
    assert np.array_equal(np.asarray(st["freq"]), oracle.freq)
    live = {q.key: q.count for q in O.patterns(st)}
    assert live == {int(k): v.count for k, v in oracle.stage2.items()}
    assert oracle.n_evicted == 1 and 7 not in live   # FIFO victim


def _assert_pattern_parity(got, exp, *, check_stats=True):
    """Merged patterns() parity: exact keys/counts/arrivals, f32-tolerance
    statistics."""
    got = {p.key: p for p in got}
    exp = {p.key: p for p in exp}
    assert set(got) == set(exp)
    for k, q in got.items():
        e = exp[k]
        assert q.count == e.count and q.arrival == e.arrival, k
        if check_stats:
            assert q.sum_dur == pytest.approx(e.sum_dur, rel=1e-4)
            assert q.sum_val == pytest.approx(e.sum_val, rel=1e-4)
            assert q.min_dur == pytest.approx(e.min_dur, rel=1e-5)
            assert q.t_first == pytest.approx(e.t_first, rel=1e-4)
            assert q.t_last == pytest.approx(e.t_last, rel=1e-4)


@pytest.mark.parametrize("impl", ["batched", "pallas"])
def test_sketch_drain_matches_oracle_under_eviction(impl):
    """Forced Stage-2 eviction pressure (small L, many distinct promoted
    keys): the drained-eviction stream preserves every FIFO victim, so
    merged patterns() — live + drained — equals the numpy oracle's.
    Without the drain the packed paths silently lose evicted patterns."""
    from repro.core.sketch import FailSlowSketch, SketchParams, split_key
    from repro.kernels.sketch_update import ops as O
    p = SketchParams(d=2, m=64, H=2, L=4)   # L=4 ≪ distinct promoted keys
    rng = np.random.default_rng(11)
    n = 500
    keys = rng.integers(0, 40, size=n).astype(np.int64) * 31337
    lo, hi = split_key(keys)
    dur = rng.random(n).astype(np.float32)
    ts = np.arange(n, dtype=np.float32)
    oracle = FailSlowSketch(p)
    oracle.insert_stream(keys, dur, dur * 2, ts.astype(float))
    assert oracle.n_evicted > p.L            # pressure actually applied
    st, dr = O.insert(O.make_state(p), jnp.asarray(lo), jnp.asarray(hi),
                      jnp.asarray(dur), jnp.asarray(dur * 2),
                      jnp.asarray(ts), params=p, impl=impl,
                      drain=O.make_drain(n))
    assert int(np.asarray(dr["d_n"])) == oracle.n_evicted
    _assert_pattern_parity(O.patterns(st, dr),
                           oracle.patterns(include_drained=True))
    # drain-less call still returns the live-only view, unchanged state
    st2 = O.insert(O.make_state(p), jnp.asarray(lo), jnp.asarray(hi),
                   jnp.asarray(dur), jnp.asarray(dur * 2),
                   jnp.asarray(ts), params=p, impl=impl)
    for k in st2:
        assert np.array_equal(np.asarray(st[k]), np.asarray(st2[k])), k


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31), st.integers(1, 3),
       st.sampled_from([8, 16, 64]), st.integers(1, 8),
       st.sampled_from([2, 4, 16]))
def test_sketch_run_path_matches_insert_run_oracle(seed, d, m, H, L):
    """Run-compressed batched insertion ≡ FailSlowSketch.insert_run over
    randomized runs: bit-identical Stage-1 tables, identical eviction
    structure, merged patterns (live + drained) equal."""
    from repro.core.sketch import FailSlowSketch, SketchParams, split_key
    from repro.kernels.sketch_update import ops as O
    p = SketchParams(d=d, m=m, H=H, L=L)
    rng = np.random.default_rng(seed)
    n = 150
    keys = rng.integers(0, 20, size=n).astype(np.int64) * 0x9E3779B9
    reps = rng.integers(1, 12, size=n)      # spans r<H, r≈H and r≫H
    durs = rng.random(n)
    vals = rng.random(n) * 3
    t0s = np.cumsum(rng.random(n))
    dts = rng.random(n) * 0.01
    oracle = FailSlowSketch(p)
    oracle.insert_runs(keys, reps, durs, vals, t0s, dts)
    lo, hi = split_key(keys)
    st, dr = O.insert_runs(
        O.make_state(p), O.make_drain(n), jnp.asarray(lo), jnp.asarray(hi),
        jnp.asarray(reps.astype(np.int32)),
        jnp.asarray(durs.astype(np.float32)),
        jnp.asarray(vals.astype(np.float32)),
        jnp.asarray(t0s.astype(np.float32)),
        jnp.asarray(dts.astype(np.float32)), params=p)
    assert np.array_equal(np.asarray(st["freq"]), oracle.freq)
    assert np.array_equal(np.asarray(st["valid"]),
                          oracle.valid.astype(np.int32))
    assert int(np.asarray(dr["d_n"])) == oracle.n_evicted
    _assert_pattern_parity(O.patterns(st, dr),
                           oracle.patterns(include_drained=True))


def test_sketch_run_path_promotion_and_steal_branches():
    """Deterministic run-path edge cases against per-record expansion:

    * mid-run promotion boundary — a key with prior Stage-1 freq f0
      promotes exactly at record k = H − f0 − 1 of the run, so the
      Stage-2 count must be r − (H − f0 − 1);
    * bucket steal — a contested bucket with freq f0 < r is cleared by f0
      decrements, record f0 claims it, and promotion happens at record
      k = f0 + H − 1;
    * pure decrement — r ≤ f0 never promotes and may clear the bucket.
    """
    from repro.core.sketch import FailSlowSketch, SketchParams, split_key
    from repro.kernels.sketch_update import ops as O
    p = SketchParams(d=1, m=1, H=4, L=4)     # one bucket: force the races
    #       key  r    scenario
    runs = [(7,  2),  # f0: 0→2 (claims empty bucket, below H)
            (7,  5),  # mid-run boundary: f0=2, promotes at k=H-f0-1=1 → n=4
            (9,  3),  # decrement only: r=3 ≤ f0=7 → freq 4, key 7 keeps it
            (9,  9),  # steal: f0=4 cleared, record 4 claims, k=4+H-1=7 → n=2
            (5,  3)]  # decrement only again (f0=5 after steal ... )
    keys = np.array([k for k, _ in runs], dtype=np.int64)
    reps = np.array([r for _, r in runs], dtype=np.int64)
    n = len(runs)
    durs = np.full(n, 0.25)
    t0s = np.arange(n, dtype=np.float64) * 10
    dts = np.full(n, 0.5)
    oracle = FailSlowSketch(p)
    oracle.insert_runs(keys, reps, durs, durs * 2, t0s, dts)
    # pin the branch arithmetic itself, not only oracle parity
    assert oracle.stage2[7].count == 4       # r=5 − first_promo(k=1)
    assert oracle.stage2[9].count == 2       # r=9 − first_promo(k=7)
    assert 5 not in oracle.stage2
    lo, hi = split_key(keys)
    st, dr = O.insert_runs(
        O.make_state(p), O.make_drain(n), jnp.asarray(lo), jnp.asarray(hi),
        jnp.asarray(reps.astype(np.int32)),
        jnp.asarray(durs.astype(np.float32)),
        jnp.asarray((durs * 2).astype(np.float32)),
        jnp.asarray(t0s.astype(np.float32)),
        jnp.asarray(dts.astype(np.float32)), params=p)
    assert np.array_equal(np.asarray(st["freq"]), oracle.freq)
    _assert_pattern_parity(O.patterns(st, dr),
                           oracle.patterns(include_drained=True))


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,t,hq,hk,d,causal,win,dtype", [
    (2, 128, 128, 4, 2, 64, True, None, jnp.float32),
    (1, 256, 256, 2, 2, 32, True, 64, jnp.float32),
    (2, 100, 200, 4, 1, 16, False, None, jnp.float32),
    (1, 1, 384, 8, 4, 64, True, None, jnp.float32),
    (1, 128, 128, 2, 2, 64, True, None, jnp.bfloat16),
])
def test_flash_attention_sweep(b, s, t, hq, hk, d, causal, win, dtype):
    from repro.kernels.flash_attention.ops import gqa_attention
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, t, hk, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, hk, d)).astype(dtype)
    a = gqa_attention(q, k, v, causal=causal, window=win, impl="pallas",
                      q_block=64, kv_block=64)
    r = gqa_attention(q, k, v, causal=causal, window=win, impl="ref")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(r, np.float32), atol=tol,
                               rtol=tol)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (2, 96, 4, 32, 2, 16, 32),
    (1, 200, 2, 16, 1, 8, 64),
    (2, 64, 8, 8, 4, 8, 16),
])
def test_ssd_kernel_sweep(b, s, h, p, g, n, chunk):
    from repro.kernels.ssd_scan.ops import ssd
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.3
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, s, g, n)) * 0.4
    cc = jax.random.normal(ks[4], (b, s, g, n)) * 0.4
    yp, sp = ssd(x, dt, a, bb, cc, impl="pallas", chunk=chunk)
    yr, sr = ssd(x, dt, a, bb, cc, impl="ref")
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr), atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr), atol=2e-4,
                               rtol=2e-4)


def test_model_ssd_matches_recurrence():
    from repro.kernels.ssd_scan.ops import ssd
    from repro.models.mamba2 import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    b, s, h, p, g, n = 2, 80, 4, 16, 2, 8
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.3
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, s, g, n)) * 0.4
    cc = jax.random.normal(ks[4], (b, s, g, n)) * 0.4
    ym, sm = ssd_chunked(x, dt, a, bb, cc, chunk=32)
    yr, sr = ssd(x, dt, a, bb, cc, impl="ref")
    np.testing.assert_allclose(np.asarray(ym), np.asarray(yr), atol=2e-4,
                               rtol=2e-4)


# ---------------------------------------------------------------------------
# failrank_step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [40, 130, 260])
def test_failrank_step_sweep(n):
    from repro.kernels.failrank_step.kernel import failrank_step
    from repro.kernels.failrank_step.ref import failrank_step_ref
    rng = np.random.default_rng(n)
    w = rng.random((n, n)).astype(np.float32)
    w /= w.sum(1, keepdims=True)
    l = rng.random((n, n)).astype(np.float32)
    s = rng.random(n).astype(np.float32)
    s0 = rng.random(n).astype(np.float32)
    sp, lp = failrank_step(jnp.asarray(w), jnp.asarray(l), jnp.asarray(s),
                           jnp.asarray(s0))
    sr, lr = failrank_step_ref(jnp.asarray(w), jnp.asarray(l),
                               jnp.asarray(s), jnp.asarray(s0))
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lr), atol=1e-5)


def test_failrank_dense_matches_coo_pipeline():
    from repro.core.failrank import failrank
    from repro.core.failures import FailSlow
    from repro.core.graph import build_workload
    from repro.core.routing import Mesh2D
    from repro.core.sloth import Sloth
    from repro.kernels.failrank_step.ops import failrank_dense
    sloth = Sloth(build_workload("darknet19"), Mesh2D(4))
    v = sloth.detect([FailSlow("core", 5, 1.0, 8.0)], seed=0)
    r_coo = failrank(v.mcg)
    _, s_raw, _, _ = failrank_dense(v.mcg, impl="pallas")
    np.testing.assert_allclose(s_raw, r_coo.raw_node_scores, atol=1e-4)
