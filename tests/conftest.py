"""Make ``python -m pytest`` work from the repo root without the
``PYTHONPATH=src`` incantation (which keeps working too — a duplicate
entry is harmless)."""

import os
import sys

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
