"""Recorder pipeline: pattern-key space disjointness, ref vs batched
recorder parity (patterns, drained streams, compression accounting) and
the campaign-level impl plumbing."""

import numpy as np
import pytest

from repro.core import probes as P
from repro.core.failures import FailSlow
from repro.core.graph import build_workload
from repro.core.recorder import record
from repro.core.routing import Mesh2D
from repro.core.sloth import Sloth, SlothConfig

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


# ---------------------------------------------------------------------------
# pattern-key spaces
# ---------------------------------------------------------------------------

def test_comp_comm_key_tags_distinct():
    """Regression: the comm tag was written ``2 << 61`` — which *is*
    ``1 << 62``, the comp tag — so the type-disambiguation bit was
    identical for both key spaces."""
    assert P.COMP_KEY_TAG != P.COMM_KEY_TAG
    assert P.COMP_KEY_TAG & P.COMM_KEY_TAG == 0
    # both tags must sit in int64 sign-free territory
    assert 0 < P.COMM_KEY_TAG < P.COMP_KEY_TAG < 2**63


def test_comp_comm_key_spaces_cannot_collide():
    """The historical aliasing example: comp(core=5, stage=1, op=0,
    fb=0) and comm(src=5, dst=1, stage=0, vb=0) packed to the same 64
    bits under the colliding tags.  With distinct tag bits, no comp key
    can equal any comm key."""
    comp = {"core": np.array([5]), "stage": np.array([1]),
            "op": np.array([0]), "flops": np.array([1.0])}
    comm = {"src": np.array([5]), "dst": np.array([1]),
            "stage": np.array([0]), "bytes": np.array([1.0])}
    ck = int(P.comp_pattern_keys(comp)[0])
    mk = int(P.comm_pattern_keys(comm)[0])
    # the payload bits still alias (that is what made the bug silent) …
    assert ck & ~(P.COMP_KEY_TAG | P.COMM_KEY_TAG) \
        == mk & ~(P.COMP_KEY_TAG | P.COMM_KEY_TAG)
    # … so only the tag bits keep the spaces apart
    assert ck != mk

    rng = np.random.default_rng(0)
    n = 500
    comp = {"core": rng.integers(0, 256, n), "stage": rng.integers(0, 64, n),
            "op": rng.integers(0, 8, n),
            "flops": rng.uniform(1, 2**50, n)}
    comm = {"src": rng.integers(0, 256, n), "dst": rng.integers(0, 256, n),
            "stage": rng.integers(0, 64, n),
            "bytes": rng.uniform(1, 2**50, n)}
    assert not set(P.comp_pattern_keys(comp).tolist()) \
        & set(P.comm_pattern_keys(comm).tolist())


def test_decoders_unaffected_by_tag_fix():
    comp = {"core": np.array([7]), "stage": np.array([3]),
            "op": np.array([2]), "flops": np.array([1e6])}
    d = P.decode_comp_key(int(P.comp_pattern_keys(comp)[0]))
    assert (d["core"], d["stage"], d["op"]) == (7, 3, 2)
    comm = {"src": np.array([4]), "dst": np.array([9]),
            "stage": np.array([5]), "bytes": np.array([4096.0])}
    d = P.decode_comm_key(int(P.comm_pattern_keys(comm)[0]))
    assert (d["src"], d["dst"], d["stage"]) == (4, 9, 5)


# ---------------------------------------------------------------------------
# ref vs batched recorder parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def deployment():
    sloth = Sloth(build_workload("darknet19"), Mesh2D(4))
    sim = sloth.run([FailSlow("core", 5, 1.0, 8.0, 10.0)], seed=0)
    return sloth, sim


def _assert_recorder_parity(a, b):
    for side in ("comp", "comm"):
        pa = {p.key: p for p in getattr(a, side + "_patterns")}
        pb = {p.key: p for p in getattr(b, side + "_patterns")}
        assert set(pa) == set(pb), side
        for k in pa:
            assert pa[k].count == pb[k].count, (side, k)
            assert pa[k].arrival == pb[k].arrival, (side, k)
            assert pa[k].sum_dur == pytest.approx(pb[k].sum_dur, rel=1e-4)
            assert pa[k].min_dur == pytest.approx(pb[k].min_dur, rel=1e-5)
    assert a.sketch_comp_bytes == b.sketch_comp_bytes
    assert a.sketch_comm_bytes == b.sketch_comm_bytes
    assert (a.n_comp_drained, a.n_comm_drained) \
        == (b.n_comp_drained, b.n_comm_drained)
    assert (a.n_comp_records, a.n_comm_records) \
        == (b.n_comp_records, b.n_comm_records)
    assert a.compression_ratio == b.compression_ratio


def test_record_impl_parity(deployment):
    """record(impl='batched') reproduces the numpy-oracle patterns (keys,
    counts, arrival order; stats to f32 tolerance) and byte-identical
    compression accounting on a real instrumented trace."""
    sloth, sim = deployment
    hop = sloth.sim_cfg.hop_latency
    a = record(sim, sloth.cfg.sketch, hop_latency=hop, impl="ref")
    b = record(sim, sloth.cfg.sketch, hop_latency=hop, impl="batched")
    assert a.impl == "ref" and b.impl == "batched"
    _assert_recorder_parity(a, b)
    # comp keys carry the tag bit the sketch's 31-bit halves truncate —
    # the batched path must restore it, or the key spaces re-collide
    assert all(p.key & P.COMP_KEY_TAG for p in b.comp_patterns)
    assert all(p.key & P.COMM_KEY_TAG for p in b.comm_patterns)


def test_record_impl_parity_under_eviction(deployment):
    """Same parity with a tiny Stage-2 (L=8 ≪ distinct patterns): both
    paths must drain the same FIFO victims and account their bytes in
    the compressed stream identically."""
    from repro.core.sketch import SketchParams
    sloth, sim = deployment
    hop = sloth.sim_cfg.hop_latency
    p = SketchParams(d=2, m=256, H=4, L=8)
    a = record(sim, p, hop_latency=hop, impl="ref")
    b = record(sim, p, hop_latency=hop, impl="batched")
    assert a.n_comp_drained > 0 and a.n_comm_drained > 0
    _assert_recorder_parity(a, b)


def test_record_unknown_impl_rejected(deployment):
    sloth, sim = deployment
    with pytest.raises(ValueError, match="unknown recorder impl"):
        record(sim, sloth.cfg.sketch, impl="vectorised")


def test_sloth_verdict_identical_across_recorder_impls(deployment):
    """End-to-end: analysing one trace with recorder_impl='batched'
    yields the same flag / kind / location / ranking order as the
    default oracle recorder."""
    sloth, sim = deployment
    va = sloth.analyse(sim)
    sloth_b = Sloth(sloth.graph, sloth.mesh,
                    cfg=SlothConfig(recorder_impl="batched"))
    vb = sloth_b.analyse(sim)
    assert (va.flagged, va.kind, va.location) \
        == (vb.flagged, vb.kind, vb.location)
    assert [(k, l) for k, l, _ in va.ranking] \
        == [(k, l) for k, l, _ in vb.ranking]
    assert va.recorder.compression_ratio == vb.recorder.compression_ratio


def test_campaign_recorder_impl_plumbing():
    """run_campaign(cfg=SlothConfig(recorder_impl='batched')) produces
    outcomes matching the default path verdict-for-verdict (scores are
    float-tolerance, so equality is on the judged fields), with
    bit-identical compression ratios."""
    from repro.core.campaign import CampaignGrid, DeploymentCache, \
        run_campaign
    grid = CampaignGrid(workloads=("darknet19",), meshes=(4,),
                        kinds=("core", "none"), severities=(10.0,),
                        reps=1, campaign_seed=7)
    res_a = run_campaign(grid, workers=0, cache=DeploymentCache())
    res_b = run_campaign(grid, workers=0, cache=DeploymentCache(),
                         cfg=SlothConfig(recorder_impl="batched"))
    assert len(res_a.outcomes) == len(res_b.outcomes) == 2
    for a, b in zip(res_a.outcomes, res_b.outcomes):
        assert a.compression_ratio == b.compression_ratio
        for da, db in zip(a.detector_results, b.detector_results):
            assert (da.flagged, da.pred_kind, da.pred_location,
                    da.matched, da.truth_rank) \
                == (db.flagged, db.pred_kind, db.pred_location,
                    db.matched, db.truth_rank)
