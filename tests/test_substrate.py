"""Data pipeline, checkpointing, optimizer and serving runtime."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from proptest import given, settings, st

from repro.checkpoint import store
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.optim import adamw

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000), st.integers(0, 50))
def test_pipeline_deterministic(seed, step):
    cfg = DataConfig(vocab=1000, batch=8, seq=16, seed=seed)
    a = TokenPipeline(cfg).batch_at(step)
    b = TokenPipeline(cfg).batch_at(step)
    assert np.array_equal(a, b)
    assert a.min() >= 0 and a.max() < 1000


def test_pipeline_shards_partition():
    cfg = DataConfig(vocab=100, batch=8, seq=4, seed=1)
    full = TokenPipeline(cfg).batch_at(3)
    parts = [TokenPipeline(cfg, shard=i, n_shards=4).shard_at(3)
             for i in range(4)]
    assert np.array_equal(np.concatenate(parts), full)


def test_pipeline_resume():
    cfg = DataConfig(vocab=100, batch=4, seq=8, seed=0)
    p = TokenPipeline(cfg)
    for _ in range(5):
        next(p)
    state = p.state()
    expected = next(TokenPipeline.restore(cfg, state))
    q = TokenPipeline(cfg)
    for _ in range(5):
        next(q)
    assert np.array_equal(next(q), expected)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32)}}
    store.save(str(tmp_path), 7, tree, extra={"data": {"step": 7,
                                                       "seed": 0}})
    assert store.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, extra = store.restore(str(tmp_path), 7, like)
    assert extra["data"]["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_latest(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        store.save(str(tmp_path), s, tree, keep=2)
    assert sorted(store.all_steps(str(tmp_path))) == [4, 5]


def test_checkpoint_atomicity(tmp_path):
    tree = {"x": jnp.ones((4,))}
    store.save(str(tmp_path), 1, tree)
    # a stale tmp dir from a crashed writer must not break anything
    os.makedirs(tmp_path / ".tmp_step_2", exist_ok=True)
    assert store.latest_step(str(tmp_path)) == 1


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore onto explicit device placements (1-device 'new mesh')."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    store.save(str(tmp_path), 3, tree)
    mesh = jax.make_mesh((1,), ("model",))
    sh = {"w": NamedSharding(mesh, P("model", None))}
    restored, _ = store.restore(str(tmp_path), 3, tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    assert np.array_equal(np.asarray(restored["w"]),
                          np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_descends_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=2000,
                            weight_decay=0.0, clip_norm=1e9,
                            min_lr_frac=1.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = adamw.init_state(params, cfg)
    norms = []
    for _ in range(150):
        grads = {"x": 2 * params["x"]}
        params, state, _ = adamw.apply(params, grads, state, cfg)
        norms.append(float(jnp.abs(params["x"]).max()))
    assert norms[-1] < 0.5
    assert norms[-1] < norms[0]          # monotone progress overall


def test_adamw_clips_gradients():
    cfg = adamw.AdamWConfig(clip_norm=1.0)
    params = {"x": jnp.zeros(3)}
    state = adamw.init_state(params, cfg)
    _, _, stats = adamw.apply(params, {"x": jnp.full(3, 1e6)}, state, cfg)
    assert float(stats["grad_norm"]) > 1.0   # raw norm reported


def test_adamw_bf16_state():
    cfg = adamw.AdamWConfig(state_dtype=jnp.bfloat16)
    params = {"x": jnp.ones(4)}
    state = adamw.init_state(params, cfg)
    assert state["m"]["x"].dtype == jnp.bfloat16
    p2, s2, _ = adamw.apply(params, {"x": jnp.ones(4)}, state, cfg)
    assert s2["v"]["x"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_serving_engine_batches():
    from repro.configs.base import get_config
    from repro.models import transformer as T
    from repro.serving.engine import EngineConfig, Request, ServeEngine
    cfg = get_config("smollm-135m", smoke=True)
    params = T.init_model(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    eng = ServeEngine(cfg, params, EngineConfig(batch=2, cache_len=64))
    rng = np.random.default_rng(0)
    for i in range(3):
        eng.submit(Request(i, rng.integers(0, cfg.vocab, size=8)
                           .astype(np.int32), max_new=4))
    done = eng.run()
    assert len(done) == 3
    assert all(len(r.out_tokens) == 4 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out_tokens)
