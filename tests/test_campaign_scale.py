"""Campaign scale-up axes: process-pool execution, multi-failure
scenarios (``n_failures``), rectangular meshes, overlap semantics and the
weighted probe-overhead aggregation."""

import dataclasses

import pytest

from repro.core.campaign import (CampaignGrid, DeploymentCache,
                                 enumerate_scenarios, materialise,
                                 run_campaign)
from repro.core.failures import FailSlow
from repro.core.metrics import (DetectorOutcome, ScenarioOutcome, aggregate,
                                recall_stat, topk_stat)
from repro.core.routing import Mesh2D
from repro.core.simulator import simulate

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

TINY = CampaignGrid(workloads=("darknet19",), meshes=(4,),
                    kinds=("core", "link", "router", "none"),
                    severities=(8.0,), n_failures=(1, 2), reps=1,
                    campaign_seed=21)


@pytest.fixture(scope="module")
def serial_result():
    return run_campaign(TINY, workers=0, cache=DeploymentCache())


# ---------------------------------------------------------------------------
# process-pool executor
# ---------------------------------------------------------------------------

def test_process_pool_bit_identical(serial_result):
    """`executor='process'` (per-worker deployment caches, spawn start
    method) reproduces serial execution outcome-for-outcome."""
    res = run_campaign(TINY, workers=2, executor="process")
    assert res.outcomes == serial_result.outcomes
    assert res.metrics == serial_result.metrics
    assert res.cells == serial_result.cells
    assert res.probe_overheads == serial_result.probe_overheads


def test_process_executor_serial_fallback(serial_result):
    """workers<=1 under the process executor runs in-process (no pool)."""
    res = run_campaign(TINY, workers=1, executor="process",
                       cache=DeploymentCache())
    assert res.outcomes == serial_result.outcomes


def test_unknown_executor_rejected():
    with pytest.raises(ValueError, match="unknown executor"):
        run_campaign(TINY, executor="gremlin")


# ---------------------------------------------------------------------------
# n_failures axis
# ---------------------------------------------------------------------------

def test_n_failures_axis_enumeration():
    scen = enumerate_scenarios(TINY)
    assert len(scen) == TINY.n_scenarios()
    # 3 positive kinds × 1 severity × 2 n_failures + 1 collapsed 'none'
    assert len(scen) == 3 * 2 + 1
    for s in scen:
        if s.kind == "none":
            assert s.n_failures == 0
        else:
            assert s.n_failures in TINY.n_failures


def test_grid_rejects_bad_n_failures():
    with pytest.raises(ValueError, match="n_failures"):
        CampaignGrid(n_failures=(0,))
    with pytest.raises(ValueError, match="n_failures"):
        CampaignGrid(n_failures=())


def test_multi_failure_materialise_distinct_locations():
    cache = DeploymentCache()
    dep = cache.get("darknet19", 4, 4)
    for s in enumerate_scenarios(TINY):
        failures, _ = materialise(TINY, s, dep)
        assert len(failures) == s.n_failures
        locs = [f.location for f in failures]
        assert len(set(locs)) == len(locs)       # distinct placements
        assert all(f.kind == s.kind for f in failures)
        assert all(f.slowdown == s.severity for f in failures)


def test_materialise_rejects_oversized_k():
    cache = DeploymentCache()
    dep = cache.get("darknet19", 4, 4)
    big = dataclasses.replace(TINY, n_failures=(10_000,))
    s = next(s for s in enumerate_scenarios(big) if s.kind == "core")
    with pytest.raises(ValueError, match="cannot place"):
        materialise(big, s, dep)
    s = next(s for s in enumerate_scenarios(big) if s.kind == "link")
    with pytest.raises(ValueError, match="cannot place"):
        materialise(big, s, dep)


def test_multi_failure_outcomes_judged_per_failure(serial_result):
    """k=2 scenarios carry two truths, each with its own rank; the
    scenario-level truth_rank is the best of them."""
    multi = [o for o in serial_result.outcomes if o.n_failures == 2]
    assert multi
    for o in multi:
        assert len(o.truth_locations) == 2
        assert len(o.truth_ranks) == 2
        ranked = [r for r in o.truth_ranks if r is not None]
        assert o.truth_rank == (min(ranked) if ranked else None)
        if o.matched:
            assert o.flagged


def test_recall_at_k_in_summary(serial_result):
    s = serial_result.summary()
    assert "recall@1" in s and "recall@3" in s and "recall@5" in s


# ---------------------------------------------------------------------------
# judging semantics on synthetic outcomes (pure metric unit tests)
# ---------------------------------------------------------------------------

def _outcome(i, kind="core", truth_ranks=(), matched=False, flagged=True,
             workload="wl", mesh=(4, 4), probe_overhead=0.0,
             detector="sloth"):
    n = len(truth_ranks)
    ranked = [r for r in truth_ranks if r is not None]
    det = DetectorOutcome(
        detector=detector, flagged=flagged, pred_kind="core",
        pred_location=0, score=1.0, matched=matched,
        truth_rank=min(ranked) if ranked else None,
        truth_ranks=tuple(truth_ranks))
    return ScenarioOutcome(
        scenario_id=i, workload=workload, mesh_w=mesh[0], mesh_h=mesh[1],
        kind=kind, severity=8.0 if kind != "none" else 0.0,
        n_failures=n, rep=0, sim_seed=i,
        truth_locations=tuple(range(n)), truth_t0s=(0.0,) * n,
        truth_durations=(1.0,) * n, detector_results=(det,),
        compression_ratio=10.0,
        total_time=1.0, probe_overhead=probe_overhead)


def test_recall_counts_individual_failures():
    outs = [
        _outcome(0, truth_ranks=(1, 4), matched=True),    # 2 failures
        _outcome(1, truth_ranks=(2, None), matched=False),
        _outcome(2, kind="none", flagged=False),          # no recall trials
    ]
    r1 = recall_stat(outs, 1)
    assert (r1.successes, r1.trials) == (1, 4)
    r3 = recall_stat(outs, 3)
    assert (r3.successes, r3.trials) == (2, 4)
    r5 = recall_stat(outs, 5)
    assert (r5.successes, r5.trials) == (3, 4)
    # scenario-level top-k uses the best rank per scenario
    t1 = topk_stat(outs, 1)
    assert (t1.successes, t1.trials) == (1, 2)
    t2 = topk_stat(outs, 2)
    assert (t2.successes, t2.trials) == (2, 2)
    m = aggregate(outs)
    assert m.recall_at(1) == 0.25 and m.accuracy.rate == 0.5


def test_probe_overhead_weighted_by_scenario_count():
    """Deployment A serves 3 scenarios, deployment B serves 1: the
    headline mean weights by scenario count; the unweighted mean does
    not."""
    outs = ([_outcome(i, workload="a", probe_overhead=0.01)
             for i in range(3)]
            + [_outcome(3, workload="b", probe_overhead=0.09)])
    m = aggregate(outs)
    assert m.mean_probe_overhead == pytest.approx((3 * 0.01 + 0.09) / 4)
    assert m.mean_probe_overhead_unweighted == pytest.approx(0.05)


# ---------------------------------------------------------------------------
# rectangular meshes
# ---------------------------------------------------------------------------

def test_mesh_spec_normalisation():
    g = CampaignGrid(meshes=(4, (6, 3), "12x8"))
    assert g.meshes == ((4, 4), (6, 3), (12, 8))
    with pytest.raises(ValueError, match="mesh"):
        CampaignGrid(meshes=("4x4x4",))
    with pytest.raises(ValueError, match="mesh"):
        CampaignGrid(meshes=((4, 4, 4),))
    with pytest.raises(ValueError, match="mesh"):
        CampaignGrid(meshes=(0,))


def test_rect_mesh_routing_link_id_round_trip():
    mesh = Mesh2D(6, 3)
    assert mesh.n_cores == 18
    # link ids and endpoint pairs are mutually inverse
    for lid, (u, v) in enumerate(mesh.links):
        assert mesh.link_id(u, v) == lid
    # XY routes walk adjacent links from src to dst with hop-count length
    for src, dst in ((0, 17), (5, 12), (13, 2), (7, 7)):
        path = mesh.route(src, dst)
        assert len(path) == mesh.hops(src, dst)
        cur = src
        for lid in path:
            u, v = mesh.links[lid]
            assert u == cur
            cur = v
        assert cur == dst


def test_rect_mesh_campaign_end_to_end():
    g = CampaignGrid(workloads=("darknet19",), meshes=("6x3",),
                     kinds=("core", "none"), severities=(8.0,),
                     reps=1, campaign_seed=5)
    res = run_campaign(g, workers=0, cache=DeploymentCache())
    assert all(o.mesh_w == 6 and o.mesh_h == 3 for o in res.outcomes)
    assert ("darknet19", "mesh", 6, 3) in res.probe_overheads
    assert all(c[1] == 6 and c[2] == 3 for c in res.cells)


def test_12x12_multi_failure_campaign():
    """Acceptance: a 12×12-mesh, n_failures=2 campaign runs end-to-end
    with per-failure recall reported."""
    g = CampaignGrid(workloads=("darknet19",), meshes=("12x12",),
                     kinds=("core", "link"), severities=(10.0,),
                     n_failures=(2,), reps=1, campaign_seed=2)
    res = run_campaign(g, workers=0, cache=DeploymentCache())
    assert len(res.outcomes) == 2
    assert all(o.mesh_w == o.mesh_h == 12 for o in res.outcomes)
    assert all(o.n_failures == 2 for o in res.outcomes)
    rec = dict(res.metrics.recall)
    assert rec[5].trials == 4            # 2 scenarios × 2 failures
    assert "recall@5" in res.summary()


# ---------------------------------------------------------------------------
# simulator multi-failure overlap semantics
# ---------------------------------------------------------------------------

def test_overlapping_failures_compound():
    """Two overlapping windows on one resource compound multiplicatively
    instead of silently overwriting each other."""
    cache = DeploymentCache()
    dep = cache.get("darknet19", 4, 4)
    sloth = dep.sloth
    cfg = dataclasses.replace(sloth.sim_cfg, seed=0)
    core = 5
    horizon = dep.healthy.total_time * 4
    one = FailSlow("core", core, 0.0, horizon, 4.0)
    two = FailSlow("core", core, 0.0, horizon, 4.0)
    t_base = simulate(sloth.mapped, cfg).total_time
    t_one = simulate(sloth.mapped, cfg, failures=[one]).total_time
    t_two = simulate(sloth.mapped, cfg, failures=[one, two]).total_time
    assert t_base < t_one < t_two


def test_two_routers_slowing_shared_link_compound():
    mesh = Mesh2D(4)
    cache = DeploymentCache()
    dep = cache.get("darknet19", 4, 4)
    sloth = dep.sloth
    cfg = dataclasses.replace(sloth.sim_cfg, seed=0)
    # adjacent routers share the link between them
    shared = set(mesh.links_of_router(5)) & set(mesh.links_of_router(6))
    assert shared
    horizon = dep.healthy.total_time * 4
    r5 = FailSlow("router", 5, 0.0, horizon, 3.0)
    r6 = FailSlow("router", 6, 0.0, horizon, 3.0)
    t_one = simulate(sloth.mapped, cfg, failures=[r5]).total_time
    t_two = simulate(sloth.mapped, cfg, failures=[r5, r6]).total_time
    assert t_two > t_one
