"""Unified detector API: registry round-trips, the one Verdict across
SLOTH and the baselines, router-aware baseline matching, deprecation
shims, executor equivalence for multi-detector campaigns, mesh-size-aware
thresholds, and wall-time telemetry."""

import dataclasses
import warnings

import pytest

from repro.core.baselines import BASELINE_NAMES, BaselineVerdict, Thres
from repro.core.campaign import (CampaignGrid, DeploymentCache,
                                 run_campaign)
from repro.core.detectors import (DEFAULT_DETECTORS, Verdict,
                                  available_detectors, get_detector,
                                  prepare_detector, register_detector)
from repro.core.failures import FailSlow, judge_verdict
from repro.core.graph import build_workload
from repro.core.metrics import (DetectorOutcome, by_detector,
                                detector_cells, wall_time_stats)
from repro.core.routing import Mesh2D
from repro.core.sloth import Sloth, SlothConfig

TINY = CampaignGrid(workloads=("darknet19",), meshes=(4,),
                    kinds=("core", "link", "router", "none"),
                    severities=(8.0,), reps=1, campaign_seed=31)


@pytest.fixture(scope="module")
def sloth():
    return Sloth(build_workload("darknet19"), Mesh2D(4))


@pytest.fixture(scope="module")
def two_detector_serial():
    return run_campaign(TINY, workers=0, detectors=("sloth", "thres"),
                        cache=DeploymentCache())


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_builtins_registered_in_order():
    names = available_detectors()
    assert names[:6] == DEFAULT_DETECTORS
    assert DEFAULT_DETECTORS == ("sloth",) + BASELINE_NAMES


def test_unknown_detector_rejected():
    with pytest.raises(KeyError, match="unknown detector 'gremlin'"):
        get_detector("gremlin")
    with pytest.raises(KeyError, match="available"):
        run_campaign(TINY, workers=0, detectors=("sloth", "gremlin"))


def test_register_detector_round_trip(sloth):
    class Oracle:
        """Trivial custom detector: never flags."""
        name = "test-oracle"

        def prepare(self, graph, mesh, profile, cfg=None):
            self.mesh = mesh
            return self

        def analyse(self, sim):
            return Verdict(flagged=False, kind=None, location=None,
                           score=0.0, total_time=sim.total_time,
                           mesh=self.mesh, detector=self.name)

    register_detector("test-oracle", Oracle, overwrite=True)
    try:
        assert "test-oracle" in available_detectors()
        with pytest.raises(ValueError, match="already registered"):
            register_detector("test-oracle", Oracle)
        det = prepare_detector("test-oracle", sloth.graph, sloth.mesh,
                               sloth.run(None, seed=0))
        v = det.analyse(sloth.run(None, seed=1))
        assert not v.flagged and v.detector == "test-oracle"
        # a registered extension flows through the campaign unchanged
        g = dataclasses.replace(TINY, kinds=("none",))
        res = run_campaign(g, workers=0,
                           detectors=("sloth", "test-oracle"),
                           cache=DeploymentCache())
        assert res.detectors == ("sloth", "test-oracle")
        assert res.detector_metrics["test-oracle"].fpr.successes == 0
    finally:
        from repro.core import detectors as D
        D._REGISTRY.pop("test-oracle", None)


def test_get_detector_case_insensitive():
    assert get_detector("SLOTH") is get_detector("sloth")


def test_registry_name_contract_enforced():
    """A factory whose instances report a different .name than their
    registry key is rejected at instantiation — outcome tables are keyed
    on .name, so a mismatch would otherwise crash aggregation."""
    class Misnamed:
        name = "Oracle"                      # key will be 'oracle'

        def prepare(self, graph, mesh, profile, cfg=None):
            return self

        def analyse(self, sim):
            raise NotImplementedError

    register_detector("oracle", Misnamed, overwrite=True)
    try:
        with pytest.raises(ValueError, match="must match"):
            run_campaign(dataclasses.replace(TINY, kinds=("none",)),
                         workers=0, detectors=("oracle",),
                         cache=DeploymentCache())
    finally:
        from repro.core import detectors as D
        D._REGISTRY.pop("oracle", None)


def test_detectors_accepts_lone_string():
    g = dataclasses.replace(TINY, kinds=("none",))
    res = run_campaign(g, workers=0, detectors="thres",
                       cache=DeploymentCache())
    assert res.detectors == ("thres",)


def test_deployment_reuses_host_pipeline_for_sloth():
    cache = DeploymentCache()
    dep = cache.get("darknet19", 4, 4, detectors=("sloth", "thres"))
    assert dep.detectors[0].pipeline is dep.sloth
    # detector subsets share the expensive host artifacts and the
    # per-name prepared detector instances
    other = cache.get("darknet19", 4, 4, detectors=("thres",))
    assert other is not dep
    assert other.sloth is dep.sloth and other.healthy is dep.healthy
    assert other.detectors[0] is dep.detectors[1]


def test_builtin_registration_does_not_clobber_user_override():
    """Built-in modules register with first-registration-wins semantics,
    so a user's ``register_detector(name, ..., overwrite=True)`` override
    of a built-in name survives module (re)imports."""
    from repro.core import detectors as D
    original = get_detector("thres")

    def custom():                                  # stand-in override
        raise NotImplementedError

    try:
        register_detector("thres", custom, overwrite=True)
        D._register_builtin("thres", Thres)        # what a re-import does
        assert get_detector("thres") is custom
    finally:
        D._REGISTRY["thres"] = original
    assert get_detector("thres") is original


# ---------------------------------------------------------------------------
# unified Verdict across detectors (router-aware baseline matching)
# ---------------------------------------------------------------------------

def test_baseline_router_aware_match_regression(sloth):
    """Regression for the `BaselineVerdict.matches` router bug: a baseline
    naming any link of a slowed router now matches the router truth.  The
    old 4-field verdict compared (kind, location) literally, so a baseline
    could never be credited for a router failure."""
    profile = sloth.run(None, seed=12345)
    det = Thres().prepare(sloth.graph, sloth.mesh, profile)
    router = 5
    lid = sloth.mesh.links_of_router(router)[0]
    sim = sloth.run([FailSlow("link", lid, 0.0, 1e9, 10.0)], seed=2)
    v = det.analyse(sim)
    assert v.flagged and v.kind == "link"
    assert v.location in sloth.mesh.links_of_router(router)
    truth = FailSlow("router", router, 0.0, 1e9, 10.0)
    assert v.matches(truth)                       # router-aware, mesh-borne
    # the shared campaign judge agrees
    matched, rank, ranks, cands = judge_verdict(v, (truth,), sloth.mesh)
    assert matched and rank == 1 and ranks == (1,)
    assert (v.kind, v.location) in cands
    # and a router on the far side of the mesh does not match
    far = next(c for c in range(sloth.mesh.n_cores)
               if v.location not in sloth.mesh.links_of_router(c))
    assert not v.matches(FailSlow("router", far, 0.0, 1e9, 10.0))


def test_baselines_return_unified_verdict(sloth):
    profile = sloth.run(None, seed=12345)
    sim = sloth.run([FailSlow("core", 5, 1.0, 8.0)], seed=1)
    for name in BASELINE_NAMES:
        v = prepare_detector(name, sloth.graph, sloth.mesh,
                             profile).analyse(sim)
        assert isinstance(v, Verdict)
        assert v.detector == name
        assert v.mesh is sloth.mesh
        assert v.recorder is None and v.failrank is None and v.mcg is None
        assert v.total_time == sim.total_time
        # multi-entry suspicion-ordered ranking, led by the top-1 verdict
        from repro.core.baselines import _Baseline
        assert len(v.ranking) <= _Baseline.max_ranked
        for k, l, s in v.ranking:
            assert k in ("core", "link") and isinstance(l, int)
        if v.flagged:
            assert v.ranking[0] == (v.kind, v.location, v.score)


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------

def test_baseline_verdict_shim_warns_and_matches():
    with pytest.warns(DeprecationWarning, match="BaselineVerdict"):
        v = BaselineVerdict(True, "core", 5, 2.0)
    assert isinstance(v, Verdict)
    assert v.ranking == [("core", 5, 2.0)]
    assert v.matches(FailSlow("core", 5, 0.0, 1.0, 8.0))
    assert not v.matches(FailSlow("core", 6, 0.0, 1.0, 8.0))
    with pytest.warns(DeprecationWarning):
        assert not BaselineVerdict(False).flagged


def test_baseline_detect_alias_warns(sloth):
    det = Thres().prepare(sloth.graph, sloth.mesh,
                          sloth.run(None, seed=12345))
    sim = sloth.run(None, seed=3)
    with pytest.warns(DeprecationWarning, match="analyse"):
        v = det.detect(sim)
    assert v == det.analyse(sim)
    # the old per-call tuning kwargs still work through the shim
    from repro.core.baselines import Mscope
    ms = Mscope().prepare(sloth.graph, sloth.mesh,
                          sloth.run(None, seed=12345))
    with pytest.warns(DeprecationWarning):
        ms.detect(sim, walks=50, seed=1)
    assert ms.walks == 50 and ms.walk_seed == 1
    with pytest.warns(DeprecationWarning), \
            pytest.raises(TypeError, match="unexpected keyword"):
        ms.detect(sim, gremlin=1)


def test_run_campaign_baselines_flag_shim(two_detector_serial):
    g = dataclasses.replace(TINY, kinds=("core",))
    with pytest.warns(DeprecationWarning, match="baselines= is deprecated"):
        old = run_campaign(g, workers=0, baselines=True,
                           cache=DeploymentCache())
    assert old.detectors == DEFAULT_DETECTORS
    new = run_campaign(g, workers=0, detectors=DEFAULT_DETECTORS,
                       cache=DeploymentCache())
    assert old.outcomes == new.outcomes
    assert old.detector_metrics == new.detector_metrics
    with pytest.warns(DeprecationWarning):
        dep = DeploymentCache().get("darknet19", 4, 4, baselines=True)
    assert tuple(d.name for d in dep.detectors) == DEFAULT_DETECTORS


# ---------------------------------------------------------------------------
# multi-detector campaigns: executor equivalence + per-detector cells
# ---------------------------------------------------------------------------

def test_multi_detector_serial_thread_process_equivalent(
        two_detector_serial):
    serial = two_detector_serial
    thread = run_campaign(TINY, workers=2, executor="thread",
                          detectors=("sloth", "thres"),
                          cache=DeploymentCache())
    process = run_campaign(TINY, workers=2, executor="process",
                           detectors=("sloth", "thres"))
    for other in (thread, process):
        assert other.outcomes == serial.outcomes
        assert other.metrics == serial.metrics
        assert other.cells == serial.cells
        assert other.detector_metrics == serial.detector_metrics
        assert other.detector_cells == serial.detector_cells


def test_per_detector_cells_cover_all_detectors(two_detector_serial):
    res = two_detector_serial
    assert res.detectors == ("sloth", "thres")
    assert set(res.detector_metrics) == {"sloth", "thres"}
    assert set(res.detector_cells) == {"sloth", "thres"}
    # the primary detector's top-level view is the per-detector entry
    assert res.metrics == res.detector_metrics["sloth"]
    assert res.cells == res.detector_cells["sloth"]
    # every cell is present for every detector, with the same trial counts
    for name in res.detectors:
        cells = res.detector_cells[name]
        assert set(cells) == set(res.cells)
        for c, m in cells.items():
            assert m.n_scenarios == res.cells[c].n_scenarios
    # reductions over outcomes reproduce the result's tables
    assert by_detector(res.outcomes) == res.detector_metrics
    assert detector_cells(res.outcomes) == res.detector_cells


def test_outcomes_carry_all_detector_verdicts(two_detector_serial):
    for o in two_detector_serial.outcomes:
        assert [d.detector for d in o.detector_results] == ["sloth",
                                                            "thres"]
        assert o.result_for("thres").detector == "thres"
        assert o.result_for(None) is o.detector_results[0]
        with pytest.raises(KeyError, match="no verdict"):
            o.result_for("adr")
        # compression comes from SLOTH's recorder artifacts
        assert o.compression_ratio > 1


# ---------------------------------------------------------------------------
# wall-time telemetry
# ---------------------------------------------------------------------------

def test_wall_time_telemetry(two_detector_serial):
    res = two_detector_serial
    for o in res.outcomes:
        assert o.sim_wall_time > 0
        assert all(d.wall_time > 0 for d in o.detector_results)
    stats = wall_time_stats(res.outcomes)
    assert set(stats) == {"simulate", "sloth", "thres"}
    for w in stats.values():
        assert 0 < w.mean <= w.p95 <= w.total
        assert w.n == len(res.outcomes)
    assert "wall time per scenario" in res.summary()


def test_wall_time_excluded_from_equality():
    a = DetectorOutcome("sloth", True, "core", 1, 1.0, True, 1, (1,),
                        wall_time=0.5)
    b = DetectorOutcome("sloth", True, "core", 1, 1.0, True, 1, (1,),
                        wall_time=99.0)
    assert a == b


# ---------------------------------------------------------------------------
# mesh-size-aware thresholds (the 12×12 'none' false-flag fix)
# ---------------------------------------------------------------------------

def test_effective_flags_scale_with_mesh():
    cfg = SlothConfig()
    # reference (4×4) and smaller meshes keep the calibrated defaults
    assert cfg.effective_core_z(16) == cfg.core_z_flag
    assert cfg.effective_core_z(4) == cfg.core_z_flag
    assert cfg.effective_link_ratio(48) == cfg.link_ratio_flag
    # larger meshes raise the flags monotonically
    z = [cfg.effective_core_z(n) for n in (16, 36, 64, 144)]
    r = [cfg.effective_link_ratio(n) for n in (48, 120, 224, 528)]
    assert all(a < b for a, b in zip(z, z[1:]))
    assert all(a < b for a, b in zip(r, r[1:]))
    # opting out recovers fixed thresholds
    fixed = SlothConfig(core_z_per_log=0.0, link_ratio_per_log=0.0)
    assert fixed.effective_link_ratio(528) == fixed.link_ratio_flag


def test_12x12_none_cell_does_not_false_flag():
    """Regression (ROADMAP follow-up): at the default config the 12×12
    'none' cell used to flag healthy links; the mesh-size-aware link flag
    keeps the FPR at zero *while 10× failures stay detectable* — both
    sides pinned, so neither a threshold drop (FPR creeps back) nor an
    over-eager raise (real failures silenced) can slip through."""
    g = CampaignGrid(workloads=("darknet19",), meshes=("12x12",),
                     kinds=("core", "link", "none"), severities=(10.0,),
                     reps=3, campaign_seed=4)
    res = run_campaign(g, workers=0, cache=DeploymentCache())
    m = res.metrics
    assert m.fpr.trials == 3
    assert m.fpr.successes == 0, (
        f"12x12 'none' scenarios false-flagged: "
        f"{[(o.pred_kind, o.pred_location, o.score) for o in res.outcomes if o.kind == 'none']}"
    )
    assert m.accuracy.trials == 6
    assert m.accuracy.rate >= 4 / 6          # measured 5/6 at this seed
    assert m.topk_rate(3) >= 5 / 6           # measured 6/6
