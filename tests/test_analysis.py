"""repro.analysis: static memory model vs measured bytes, kernel audit,
determinism lints, interprocedural dataflow, fingerprints + baseline
workflow, CLI exit-code contract, and the construction-time budget
guards."""

import dataclasses
import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
from proptest import given, settings, st

from repro.analysis import (MemoryBudgetError, load_baseline,
                            memory_report, new_findings, run_checks,
                            validate_params)
from repro.analysis import dataflow, kernel_audit, lints, memory_model
from repro.core.failures import FailSlow
from repro.core.graph import build_workload
from repro.core.recorder import record
from repro.core.routing import Mesh2D
from repro.core.sketch import (STAGE2_SLOT_BYTES, FailSlowSketch,
                               SketchParams)
from repro.core.sloth import Sloth, SlothConfig
from repro.core.streaming import StreamingRecorder

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

REPO = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# the clean tree passes; each pass's planted violations are caught
# ---------------------------------------------------------------------------

def test_clean_tree_has_no_unbaselined_findings():
    """Every finding on the committed tree is carried by the committed
    baseline — new fingerprints are regressions."""
    baseline = load_baseline()
    new = new_findings(run_checks("all"), baseline)
    assert new == [], "\n".join(
        f"{f.render()}  fp={f.fingerprint}" for f in new)


def test_memory_self_test():
    memory_model.self_test()


def test_kernel_audit_self_test():
    kernel_audit.self_test()


def test_lints_self_test():
    lints.self_test()


def test_dataflow_self_test():
    dataflow.self_test()


def _cli(*argv, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv], cwd=cwd,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True)


def test_cli_exit_codes():
    """--check all --baseline exits 0 on the clean tree; a seeded
    violation (the memory pass under an impossible budget) exits
    nonzero."""
    ok = _cli("--check", "all", "--baseline", "analysis/baseline.json")
    assert ok.returncode == 0, ok.stdout + ok.stderr
    bad = _cli("--check", "memory", "--budget-kb", "1")
    assert bad.returncode == 1, bad.stdout + bad.stderr
    assert "over-budget" in bad.stdout


def test_cli_budget_kb_rejected_for_non_memory_checks():
    """--budget-kb used to be silently ignored outside the memory pass;
    now it is a usage error (argparse exit code 2)."""
    r = _cli("--check", "lints", "--budget-kb", "100")
    assert r.returncode == 2, r.stdout + r.stderr
    assert "--budget-kb" in r.stderr
    # still accepted where it applies
    assert _cli("--check", "memory", "--budget-kb", "512")\
        .returncode == 0


def test_cli_json_includes_fingerprints_and_timings():
    r = _cli("--check", "all", "--baseline", "analysis/baseline.json",
             "--json")
    assert r.returncode == 0, r.stdout + r.stderr
    doc = json.loads(r.stdout)
    assert doc["ok"] and doc["new"] == 0
    assert set(doc["timings"]) == {"memory", "kernels", "lints",
                                   "dataflow"}
    assert all(t >= 0 for t in doc["timings"].values())
    for row in doc["findings"]:
        assert row["baselined"] is True
        assert len(row["fingerprint"]) == 16


def test_each_pass_flags_its_synthetic_violation():
    """One seeded violation per pass, through the pass's public unit
    API (the CLI --self-test covers the same ground in CI)."""
    # memory: an over-budget geometry
    rep = memory_report(SketchParams(m=65536), impl="batched")
    assert rep["total_budget_bytes"] > 256 * 1024
    # kernels: a parallel grid writing through an alias
    src = kernel_audit._SYNTHETIC_BAD
    assert any(f.rule == "parallel-write-race"
               for f in kernel_audit.audit_source(src, "<s>"))
    # lints: unseeded global RNG
    fs = lints.lint_source("import numpy as np\n"
                           "x = np.random.rand(3)\n", "<s>")
    assert any(f.rule == "unseeded-rng" for f in fs)


# ---------------------------------------------------------------------------
# memory model == measured bytes (property tests, both impls)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.data())
def test_static_model_matches_array_nbytes(data):
    """Closed forms equal the actual allocated array nbytes across
    randomized geometries, for the ref numpy arrays, the packed jnp
    state, and the drain buffer."""
    from repro.kernels.sketch_update import ref as kref
    p = SketchParams(d=data.draw(st.integers(1, 4)),
                     m=data.draw(st.sampled_from([16, 64, 257, 1024])),
                     H=data.draw(st.integers(1, 8)),
                     L=data.draw(st.sampled_from([8, 33, 256, 1024])))
    cap = data.draw(st.sampled_from([0, 1, 7, 256]))

    sk = FailSlowSketch(p)
    measured_ref = sum(a.nbytes for a in
                       (sk.keys_lo, sk.keys_hi, sk.valid, sk.freq))
    assert measured_ref == memory_model.ref_stage1_nbytes(p)

    state = kref.make_state(p)
    assert sum(int(v.nbytes) for v in state.values()) \
        == memory_model.packed_state_bytes(p)

    drain = kref.make_drain(cap)
    assert sum(int(v.nbytes) for v in drain.values()) \
        == memory_model.drain_bytes(cap)

    assert memory_model.accounting_bytes(p) == p.total_bytes()


@pytest.fixture(scope="module")
def deployment():
    sloth = Sloth(build_workload("darknet19"), Mesh2D(4))
    sim = sloth.run([FailSlow("core", 5, 1.0, 8.0, 10.0)], seed=0)
    return sloth, sim


@pytest.mark.parametrize("impl", ["ref", "batched"])
@pytest.mark.parametrize("geometry", [
    SketchParams(),                         # defaults, no eviction
    SketchParams(d=2, m=256, H=4, L=8),     # forced FIFO eviction
])
def test_static_footprint_equals_measured_onchip(deployment, impl,
                                                 geometry):
    """The execution-free accounting model predicts the measured
    RecorderOutput.onchip_bytes() exactly — including under eviction
    pressure, where the drained stream must be excluded from the
    on-chip figure and accounted at exactly one Stage-2 slot per
    drained pattern."""
    sloth, sim = deployment
    out = record(sim, geometry, hop_latency=sloth.sim_cfg.hop_latency,
                 impl=impl)
    static = 2 * memory_model.accounting_bytes(geometry)  # comp + comm
    assert out.onchip_bytes() == static
    drained = out.n_comp_drained + out.n_comm_drained
    assert out.sketch_bytes == static + drained * STAGE2_SLOT_BYTES
    if geometry.L == 8:
        assert drained > 0   # the small Stage-2 actually evicted


@pytest.mark.parametrize("impl", ["ref", "batched"])
def test_streaming_footprint_matches_static(deployment, impl):
    """The always-on recorder's cumulative output obeys the same static
    accounting (its on-chip state never grows with chunk count)."""
    from repro.core.streaming import split_sim
    sloth, sim = deployment
    p = SketchParams(d=2, m=256, H=4, L=8)
    rec = StreamingRecorder(p, hop_latency=sloth.sim_cfg.hop_latency,
                            impl=impl)
    for chunk in split_sim(sim, 4):
        rec.observe(chunk)
    out = rec.output()
    assert out.onchip_bytes() == 2 * memory_model.accounting_bytes(p)


# ---------------------------------------------------------------------------
# construction-time budget guards
# ---------------------------------------------------------------------------

def test_over_budget_sloth_config_rejected():
    cfg = SlothConfig(sketch=SketchParams(m=65536))
    with pytest.raises(MemoryBudgetError, match="over the .* budget"):
        Sloth(build_workload("darknet19"), Mesh2D(4), cfg=cfg)


def test_budget_none_disables_guard():
    cfg = SlothConfig(sketch=SketchParams(m=4096), budget_kb=None)
    Sloth(build_workload("darknet19"), Mesh2D(4), cfg=cfg)


def test_default_configs_fit_budget():
    Sloth(build_workload("darknet19"), Mesh2D(4))
    Sloth(build_workload("darknet19"), Mesh2D(4),
          cfg=SlothConfig(recorder_impl="batched"))


def test_streaming_recorder_guard():
    with pytest.raises(MemoryBudgetError):
        StreamingRecorder(SketchParams(m=65536))
    StreamingRecorder(SketchParams(m=65536), budget_kb=None)
    with pytest.raises(MemoryBudgetError):
        validate_params(SketchParams(), SketchParams(m=65536))


def test_budget_error_message_is_actionable():
    try:
        StreamingRecorder(SketchParams(m=65536), impl="batched")
    except MemoryBudgetError as e:
        msg = str(e)
        assert "KiB" in msg and "budget_kb" in msg and "m=65536" in msg
    else:
        pytest.fail("no MemoryBudgetError raised")


# ---------------------------------------------------------------------------
# satellite: exact per-slot drained accounting (non-divisible geometry)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _HeaderedParams(SketchParams):
    """A Stage-2 layout with a fixed 24-byte header: stage2_bytes() is
    no longer an exact multiple of L, the case where the historical
    ``stage2_bytes() // L`` per-pattern formula floor-truncates."""

    def stage2_bytes(self) -> int:
        return 24 + self.L * self.stage2_slot_bytes()


def test_drained_accounting_is_exact_per_slot():
    p = _HeaderedParams(d=2, m=1024, H=1, L=7)
    assert p.stage2_bytes() % p.L != 0   # genuinely non-divisible
    sk = FailSlowSketch(p)
    # many distinct keys promoted at H=1 → FIFO evictions past L slots
    for k in range(p.L + 20):
        sk.insert(k + 1, 1.0, 1.0, float(k))
    n = len(sk.drained)
    assert n > 0
    exact = p.total_bytes() + n * STAGE2_SLOT_BYTES
    assert sk.compressed_bytes() == exact
    # the old floor-division formula under-counts on this geometry
    old = p.total_bytes() + n * (p.stage2_bytes() // p.L)
    assert old != exact


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 200), st.integers(2, 31))
def test_slot_accounting_independent_of_L(extra, L):
    """Per-drained-pattern cost is the slot size, never a function of
    L: two sketches with different L but equal drained counts charge
    identical per-pattern bytes."""
    rng = np.random.default_rng(extra * 31 + L)
    p = SketchParams(d=1, m=64, H=1, L=L)
    sk = FailSlowSketch(p)
    for k in range(L + extra):
        sk.insert(int(k + 1), float(rng.random()), 1.0, float(k))
    per = (sk.compressed_bytes() - p.total_bytes()) / max(
        len(sk.drained), 1)
    if sk.drained:
        assert per == STAGE2_SLOT_BYTES


# ---------------------------------------------------------------------------
# kernel audit: the shipped contracts describe the shipped kernels
# ---------------------------------------------------------------------------

def test_kernel_audit_contracts_present_and_consistent():
    findings = kernel_audit.check()
    assert findings == [], "\n".join(f.render() for f in findings)
    files = {f.parent.name: f for f in
             (REPO / "src/repro/kernels").glob("*/kernel.py")}
    assert set(files) == {"sketch_update", "flash_attention",
                          "ssd_scan", "failrank_step"}
    for name, f in files.items():
        assert "AUDIT" in f.read_text(), f"{name} lost its contract"


def test_kernel_audit_catches_grid_rank_drift():
    """Editing a kernel's grid without updating AUDIT is flagged."""
    src = (REPO / "src/repro/kernels/failrank_step/kernel.py")\
        .read_text().replace("grid=(nb,)", "grid=(nb, 2)")
    fs = kernel_audit.audit_source(src, "<mutated>")
    assert any(f.rule == "audit-grid-rank-mismatch" for f in fs)


def test_lint_wallclock_allowlist_is_tight():
    """campaign.py keeps exactly one blessed wall-clock reader."""
    src = (REPO / "src/repro/core/campaign.py").read_text()
    assert src.count("time.perf_counter()") == 1
    assert "# lint: allow-wallclock" in src
    # stripping the marker re-triggers the lint
    stripped = src.replace("# lint: allow-wallclock", "")
    fs = lints.lint_source(stripped, "<campaign>")
    assert any(f.rule == "wallclock" for f in fs)


# ---------------------------------------------------------------------------
# dataflow pass: planted violations per rule (interprocedural)
# ---------------------------------------------------------------------------

def _df(modules):
    return dataflow.analyze_modules(modules)


def test_dataflow_literal_seed_flagged():
    fs = _df({"p.core.m": (
        "import jax\n"
        "def k():\n"
        "    return jax.random.PRNGKey(0)\n",
        "src/repro/core/m.py")})
    assert any(f.rule == "literal-seed" for f in fs)


def test_dataflow_seeded_arguments_stay_clean():
    """Scenario-seed lists, cfg fields and CLI --seed all classify as
    seeded; so does a param whose every call site passes a seed."""
    fs = _df({
        "p.core.lib": (
            "import numpy as np\n"
            "def stream(x):\n"
            "    return np.random.default_rng(x)\n",
            "src/repro/core/lib.py"),
        "p.core.use": (
            "from .lib import stream\n"
            "def f(cfg, args, base_seed):\n"
            "    a = stream(cfg.seed)\n"
            "    b = stream(args.seed)\n"
            "    c = stream([base_seed, 3, 7])\n"
            "    return a, b, c\n",
            "src/repro/core/use.py")})
    assert fs == [], "\n".join(f.render() for f in fs)


def test_dataflow_unseeded_provenance_traced_across_modules():
    """An RNG param fed an untraceable value at a call site in another
    module is flagged at the constructor."""
    fs = _df({
        "p.core.lib": (
            "import numpy as np\n"
            "def stream(x):\n"
            "    return np.random.default_rng(x)\n",
            "src/repro/core/lib.py"),
        "p.core.use": (
            "from .lib import stream\n"
            "def f(values):\n"
            "    return stream(len(values))\n",
            "src/repro/core/use.py")})
    hit = [f for f in fs if f.rule == "unseeded-provenance"]
    assert hit and hit[0].path.endswith("lib.py")
    assert hit[0].symbol == "stream"


def test_dataflow_cross_module_narrowing_flagged():
    fs = _df({
        "p.core.pack": (
            "import jax.numpy as jnp\n"
            "def pack(x):\n"
            "    return x.astype(jnp.bfloat16)\n",
            "src/repro/core/pack.py"),
        "p.core.use": (
            "from .pack import pack\n"
            "def f(x):\n"
            "    return pack(x) * 2\n",
            "src/repro/core/use.py")})
    assert any(f.rule == "cross-module-narrowing" for f in fs)
    # same shape with a *widening* cast stays clean
    fs2 = _df({
        "p.core.pack": (
            "import jax.numpy as jnp\n"
            "def pack(x):\n"
            "    return x.astype(jnp.float32)\n",
            "src/repro/core/pack.py"),
        "p.core.use": (
            "from .pack import pack\n"
            "def f(x):\n"
            "    return pack(x) * 2\n",
            "src/repro/core/use.py")})
    assert fs2 == []


def test_dataflow_unsorted_accumulation_flagged():
    src = ("def merge(parts):\n"
           "    acc = 0.0\n"
           "    for v in parts.values():\n"
           "        acc += v\n"
           "    return acc\n")
    fs = _df({"p.core.m": (src, "src/repro/core/m.py")})
    assert any(f.rule == "unsorted-accumulation" for f in fs)
    # integer counters over the same iteration are exact — not flagged
    src_int = ("def count(parts):\n"
               "    n = 0\n"
               "    for v in parts.values():\n"
               "        n += 1\n"
               "    return n\n")
    assert _df({"p.core.m": (src_int, "src/repro/core/m.py")}) == []


def test_dataflow_unordered_sum_and_fixes():
    bad = ("def t(parts):\n"
           "    return sum(parts.values())\n")
    fs = _df({"p.core.m": (bad, "src/repro/core/m.py")})
    assert any(f.rule == "unordered-sum" for f in fs)
    good = ("import math\n"
            "def t(parts):\n"
            "    return sum(sorted(parts.values()))\n"
            "def u(parts):\n"
            "    return math.fsum(parts.values())\n")
    assert _df({"p.core.m": (good, "src/repro/core/m.py")}) == []


# ---------------------------------------------------------------------------
# fingerprints + baseline workflow
# ---------------------------------------------------------------------------

def test_fingerprint_stable_under_line_shifts():
    """Inserting unrelated lines above a finding moves its line but not
    its fingerprint (the baseline keys on symbols, not lines)."""
    body = ("import jax\n"
            "def k():\n"
            "    return jax.random.PRNGKey(0)\n")
    shifted = ("import jax\n"
               "# a comment\n\n\n"
               "def unrelated():\n"
               "    return 1\n\n"
               "def k():\n"
               "    return jax.random.PRNGKey(0)\n")
    f1 = _df({"p.core.m": (body, "src/repro/core/m.py")})
    f2 = _df({"p.core.m": (shifted, "src/repro/core/m.py")})
    assert len(f1) == len(f2) == 1
    assert f1[0].line != f2[0].line
    assert f1[0].symbol == f2[0].symbol == "k"
    assert f1[0].fingerprint == f2[0].fingerprint


def test_fingerprint_distinguishes_rule_and_symbol():
    fs = _df({"p.core.m": (
        "import jax\n"
        "def k1():\n"
        "    return jax.random.PRNGKey(0)\n"
        "def k2():\n"
        "    return jax.random.PRNGKey(7)\n",
        "src/repro/core/m.py")})
    fps = {f.fingerprint for f in fs}
    assert len(fps) == len(fs) == 2


def test_baseline_round_trip(tmp_path):
    """--update-baseline then --baseline exits 0; a planted violation
    in the tree afterwards still exits 1."""
    bl = tmp_path / "bl.json"
    wrote = _cli("--check", "all", "--update-baseline", str(bl))
    assert wrote.returncode == 0, wrote.stdout + wrote.stderr
    ok = _cli("--check", "all", "--baseline", str(bl))
    assert ok.returncode == 0, ok.stdout + ok.stderr

    planted = REPO / "src/repro/core/_planted_analysis_smoke.py"
    try:
        planted.write_text("import numpy as np\n"
                           "x = np.random.rand(3)\n")
        bad = _cli("--check", "all", "--baseline", str(bl))
        assert bad.returncode == 1, bad.stdout + bad.stderr
        assert "unseeded-rng" in bad.stdout
    finally:
        planted.unlink()


def test_shipped_baseline_is_tight():
    """Every fingerprint in the committed baseline matches a live
    finding — stale entries would mask future regressions."""
    live = {f.fingerprint for f in run_checks("all")}
    baseline = load_baseline()
    stale = set(baseline) - live
    assert not stale, \
        f"stale baseline entries: " \
        f"{ {fp: baseline[fp] for fp in stale} }"
