"""repro.analysis: static memory model vs measured bytes, kernel audit,
determinism lints, CLI exit-code contract, and the construction-time
budget guards."""

import dataclasses
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
from proptest import given, settings, st

from repro.analysis import (MemoryBudgetError, memory_report,
                            run_checks, validate_params)
from repro.analysis import kernel_audit, lints, memory_model
from repro.core.failures import FailSlow
from repro.core.graph import build_workload
from repro.core.recorder import record
from repro.core.routing import Mesh2D
from repro.core.sketch import (STAGE2_SLOT_BYTES, FailSlowSketch,
                               SketchParams)
from repro.core.sloth import Sloth, SlothConfig
from repro.core.streaming import StreamingRecorder

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

REPO = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# the clean tree passes; each pass's planted violations are caught
# ---------------------------------------------------------------------------

def test_clean_tree_has_no_findings():
    assert run_checks("all") == []


def test_memory_self_test():
    memory_model.self_test()


def test_kernel_audit_self_test():
    kernel_audit.self_test()


def test_lints_self_test():
    lints.self_test()


def test_cli_exit_codes():
    """--check all exits 0 on the clean tree; a seeded violation (the
    memory pass under an impossible budget) exits nonzero."""
    env_cmd = [sys.executable, "-m", "repro.analysis"]
    ok = subprocess.run(env_cmd + ["--check", "all"], cwd=REPO,
                        env={"PYTHONPATH": str(REPO / "src"),
                             "PATH": "/usr/bin:/bin"},
                        capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    bad = subprocess.run(env_cmd + ["--check", "memory",
                                    "--budget-kb", "1"], cwd=REPO,
                         env={"PYTHONPATH": str(REPO / "src"),
                              "PATH": "/usr/bin:/bin"},
                         capture_output=True, text=True)
    assert bad.returncode == 1, bad.stdout + bad.stderr
    assert "over-budget" in bad.stdout


def test_each_pass_flags_its_synthetic_violation():
    """One seeded violation per pass, through the pass's public unit
    API (the CLI --self-test covers the same ground in CI)."""
    # memory: an over-budget geometry
    rep = memory_report(SketchParams(m=65536), impl="batched")
    assert rep["total_budget_bytes"] > 256 * 1024
    # kernels: a parallel grid writing through an alias
    src = kernel_audit._SYNTHETIC_BAD
    assert any(f.rule == "parallel-write-race"
               for f in kernel_audit.audit_source(src, "<s>"))
    # lints: unseeded global RNG
    fs = lints.lint_source("import numpy as np\n"
                           "x = np.random.rand(3)\n", "<s>")
    assert any(f.rule == "unseeded-rng" for f in fs)


# ---------------------------------------------------------------------------
# memory model == measured bytes (property tests, both impls)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.data())
def test_static_model_matches_array_nbytes(data):
    """Closed forms equal the actual allocated array nbytes across
    randomized geometries, for the ref numpy arrays, the packed jnp
    state, and the drain buffer."""
    from repro.kernels.sketch_update import ref as kref
    p = SketchParams(d=data.draw(st.integers(1, 4)),
                     m=data.draw(st.sampled_from([16, 64, 257, 1024])),
                     H=data.draw(st.integers(1, 8)),
                     L=data.draw(st.sampled_from([8, 33, 256, 1024])))
    cap = data.draw(st.sampled_from([0, 1, 7, 256]))

    sk = FailSlowSketch(p)
    measured_ref = sum(a.nbytes for a in
                       (sk.keys_lo, sk.keys_hi, sk.valid, sk.freq))
    assert measured_ref == memory_model.ref_stage1_nbytes(p)

    state = kref.make_state(p)
    assert sum(int(v.nbytes) for v in state.values()) \
        == memory_model.packed_state_bytes(p)

    drain = kref.make_drain(cap)
    assert sum(int(v.nbytes) for v in drain.values()) \
        == memory_model.drain_bytes(cap)

    assert memory_model.accounting_bytes(p) == p.total_bytes()


@pytest.fixture(scope="module")
def deployment():
    sloth = Sloth(build_workload("darknet19"), Mesh2D(4))
    sim = sloth.run([FailSlow("core", 5, 1.0, 8.0, 10.0)], seed=0)
    return sloth, sim


@pytest.mark.parametrize("impl", ["ref", "batched"])
@pytest.mark.parametrize("geometry", [
    SketchParams(),                         # defaults, no eviction
    SketchParams(d=2, m=256, H=4, L=8),     # forced FIFO eviction
])
def test_static_footprint_equals_measured_onchip(deployment, impl,
                                                 geometry):
    """The execution-free accounting model predicts the measured
    RecorderOutput.onchip_bytes() exactly — including under eviction
    pressure, where the drained stream must be excluded from the
    on-chip figure and accounted at exactly one Stage-2 slot per
    drained pattern."""
    sloth, sim = deployment
    out = record(sim, geometry, hop_latency=sloth.sim_cfg.hop_latency,
                 impl=impl)
    static = 2 * memory_model.accounting_bytes(geometry)  # comp + comm
    assert out.onchip_bytes() == static
    drained = out.n_comp_drained + out.n_comm_drained
    assert out.sketch_bytes == static + drained * STAGE2_SLOT_BYTES
    if geometry.L == 8:
        assert drained > 0   # the small Stage-2 actually evicted


@pytest.mark.parametrize("impl", ["ref", "batched"])
def test_streaming_footprint_matches_static(deployment, impl):
    """The always-on recorder's cumulative output obeys the same static
    accounting (its on-chip state never grows with chunk count)."""
    from repro.core.streaming import split_sim
    sloth, sim = deployment
    p = SketchParams(d=2, m=256, H=4, L=8)
    rec = StreamingRecorder(p, hop_latency=sloth.sim_cfg.hop_latency,
                            impl=impl)
    for chunk in split_sim(sim, 4):
        rec.observe(chunk)
    out = rec.output()
    assert out.onchip_bytes() == 2 * memory_model.accounting_bytes(p)


# ---------------------------------------------------------------------------
# construction-time budget guards
# ---------------------------------------------------------------------------

def test_over_budget_sloth_config_rejected():
    cfg = SlothConfig(sketch=SketchParams(m=65536))
    with pytest.raises(MemoryBudgetError, match="over the .* budget"):
        Sloth(build_workload("darknet19"), Mesh2D(4), cfg=cfg)


def test_budget_none_disables_guard():
    cfg = SlothConfig(sketch=SketchParams(m=4096), budget_kb=None)
    Sloth(build_workload("darknet19"), Mesh2D(4), cfg=cfg)


def test_default_configs_fit_budget():
    Sloth(build_workload("darknet19"), Mesh2D(4))
    Sloth(build_workload("darknet19"), Mesh2D(4),
          cfg=SlothConfig(recorder_impl="batched"))


def test_streaming_recorder_guard():
    with pytest.raises(MemoryBudgetError):
        StreamingRecorder(SketchParams(m=65536))
    StreamingRecorder(SketchParams(m=65536), budget_kb=None)
    with pytest.raises(MemoryBudgetError):
        validate_params(SketchParams(), SketchParams(m=65536))


def test_budget_error_message_is_actionable():
    try:
        StreamingRecorder(SketchParams(m=65536), impl="batched")
    except MemoryBudgetError as e:
        msg = str(e)
        assert "KiB" in msg and "budget_kb" in msg and "m=65536" in msg
    else:
        pytest.fail("no MemoryBudgetError raised")


# ---------------------------------------------------------------------------
# satellite: exact per-slot drained accounting (non-divisible geometry)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _HeaderedParams(SketchParams):
    """A Stage-2 layout with a fixed 24-byte header: stage2_bytes() is
    no longer an exact multiple of L, the case where the historical
    ``stage2_bytes() // L`` per-pattern formula floor-truncates."""

    def stage2_bytes(self) -> int:
        return 24 + self.L * self.stage2_slot_bytes()


def test_drained_accounting_is_exact_per_slot():
    p = _HeaderedParams(d=2, m=1024, H=1, L=7)
    assert p.stage2_bytes() % p.L != 0   # genuinely non-divisible
    sk = FailSlowSketch(p)
    # many distinct keys promoted at H=1 → FIFO evictions past L slots
    for k in range(p.L + 20):
        sk.insert(k + 1, 1.0, 1.0, float(k))
    n = len(sk.drained)
    assert n > 0
    exact = p.total_bytes() + n * STAGE2_SLOT_BYTES
    assert sk.compressed_bytes() == exact
    # the old floor-division formula under-counts on this geometry
    old = p.total_bytes() + n * (p.stage2_bytes() // p.L)
    assert old != exact


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 200), st.integers(2, 31))
def test_slot_accounting_independent_of_L(extra, L):
    """Per-drained-pattern cost is the slot size, never a function of
    L: two sketches with different L but equal drained counts charge
    identical per-pattern bytes."""
    rng = np.random.default_rng(extra * 31 + L)
    p = SketchParams(d=1, m=64, H=1, L=L)
    sk = FailSlowSketch(p)
    for k in range(L + extra):
        sk.insert(int(k + 1), float(rng.random()), 1.0, float(k))
    per = (sk.compressed_bytes() - p.total_bytes()) / max(
        len(sk.drained), 1)
    if sk.drained:
        assert per == STAGE2_SLOT_BYTES


# ---------------------------------------------------------------------------
# kernel audit: the shipped contracts describe the shipped kernels
# ---------------------------------------------------------------------------

def test_kernel_audit_contracts_present_and_consistent():
    findings = kernel_audit.check()
    assert findings == [], "\n".join(f.render() for f in findings)
    files = {f.parent.name: f for f in
             (REPO / "src/repro/kernels").glob("*/kernel.py")}
    assert set(files) == {"sketch_update", "flash_attention",
                          "ssd_scan", "failrank_step"}
    for name, f in files.items():
        assert "AUDIT" in f.read_text(), f"{name} lost its contract"


def test_kernel_audit_catches_grid_rank_drift():
    """Editing a kernel's grid without updating AUDIT is flagged."""
    src = (REPO / "src/repro/kernels/failrank_step/kernel.py")\
        .read_text().replace("grid=(nb,)", "grid=(nb, 2)")
    fs = kernel_audit.audit_source(src, "<mutated>")
    assert any(f.rule == "audit-grid-rank-mismatch" for f in fs)


def test_lint_wallclock_allowlist_is_tight():
    """campaign.py keeps exactly one blessed wall-clock reader."""
    src = (REPO / "src/repro/core/campaign.py").read_text()
    assert src.count("time.perf_counter()") == 1
    assert "# lint: allow-wallclock" in src
    # stripping the marker re-triggers the lint
    stripped = src.replace("# lint: allow-wallclock", "")
    fs = lints.lint_source(stripped, "<campaign>")
    assert any(f.rule == "wallclock" for f in fs)
