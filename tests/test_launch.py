"""Launch layer: cell specs, sharding sanitisation, HLO analysis, and a
1-device lowering smoke test."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config, list_archs
from repro.launch import steps
from repro.launch.hlo_analysis import analyze_hlo

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def test_shapes_table():
    assert set(steps.SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"}
    assert steps.SHAPES["long_500k"]["seq"] == 524288
    assert steps.SHAPES["train_4k"]["batch"] == 256


def test_skip_rules():
    skipped = {a for a in list_archs()
               if steps.skip_reason(get_config(a), "long_500k")}
    assert skipped == {"qwen2-vl-2b", "whisper-large-v3", "yi-34b",
                       "smollm-135m", "stablelm-1.6b", "dbrx-132b"}
    for a in list_archs():
        for sh in ("train_4k", "prefill_32k", "decode_32k"):
            assert steps.skip_reason(get_config(a), sh) is None


@pytest.mark.parametrize("arch", list_archs())
def test_input_specs_all_cells(arch):
    cfg = get_config(arch)
    for shape in steps.SHAPES:
        if steps.skip_reason(cfg, shape):
            continue
        plan = steps.default_plan(cfg, shape)
        specs = steps.input_specs(cfg, shape, plan)
        assert "tokens" in specs
        sh = steps.SHAPES[shape]
        if sh["kind"] == "decode":
            assert specs["tokens"].shape == (sh["batch"], 1)
            assert "cache" in specs
        else:
            assert specs["tokens"].shape == (sh["batch"], sh["seq"])


def test_sanitize_drops_indivisible_axes():
    from repro.models.sharding import sanitize
    mesh = jax.make_mesh((1,), ("model",))

    class FakeMesh:
        shape = {"model": 16, "data": 16}
    spec = sanitize(P("model", None), (50280, 2048), FakeMesh())
    assert spec == P(None, None)
    spec = sanitize(P("model", "data"), (64000, 4096), FakeMesh())
    assert spec == P("model", "data")


def test_hlo_analysis_counts_scan_trips():
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((9, 128, 128), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    r = analyze_hlo(compiled.as_text())
    expect = 2 * 128 ** 3 * 9
    assert abs(r["flops"] - expect) / expect < 0.01


def test_lowering_smoke_single_device():
    """A reduced config lowers + compiles on the 1-device debug mesh with
    the same build_cell machinery the 512-chip dry-run uses."""
    import dataclasses
    from repro.launch.mesh import make_debug_mesh
    cfg = get_config("smollm-135m", smoke=True)
    mesh = make_debug_mesh(1, 1)
    plan = steps.CellPlan(grad_accum=1, remat=False,
                          param_dtype=jnp.float32)
    # shrink the shape table for the smoke lowering
    orig = steps.SHAPES["train_4k"]
    steps.SHAPES["train_4k"] = dict(kind="train", seq=32, batch=4)
    try:
        fn, args, in_sh, out_sh = steps.build_cell(cfg, "train_4k", mesh,
                                                   plan)
        with mesh:
            compiled = jax.jit(fn, in_shardings=in_sh,
                               out_shardings=out_sh).lower(*args).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):       # older jax: one per device
            ca = ca[0]
        assert ca["flops"] > 0
    finally:
        steps.SHAPES["train_4k"] = orig


def test_production_mesh_shapes():
    """Mesh construction logic (validated against the real 512-device
    config by the dry-run; here we only check shape arithmetic)."""
    import repro.launch.mesh as M
    import inspect
    src = inspect.getsource(M.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src


def test_dryrun_results_complete():
    """The committed dry-run sweep covers every (arch × shape × mesh) cell
    with ok or a documented skip."""
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "..",
                        "dryrun_results.json")
    if not os.path.exists(path):
        pytest.skip("dry-run results not generated yet")
    data = json.load(open(path))
    for arch in list_archs():
        for shape in steps.SHAPES:
            for mesh in ("single", "multi"):
                key = f"{arch}|{shape}|{mesh}"
                assert key in data, f"missing cell {key}"
                assert data[key]["status"] in ("ok", "skipped"), key
                if data[key]["status"] == "skipped":
                    assert steps.skip_reason(get_config(arch), shape)
