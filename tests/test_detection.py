"""Routing, EM link inference and detector unit behaviour."""

import numpy as np
import pytest
from proptest import given, settings, st

from repro.core.detection import em_link_inverse_bw, gamma_sf
from repro.core.routing import Mesh2D

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 8), st.integers(2, 8), st.data())
def test_xy_route_properties(w, h, data):
    mesh = Mesh2D(w, h)
    src = data.draw(st.integers(0, mesh.n_cores - 1))
    dst = data.draw(st.integers(0, mesh.n_cores - 1))
    path = mesh.route(src, dst)
    # length = manhattan distance
    assert len(path) == mesh.hops(src, dst)
    # path is connected src → dst over adjacent links
    cur = src
    for lid in path:
        u, v = mesh.links[lid]
        assert u == cur
        cur = v
    assert cur == dst
    # deterministic
    assert path == mesh.route(src, dst)


def test_link_ids_bijective():
    mesh = Mesh2D(4)
    assert mesh.n_links == 2 * (2 * 4 * 3)   # 2 directions × edges
    seen = set()
    for lid, (u, v) in enumerate(mesh.links):
        assert mesh.link_id(u, v) == lid
        assert (u, v) not in seen
        seen.add((u, v))


def test_em_recovers_slow_link():
    """Synthetic tomography: events over known paths with one slow link."""
    mesh = Mesh2D(4)
    rng = np.random.default_rng(0)
    theta_true = np.full(mesh.n_links, 1e-9)
    slow = 20
    theta_true[slow] = 1e-8
    pairs = [(int(rng.integers(16)), int(rng.integers(16)))
             for _ in range(400)]
    pairs = [p for p in pairs if p[0] != p[1]]
    A = mesh.path_matrix(pairs)
    V = rng.uniform(1e3, 1e5, len(pairs))
    T = (A * V[:, None]) @ theta_true
    T *= rng.gamma(64, 1 / 64, len(T))       # mild noise
    th = em_link_inverse_bw(A, T, V, np.ones(len(T)))
    seen = A.sum(axis=0) > 0
    assert seen[slow]
    ranked = np.argsort(-np.where(seen, th, 0))
    assert ranked[0] == slow
    assert th[slow] > 4 * np.median(th[seen])


def test_gamma_sf_properties():
    assert gamma_sf(0.0, 2.0, 1.0) == pytest.approx(1.0)
    assert gamma_sf(1e9, 2.0, 1.0) == pytest.approx(0.0, abs=1e-9)
    # monotone decreasing
    vals = [gamma_sf(x, 3.0, 0.5) for x in (0.1, 0.5, 1.0, 3.0, 10.0)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))
    # median of Gamma(1,1) ≈ ln 2
    assert gamma_sf(np.log(2), 1.0, 1.0) == pytest.approx(0.5, abs=1e-6)
