"""End-to-end SLOTH behaviour: localisation accuracy, FPR, compression,
probe overhead, baselines, and the pod-level telemetry detector."""

import numpy as np
import pytest

from repro.core import baselines as B
from repro.core.compiler import plan_for_mode, plan_probes
from repro.core.failures import FailSlow, effective_samples, make_dataset
from repro.core.graph import build_workload
from repro.core.routing import Mesh2D
from repro.core.simulator import simulate
from repro.core.sloth import Sloth

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


@pytest.fixture(scope="module")
def sloth():
    return Sloth(build_workload("resnet50"), Mesh2D(4))


def test_core_failure_localised(sloth):
    v = sloth.detect([FailSlow("core", 6, 1.0, 8.0)], seed=1)
    assert v.flagged and v.kind == "core" and v.location == 6


def test_link_failure_localised(sloth):
    v = sloth.detect([FailSlow("link", 20, 1.0, 8.0)], seed=1)
    assert v.flagged and v.kind == "link"
    # exact link or within top-3 of the ranking
    top = [(k, l) for k, l, _ in v.ranking[:3]]
    assert v.location == 20 or ("link", 20) in top


def test_healthy_not_flagged(sloth):
    flagged = sum(sloth.detect(None, seed=s).flagged for s in range(5))
    assert flagged <= 1          # FPR well under 50% on this small sample


def test_accuracy_beats_50pct(sloth):
    healthy = sloth.run(None, seed=999)
    used = set()
    for s, d in zip(healthy.comm["src"], healthy.comm["dst"]):
        if s != d:
            used.update(sloth.mesh.route(int(s), int(d)))
    ds = effective_samples(make_dataset(sloth.mesh, 10, seed=3),
                           healthy.total_time, used)
    pos = [s for s in ds if s.failure is not None]
    ok = sum(sloth.detect([s.failure], seed=100 + s.sample_id)
             .matches(s.failure) for s in pos)
    assert ok / len(pos) > 0.5


def test_compression_ratio(sloth):
    v = sloth.detect([FailSlow("core", 3, 1.0, 5.0)], seed=0)
    assert v.recorder.compression_ratio > 20


def test_probe_overhead_small(sloth):
    import dataclasses as dc
    cfg = dc.replace(sloth.sim_cfg, seed=0)
    t_none = simulate(sloth.mapped, cfg, probes=None).total_time
    t_full = simulate(sloth.mapped, cfg,
                      probes=plan_for_mode("full")).total_time
    assert (t_full / t_none - 1) < 0.10        # ≤10% (paper Fig 10)


def test_probe_plan_structure(sloth):
    plan = plan_probes(sloth.graph, sloth.mapped)
    assert "conv" in plan.exec_ops             # compute-heavy ops probed
    assert len(plan.specs) >= 2                # Exec + Route probes
    assert plan.route_stages                   # data movement covered


def test_baselines_run(sloth):
    from repro.core.detectors import get_detector
    profile = sloth.run(None, seed=12345)
    sim = sloth.run([FailSlow("core", 5, 1.0, 8.0)], seed=1)
    flags = {}
    for name in B.BASELINE_NAMES:
        det = get_detector(name)().prepare(sloth.graph, sloth.mesh, profile)
        v = det.analyse(sim)
        assert v.detector == name and v.mesh is sloth.mesh
        if v.flagged:                # ranking is led by the top-1 verdict
            assert v.ranking
            assert v.ranking[0][:2] == (v.kind, v.location)
        flags[name] = (v.flagged, v.kind, v.location)
    # the stronger baselines find the core failure
    assert flags["thres"][0] and flags["perseus"][0]
    assert flags["perseus"][1:] == ("core", 5)


def test_pod_telemetry_detects_straggler():
    from repro.distributed.telemetry import (PodDetector, PodSimulator,
                                             PodTelemetryConfig)
    cfg = PodTelemetryConfig(mesh_w=4, mesh_h=4)
    pod = PodSimulator(cfg, step_flops=5e12, collective_bytes=1e9, seed=0)
    det = PodDetector(cfg)
    healthy = pod.run_steps(48)
    assert not det.analyse(healthy).flagged
    pod.inject(FailSlow("core", 9, 0.0, 1e9, 4.0))
    v = det.analyse(pod.run_steps(48))
    assert v.flagged and v.kind == "core" and v.location == 9
    assert v.action in ("rebalance", "exclude_and_restart")
