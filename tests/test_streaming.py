"""Streaming detection: chunked-vs-one-shot recorder parity, streamed
verdict ≡ post-hoc verdict, detection latency, the campaign streaming
axis, pod-telemetry regressions and the serving engine's split timing
series."""

import dataclasses
import math

import numpy as np
import pytest

from repro.core.campaign import CampaignGrid, run_campaign
from repro.core.failures import FailSlow
from repro.core.graph import build_workload
from repro.core.metrics import (DetectorOutcome, ScenarioOutcome,
                                detection_latency_stats)
from repro.core.recorder import record
from repro.core.routing import Mesh2D
from repro.core.sloth import Sloth, SlothConfig
from repro.core.streaming import StreamingRecorder, split_sim
from repro.distributed.telemetry import (PodDetector, PodSimulator,
                                         PodTelemetryConfig, StepTelemetry)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

ONSET = 1.0    # injected failure onset used by the module deployment


@pytest.fixture(scope="module")
def deployment():
    sloth = Sloth(build_workload("darknet19"), Mesh2D(4))
    sim = sloth.run([FailSlow("core", 5, ONSET, 8.0, 10.0)], seed=0)
    return sloth, sim


# ---------------------------------------------------------------------------
# split_sim: chunk concatenation must reproduce the exact record order
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_chunks", (1, 5, 64))
def test_split_sim_preserves_record_order(deployment, n_chunks):
    """The sketch is order-sensitive, so chunking must be a pure
    partition of the original row order (64 chunks forces empty ones)."""
    _, sim = deployment
    chunks = split_sim(sim, n_chunks)
    assert len(chunks) == n_chunks
    for side in ("comp", "comm"):
        orig = getattr(sim, side)
        for k, v in orig.items():
            cat = np.concatenate(
                [np.asarray(getattr(c, side)[k]) for c in chunks])
            np.testing.assert_array_equal(cat, np.asarray(v))
    clocks = [c.total_time for c in chunks]
    assert clocks == sorted(clocks)          # stream clock is monotone
    assert clocks[-1] <= sim.total_time + 1e-9


# ---------------------------------------------------------------------------
# StreamingRecorder ≡ one-shot record, per impl
# ---------------------------------------------------------------------------

def _stream_over(sim, params, hop, impl, n_chunks):
    sr = StreamingRecorder(params, hop_latency=hop, impl=impl)
    for c in split_sim(sim, n_chunks):
        sr.observe(c)
    return sr.output()


@pytest.mark.parametrize("impl", ("ref", "batched"))
def test_streaming_recorder_matches_one_shot(deployment, impl):
    """Same impl, any chunking → bit-identical patterns and accounting
    (the chunks feed the identical record sequence through the identical
    sketch, and partial-pattern merging is associative)."""
    sloth, sim = deployment
    hop = sloth.sim_cfg.hop_latency
    one = record(sim, sloth.cfg.sketch, hop_latency=hop, impl=impl)
    out = _stream_over(sim, sloth.cfg.sketch, hop, impl, 5)
    assert out.comp_patterns == one.comp_patterns
    assert out.comm_patterns == one.comm_patterns
    assert (out.n_comp_records, out.n_comm_records) \
        == (one.n_comp_records, one.n_comm_records)
    assert (out.n_comp_drained, out.n_comm_drained) \
        == (one.n_comp_drained, one.n_comm_drained)
    assert (out.sketch_comp_bytes, out.sketch_comm_bytes) \
        == (one.sketch_comp_bytes, one.sketch_comm_bytes)
    assert (out.raw_comp_bytes, out.raw_comm_bytes) \
        == (one.raw_comp_bytes, one.raw_comm_bytes)
    assert out.compression_ratio == one.compression_ratio


@pytest.mark.parametrize("impl", ("ref", "batched"))
def test_streaming_recorder_parity_under_eviction(deployment, impl):
    """Tiny Stage-2 (L=8 ≪ distinct patterns): the per-chunk drained
    partials must fold into exactly the one-shot eviction stream."""
    from repro.core.sketch import SketchParams
    sloth, sim = deployment
    hop = sloth.sim_cfg.hop_latency
    p = SketchParams(d=2, m=256, H=4, L=8)
    one = record(sim, p, hop_latency=hop, impl=impl)
    out = _stream_over(sim, p, hop, impl, 7)
    assert one.n_comp_drained > 0 and one.n_comm_drained > 0
    assert out.comp_patterns == one.comp_patterns
    assert out.comm_patterns == one.comm_patterns
    assert (out.n_comp_drained, out.n_comm_drained) \
        == (one.n_comp_drained, one.n_comm_drained)
    assert out.compression_ratio == one.compression_ratio


def test_streaming_recorder_unknown_impl_rejected(deployment):
    sloth, _ = deployment
    with pytest.raises(ValueError, match="unknown recorder impl"):
        StreamingRecorder(sloth.cfg.sketch, impl="vectorised")


# ---------------------------------------------------------------------------
# SlothStream: streamed final verdict ≡ post-hoc analyse
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ("ref", "batched"))
def test_stream_analyse_matches_post_hoc(deployment, impl):
    sloth, sim = deployment
    s = sloth if impl == "ref" else Sloth(
        sloth.graph, sloth.mesh, cfg=SlothConfig(recorder_impl=impl))
    post = s.analyse(sim)
    v, first_flag = s.stream_analyse(sim, n_chunks=5)
    assert (v.flagged, v.kind, v.location) \
        == (post.flagged, post.kind, post.location)
    assert v.ranking == post.ranking         # scores included: bit-equal
    assert v.recorder.compression_ratio \
        == post.recorder.compression_ratio
    assert post.flagged and first_flag is not None


def test_stream_detection_latency_known_onset(deployment):
    """A decisive failure injected at a known onset must be flagged
    mid-stream, and the latency must be the first flagged chunk's
    stream clock minus that onset."""
    sloth, sim = deployment
    st = sloth.stream()
    chunks = split_sim(sim, 6)
    flag_clock = None
    for i, c in enumerate(chunks):
        horizon = sim.total_time if i == len(chunks) - 1 else None
        v = st.observe(c, total_time=horizon)
        if v.flagged and flag_clock is None:
            flag_clock = sim.total_time if horizon is not None \
                else st.recorder.elapsed
    assert st.first_flag_time == flag_clock
    lat = st.detection_latency(ONSET)
    assert math.isfinite(lat) and lat > 0.0
    assert lat == st.first_flag_time - ONSET
    # flagged before the trace ended: streaming beats post-hoc to the
    # verdict by a nonzero margin
    assert st.first_flag_time < sim.total_time


def test_stream_detection_latency_inf_when_healthy(deployment):
    sloth, _ = deployment
    healthy = sloth.run([], seed=3)
    st = sloth.stream()
    for c in split_sim(healthy, 4):
        st.observe(c)
    assert st.first_flag_time is None
    assert st.detection_latency(0.0) == math.inf
    assert not any(v.flagged for v in st.verdicts)


# ---------------------------------------------------------------------------
# campaign streaming axis
# ---------------------------------------------------------------------------

def test_campaign_streaming_axis():
    """streaming=N must leave every judged field identical to the
    post-hoc campaign and attach latencies with the documented
    semantics: None on negatives, finite iff flagged on positives."""
    grid = CampaignGrid(workloads=("darknet19",), meshes=(4,),
                        kinds=("core", "none"), severities=(10.0,),
                        n_failures=(1,), reps=1, campaign_seed=0)
    res_s = run_campaign(grid, streaming=3)
    res_p = run_campaign(grid)
    judged = lambda d: (d.detector, d.flagged, d.pred_kind,  # noqa: E731
                        d.pred_location, d.matched, d.truth_rank,
                        d.truth_ranks)
    for s, p in zip(res_s.outcomes, res_p.outcomes):
        for ds, dp in zip(s.detector_results, p.detector_results):
            assert judged(ds) == judged(dp)
            assert dp.detection_latency is None     # post-hoc: no latency
            if s.kind == "none":
                assert ds.detection_latency is None
            else:
                assert ds.detection_latency is not None
                assert math.isfinite(ds.detection_latency) == ds.flagged
    assert res_s.metrics.detection is not None
    assert res_p.metrics.detection is None
    assert "detection latency" in res_s.summary()
    assert "detection latency" not in res_p.summary()


def test_campaign_streaming_validation():
    grid = CampaignGrid(workloads=("darknet19",), meshes=(4,),
                        kinds=("core",), severities=(10.0,),
                        n_failures=(1,), reps=1, campaign_seed=0)
    with pytest.raises(ValueError, match="streaming"):
        run_campaign(grid, streaming=-2)


def test_detection_latency_stats_reduction():
    """Unit-level reduction semantics: negatives and non-streamed
    outcomes are excluded, inf counts as streamed-but-missed, and the
    mean/p95 summarise only the finite latencies."""
    def scen(i, kind, lat, flagged):
        d = DetectorOutcome(
            detector="sloth", flagged=flagged,
            pred_kind="core" if flagged else None,
            pred_location=0 if flagged else None, score=1.0,
            matched=flagged, truth_rank=1 if flagged else None,
            detection_latency=lat)
        return ScenarioOutcome(
            scenario_id=i, workload="w", mesh_w=4, mesh_h=4, kind=kind,
            severity=0.0 if kind == "none" else 10.0,
            n_failures=0 if kind == "none" else 1, rep=0, sim_seed=0,
            truth_locations=(), truth_t0s=(), truth_durations=(),
            detector_results=(d,), compression_ratio=1.0,
            total_time=1.0, probe_overhead=0.0)

    outs = [scen(0, "core", 2.0, True), scen(1, "core", math.inf, False),
            scen(2, "none", None, False), scen(3, "core", 4.0, True)]
    st = detection_latency_stats(outs)
    assert (st.n_measured, st.n_detected) == (3, 2)
    assert st.mean == pytest.approx(3.0)
    assert 2.0 <= st.p95 <= 4.0
    # a campaign that never streamed reports no latency block at all
    assert detection_latency_stats([scen(0, "core", None, True)]) is None


# ---------------------------------------------------------------------------
# pod telemetry: step-gap regression, impl plumbing, live windows
# ---------------------------------------------------------------------------

def test_pod_step_gap_uses_slowest_link():
    """Regression: the all-reduce barrier used to wait only on the *last
    enumerated* link (``max`` over a one-element list), so a slow
    non-last link never delayed the next step."""
    cfg = PodTelemetryConfig(mesh_w=2, mesh_h=2, window_steps=4)
    pod = PodSimulator(cfg, step_flops=1e12, collective_bytes=1e8, seed=0)
    assert pod.mesh.n_links > 1
    pod.inject(FailSlow("link", 0, 0.0, 1e9, 100.0))   # NOT the last link
    sim = pod.run_steps(2)
    nl, nc = pod.mesh.n_links, pod.mesh.n_cores
    arrive0 = float(np.max(np.asarray(sim.comm["t_arrive"])[:nl]))
    start1 = float(np.min(np.asarray(sim.comp["t_start"])[nc:]))
    assert start1 >= arrive0 - 1e-12


def test_pod_detector_recorder_impl_plumbing():
    """PodTelemetryConfig.recorder_impl reaches the recorder (the pod
    detector used to hard-code impl='ref') and both impls agree."""
    cfg_r = PodTelemetryConfig(mesh_w=4, mesh_h=4, window_steps=16)
    cfg_b = dataclasses.replace(cfg_r, recorder_impl="batched")
    pod = PodSimulator(cfg_r, step_flops=5e12, collective_bytes=4e9,
                       seed=1)
    pod.inject(FailSlow("core", 5, 0.0, 1e9, 10.0))
    sim = pod.run_steps(16)
    va = PodDetector(cfg_r).analyse(sim)
    vb = PodDetector(cfg_b).analyse(sim)
    assert va.flagged and (va.kind, va.location) == ("core", 5)
    assert (va.flagged, va.kind, va.location) \
        == (vb.flagged, vb.kind, vb.location)


def test_pod_detector_observe_streams_windows():
    """observe() holds sketch state across windows: streaming the trace
    window-by-window reaches the same localisation as post-hoc
    analyse(), and the failure is flagged before the last window."""
    cfg = PodTelemetryConfig(mesh_w=4, mesh_h=4, window_steps=8)
    pod = PodSimulator(cfg, step_flops=5e12, collective_bytes=4e9,
                       seed=1)
    pod.inject(FailSlow("core", 5, 0.0, 1e9, 10.0))
    sim = pod.run_steps(24)
    post = PodDetector(cfg).analyse(sim)
    det = PodDetector(cfg)
    verdicts = [det.observe(c) for c in split_sim(sim, 3)]
    assert (verdicts[-1].flagged, verdicts[-1].kind,
            verdicts[-1].location) == (post.flagged, post.kind,
                                       post.location) == (True, "core", 5)
    assert verdicts[0].flagged          # detected in the first window


def test_step_telemetry_flags_injected_slow_host():
    """The live bridge: measured step times with a 10× slow burst must
    flag the local host (chip 0) and stay core-localised."""
    telem = StepTelemetry(seed=0)
    rng = np.random.default_rng(0)
    for step in range(25):
        dt = 0.05 * (1 + 0.01 * abs(rng.standard_normal()))
        if 10 <= step < 18:
            dt *= 10.0
        telem.record_step(dt)
    telem.flush()
    assert telem.flagged
    flagged = [v for v in telem.verdicts if v.flagged]
    assert all((v.kind, v.location) == ("core", 0) for v in flagged)
    assert telem.plans[-1]["action"] != "none" or not \
        telem.verdicts[-1].flagged


def test_step_telemetry_clean_loop_stays_silent():
    telem = StepTelemetry(seed=0)
    rng = np.random.default_rng(1)
    for _ in range(25):
        telem.record_step(0.05 * (1 + 0.01 * abs(rng.standard_normal())))
    telem.flush()
    assert telem.verdicts and not telem.flagged
    assert all(p["action"] == "none" for p in telem.plans)


def test_step_telemetry_warmup_discards_compile_step():
    """The first (jit-compile) step is orders slower than steady state;
    warmup must keep it out of both the baseline and the windows."""
    telem = StepTelemetry(warmup=1, seed=0)
    telem.record_step(30.0)              # compile step
    for _ in range(telem.cfg.window_steps):
        telem.record_step(0.05)
    assert telem.verdicts and not telem.flagged


# ---------------------------------------------------------------------------
# serving engine: split prefill/decode series + step hook
# ---------------------------------------------------------------------------

def test_engine_split_timing_series():
    """Regression: p50/p99 'decode' percentiles were computed over the
    interleaved step_times with only index 0 dropped, so every later
    batch's prefill polluted the decode distribution."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config
    from repro.models import transformer as T
    from repro.serving.engine import EngineConfig, Request, ServeEngine

    cfg = get_config("smollm-135m", smoke=True)
    params = T.init_model(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    events = []
    engine = ServeEngine(
        cfg, params, EngineConfig(batch=2, cache_len=32),
        step_hook=lambda kind, dt: events.append((kind, dt)))
    rng = np.random.default_rng(0)
    for i in range(3):                   # 2 batches at batch=2
        engine.submit(Request(i, rng.integers(0, cfg.vocab, size=4)
                              .astype(np.int32), max_new=3))
    done = engine.run()
    assert len(done) == 3
    assert len(engine.prefill_times) == 2
    assert len(engine.decode_times) == 2 * 3
    assert len(engine.step_times) \
        == len(engine.prefill_times) + len(engine.decode_times)
    assert [k for k, _ in events] \
        == ["prefill"] + ["decode"] * 3 + ["prefill"] + ["decode"] * 3
    assert engine.decode_times == [dt for k, dt in events if k == "decode"]
    assert engine.prefill_times \
        == [dt for k, dt in events if k == "prefill"]
