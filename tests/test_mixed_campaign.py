"""Mixed-kind multi-failure campaigns and near-threshold severity sweeps:
grid spellings ('mixed', explicit kind tuples, linspace severity specs),
the severity-bit RNG keying regression, heterogeneous judging, per-truth-
kind metric splits, multi-entry baseline rankings, the severity_curve()
readout, make_dataset's router_ratio, and executor equivalence for the
combined grid."""

import dataclasses

import pytest

from repro.core.campaign import (FAILURE_KINDS, CampaignGrid,
                                 DeploymentCache, enumerate_scenarios,
                                 materialise, run_campaign)
from repro.core.detectors import Verdict, prepare_detector
from repro.core.failures import FailSlow, judge_verdict, make_dataset
from repro.core.graph import build_workload
from repro.core.metrics import (DetectorOutcome, ScenarioOutcome,
                                by_truth_kind, severity_curve)
from repro.core.routing import Mesh2D
from repro.core.simulator import simulate
from repro.core.sloth import Sloth

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

MIXED_GRID = CampaignGrid(workloads=("darknet19",), meshes=(4,),
                          kinds=("mixed", "core+link", "none"),
                          severities=(2.0, 2.0001, 10.0),
                          n_failures=(2,), reps=1, campaign_seed=41)


@pytest.fixture(scope="module")
def cache():
    c = DeploymentCache()
    c.get("darknet19", 4, 4)
    return c


@pytest.fixture(scope="module")
def mixed_serial(cache):
    return run_campaign(MIXED_GRID, workers=0, cache=cache)


# ---------------------------------------------------------------------------
# grid spellings: mixed / composite kinds, linspace severities
# ---------------------------------------------------------------------------

def test_kind_normalisation():
    g = CampaignGrid(kinds=("mixed", ("link", "core"), "router+core",
                            "none"))
    assert g.kinds == ("mixed", "core+link", "core+router", "none")
    with pytest.raises(ValueError, match="unknown failure kind"):
        CampaignGrid(kinds=("gremlin",))
    with pytest.raises(ValueError, match="composite"):
        CampaignGrid(kinds=(("core", "none"),))
    with pytest.raises(ValueError, match="composite"):
        CampaignGrid(kinds=("core+gremlin",))
    # a 1-tuple cannot honour the pin-to-length contract once normalised
    # to the plain kind string — rejected with the unambiguous spellings
    with pytest.raises(ValueError, match="ambiguous"):
        CampaignGrid(kinds=(("core",),))


def test_workload_key_folds_full_name():
    """Regression: the workload RNG key used to fold only the first 8
    name bytes, so workloads sharing an 8-byte prefix ('resnet50_v1' vs
    'resnet50_v2') reused one scenario stream."""
    from repro.core.campaign import Scenario, _scenario_rng
    g = CampaignGrid(workloads=("resnet50_v1", "resnet50_v2"),
                     kinds=("core",), severities=(8.0,))
    s1 = Scenario(0, "resnet50_v1", 4, 4, "core", 8.0, 1, 0)
    s2 = Scenario(1, "resnet50_v2", 4, 4, "core", 8.0, 1, 0)
    draws1 = _scenario_rng(g, s1).integers(1 << 31, size=4)
    draws2 = _scenario_rng(g, s2).integers(1 << 31, size=4)
    assert list(draws1) != list(draws2)


def test_long_composite_kinds_key_distinct_streams(cache):
    """Regression: _kind_key used to fold only the first 8 name bytes, so
    'core+link+link' and 'core+link+router' (same prefix, same pinned
    n_failures=3) collided onto one RNG stream and drew correlated
    failure sites."""
    from repro.core.campaign import _kind_key
    assert _kind_key("core+link+link") != _kind_key("core+link+router")
    dep = cache.get("darknet19", 4, 4)
    a = CampaignGrid(workloads=("darknet19",),
                     kinds=(("core", "link", "link"),),
                     severities=(8.0,), campaign_seed=0)
    b = dataclasses.replace(a, kinds=(("core", "link", "router"),))
    fa, seed_a = materialise(a, enumerate_scenarios(a)[0], dep)
    fb, seed_b = materialise(b, enumerate_scenarios(b)[0], dep)
    assert seed_a != seed_b
    assert [(f.kind, f.location, f.t0) for f in fa] \
        != [(f.kind, f.location, f.t0) for f in fb]


def test_kind_alias_spellings_deduplicate():
    """'core+link' and ('link', 'core') normalise to one entry — alias
    duplicates would enumerate bit-identical scenarios twice on one RNG
    stream and double-count every metric."""
    g = CampaignGrid(kinds=("core+link", ("link", "core"), "mixed",
                            "mixed"))
    assert g.kinds == ("core+link", "mixed")


def test_composite_kind_pins_n_failures():
    g = CampaignGrid(workloads=("darknet19",),
                     kinds=("mixed", ("core", "link", "link"), "none"),
                     severities=(8.0,), n_failures=(1, 2), reps=2)
    scen = enumerate_scenarios(g)
    assert len(scen) == g.n_scenarios()
    # mixed sweeps the n_failures axis (1 sev × 2 k), the 3-tuple pins
    # k=3 (1 × 1), none collapses both axes (1)
    assert len(scen) == (1 * 2 + 1 * 1 + 1) * 2
    assert {s.n_failures for s in scen if s.kind == "core+link+link"} \
        == {3}
    assert {s.n_failures for s in scen if s.kind == "mixed"} == {1, 2}


def test_severity_linspace_specs():
    g = CampaignGrid(severities=(1.5, "linspace:2:3:3",
                                 ("linspace", 8.0, 10.0, 2)))
    assert g.severities == (1.5, 2.0, 2.5, 3.0, 8.0, 10.0)
    # exact duplicates collapse (first occurrence wins): duplicate cells
    # would share one RNG stream and double-count identical outcomes
    dup = CampaignGrid(severities=("linspace:1:3:3", 2.0, 1.0))
    assert dup.severities == (1.0, 2.0, 3.0)
    # a bare spec passed as the whole severities value works too
    bare = CampaignGrid(severities=("linspace", 2.0, 3.0, 3))
    assert bare.severities == (2.0, 2.5, 3.0)
    with pytest.raises(ValueError, match="severity spec"):
        CampaignGrid(severities=("linspace:1:2",))
    with pytest.raises(ValueError, match="severity spec"):
        CampaignGrid(severities=("linspace:1:2:0",))
    # malformed tuple specs get the guidance too, not a raw TypeError
    with pytest.raises(ValueError, match="severity spec"):
        CampaignGrid(severities=(("linspace", 1.0, 3.0),))
    # a nested all-numeric tuple is a per-failure severity mix, not a
    # malformed spec (see test_mitigate.py for the mix semantics)
    mix = CampaignGrid(severities=((1.0, 3.0),))
    assert mix.severities == ((1.0, 3.0),)
    with pytest.raises(ValueError, match="positive"):
        CampaignGrid(severities=(0.0,))


def test_boolean_detectors_maps_to_baselines_shim(cache):
    """A legacy positional baselines flag landing on the detectors
    parameter follows the deprecation shim instead of crashing with
    \"'bool' object is not iterable\"."""
    with pytest.warns(DeprecationWarning, match="baselines"):
        dep = cache.get("darknet19", 4, 4, None, True)
    assert len(dep.detectors) == 6        # DEFAULT_DETECTORS prepared
    with pytest.warns(DeprecationWarning, match="baselines"):
        dep = cache.get("darknet19", 4, 4, None, False)
    assert tuple(d.name for d in dep.detectors) == ("sloth",)


# ---------------------------------------------------------------------------
# RNG keying: the severity-collision bugfix
# ---------------------------------------------------------------------------

def test_near_threshold_severities_draw_distinct_sites(cache):
    """Regression: scenario RNG used to key on int(severity * 1000), so
    severities closer than 1e-3 collided into identical location/onset/
    duration draws — exactly the near-threshold sweep case.  Keying on
    the float's bit pattern separates severities 1e-4 apart while the
    same severity stays bit-for-bit reproducible."""
    dep = cache.get("darknet19", 4, 4)
    base = CampaignGrid(workloads=("darknet19",), kinds=("core",),
                        severities=(2.0,), n_failures=(2,),
                        campaign_seed=0)
    near = dataclasses.replace(base, severities=(2.0001,))
    sa = enumerate_scenarios(base)[0]
    sb = enumerate_scenarios(near)[0]
    fa, seed_a = materialise(base, sa, dep)
    fb, seed_b = materialise(near, sb, dep)
    assert seed_a != seed_b
    assert [f.location for f in fa] != [f.location for f in fb] \
        or [f.t0 for f in fa] != [f.t0 for f in fb]
    # identical severity reproduces identical draws
    fa2, seed_a2 = materialise(base, sa, dep)
    assert fa2 == fa and seed_a2 == seed_a


def test_mixed_scenarios_in_grid_key_distinct_streams(mixed_serial):
    """The three severities of the mixed grid (two of them 1e-4 apart)
    materialise different failure sets."""
    by_sev = {}
    for o in mixed_serial.outcomes:
        if o.kind == "mixed":
            by_sev[o.severity] = (o.truth_kinds, o.truth_locations,
                                  o.sim_seed)
    assert len(by_sev) == 3
    assert len({v for v in by_sev.values()}) == 3


# ---------------------------------------------------------------------------
# materialisation: heterogeneous sites
# ---------------------------------------------------------------------------

def test_mixed_materialise_distinct_heterogeneous_sites(cache):
    dep = cache.get("darknet19", 4, 4)
    g = dataclasses.replace(MIXED_GRID, kinds=("mixed",),
                            n_failures=(4,), reps=3)
    seen_kinds = set()
    for s in enumerate_scenarios(g):
        failures, _ = materialise(g, s, dep)
        assert len(failures) == 4
        sites = [(f.kind, f.location) for f in failures]
        assert len(set(sites)) == len(sites)        # distinct sites
        for f in failures:
            assert f.kind in FAILURE_KINDS
            assert f.slowdown == s.severity
            if f.kind == "link":
                assert f.location in dep.used_links
            elif f.kind == "router":
                assert f.location in dep.used_routers
        seen_kinds.update(f.kind for f in failures)
    # across the grid the union population surfaces >1 kind
    assert len(seen_kinds) > 1


def test_composite_materialise_one_failure_per_component(cache):
    dep = cache.get("darknet19", 4, 4)
    g = dataclasses.replace(MIXED_GRID, kinds=(("router", "core", "link"),))
    for s in enumerate_scenarios(g):
        failures, _ = materialise(g, s, dep)
        assert sorted(f.kind for f in failures) == ["core", "link",
                                                    "router"]


def test_mixed_materialise_rejects_oversized_k(cache):
    dep = cache.get("darknet19", 4, 4)
    g = dataclasses.replace(MIXED_GRID, kinds=("mixed",),
                            n_failures=(10_000,))
    s = next(s for s in enumerate_scenarios(g) if s.kind == "mixed")
    with pytest.raises(ValueError, match="cannot place"):
        materialise(g, s, dep)


def test_composite_materialise_rejects_unusable_component(cache):
    dep = dataclasses.replace(cache.get("darknet19", 4, 4),
                              used_links=(), used_routers=())
    g = dataclasses.replace(MIXED_GRID, kinds=("core+link",))
    s = enumerate_scenarios(g)[0]
    with pytest.raises(ValueError, match="no used links"):
        materialise(g, s, dep)


# ---------------------------------------------------------------------------
# judging: heterogeneous truth sets vs multi-entry rankings
# ---------------------------------------------------------------------------

def test_judge_verdict_mixed_truth_set():
    """A core+link+router truth set judged against one multi-entry
    ranking: per-kind ranks, any-match accuracy and the router-candidate
    union all follow the shared rule."""
    mesh = Mesh2D(4)
    router = 5
    rlink = mesh.links_of_router(router)[0]
    truths = (FailSlow("core", 3, 0.0, 1.0, 8.0),
              FailSlow("link", 20, 0.0, 1.0, 8.0),
              FailSlow("router", router, 0.0, 1.0, 8.0))
    v = Verdict(flagged=True, kind="link", location=rlink, score=3.0,
                ranking=[("link", rlink, 3.0), ("core", 3, 2.0),
                         ("link", 40, 1.5), ("link", 20, 1.2)],
                mesh=mesh)
    matched, best, ranks, union = judge_verdict(v, truths, mesh)
    assert matched                       # top-1 names the router's link
    assert ranks == (2, 4, 1) and best == 1
    # the candidate union is router-aware: all of the slowed router's
    # links are acceptable, plus the exact core and link truths
    assert ("core", 3) in union and ("link", 20) in union
    assert {("link", lid) for lid in mesh.links_of_router(router)} <= union
    # dropping the router link from the ranking leaves core as best hit
    v2 = dataclasses.replace(v, kind="core", location=3,
                             ranking=[("core", 3, 2.0)])
    matched2, best2, ranks2, _ = judge_verdict(v2, truths, mesh)
    assert matched2 and best2 == 1 and ranks2 == (1, None, None)


def test_campaign_outcomes_carry_truth_kinds(mixed_serial):
    for o in mixed_serial.outcomes:
        assert len(o.truth_kinds) == o.n_failures
        assert o.truth_kinds == o.effective_truth_kinds
        if o.kind == "core+link":
            assert sorted(o.truth_kinds) == ["core", "link"]
        elif o.kind == "none":
            assert o.truth_kinds == ()


def test_effective_truth_kinds_fallback():
    det = DetectorOutcome(detector="sloth", flagged=True, pred_kind="core",
                          pred_location=0, score=1.0, matched=True,
                          truth_rank=1, truth_ranks=(1, 2))
    o = ScenarioOutcome(
        scenario_id=0, workload="wl", mesh_w=4, mesh_h=4, kind="core",
        severity=8.0, n_failures=2, rep=0, sim_seed=0,
        truth_locations=(1, 2), truth_t0s=(0.0, 0.0),
        truth_durations=(1.0, 1.0), detector_results=(det,),
        compression_ratio=1.0, total_time=1.0, probe_overhead=0.0)
    assert o.truth_kinds == ()
    assert o.effective_truth_kinds == ("core", "core")


# ---------------------------------------------------------------------------
# metrics: by_truth_kind + severity_curve semantics
# ---------------------------------------------------------------------------

def _outcome(i, kind, severity, truth_kinds, truth_ranks, matched,
             flagged=True):
    n = len(truth_kinds)
    ranked = [r for r in truth_ranks if r is not None]
    det = DetectorOutcome(
        detector="sloth", flagged=flagged, pred_kind="core",
        pred_location=0, score=1.0, matched=matched,
        truth_rank=min(ranked) if ranked else None,
        truth_ranks=tuple(truth_ranks))
    return ScenarioOutcome(
        scenario_id=i, workload="wl", mesh_w=4, mesh_h=4, kind=kind,
        severity=severity, n_failures=n, rep=0, sim_seed=i,
        truth_locations=tuple(range(n)), truth_t0s=(0.0,) * n,
        truth_durations=(1.0,) * n, detector_results=(det,),
        compression_ratio=1.0, total_time=1.0, probe_overhead=0.0,
        truth_kinds=tuple(truth_kinds))


def test_by_truth_kind_splits_per_failure_ranks():
    outs = [
        _outcome(0, "mixed", 8.0, ("core", "link"), (1, 4), True),
        _outcome(1, "mixed", 8.0, ("link", "router"), (None, 2), True),
        _outcome(2, "none", 0.0, (), (), True, flagged=False),
    ]
    tk = by_truth_kind(outs)
    assert list(tk) == ["core", "link", "router"]    # canonical order
    assert tk["core"].n_failures == 1
    assert tk["link"].n_failures == 2
    assert tk["link"].ranked.successes == 1          # one link unranked
    assert tk["link"].recall_at(3) == 0.0
    assert tk["link"].recall_at(5) == 0.5
    assert tk["core"].mean_rank == 1.0
    assert tk["router"].recall_at(3) == 1.0
    # unranked-only bucket reports mean_rank None
    only_miss = [_outcome(0, "mixed", 8.0, ("core",), (None,), False)]
    assert by_truth_kind(only_miss)["core"].mean_rank is None


def test_severity_curve_semantics():
    outs = [
        _outcome(0, "core", 1.5, ("core",), (None,), False),
        _outcome(1, "core", 1.5, ("core",), (1,), True),
        _outcome(2, "core", 10.0, ("core",), (1,), True),
        _outcome(3, "core", 10.0, ("core",), (1,), True),
        _outcome(4, "none", 0.0, (), (), True, flagged=False),
        _outcome(5, "none", 0.0, (), (), True, flagged=True),
    ]
    curve = severity_curve(outs, ks=(1, 3))
    assert [p.severity for p in curve] == [1.5, 10.0]   # ascending
    lo, hi = curve
    assert (lo.accuracy.successes, lo.accuracy.trials) == (1, 2)
    assert (hi.accuracy.successes, hi.accuracy.trials) == (2, 2)
    assert lo.recall_at(1) == 0.5 and hi.recall_at(1) == 1.0
    # FPR is the campaign's negative rate, attached to every point
    assert lo.fpr == hi.fpr
    assert (lo.fpr.successes, lo.fpr.trials) == (1, 2)
    # Wilson CIs ride along
    assert 0.0 <= lo.accuracy.interval[0] <= lo.accuracy.rate


def test_severity_curve_trends_monotone_across_threshold(cache):
    """Acceptance: a near-threshold sweep shows accuracy trending up with
    severity — barely-degraded 1.25× failures are hard, 3× failures land
    above the detection statistic."""
    g = CampaignGrid(workloads=("darknet19",), meshes=(4,),
                     kinds=("core", "link", "none"),
                     severities=(1.25, 3.0), reps=3, campaign_seed=9)
    res = run_campaign(g, workers=0, cache=cache)
    curve = res.severity_curve()
    assert [p.severity for p in curve] == [1.25, 3.0]
    lo, hi = curve
    assert lo.accuracy.rate < hi.accuracy.rate
    assert hi.accuracy.rate >= 0.75
    assert lo.recall_at(3) <= hi.recall_at(3)
    assert "severity curve" in res.summary()


# ---------------------------------------------------------------------------
# multi-entry baseline rankings
# ---------------------------------------------------------------------------

def test_baselines_emit_multi_entry_rankings():
    """With two simultaneous strong failures the statistic-driven
    baselines rank several resources — the single-entry degeneracy that
    froze their top-k/recall@k cells is gone."""
    sloth = Sloth(build_workload("darknet19"), Mesh2D(4))
    profile = sloth.run(None, seed=12345)
    sim = sloth.run([FailSlow("core", 5, 1.0, 8.0, 10.0),
                     FailSlow("link", 20, 0.5, 7.0, 10.0)], seed=2)
    entries = {}
    for name in ("thres", "mscope", "perseus", "adr", "iaso"):
        v = prepare_detector(name, sloth.graph, sloth.mesh,
                             profile).analyse(sim)
        entries[name] = v.ranking
        if v.flagged:
            assert v.ranking[0][:2] == (v.kind, v.location)
        assert len(v.ranking) <= 16
    assert len(entries["thres"]) >= 3
    assert len(entries["mscope"]) >= 3
    # thres sees both victims: the slowed core and the slowed link rank
    ranked_sites = [(k, l) for k, l, _ in entries["thres"]]
    assert ("core", 5) in ranked_sites and ("link", 20) in ranked_sites


def test_iaso_all_noise_clustering_still_ranks(monkeypatch):
    """Regression: when 1-D DBSCAN dissolves every cluster into noise,
    IASO used to return an empty ranking — unlike its other unflagged
    path — zeroing recall at exactly the near-threshold sweep points.
    Unflagged verdicts now always report the AIMD score mass."""
    import numpy as np

    import repro.core.baselines as B
    sloth = Sloth(build_workload("darknet19"), Mesh2D(4))
    profile = sloth.run(None, seed=12345)
    sim = sloth.run([FailSlow("core", 5, 0.5, 8.0, 10.0)], seed=2)
    det = prepare_detector("iaso", sloth.graph, sloth.mesh, profile)
    monkeypatch.setattr(B, "_dbscan_1d",
                        lambda x, eps, min_pts=3: np.full(len(x), -1))
    v = det.analyse(sim)
    assert not v.flagged
    assert v.ranking                     # score mass still reported
    scores = [s for _, _, s in v.ranking]
    assert scores == sorted(scores, reverse=True)


def test_mixed_campaign_baseline_cells_non_degenerate(cache):
    """Acceptance: in a mixed-kind multi-severity campaign the baselines
    produce multi-entry rankings (so recall@k can exceed top-1) and the
    per-detector cells are populated for every detector."""
    res = run_campaign(MIXED_GRID, workers=0,
                       detectors=("sloth", "thres", "mscope"),
                       cache=cache)
    assert set(res.detector_metrics) == {"sloth", "thres", "mscope"}
    for name in res.detectors:
        assert set(res.detector_cells[name]) == set(res.cells)
    # some positive scenario carries a ≥3-entry thres ranking: both truth
    # ranks resolved beyond rank 1 implies a real candidate list
    multi = [o.result_for("thres").truth_ranks
             for o in res.outcomes if o.positive]
    assert any(len([r for r in ranks if r is not None]) >= 2
               or any(r is not None and r >= 3 for r in ranks)
               for ranks in multi)


# ---------------------------------------------------------------------------
# executor equivalence for the combined mixed-kind, multi-severity grid
# ---------------------------------------------------------------------------

def test_mixed_grid_executors_bit_identical(mixed_serial):
    thread = run_campaign(MIXED_GRID, workers=2, executor="thread",
                          cache=DeploymentCache())
    process = run_campaign(MIXED_GRID, workers=2, executor="process")
    for other in (thread, process):
        assert other.outcomes == mixed_serial.outcomes
        assert other.metrics == mixed_serial.metrics
        assert other.cells == mixed_serial.cells
        assert other.severity_curve() == mixed_serial.severity_curve()
        assert other.by_truth_kind() == mixed_serial.by_truth_kind()


# ---------------------------------------------------------------------------
# simulator: mixed-kind windows coexist and compound
# ---------------------------------------------------------------------------

def test_mixed_kind_failures_compound_in_one_run(cache):
    """A core failure and a link failure injected together each keep
    their own slowdown window; the combined run is slower than either
    alone (core and link windows live in separate tables and compound)."""
    dep = cache.get("darknet19", 4, 4)
    sloth = dep.sloth
    cfg = dataclasses.replace(sloth.sim_cfg, seed=0)
    horizon = dep.healthy.total_time * 4
    busy_link = dep.used_links[0]
    core_f = FailSlow("core", 5, 0.0, horizon, 6.0)
    link_f = FailSlow("link", busy_link, 0.0, horizon, 6.0)
    t_base = simulate(sloth.mapped, cfg).total_time
    t_core = simulate(sloth.mapped, cfg, failures=[core_f]).total_time
    t_link = simulate(sloth.mapped, cfg, failures=[link_f]).total_time
    t_both = simulate(sloth.mapped, cfg,
                      failures=[core_f, link_f]).total_time
    assert t_core > t_base and t_link > t_base
    assert t_both >= max(t_core, t_link)


# ---------------------------------------------------------------------------
# make_dataset: router coverage + duration range
# ---------------------------------------------------------------------------

def test_make_dataset_router_ratio_default_preserves_draws():
    """router_ratio=0 must reproduce the historical two-kind draws
    bit-for-bit (same seed, same samples) — the parameter dilutes the
    population only when asked."""
    mesh = Mesh2D(4)
    old = make_dataset(mesh, 24, seed=7)
    new = make_dataset(mesh, 24, seed=7, router_ratio=0.0)
    assert old == new
    assert all(s.failure.kind in ("core", "link")
               for s in old if s.failure is not None)


def test_make_dataset_router_ratio_emits_routers():
    mesh = Mesh2D(4)
    ds = make_dataset(mesh, 200, seed=7, router_ratio=0.3)
    kinds = [s.failure.kind for s in ds if s.failure is not None]
    frac = kinds.count("router") / len(kinds)
    assert 0.2 < frac < 0.4
    assert set(kinds) == {"core", "link", "router"}
    # router locations are router (= core) ids
    for s in ds:
        if s.failure is not None and s.failure.kind == "router":
            assert 0 <= s.failure.location < mesh.n_cores
    # all-router datasets are expressible too
    only = make_dataset(mesh, 20, seed=7, router_ratio=1.0)
    assert all(s.failure.kind == "router"
               for s in only if s.failure is not None)
    with pytest.raises(ValueError, match="router_ratio"):
        make_dataset(mesh, 10, router_ratio=1.5)


def test_effective_samples_drops_unobservable_routers():
    """A router none of whose links carry traffic cannot affect execution
    — with mesh provided, effective_samples excludes it (the same
    invariant the campaign's used_routers pool enforces)."""
    from repro.core.failures import Sample, effective_samples
    mesh = Mesh2D(4)
    dead, live = 0, 5
    used = (set(mesh.links_of_router(live))
            - set(mesh.links_of_router(dead)))   # dead router fully unused
    assert used
    samples = [Sample(0, FailSlow("router", dead, 0.0, 5.0, 10.0)),
               Sample(1, FailSlow("router", live, 0.0, 5.0, 10.0)),
               Sample(2, None)]
    kept = effective_samples(samples, 10.0, used, mesh)
    assert [s.sample_id for s in kept] == [1, 2]
    # without a mesh the router filter cannot apply and samples survive
    kept = effective_samples(samples, 10.0, used)
    assert [s.sample_id for s in kept] == [0, 1, 2]


def test_make_dataset_duration_range_matches_doc():
    """The reconciled §IV-A distribution: durations U(1, 10) s — stated
    in the module docstring, the make_dataset signature and the drawn
    samples alike."""
    mesh = Mesh2D(4)
    ds = make_dataset(mesh, 200, seed=11)
    durs = [s.failure.duration for s in ds if s.failure is not None]
    assert min(durs) >= 1.0 and max(durs) <= 10.0
    import repro.core.failures as F
    assert "U(1, 10)" in F.__doc__
    assert "U(min_dur,\n    max_dur) = U(1, 10) s" in F.make_dataset.__doc__
