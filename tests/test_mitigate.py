"""Detect → mitigate subsystem: policy registry, plan semantics,
mitigated re-simulation, recovered-throughput metrics, and the
campaign/streaming wiring.

The wrong-verdict regression tests register a custom always-wrong
detector at module import; it exists only in this interpreter, so every
campaign here that uses it runs the serial executor (process-pool
workers re-import modules in fresh interpreters and would not see it).
"""

import dataclasses

import pytest

from repro.core.campaign import (CampaignGrid, DeploymentCache,
                                 enumerate_scenarios, run_campaign)
from repro.core.detectors import Verdict, register_detector
from repro.core.failures import FailSlow
from repro.core.graph import build_workload
from repro.core.mapping import map_graph
from repro.core.metrics import MIN_GAP_FRAC
from repro.core.routing import DetourMesh, Mesh2D
from repro.core.simulator import clip_failures
from repro.core.sloth import Sloth
from repro.mitigate import (MitigationPlan, MitigationPolicy,
                            QuarantinePolicy, RemapPolicy,
                            available_policies, flagged_sites,
                            instantiate_policy, register_policy)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


class WrongCoreDetector:
    """Always flags core 0 with high confidence — the mis-mitigation
    probe (campaign seeds below never draw core 0 as truth)."""

    name = "wrongcore"

    def prepare(self, graph, mesh, profile=None, cfg=None):
        self.mesh = mesh
        return self

    def analyse(self, sim):
        return Verdict(True, "core", 0, 99.0,
                       ranking=[("core", 0, 99.0)],
                       flagged_resources=(("core", 0, 99.0),),
                       mesh=self.mesh, detector="wrongcore")


register_detector("wrongcore", WrongCoreDetector)


def core_verdict(mesh, *cores):
    return Verdict(True, "core", cores[0], 9.0,
                   ranking=[("core", c, 9.0) for c in cores],
                   flagged_resources=tuple(("core", c, 9.0)
                                           for c in cores),
                   mesh=mesh)


def link_verdict(mesh, *links):
    return Verdict(True, "link", links[0], 9.0,
                   ranking=[("link", l, 9.0) for l in links],
                   flagged_resources=tuple(("link", l, 9.0)
                                           for l in links),
                   mesh=mesh)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_builtin_registry_order_and_protocol():
    assert available_policies()[:4] == ("remap", "reroute", "quarantine",
                                        "none")
    for name in ("remap", "reroute", "quarantine", "none"):
        pol = instantiate_policy(name)
        assert pol.name == name
        assert isinstance(pol, MitigationPolicy)


def test_registry_round_trip_and_name_contract():
    class Custom:
        name = "custom-mit"

        def plan(self, verdict, mapped, mesh, cfg=None):
            return MitigationPlan(policy=self.name, acted=False)

        def apply(self, plan, mapped, cfg=None):
            return mapped

    register_policy("custom-mit", Custom)
    try:
        assert "custom-mit" in available_policies()
        assert instantiate_policy("CUSTOM-MIT").name == "custom-mit"
        # duplicate registration is an error without overwrite
        with pytest.raises(ValueError):
            register_policy("custom-mit", Custom)

        class Misnamed:
            name = "other"
            plan = Custom.plan
            apply = Custom.apply

        register_policy("misnamed", Misnamed)
        with pytest.raises(ValueError):
            instantiate_policy("misnamed")
    finally:
        from repro.mitigate.policy import _REGISTRY
        _REGISTRY.pop("custom-mit", None)
        _REGISTRY.pop("misnamed", None)


def test_unknown_policy_rejected():
    with pytest.raises(KeyError):
        instantiate_policy("gremlin")


# ---------------------------------------------------------------------------
# flagged_sites
# ---------------------------------------------------------------------------

def test_flagged_sites_multi_and_dedup():
    mesh = Mesh2D(4)
    v = Verdict(True, "core", 5, 9.0,
                flagged_resources=(("core", 5, 9.0), ("link", 3, 4.0),
                                   ("core", 5, 8.0)), mesh=mesh)
    assert flagged_sites(v) == (("core", 5), ("link", 3))


def test_flagged_sites_top1_fallback_and_unflagged():
    mesh = Mesh2D(4)
    # baselines leave flagged_resources empty → top-1 kind/location
    v = Verdict(True, "link", 7, 3.0, mesh=mesh)
    assert flagged_sites(v) == (("link", 7),)
    assert flagged_sites(Verdict(False, None, None, 0.0)) == ()


# ---------------------------------------------------------------------------
# plan semantics
# ---------------------------------------------------------------------------

def test_remap_excludes_exactly_flagged_cores():
    mesh = Mesh2D(4)
    plan = RemapPolicy().plan(core_verdict(mesh, 5, 9), None, mesh)
    assert plan.acted
    assert plan.exclude_cores == (5, 9)
    assert plan.avoid_links == ()


def test_remap_ignores_link_only_verdicts():
    mesh = Mesh2D(4)
    plan = RemapPolicy().plan(link_verdict(mesh, 3), None, mesh)
    assert not plan.acted


def test_reroute_avoids_flagged_links():
    mesh = Mesh2D(4)
    pol = instantiate_policy("reroute")
    plan = pol.plan(link_verdict(mesh, 3), None, mesh)
    assert plan.acted
    assert plan.avoid_links == (3,)
    assert plan.exclude_cores == ()


def test_reroute_router_fallback():
    """≥2 flagged links incident on one router → the router's core is
    excluded and all its links avoided."""
    mesh = Mesh2D(4)
    lids = mesh.links_of_router(5)
    plan = instantiate_policy("reroute").plan(
        link_verdict(mesh, lids[0], lids[1]), None, mesh)
    assert plan.acted
    assert 5 in plan.exclude_cores
    assert set(lids) <= set(plan.avoid_links)


def test_quarantine_neighbourhood():
    mesh = Mesh2D(4)
    plan = QuarantinePolicy().plan(core_verdict(mesh, 5), None, mesh)
    assert plan.exclude_cores == (1, 4, 5, 6, 9)


def test_exclusion_never_empties_mesh():
    mesh = Mesh2D(2, 1)
    plan = QuarantinePolicy().plan(core_verdict(mesh, 0), None, mesh)
    assert len(plan.exclude_cores) < mesh.n_cores


def test_none_policy_never_acts():
    mesh = Mesh2D(4)
    pol = instantiate_policy("none")
    plan = pol.plan(core_verdict(mesh, 5), None, mesh)
    assert not plan.acted
    g = build_workload("darknet19")
    mapped = map_graph(g, mesh)
    assert pol.apply(plan, mapped) is mapped


# ---------------------------------------------------------------------------
# apply: mapping + routing edits
# ---------------------------------------------------------------------------

def test_map_graph_empty_exclusion_bit_identical():
    g = build_workload("darknet19")
    mesh = Mesh2D(4)
    a = map_graph(g, mesh)
    b = map_graph(g, mesh, exclude_cores=())
    assert [t.core for t in a.tasks] == [t.core for t in b.tasks]


def test_map_graph_exclusion_placement():
    g = build_workload("darknet19")
    mesh = Mesh2D(4)
    mapped = map_graph(g, mesh, exclude_cores=(5, 9))
    assert {t.core for t in mapped.tasks}.isdisjoint({5, 9})
    with pytest.raises(ValueError):
        map_graph(g, mesh, exclude_cores=(99,))
    with pytest.raises(ValueError):
        map_graph(g, mesh, exclude_cores=tuple(range(16)))


def test_remap_apply_moves_work_off_flagged_core():
    g = build_workload("darknet19")
    mesh = Mesh2D(4)
    mapped = map_graph(g, mesh)
    pol = RemapPolicy()
    plan = pol.plan(core_verdict(mesh, 5), mapped, mesh)
    out = pol.apply(plan, mapped)
    assert 5 not in {t.core for t in out.tasks}
    assert out.mesh is mesh                 # routing untouched
    assert 5 in {t.core for t in mapped.tasks}  # input not mutated


def test_detour_mesh_avoids_links_same_identities():
    mesh = Mesh2D(4)
    det = DetourMesh(mesh, avoid_links=(3,))
    assert det.links == mesh.links          # link ids stable
    u, v = mesh.links[3]
    path = det.route(u, v)
    assert 3 not in path
    # un-avoided pairs may still route differently but never through 3
    for src in range(mesh.n_cores):
        for dst in range(mesh.n_cores):
            if src != dst:
                assert 3 not in det.route(src, dst)


def test_detour_mesh_disconnection_falls_back():
    mesh = Mesh2D(2, 1)
    det = DetourMesh(mesh, avoid_links=tuple(range(mesh.n_links)))
    # nothing left to route over: fall back to the base XY path
    assert det.route(0, 1) == mesh.route(0, 1)


def test_route_avoiding_deterministic_shortest():
    mesh = Mesh2D(4)
    base = mesh.route(0, 5)
    detour = mesh.route_avoiding(0, 5, {base[0]})
    assert detour is not None
    assert base[0] not in detour
    assert len(detour) == len(base)         # a 2-hop alternative exists
    assert detour == mesh.route_avoiding(0, 5, {base[0]})


def test_reroute_apply_keeps_placement():
    g = build_workload("darknet19")
    mesh = Mesh2D(4)
    mapped = map_graph(g, mesh)
    pol = instantiate_policy("reroute")
    plan = pol.plan(link_verdict(mesh, 3), mapped, mesh)
    out = pol.apply(plan, mapped)
    assert [t.core for t in out.tasks] == [t.core for t in mapped.tasks]
    assert isinstance(out.mesh, DetourMesh)
    assert out.mesh.avoid == frozenset({3})


# ---------------------------------------------------------------------------
# clip_failures: the remaining-window semantics
# ---------------------------------------------------------------------------

def test_clip_failures_rebases_windows():
    fs = [FailSlow("core", 5, 2.0, 10.0, 8.0),   # spans the cut
          FailSlow("core", 6, 8.0, 4.0, 8.0),    # starts after the cut
          FailSlow("link", 3, 0.0, 4.0, 8.0)]    # elapsed before the cut
    out = clip_failures(fs, 5.0)
    assert [(f.location, f.t0, f.duration) for f in out] \
        == [(5, 0.0, 7.0), (6, 3.0, 4.0)]
    # from_time=0 is the identity
    assert clip_failures(fs, 0.0) == fs
    assert clip_failures(None, 5.0) == []


# ---------------------------------------------------------------------------
# campaign integration: recovered throughput
# ---------------------------------------------------------------------------

GRID = CampaignGrid(workloads=("darknet19",), meshes=(4,),
                    kinds=("core", "none"), severities=(10.0,),
                    reps=4, campaign_seed=7)


@pytest.fixture(scope="module")
def mitigated_result():
    return run_campaign(GRID, workers=0, cache=DeploymentCache(),
                        mitigation=("remap", "none"))


def test_campaign_remap_recovers_majority_of_gap(mitigated_result):
    """The headline acceptance: on decisive 10× core failures, remap on
    correct verdicts recovers at least half the failure-induced gap."""
    st = mitigated_result.mitigation[("sloth", "remap")]
    assert st.recovered_mean >= 0.5
    assert st.improved.successes == st.improved.trials > 0


def test_campaign_none_control_exact_zero(mitigated_result):
    st = mitigated_result.mitigation[("sloth", "none")]
    assert st.acted.successes == 0
    assert st.recovered_mean == 0.0
    for o in mitigated_result.outcomes:
        mo = o.mitigation_for("sloth", "none")
        assert not mo.acted
        assert mo.mitigated_time == mo.failed_time
        assert mo.recovered_frac == 0.0
        assert mo.switch_time is None


def test_campaign_mitigation_outcome_consistency(mitigated_result):
    assert mitigated_result.policies == ("remap", "none")
    for o in mitigated_result.outcomes:
        assert [m.policy for m in o.mitigation_results] == ["remap",
                                                            "none"]
        mo = o.mitigation_for("sloth", "remap")
        assert mo.detector == "sloth" and mo.policy == "remap"
        if o.kind == "core":
            assert mo.gap > MIN_GAP_FRAC * mo.healthy_time
            if mo.correct and mo.acted:
                assert mo.recovered_frac > 0.0
                assert mo.slowdown_vs_healthy < mo.failed_time \
                    / mo.healthy_time
        else:
            # failure-free and correctly unflagged: nothing to act on,
            # so the mitigated makespan is exactly the failed one
            if mo.correct:
                assert not mo.acted
                assert mo.recovered_frac == 0.0
                assert mo.mitigated_time == mo.failed_time
        with pytest.raises(KeyError):
            o.mitigation_for("sloth", "quarantine")


def test_campaign_mitigation_executors_bit_identical(mitigated_result):
    thread = run_campaign(GRID, workers=2, executor="thread",
                          cache=DeploymentCache(),
                          mitigation=("remap", "none"))
    process = run_campaign(GRID, workers=2, executor="process",
                           mitigation=("remap", "none"))
    for other in (thread, process):
        assert other.outcomes == mitigated_result.outcomes
        assert other.mitigation == mitigated_result.mitigation


def test_campaign_mitigation_normalisation():
    scens = enumerate_scenarios(GRID)
    assert len(scens) == GRID.n_scenarios()
    with pytest.raises(KeyError):
        run_campaign(GRID, workers=0, mitigation=("gremlin",))
    res = run_campaign(
        dataclasses.replace(GRID, kinds=("none",), reps=1),
        workers=0, cache=DeploymentCache(), mitigation="remap")
    assert res.policies == ("remap",)


def test_streaming_mitigation_switches_at_first_flag(mitigated_result):
    res = run_campaign(GRID, workers=0, cache=DeploymentCache(),
                       streaming=4, mitigation=("remap",))
    for o in res.outcomes:
        mo = o.mitigation_for("sloth", "remap")
        det = o.detector_results[0]
        if o.kind == "core" and mo.acted:
            assert mo.switch_time is not None
            assert det.detection_latency is not None
            assert 0.0 < mo.switch_time <= mo.failed_time
            assert mo.recovered_frac > 0.0
            # paying the detection latency can only shrink the recovery
            # relative to the post-hoc restart of the same scenario
            ph = next(p for p in mitigated_result.outcomes
                      if p.scenario_id == o.scenario_id)
            assert mo.recovered_frac <= \
                ph.mitigation_for("sloth", "remap").recovered_frac + 1e-9
        elif not mo.acted:
            assert mo.switch_time is None


def test_wrong_verdict_negative_recovery():
    """Acting on a wrong verdict makes things worse: recovered fraction
    goes negative on real failures, and false-positive actions carry a
    positive mis-mitigation penalty."""
    grid = CampaignGrid(workloads=("darknet19",), meshes=(4,),
                        kinds=("core", "none"), severities=(10.0,),
                        reps=3, campaign_seed=21)
    res = run_campaign(grid, workers=0, detectors=("wrongcore",),
                       cache=DeploymentCache(), mitigation=("remap",))
    st = res.mitigation[("wrongcore", "remap")]
    assert st.mis_acted.successes == st.mis_acted.trials > 0
    assert st.penalty_mean > 0.0
    for o in res.outcomes:
        mo = o.mitigation_for("wrongcore", "remap")
        assert mo.acted and not mo.correct
        if o.kind == "core":
            assert 0 not in o.truth_locations   # probe premise
            assert mo.recovered_frac < 0.0
        assert mo.penalty > 0.0


# ---------------------------------------------------------------------------
# per-failure severity mixes + weighted mixed draws + per-mesh curves
# ---------------------------------------------------------------------------

def test_severity_mix_pins_failure_count():
    g = CampaignGrid(workloads=("darknet19",), meshes=(4,),
                     kinds=("core",), severities=((2.0, 4.0, 8.0),),
                     reps=1, n_failures=(1, 2))
    scens = enumerate_scenarios(g)
    assert [s.n_failures for s in scens] == [3]
    assert g.n_scenarios() == 1


def test_severity_mix_assigns_per_failure():
    g = CampaignGrid(workloads=("darknet19",), meshes=(4,),
                     kinds=("core+link",), severities=((1.5, 10.0),),
                     reps=1, campaign_seed=5)
    res = run_campaign(g, workers=0, cache=DeploymentCache())
    (o,) = res.outcomes
    assert o.severity == (1.5, 10.0)
    assert o.truth_kinds == ("core", "link")
    assert o.truth_severities == (1.5, 10.0)
    assert o.effective_truth_severities == (1.5, 10.0)


def test_scalar_severity_broadcasts():
    g = CampaignGrid(workloads=("darknet19",), meshes=(4,),
                     kinds=("core",), severities=(8.0,), n_failures=(2,),
                     reps=1)
    res = run_campaign(g, workers=0, cache=DeploymentCache())
    (o,) = res.outcomes
    assert o.truth_severities == (8.0, 8.0)
    assert o.effective_truth_severities == (8.0, 8.0)


def test_severity_mix_validation():
    with pytest.raises(ValueError):
        CampaignGrid(severities=((1.5,),))          # ambiguous 1-tuple
    with pytest.raises(ValueError):
        CampaignGrid(severities=((1.5, 0.0),))      # non-positive entry
    with pytest.raises(ValueError):
        # composite pins 2 failures, mix assigns 3
        CampaignGrid(kinds=("core+link",),
                     severities=((1.0, 2.0, 3.0),)).n_scenarios()


def test_mixed_weights_bias_and_validation():
    g = CampaignGrid(workloads=("darknet19",), meshes=(4,),
                     kinds=("mixed",), severities=(10.0,),
                     n_failures=(2,), reps=10, campaign_seed=11,
                     mixed_weights={"core": 7, "link": 3})
    assert g.mixed_weights == (("core", 7.0), ("link", 3.0))
    res = run_campaign(g, workers=0, cache=DeploymentCache())
    kinds = [k for o in res.outcomes for k in o.truth_kinds]
    assert "router" not in kinds            # zero-weight kind never drawn
    assert kinds.count("core") > 0 and kinds.count("link") > 0
    with pytest.raises(ValueError):
        CampaignGrid(mixed_weights={"gremlin": 1})
    with pytest.raises(ValueError):
        CampaignGrid(mixed_weights={"core": 0.0, "link": 0.0})


def test_mixed_weights_default_bit_identical():
    base = CampaignGrid(workloads=("darknet19",), meshes=(4,),
                        kinds=("mixed",), severities=(10.0,),
                        n_failures=(2,), reps=3, campaign_seed=11)
    a = run_campaign(base, workers=0, cache=DeploymentCache())
    b = run_campaign(dataclasses.replace(base, mixed_weights=None),
                     workers=0, cache=DeploymentCache())
    assert a.outcomes == b.outcomes


def test_severity_curve_by_mesh():
    g = CampaignGrid(workloads=("darknet19",), meshes=(4, (4, 2)),
                     kinds=("core", "none"), severities=(2.0, 10.0),
                     reps=2, campaign_seed=9)
    res = run_campaign(g, workers=0, cache=DeploymentCache())
    pooled = res.severity_curve()
    per_mesh = res.severity_curve_by_mesh()
    assert set(per_mesh) == {(4, 4), (4, 2)}
    for mesh_key, curve in per_mesh.items():
        assert [p.severity for p in curve] == [p.severity for p in pooled]
        for p in curve:
            assert p.accuracy.trials == 2   # reps per (mesh, severity)
    # per-mesh trials partition the pooled trials
    for i, p in enumerate(pooled):
        assert sum(c[i].accuracy.trials for c in per_mesh.values()) \
            == p.accuracy.trials
