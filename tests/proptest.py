"""Seeded-numpy property-test shim with a hypothesis fallback.

The seed test-suite hard-imported ``hypothesis``, which is not part of the
repo's dependency set — collection failed wholesale on a clean machine.
This module keeps the same test-authoring surface (``@given`` over
strategies, ``@settings(max_examples=...)``, ``data.draw``) with **zero
third-party dependencies**: when hypothesis is installed it is used
verbatim (shrinking, the database, etc.); otherwise a deterministic
numpy-backed generator produces the same case families.

Shim semantics:

* each test gets a private ``np.random.default_rng`` stream seeded from
  ``crc32(module.qualname)`` and the example index — runs are reproducible
  and independent of execution order,
* ``max_examples`` examples are generated per test (default 10),
* no shrinking: the failing example's arguments appear in the assertion
  traceback via pytest's report.

Supported strategies: ``integers``, ``floats``, ``booleans``,
``sampled_from``, ``lists``, ``data``.
"""

from __future__ import annotations

try:                                    # opt-in: real hypothesis if present
    from hypothesis import given, settings       # noqa: F401
    from hypothesis import strategies as st      # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import zlib

    import numpy as np

    class Strategy:
        """A value generator: ``draw(rng) -> value``."""

        def __init__(self, draw_fn, name="strategy"):
            self._draw = draw_fn
            self._name = name

        def draw(self, rng):
            return self._draw(rng)

        def __repr__(self):
            return self._name

    class DataObject:
        """Interactive draws inside a test body (``st.data()``)."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.draw(self._rng)

    class _DataStrategy(Strategy):
        def __init__(self):
            super().__init__(lambda rng: DataObject(rng), "data()")

    class _StrategiesNamespace:
        """Mimics ``hypothesis.strategies`` for the subset the suite uses."""

        @staticmethod
        def integers(min_value=0, max_value=2 ** 31 - 1):
            return Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                f"integers({min_value}, {max_value})")

        @staticmethod
        def floats(min_value=0.0, max_value=1.0):
            return Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)),
                f"floats({min_value}, {max_value})")

        @staticmethod
        def booleans():
            return Strategy(lambda rng: bool(rng.integers(2)), "booleans()")

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return Strategy(
                lambda rng: seq[int(rng.integers(len(seq)))],
                f"sampled_from({seq!r})")

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.draw(rng) for _ in range(n)]
            return Strategy(draw, "lists(...)")

        @staticmethod
        def data():
            return _DataStrategy()

    st = _StrategiesNamespace()

    def settings(max_examples=10, deadline=None, **_ignored):
        """Records ``max_examples`` on the (possibly given-wrapped) test."""
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        """Run the test once per generated example, deterministically."""
        def deco(fn):
            base_seed = zlib.crc32(
                f"{fn.__module__}.{fn.__qualname__}".encode())

            def wrapper(*args, **kwargs):
                # @settings may sit above @given (attr lands on wrapper)
                # or below it (attr lands on fn) — honour both orders
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples", 10))
                for example in range(n):
                    rng = np.random.default_rng([base_seed, example])
                    drawn = [s.draw(rng) for s in strategies]
                    try:
                        fn(*args, *drawn, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example #{example} "
                            f"(seed [{base_seed}, {example}]): "
                            f"{fn.__qualname__}{tuple(drawn)}") from e

            # Deliberately no functools.wraps: a __wrapped__ attribute
            # would make pytest introspect the original signature and
            # treat the strategy parameters as fixtures.
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
