"""MCG construction invariants and FailRank convergence."""

import numpy as np
import pytest

from repro.core.detection import detect_cores, detect_links
from repro.core.failrank import FailRankParams, failrank
from repro.core.failures import FailSlow
from repro.core.graph import build_workload
from repro.core.mcg import build_mcg
from repro.core.recorder import record
from repro.core.routing import Mesh2D
from repro.core.sloth import Sloth

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


@pytest.fixture(scope="module")
def pipeline():
    mesh = Mesh2D(4)
    sloth = Sloth(build_workload("darknet19"), mesh)
    sim = sloth.run([FailSlow("core", 5, 1.0, 8.0)], seed=0)
    rec = record(sim, sloth.cfg.sketch,
                 hop_latency=sloth.sim_cfg.hop_latency)
    cores = detect_cores(rec.comp_patterns, sim.total_time, 4)
    links = detect_links(rec.comm_patterns, mesh, sim.total_time, 4,
                         sloth.sim_cfg.hop_latency)
    mcg = build_mcg(rec.comm_patterns, mesh, sim.total_time, cores, links, 4)
    return mesh, mcg


def test_mcg_weight_normalisation(pipeline):
    """Σ_out w(u,·) = 1 for every node with outgoing edges."""
    _, mcg = pipeline
    sums = np.zeros(mcg.n_nodes)
    np.add.at(sums, mcg.edge_src, mcg.edge_w)
    has_out = np.zeros(mcg.n_nodes, bool)
    has_out[mcg.edge_src] = True
    assert np.allclose(sums[has_out], 1.0, atol=1e-9)


def test_mcg_structure(pipeline):
    mesh, mcg = pipeline
    # virtual DRAM nodes exist and connect consecutive levels
    assert mcg.n_nodes == mcg.n_windows * mesh.n_cores + mcg.n_windows
    dram = set(range(mcg.n_windows * mesh.n_cores, mcg.n_nodes))
    dram_edges = [i for i in range(len(mcg.edge_src))
                  if int(mcg.edge_src[i]) in dram
                  or int(mcg.edge_dst[i]) in dram]
    assert dram_edges, "no inter-level (DRAM) edges"
    for i in dram_edges:
        assert not mcg.edge_link_path[i]        # virtual edges have no path
    # physical edges route within one window level
    for i, path in enumerate(mcg.edge_link_path):
        if path:
            ws = int(mcg.edge_src[i]) // mesh.n_cores
            wd = int(mcg.edge_dst[i]) // mesh.n_cores
            assert ws == wd


def test_failrank_converges(pipeline):
    _, mcg = pipeline
    res = failrank(mcg, FailRankParams())
    assert res.iterations < 100
    assert res.residuals[-1] < 1e-4 or res.iterations == 100
    # residuals eventually decay monotonically (geometric phase)
    tail = res.residuals[2:]
    assert all(a >= b * 0.999 for a, b in zip(tail, tail[1:]))


def test_failrank_softmax_normalised(pipeline):
    _, mcg = pipeline
    res = failrank(mcg)
    for lv in np.unique(mcg.node_window):
        sel = mcg.node_window == lv
        assert np.isclose(res.node_scores[sel].sum(), 1.0, atol=1e-6)


def test_failrank_zero_signal():
    """No initial evidence → flat scores, immediate convergence."""
    mesh = Mesh2D(4)
    sloth = Sloth(build_workload("binary_tree"), mesh)
    sim = sloth.run(None, seed=0)
    rec = record(sim, sloth.cfg.sketch,
                 hop_latency=sloth.sim_cfg.hop_latency)
    mcg = build_mcg(rec.comm_patterns, mesh, sim.total_time, [],
                    detect_links([], mesh, sim.total_time), 4)
    res = failrank(mcg)
    assert float(np.max(res.raw_node_scores)) < 1e-6
