"""Topology abstraction layer: the fabric registry, the per-topology
routing contract, campaign threading across torus / systolic /
heterogeneous fabrics, and the bit-identity regression pinning default
mesh campaigns to the pre-refactor snapshot."""

import json
import pathlib

import numpy as np
import pytest

from repro.core.campaign import CampaignGrid, DeploymentCache, run_campaign
from repro.core.routing import (HET_SLOW_RATE, DetourTopology, HetMesh2D,
                                Mesh2D, Systolic2D, Topology, Torus2D,
                                available_topologies, build_topology,
                                get_topology, mesh_mean_degree,
                                parse_topology_spec, register_topology,
                                topology_spec)
from repro.distributed.telemetry import PodSimulator, PodTelemetryConfig

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

DATA = pathlib.Path(__file__).parent / "data"

# every registered builtin, instantiated at a couple of shapes
FABRICS = [
    ("mesh", Mesh2D(4, 4)),
    ("mesh", Mesh2D(6, 3)),
    ("torus", Torus2D(4, 4)),
    ("torus", Torus2D(5, 3)),
    ("systolic", Systolic2D(4, 4)),
    ("systolic", Systolic2D(8, 8)),
    ("het", HetMesh2D(4, 4, "fast2slow1")),
]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_builtins_registered():
    assert set(available_topologies()) >= {"mesh", "torus", "systolic",
                                           "het"}
    assert get_topology("mesh") is Mesh2D
    assert get_topology("torus") is Torus2D
    assert get_topology("systolic") is Systolic2D
    assert get_topology("het") is HetMesh2D


def test_get_topology_unknown_lists_options():
    with pytest.raises(KeyError, match="mesh"):
        get_topology("bogus")


def test_register_topology_rejects_collision_and_bad_key():
    with pytest.raises(ValueError, match="registered"):
        register_topology("mesh", Torus2D)
    with pytest.raises(ValueError, match="identifier"):
        register_topology("4x4", Torus2D)
    register_topology("mesh", Mesh2D, overwrite=True)   # explicit wins


def test_build_topology_variant():
    het = build_topology("het:fast2slow1", 4, 4)
    assert isinstance(het, HetMesh2D)
    assert het.rate_class[2] == HET_SLOW_RATE
    plain = build_topology("torus", 4, 4)
    assert isinstance(plain, Torus2D)


def test_parse_topology_spec():
    assert parse_topology_spec(4) == ("mesh", 4, 4)
    assert parse_topology_spec((6, 3)) == ("mesh", 6, 3)
    assert parse_topology_spec("6x3") == ("mesh", 6, 3)
    assert parse_topology_spec("torus:8x8") == ("torus", 8, 8)
    assert parse_topology_spec("het:4x4:fast2slow1") == \
        ("het:fast2slow1", 4, 4)
    for bad in ("4x4x4", "bogus:4x4", "het:4x4:fast0slow0", 0,
                (4, 4, 4)):
        with pytest.raises((ValueError, KeyError)):
            parse_topology_spec(bad)


def test_topology_spec_round_trip():
    for spec in ("mesh:4x4", "torus:8x8", "het:4x4:fast2slow1"):
        topo, w, h = parse_topology_spec(spec)
        assert topology_spec(topo, w, h) == spec


# ---------------------------------------------------------------------------
# per-topology routing contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,topo", FABRICS,
                         ids=lambda v: v if isinstance(v, str)
                         else f"{v.width}x{v.height}")
def test_link_id_bijection(name, topo):
    assert topo.n_links == len(topo.links)
    assert len(set(topo.links)) == topo.n_links
    for lid, (u, v) in enumerate(topo.links):
        assert u != v
        assert topo.link_id(u, v) == lid


@pytest.mark.parametrize("name,topo", FABRICS,
                         ids=lambda v: v if isinstance(v, str)
                         else f"{v.width}x{v.height}")
def test_routes_walk_links_from_src_to_dst(name, topo):
    n = topo.n_cores
    for src in range(n):
        for dst in range(0, n, 3):
            path = topo.route(src, dst)
            assert len(path) == topo.hops(src, dst)
            cur = src
            for lid in path:
                u, v = topo.links[lid]
                assert u == cur
                cur = v
            assert cur == dst


@pytest.mark.parametrize("name,topo", FABRICS,
                         ids=lambda v: v if isinstance(v, str)
                         else f"{v.width}x{v.height}")
def test_links_of_router_matches_brute_force(name, topo):
    for core in range(topo.n_cores):
        expect = sorted(lid for lid, (u, v) in enumerate(topo.links)
                        if u == core or v == core)
        assert topo.links_of_router(core) == expect


@pytest.mark.parametrize("name,topo", FABRICS,
                         ids=lambda v: v if isinstance(v, str)
                         else f"{v.width}x{v.height}")
def test_route_avoiding_deterministic_and_honoured(name, topo):
    src, dst = 0, topo.n_cores - 1
    avoid = set(topo.route(src, dst)[:1])
    first = topo.route_avoiding(src, dst, avoid)
    assert first == topo.route_avoiding(src, dst, avoid)
    if first is not None:
        assert not (set(first) & avoid)
        cur = src
        for lid in first:
            u, v = topo.links[lid]
            assert u == cur
            cur = v
        assert cur == dst


def test_torus_wrap_distances():
    t = Torus2D(6, 6)
    # edge-to-edge neighbours are one wrap hop apart
    assert t.hops(t.core_id(0, 0), t.core_id(5, 0)) == 1
    assert t.hops(t.core_id(0, 0), t.core_id(0, 5)) == 1
    assert t.hops(t.core_id(0, 0), t.core_id(3, 3)) == 6
    # never worse than the mesh distance
    m = Mesh2D(6, 6)
    for src in range(36):
        for dst in range(36):
            assert t.hops(src, dst) <= m.hops(src, dst)


def test_systolic_unidirectional_with_wrap():
    s = Systolic2D(4, 4)
    for u, v in s.links:
        ux, uy = s.coords(u)
        vx, vy = s.coords(v)
        east = vy == uy and vx == (ux + 1) % 4
        south = vx == ux and vy == (uy + 1) % 4
        assert east or south
    # going "west" costs W-1 eastward hops (drain + edge re-injection)
    assert s.hops(s.core_id(1, 0), s.core_id(0, 0)) == 3


def test_mesh_mean_degree_matches_topology():
    for w, h in ((4, 4), (6, 3), (12, 8)):
        assert Mesh2D(w, h).mean_degree() == \
            pytest.approx(mesh_mean_degree(w, h))
    assert Torus2D(4, 4).mean_degree() > mesh_mean_degree(4, 4)


def test_het_rate_class_pattern():
    het = HetMesh2D(6, 1, "fast2slow1")
    assert het.rate_class.tolist() == [1.0, 1.0, HET_SLOW_RATE] * 2
    assert np.all(Mesh2D(4, 4).rate_class == 1.0)
    with pytest.raises(ValueError, match="pattern"):
        HetMesh2D(4, 4, "fast0slow0")


def test_detour_topology_wraps_any_fabric():
    base = Torus2D(4, 4)
    avoid = {base.route(0, 5)[0]}
    det = DetourTopology(base, avoid)
    assert det.n_cores == base.n_cores          # delegation
    path = det.route(0, 5)
    assert not (set(path) & avoid)
    assert det.path_matrix([(0, 5)]).shape == (1, base.n_links)


def test_base_topology_is_abstract():
    with pytest.raises(NotImplementedError):
        Topology(2, 2)


# ---------------------------------------------------------------------------
# campaign threading
# ---------------------------------------------------------------------------

def test_cross_topology_campaign_with_reroute():
    grid = CampaignGrid(workloads=("darknet19",),
                        meshes=("4x4", "torus:4x4", "systolic:8x8"),
                        kinds=("core", "link", "none"),
                        severities=(10.0,), reps=1, campaign_seed=3)
    res = run_campaign(grid, workers=0, detectors=("sloth",),
                       mitigation=("reroute",), cache=DeploymentCache())
    assert len(res.outcomes) == 3 * 3
    for o in res.outcomes:
        assert o.detector_results       # judged verdicts on every fabric
        assert o.topology in ("mesh", "torus", "systolic")
    table = res.by_topology()
    assert set(table) == {"mesh:4x4", "torus:4x4", "systolic:8x8"}
    for m in table.values():
        assert m.accuracy.trials == 2 and m.fpr.trials == 1
    # reroute acts on the torus core failure (material compute gap)
    torus_mit = [m for o in res.outcomes
                 if o.topology == "torus" and o.kind == "core"
                 for m in o.mitigation_results]
    assert any(m.acted for m in torus_mit)
    assert "torus:4x4" in res.summary()


def test_topology_cell_and_deploy_keys():
    grid = CampaignGrid(workloads=("darknet19",),
                        meshes=("4x4", "torus:4x4"), kinds=("none",),
                        severities=(8.0,), reps=1, campaign_seed=1)
    res = run_campaign(grid, workers=0, cache=DeploymentCache())
    cells = set(res.cells)
    assert ("darknet19", 4, 4, "none", 0.0, 0, "mesh") in cells
    assert ("darknet19", 4, 4, "none", 0.0, 0, "torus") in cells
    assert ("darknet19", "torus", 4, 4) in res.probe_overheads


def test_healthy_fpr_within_five_points_of_mesh():
    """Acceptance: re-derived thresholds keep healthy-fabric false-flag
    rates on torus/systolic within 5 points of the mesh baseline."""
    fprs = {}
    for spec in ("4x4", "torus:4x4", "systolic:4x4"):
        grid = CampaignGrid(workloads=("darknet19",), meshes=(spec,),
                            kinds=("none",), severities=(8.0,),
                            reps=5, campaign_seed=11)
        res = run_campaign(grid, workers=0, cache=DeploymentCache())
        label = next(iter(res.by_topology()))
        fprs[label] = res.metrics.fpr.rate
    assert fprs["torus:4x4"] <= fprs["mesh:4x4"] + 0.05
    assert fprs["systolic:4x4"] <= fprs["mesh:4x4"] + 0.05


def test_telemetry_pod_on_torus():
    """Both telemetry halves build their fabric through the registry
    from the one config field (the old code hard-coded Mesh2D twice)."""
    from repro.distributed.telemetry import PodDetector
    cfg = PodTelemetryConfig(mesh_w=4, mesh_h=4, topology="torus")
    pod = PodSimulator(cfg, step_flops=1e9, collective_bytes=1e6)
    assert isinstance(pod.mesh, Torus2D)
    det = PodDetector(cfg)
    assert isinstance(det.mesh, Torus2D)
    assert det.mesh.n_links == pod.mesh.n_links


# ---------------------------------------------------------------------------
# bit-identity regression vs the pre-refactor snapshot
# ---------------------------------------------------------------------------

def test_default_mesh_campaign_bit_identical_to_snapshot():
    """The snapshot in tests/data/ was captured from the pre-topology
    codebase; default W×H mesh campaigns must reproduce it bit for bit
    (same RNG streams, thresholds, verdicts, mitigation outcomes)."""
    base = json.loads((DATA / "mesh_campaign_baseline.json").read_text())
    g = base["grid"]
    grid = CampaignGrid(workloads=tuple(g["workloads"]),
                        meshes=tuple(tuple(m) for m in g["meshes"]),
                        kinds=tuple(g["kinds"]),
                        severities=tuple(g["severities"]),
                        n_failures=tuple(g["n_failures"]),
                        reps=g["reps"],
                        campaign_seed=g["campaign_seed"])
    res = run_campaign(grid, workers=0, executor="thread",
                       detectors=("sloth",),
                       mitigation=("reroute", "remap"),
                       cache=DeploymentCache())
    assert len(res.outcomes) == len(base["outcomes"])
    for o, b in zip(res.outcomes, base["outcomes"]):
        assert o.sim_seed == b["sim_seed"]
        assert list(o.truth_locations) == b["truth_locations"]
        assert list(o.truth_t0s) == b["truth_t0s"]
        assert list(o.truth_durations) == b["truth_durations"]
        assert o.compression_ratio == b["compression_ratio"]
        for r, br in zip(o.detector_results, b["detectors"]):
            assert r.flagged == br["flagged"]
            assert r.pred_kind == br["pred_kind"]
            assert r.pred_location == br["pred_location"]
            assert r.score == br["score"]           # exact float bits
            assert r.matched == br["matched"]
            assert r.truth_rank == br["truth_rank"]
            assert list(r.truth_ranks) == br["truth_ranks"]
        for m, bm in zip(o.mitigation_results, b["mitigation"]):
            assert m.policy == bm["policy"]
            assert m.acted == bm["acted"]
            assert m.correct == bm["correct"]
            assert list(m.exclude_cores) == bm["exclude_cores"]
            assert list(m.avoid_links) == bm["avoid_links"]
            assert m.healthy_time == bm["healthy_time"]
            assert m.failed_time == bm["failed_time"]
            assert m.mitigated_time == bm["mitigated_time"]


def test_lint_self_test_covers_topology_shape():
    from repro.analysis.lints import self_test
    self_test()
