"""End-to-end system behaviour: training convergence, crash-resume,
straggler mitigation loop."""

import numpy as np
import pytest

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def test_training_loss_decreases(tmp_path):
    """Memorisation check: a fixed batch must be learnable well below the
    uniform-entropy floor (the synthetic stream itself is uniform, so the
    launcher integration test asserts continuity, not convergence)."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_config
    from repro.launch.steps import CellPlan, make_train_step
    from repro.models import transformer as T
    from repro.optim import adamw
    cfg = get_config("smollm-135m", smoke=True)
    params = T.init_model(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=2000,
                                min_lr_frac=1.0)
    opt = adamw.init_state(params, opt_cfg)
    step = jax.jit(make_train_step(
        cfg, CellPlan(grad_accum=1, remat=False,
                      param_dtype=jnp.float32), opt_cfg))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                cfg.vocab)
    losses = []
    for _ in range(40):
        params, opt, loss, _ = step(params, opt, tokens)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_crash_resume_continuity(tmp_path):
    """Training N steps straight equals training with a crash+resume in the
    middle (same data stream, same final loss)."""
    from repro.launch.train import main
    ck1 = str(tmp_path / "a")
    ck2 = str(tmp_path / "b")
    full = main(["--arch", "smollm-135m", "--smoke", "--steps", "20",
                 "--batch", "2", "--seq", "32", "--ckpt-dir", ck1,
                 "--ckpt-every", "10", "--log-every", "100"])
    main(["--arch", "smollm-135m", "--smoke", "--steps", "10",
          "--batch", "2", "--seq", "32", "--ckpt-dir", ck2,
          "--ckpt-every", "10", "--log-every", "100"])
    resumed = main(["--arch", "smollm-135m", "--smoke", "--steps", "20",
                    "--batch", "2", "--seq", "32", "--ckpt-dir", ck2,
                    "--resume", "--log-every", "100"])
    assert resumed[-1] == pytest.approx(full[-1], rel=1e-4)


def test_mitigation_policy_actions():
    from repro.distributed.telemetry import MitigationPolicy, PodVerdict
    pol = MitigationPolicy(n_shards=4)
    assert pol.plan(PodVerdict(False, None, None, 0, "none"))["action"] \
        == "none"
    plan = pol.plan(PodVerdict(True, "core", 5, 4.0, "rebalance"))
    assert plan["action"] == "rebalance"
    w = plan["shard_weights"]
    assert w.sum() == pytest.approx(1.0) and w[1] < w[0]
    plan = pol.plan(PodVerdict(True, "core", 5, 12.0,
                               "exclude_and_restart"))
    assert plan["action"] == "exclude_and_restart"
    assert plan["exclude"] == ("core", 5)


def test_pod_link_failure_detected():
    from repro.core.failures import FailSlow
    from repro.distributed.telemetry import (PodDetector, PodSimulator,
                                             PodTelemetryConfig)
    cfg = PodTelemetryConfig(mesh_w=4, mesh_h=4)
    pod = PodSimulator(cfg, step_flops=5e12, collective_bytes=4e9, seed=1)
    pod.inject(FailSlow("link", 11, 0.0, 1e9, 8.0))
    det = PodDetector(cfg)
    v = det.analyse(pod.run_steps(48))
    assert v.flagged and v.kind == "link" and v.location == 11
