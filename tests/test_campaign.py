"""Scenario-campaign subsystem: deterministic derivation, metric
semantics, and equivalence with serial ``Sloth.detect``."""

import dataclasses

import pytest

from repro.core.campaign import (CampaignGrid, DeploymentCache,
                                 enumerate_scenarios, materialise,
                                 run_campaign, truth_candidates)
from repro.core.failures import FailSlow
from repro.core.graph import build_workload
from repro.core.metrics import (BinomialStat, aggregate, wilson_interval)
from repro.core.routing import Mesh2D
from repro.core.sloth import Sloth

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

SMALL = CampaignGrid(workloads=("darknet19",), meshes=(4,),
                     kinds=("core", "link", "router", "none"),
                     severities=(8.0,), reps=1, campaign_seed=11)


@pytest.fixture(scope="module")
def small_result():
    return run_campaign(SMALL, workers=2)


# ---------------------------------------------------------------------------
# grid enumeration + scenario derivation
# ---------------------------------------------------------------------------

def test_grid_enumeration_counts():
    g = CampaignGrid(workloads=("darknet19", "binary_tree"), meshes=(4, 6),
                     kinds=("core", "link", "none"), severities=(5.0, 10.0),
                     reps=3)
    scen = enumerate_scenarios(g)
    assert len(scen) == g.n_scenarios()
    # 2 wl × 2 mesh × (2 kinds × 2 sev × 3 + 1 none × 3)
    assert len(scen) == 2 * 2 * (2 * 2 * 3 + 3)
    assert [s.scenario_id for s in scen] == list(range(len(scen)))


def test_grid_rejects_unknown_kind():
    with pytest.raises(ValueError):
        CampaignGrid(kinds=("core", "gremlin"))


def test_scenario_derivation_no_global_rng(small_result):
    """Materialisation depends only on scenario coordinates: re-deriving
    any single scenario in isolation reproduces the campaign's draw."""
    cache = DeploymentCache()
    for o in small_result.outcomes:
        s = next(s for s in enumerate_scenarios(SMALL)
                 if s.scenario_id == o.scenario_id)
        dep = cache.get(s.workload, s.mesh_w, s.mesh_h)
        failures, sim_seed = materialise(SMALL, s, dep)
        assert sim_seed == o.sim_seed
        if o.kind == "none":
            assert failures == ()
        else:
            assert tuple(f.location for f in failures) == o.truth_locations
            assert tuple(f.t0 for f in failures) == o.truth_t0s
            assert tuple(f.duration for f in failures) == o.truth_durations
            assert all(f.slowdown == o.severity for f in failures)


def test_campaign_deterministic(small_result):
    """Same seed → bit-identical outcomes and aggregate metrics, for any
    worker count."""
    again = run_campaign(SMALL, workers=1, cache=DeploymentCache())
    assert again.outcomes == small_result.outcomes
    assert again.metrics == small_result.metrics
    assert again.cells == small_result.cells


def test_different_seed_differs():
    g = dataclasses.replace(SMALL, campaign_seed=12, kinds=("core",),
                            reps=2)
    a = run_campaign(g, workers=0)
    b = run_campaign(dataclasses.replace(g, campaign_seed=13), workers=0)
    assert [o.sim_seed for o in a.outcomes] != [o.sim_seed
                                                for o in b.outcomes]


# ---------------------------------------------------------------------------
# metric semantics
# ---------------------------------------------------------------------------

def test_negative_cells_feed_fpr_not_accuracy(small_result):
    pos = [o for o in small_result.outcomes if o.kind != "none"]
    neg = [o for o in small_result.outcomes if o.kind == "none"]
    assert neg and pos
    m = small_result.metrics
    assert m.accuracy.trials == len(pos)
    assert m.fpr.trials == len(neg)
    # the 'none' cell aggregates to zero accuracy trials
    none_cells = {c: v for c, v in small_result.cells.items()
                  if c[3] == "none"}
    assert none_cells
    for v in none_cells.values():
        assert v.accuracy.trials == 0 and v.fpr.trials > 0


def test_topk_monotone_in_k(small_result):
    m = aggregate(small_result.outcomes, ks=(1, 2, 3, 5, 10))
    rates = [stat.rate for _, stat in m.topk]
    assert all(a <= b for a, b in zip(rates, rates[1:]))
    # top-1 agrees with matched-rate at least for core/link truths
    # (router truths can be matched only via their links)
    assert m.topk_rate(1) >= m.accuracy.rate - 1e-12


def test_wilson_interval_sane():
    lo, hi = wilson_interval(0, 0)
    assert (lo, hi) == (0.0, 1.0)
    lo, hi = wilson_interval(9, 10)
    assert 0.0 < lo < 0.9 < hi <= 1.0
    s = BinomialStat(9, 10)
    assert s.rate == pytest.approx(0.9) and s.interval == (lo, hi)


def test_truth_candidates_router_maps_to_links():
    mesh = Mesh2D(4)
    f = FailSlow("router", 5, 0.0, 1.0, 8.0)
    cands = truth_candidates(f, mesh)
    assert cands == {("link", lid) for lid in mesh.links_of_router(5)}
    f = FailSlow("core", 5, 0.0, 1.0, 8.0)
    assert truth_candidates(f, mesh) == {("core", 5)}


def test_verdict_matches_router_truths():
    """Regression: `Verdict.matches` must accept a router truth when the
    verdict names any link of the slowed router — it used to compare
    (kind, location) literally, so router truths could never match."""
    from repro.core.sloth import Verdict
    mesh = Mesh2D(4)
    router = 5
    lid = mesh.links_of_router(router)[0]
    v = Verdict(flagged=True, kind="link", location=lid, score=1.0,
                ranking=[("link", lid, 1.0)], recorder=None, failrank=None,
                mcg=None, total_time=1.0, mesh=mesh)
    hit = FailSlow("router", router, 0.0, 1.0, 8.0)
    assert v.matches(hit)
    assert v.matches(hit, mesh)         # explicit mesh overrides
    # a different router that does not own `lid` must not match
    other = next(c for c in range(mesh.n_cores)
                 if lid not in mesh.links_of_router(c))
    assert not v.matches(FailSlow("router", other, 0.0, 1.0, 8.0))
    # core/link truths keep exact-match semantics
    assert not v.matches(FailSlow("core", 5, 0.0, 1.0, 8.0))
    assert v.matches(FailSlow("link", lid, 0.0, 1.0, 8.0))
    assert not v.matches(None)          # flagged verdict vs negative truth
    # a mesh-less verdict cannot judge router truths
    bare = dataclasses.replace(v, mesh=None)
    with pytest.raises(ValueError):
        bare.matches(hit)


# ---------------------------------------------------------------------------
# campaign ≡ serial Sloth.detect
# ---------------------------------------------------------------------------

def test_campaign_matches_serial_detect(small_result):
    """The campaign's verdicts are exactly what a serial `Sloth.detect`
    produces for the same materialised failure and seed."""
    sloths = {}
    for o in small_result.outcomes:
        key = (o.workload, o.mesh_w, o.mesh_h)
        if key not in sloths:
            sloths[key] = Sloth(build_workload(o.workload),
                                Mesh2D(o.mesh_w, o.mesh_h))
        sloth = sloths[key]
        failures = [FailSlow(o.kind, loc, t0, dur, o.severity)
                    for loc, t0, dur in zip(o.truth_locations, o.truth_t0s,
                                            o.truth_durations)] or None
        v = sloth.detect(failures, seed=o.sim_seed)
        assert bool(v.flagged) == o.flagged
        assert v.kind == o.pred_kind
        assert v.location == o.pred_location
        assert float(v.score) == o.score


# ---------------------------------------------------------------------------
# substrate quality: the detector actually works across the grid
# ---------------------------------------------------------------------------

def test_campaign_detects_most_injected_failures(small_result):
    m = small_result.metrics
    assert m.accuracy.trials >= 3
    assert m.topk_rate(5) >= 0.5          # truth ranked for most positives
    assert m.mean_compression > 10
    assert 0 <= m.mean_probe_overhead < 0.2


def test_link_router_placements_use_live_resources(small_result):
    """Injected link/router failures land on resources the healthy run
    exercises (paper: unused-resource failures are excluded)."""
    cache = DeploymentCache()
    dep = cache.get("darknet19", 4, 4)
    for o in small_result.outcomes:
        if o.kind == "link":
            assert o.truth_location in dep.used_links
        elif o.kind == "router":
            assert o.truth_location in dep.used_routers


def test_materialise_rejects_unusable_kind():
    cache = DeploymentCache()
    dep = dataclasses.replace(cache.get("darknet19", 4, 4),
                              used_links=(), used_routers=())
    s = next(s for s in enumerate_scenarios(SMALL) if s.kind == "link")
    with pytest.raises(ValueError, match="no used links"):
        materialise(SMALL, s, dep)


def test_baselines_judged_router_aware():
    """Baseline verdicts naming a slowed router's link count as matches
    (no detector emits kind='router')."""
    from repro.core.detectors import DEFAULT_DETECTORS
    g = dataclasses.replace(SMALL, kinds=("router",), reps=1)
    res = run_campaign(g, workers=0, detectors=DEFAULT_DETECTORS,
                       cache=DeploymentCache())
    (o,) = res.outcomes
    assert tuple(d.detector for d in o.detector_results) \
        == DEFAULT_DETECTORS
    assert len(o.baseline_results) == 5       # deprecated view: non-primary
    for d in o.detector_results:
        if d.matched:                # a match implies the detector flagged
            assert d.flagged


def test_deployment_cache_reused():
    from repro.core.detectors import DEFAULT_DETECTORS
    cache = DeploymentCache()
    a = cache.get("darknet19", 4, 4)
    b = cache.get("darknet19", 4, 4)
    assert a is b
    c = cache.get("darknet19", 4, 4, detectors=DEFAULT_DETECTORS)
    assert c is not a and len(c.detectors) == 6


def test_deployment_cache_normalises_default_cfg():
    """Regression: `cfg=None` and an explicit default `SlothConfig()` must
    hit the same cache entry instead of building twice."""
    from repro.core.sloth import SlothConfig
    cache = DeploymentCache()
    a = cache.get("darknet19", 4, 4)
    b = cache.get("darknet19", 4, 4, cfg=SlothConfig())
    assert a is b
    # a genuinely different config still gets its own deployment
    c = cache.get("darknet19", 4, 4,
                  cfg=SlothConfig(detect_threshold=0.9))
    assert c is not a
