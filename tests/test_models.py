"""Per-architecture smoke tests (reduced configs, one fwd/train step on
CPU) + KV-cache equivalence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_archs
from repro.models import transformer as T

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

ARCHS = list_archs()


def _inputs(cfg, rng, b=2, s=24):
    tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab)
    kw = {}
    if cfg.enc_dec:
        kw["enc_frames"] = jax.random.normal(
            rng, (b, cfg.n_frames, cfg.d_model)) * 0.02
    return tokens, kw


def test_all_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    rng = jax.random.PRNGKey(0)
    params = T.init_model(cfg, rng, dtype=jnp.float32)
    tokens, kw = _inputs(cfg, rng)
    logits, aux = T.forward_train(cfg, params, tokens, **kw)
    assert logits.shape == (2, 24, cfg.vocab)
    assert not np.any(np.isnan(np.asarray(logits)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ["smollm-135m", "mixtral-8x7b",
                                  "mamba2-1.3b", "jamba-1.5-large-398b",
                                  "whisper-large-v3"])
def test_smoke_train_step(arch):
    from repro.launch.steps import CellPlan, make_train_step
    from repro.optim import adamw
    cfg = get_config(arch, smoke=True)
    rng = jax.random.PRNGKey(0)
    params = T.init_model(cfg, rng, dtype=jnp.float32)
    opt_cfg = adamw.AdamWConfig()
    opt = adamw.init_state(params, opt_cfg)
    step = jax.jit(make_train_step(
        cfg, CellPlan(grad_accum=1, remat=False,
                      param_dtype=jnp.float32), opt_cfg))
    tokens, kw = _inputs(cfg, rng)
    args = (params, opt, tokens) + ((kw["enc_frames"],) if cfg.enc_dec
                                    else ())
    p2, o2, loss, gnorm = step(*args)
    assert np.isfinite(float(loss)) and float(loss) > 0
    assert np.isfinite(float(gnorm))
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, p2))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_equivalence(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.n_experts:   # avoid capacity-truncation mismatches
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    rng = jax.random.PRNGKey(0)
    params = T.init_model(cfg, rng, dtype=jnp.float32)
    tokens, kw = _inputs(cfg, rng)
    logits, _ = T.forward_train(cfg, params, tokens, **kw)
    cache = T.init_cache(cfg, 2, 64, dtype=jnp.float32)
    last, cache, mem = T.prefill(cfg, params, tokens, cache, **kw)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(logits[:, -1]),
                               rtol=2e-4, atol=2e-4)
    nxt = jax.random.randint(jax.random.PRNGKey(7), (2, 1), 0, cfg.vocab)
    dec, _ = T.decode_step(cfg, params, nxt, cache, jnp.int32(24),
                           memory=mem)
    full, _ = T.forward_train(cfg, params,
                              jnp.concatenate([tokens, nxt], 1), **kw)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_masks_history():
    """SWA: logits must be invariant to tokens beyond the window."""
    cfg = dataclasses.replace(get_config("h2o-danube-3-4b", smoke=True),
                              window=8)
    rng = jax.random.PRNGKey(0)
    params = T.init_model(cfg, rng, dtype=jnp.float32)
    t1 = jax.random.randint(rng, (1, 32), 0, cfg.vocab)
    t2 = t1.at[:, :8].set((t1[:, :8] + 7) % cfg.vocab)  # mutate old tokens
    l1, _ = T.forward_train(cfg, params, t1)
    l2, _ = T.forward_train(cfg, params, t2)
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               rtol=1e-5, atol=1e-5)


def test_param_counts_match_claims():
    expect = {"yi-34b": 34e9, "mixtral-8x7b": 46.7e9, "dbrx-132b": 132e9,
              "jamba-1.5-large-398b": 398e9, "smollm-135m": 135e6}
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.05, (arch, got, n)
