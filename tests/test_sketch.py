"""Fail-Slow Sketch: Algorithm-1 semantics, run-compression exactness,
jnp/Pallas parity, and the Lemma 3.1 retention bound."""

import numpy as np
import pytest
from proptest import given, settings, st

from repro.core.sketch import (FailSlowSketch, SketchParams,
                               retention_lower_bound, split_key)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31), st.data())
def test_run_equals_records(seed, data):
    """insert_run(key, r, ...) ≡ r sequential insert() calls — exactly."""
    rng = np.random.default_rng(seed)
    p = SketchParams(d=data.draw(st.integers(1, 3)),
                     m=data.draw(st.sampled_from([16, 64])),
                     H=data.draw(st.integers(1, 8)), L=8)
    n = 60
    keys = rng.integers(0, 25, size=n)
    reps = rng.integers(1, 9, size=n)
    durs = rng.random(n)
    t0s = np.cumsum(rng.random(n))
    a, b = FailSlowSketch(p), FailSlowSketch(p)
    for k, r, d, t in zip(keys, reps, durs, t0s):
        a.insert_run(int(k), int(r), float(d), float(2 * d), float(t), 0.01)
        for j in range(int(r)):
            b.insert(int(k), float(d), float(2 * d), float(t + 0.01 * j))
    assert np.array_equal(a.freq, b.freq)
    assert np.array_equal(a.valid, b.valid)
    assert set(a.stage2) == set(b.stage2)
    for k in a.stage2:
        pa, pb = a.stage2[k], b.stage2[k]
        assert pa.count == pb.count and pa.arrival == pb.arrival
        assert pa.sum_dur == pytest.approx(pb.sum_dur)
        assert pa.min_dur == pytest.approx(pb.min_dur)


def test_promotion_threshold():
    p = SketchParams(d=1, m=8, H=5, L=4)
    s = FailSlowSketch(p)
    for i in range(4):
        s.insert(42, 0.1, 1.0, float(i))
    assert len(s.stage2) == 0           # below threshold
    s.insert(42, 0.1, 1.0, 4.0)
    assert 42 in s.stage2               # promoted exactly at H
    assert s.stage2[42].count == 1      # stats start at promotion


def test_fifo_eviction_and_drain():
    p = SketchParams(d=1, m=64, H=1, L=2)
    s = FailSlowSketch(p)
    for k in (1, 2, 3):
        s.insert(k, 0.1, 1.0, float(k))
    assert len(s.stage2) == 2
    assert 1 not in s.stage2            # earliest-arrival evicted
    assert s.n_evicted == 1
    # drained patterns still recoverable for analysis
    keys = {q.key for q in s.patterns(include_drained=True)}
    assert keys == {1, 2, 3}


def test_majority_decrement():
    p = SketchParams(d=1, m=1, H=100, L=4)   # force collisions
    s = FailSlowSketch(p)
    for _ in range(5):
        s.insert(7, 0.1, 1.0, 0.0)
    assert s.freq[0, 0] == 5
    for _ in range(3):
        s.insert(9, 0.1, 1.0, 0.0)           # decrements
    assert s.freq[0, 0] == 2 and s.keys_lo[0, 0] == 7
    for _ in range(3):
        s.insert(9, 0.1, 1.0, 0.0)           # clears then claims
    assert s.keys_lo[0, 0] == 9 and s.freq[0, 0] == 1


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31))
def test_retention_bound(seed):
    """Hot patterns are retained at least as often as Lemma 3.1 predicts."""
    rng = np.random.default_rng(seed)
    p = SketchParams(d=2, m=64, H=4, L=64)
    hot, f_hot = 999, 64
    n_noise = 512
    keys = np.concatenate([np.full(f_hot, hot),
                           rng.integers(0, 5000, n_noise) + 1000])
    rng.shuffle(keys)
    s = FailSlowSketch(p)
    for i, k in enumerate(keys):
        s.insert(int(k), 0.1, 1.0, float(i))
    bound = retention_lower_bound(len(keys), f_hot, p)
    if bound >= 0.999:                  # near-certain retention predicted
        assert hot in s.stage2


@settings(max_examples=30, deadline=None)
@given(st.floats(0.0, 1e6), st.floats(0.0, 1e6), st.integers(1, 8),
       st.sampled_from([16, 64, 1024]), st.integers(1, 32))
def test_retention_bound_is_a_probability(N, f_i, d, m, H):
    """Lemma 3.1 lower bound stays in [0, 1] over the whole domain —
    notably N < f_i with odd d, where the unclamped 1 − x**d exceeds 1."""
    p = SketchParams(d=d, m=m, H=H, L=8)
    b = retention_lower_bound(N, f_i, p)
    assert 0.0 <= b <= 1.0, (N, f_i, d, m, H, b)


def test_retention_bound_clamped_above():
    """Regression: N < f_i and odd d made the bound exceed 1 (x < 0 ⇒
    1 − x**d > 1); it must clamp to exactly 1.0."""
    p = SketchParams(d=3, m=64, H=4, L=8)
    assert retention_lower_bound(10.0, 100.0, p) == 1.0


def test_split_key_roundtrip():
    keys = np.array([0, 1, 2**31 - 1, 2**40, 2**62 - 1], dtype=np.int64)
    lo, hi = split_key(keys)
    back = lo.astype(np.int64) + (hi.astype(np.int64) << 31)
    assert np.array_equal(back, keys)


def test_memory_budget():
    """Default config stays within the paper's ~150 KiB on-chip budget for
    the pair of sketches (comp + comm)."""
    p = SketchParams()
    assert 2 * p.total_bytes() <= 160 * 1024
