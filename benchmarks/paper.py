"""Benchmarks reproducing each table/figure of the SLOTH paper.

Each function returns a list of CSV rows ``(name, us_per_call, derived)``
where ``derived`` is the figure's headline quantity.  ``quick`` keeps CPU
runtime bounded; ``BENCH_FULL=1`` scales to paper-size datasets.
"""

from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from repro.core import campaign as C
from repro.core import detectors as D
from repro.core import metrics as M
from repro.core.failures import FailSlow, effective_samples, make_dataset
from repro.core.graph import build_workload
from repro.core.recorder import record
from repro.core.routing import Mesh2D
from repro.core.sloth import Sloth, SlothConfig
from repro.core.sketch import SketchParams

WORKLOADS = ("darknet19", "googlenet", "vgg16", "resnet50", "binary_tree")

FULL = os.environ.get("BENCH_FULL", "0") == "1"


def _used_links(sloth: Sloth, sim) -> set[int]:
    used = set()
    for s, d in zip(sim.comm["src"], sim.comm["dst"]):
        if s != d:
            used.update(sloth.mesh.route(int(s), int(d)))
    return used


# ---------------------------------------------------------------------------
# Fig 1b: impact of one fail-slow on end-to-end time (ResNet-50, 4×4, 10×)
# ---------------------------------------------------------------------------

def bench_impact():
    """Persistent 10× fail-slow on the busiest link / router / core of a
    comm-heavy ResNet-50 mapping (max_parts=4, fan-in 10, NoC-class link
    bandwidth).  Paper reports 1.26×/1.67×/2.48×; our platform reproduces
    the ordering (core > router > link) with router ≈ paper."""
    from repro.core.mapping import map_graph
    from repro.core.simulator import SimConfig, calibrate, simulate
    mesh = Mesh2D(4)
    g = build_workload("resnet50")
    mg = map_graph(g, mesh, shuffle_fanin=10, max_parts=4)
    cfg = SimConfig(mu_c=calibrate(g.total_flops(), mesh.n_cores),
                    link_bw=64e9 / 256, seed=0)
    t0 = time.perf_counter()
    base = simulate(mg, cfg)
    cnt = np.zeros(mesh.n_links)
    for s, d, b in zip(base.comm["src"], base.comm["dst"],
                       base.comm["bytes"]):
        if s != d:
            for lid in mesh.route(int(s), int(d)):
                cnt[lid] += b
    busiest = int(np.argmax(cnt))
    busy_core = int(np.argmax(np.bincount(
        base.comp["core"], weights=base.comp["flops"], minlength=16)))
    rows = []
    for kind, loc in (("link", busiest), ("router", busy_core),
                      ("core", busy_core)):
        t = simulate(mg, cfg,
                     failures=[FailSlow(kind, loc, 0.0, 1e9, 10.0)])
        rows.append((f"fig1b_{kind}_slowdown", 0.0,
                     round(float(t.total_time / base.total_time), 2)))
    us = (time.perf_counter() - t0) / 4 * 1e6
    return [(r[0], round(us, 1), r[2]) for r in rows]


# ---------------------------------------------------------------------------
# Table III: detection accuracy / FPR, SLOTH vs 5 baselines, 5 workloads
# ---------------------------------------------------------------------------

def bench_accuracy(n_failures=None):
    """Campaign-driven Table III: one scenario grid over the five
    workloads, every registered detector (SLOTH + the five baselines)
    judged on the same traces through the unified detector API."""
    n_failures = n_failures or (152 if FULL else 24)
    reps = max(2, n_failures // 4)
    detectors = D.DEFAULT_DETECTORS
    grid = C.CampaignGrid(workloads=WORKLOADS, meshes=(4,),
                          kinds=("core", "link", "none"),
                          severities=(10.0,), reps=reps, campaign_seed=3)
    # fresh cache, pre-built deployments, serial dispatch: the timed
    # region covers scenario execution (one simulate + 6 detector
    # analyses) only and is independent of core count, so us_per_call is
    # reproducible and comparable across invocations
    cache = C.DeploymentCache()
    for wl in WORKLOADS:
        cache.get(wl, 4, 4, detectors=detectors)
    t0 = time.perf_counter()
    res = C.run_campaign(grid, detectors=detectors, cache=cache, workers=1)
    us = (time.perf_counter() - t0) / max(len(res.outcomes), 1) * 1e6
    rows = []
    agg = {}
    for wl in WORKLOADS:
        sub = [o for o in res.outcomes if o.workload == wl]
        for name, m in M.by_detector(sub).items():
            acc, fpr = m.accuracy, m.fpr
            rows.append((f"tab3_{wl}_{name}_acc", round(us, 1),
                         round(acc.pct(), 2)))
            rows.append((f"tab3_{wl}_{name}_fpr", round(us, 1),
                         round(fpr.pct(), 2)))
            agg.setdefault(name, []).append((acc.pct(), fpr.pct()))
    for name, vals in agg.items():
        rows.append((f"tab3_avg_{name}_acc", 0.0,
                     round(float(np.mean([a for a, _ in vals])), 2)))
        rows.append((f"tab3_avg_{name}_fpr", 0.0,
                     round(float(np.mean([f for _, f in vals])), 2)))
    return rows


# ---------------------------------------------------------------------------
# Fig 10: probe time overhead (comm / comp / full)
# ---------------------------------------------------------------------------

def bench_probe_overhead():
    from repro.core.compiler import plan_for_mode
    mesh = Mesh2D(4)
    rows = []
    for wl in WORKLOADS:
        sloth = Sloth(build_workload(wl), mesh)
        import dataclasses as dc
        base = None
        t0 = time.perf_counter()
        for mode in ("none", "comm", "comp", "full"):
            plan = plan_for_mode(mode)
            from repro.core.simulator import simulate
            cfg = dc.replace(sloth.sim_cfg, seed=0)
            t = simulate(sloth.mapped, cfg, probes=plan).total_time
            if mode == "none":
                base = t
            else:
                rows.append((f"fig10_{wl}_{mode}_overhead_pct", 0.0,
                             round((t / base - 1) * 100, 3)))
        us = (time.perf_counter() - t0) / 4 * 1e6
        rows = [(n, round(us, 1) if n.startswith(f"fig10_{wl}") and u == 0.0
                 else u, d) for n, u, d in rows]
    return rows


# ---------------------------------------------------------------------------
# Fig 11/12: storage cost (raw vs IASO/Perseus/ADR vs SL-Recorder)
# ---------------------------------------------------------------------------

def bench_storage():
    mesh = Mesh2D(4)
    rows = []
    ratios = []
    for wl in WORKLOADS:
        sloth = Sloth(build_workload(wl), mesh)
        sim = sloth.run(None, seed=0)
        t0 = time.perf_counter()
        rec = record(sim, sloth.cfg.sketch,
                     hop_latency=sloth.sim_cfg.hop_latency)
        us = (time.perf_counter() - t0) * 1e6
        # baseline retention models: IASO keeps full comm traces minus
        # 30-40% (timeout aggregation); Perseus/ADR keep per-instruction
        # records for regression / adaptive thresholds (25-50% savings).
        iaso = int(rec.raw_comm_bytes * 0.65)
        perseus = int(rec.raw_comp_bytes * 0.60)
        adr = int(rec.raw_comp_bytes * 0.70)
        rows += [
            (f"fig11_{wl}_raw_comm_KiB", round(us, 1),
             round(rec.raw_comm_bytes / 1024, 1)),
            (f"fig11_{wl}_iaso_KiB", 0.0, round(iaso / 1024, 1)),
            (f"fig11_{wl}_sketch_comm_KiB", 0.0,
             round(rec.sketch_comm_bytes / 1024, 1)),
            (f"fig12_{wl}_raw_comp_KiB", 0.0,
             round(rec.raw_comp_bytes / 1024, 1)),
            (f"fig12_{wl}_perseus_KiB", 0.0, round(perseus / 1024, 1)),
            (f"fig12_{wl}_adr_KiB", 0.0, round(adr / 1024, 1)),
            (f"fig12_{wl}_sketch_comp_KiB", 0.0,
             round(rec.sketch_comp_bytes / 1024, 1)),
        ]
        ratios.append(rec.compression_ratio)
        rows.append((f"storage_{wl}_compression_x", 0.0,
                     round(rec.compression_ratio, 1)))
    rows.append(("storage_avg_compression_x", 0.0,
                 round(float(np.mean(ratios)), 1)))
    return rows


# ---------------------------------------------------------------------------
# recorder pipeline: per-run numpy oracle vs on-device batched run path
# ---------------------------------------------------------------------------

def bench_recorder(reps=None):
    """Recorder wall time, ``impl="ref"`` (per-run numpy oracle) vs
    ``impl="batched"`` (run-compressed on-device scan with the
    drained-eviction stream), on healthy traces of the campaign-default
    workload and the comm-heavy ResNet-50.  Asserts pattern parity —
    identical key sets, counts, arrival order, drained-row counts and
    compression ratios — before reporting timings, so the speedup rows
    can only exist when both paths compress identically."""
    reps = reps or (10 if FULL else 4)
    mesh = Mesh2D(4)
    rows = []
    for wl in ("darknet19", "resnet50"):
        sloth = Sloth(build_workload(wl), mesh)
        sim = sloth.run(None, seed=0)
        hop = sloth.sim_cfg.hop_latency

        def run(impl):
            return record(sim, sloth.cfg.sketch,
                          hop_latency=hop, impl=impl)

        ref, bat = run("ref"), run("batched")   # batched call also warms jit
        for side in ("comp", "comm"):
            pr = {p.key: p for p in getattr(ref, side + "_patterns")}
            pb = {p.key: p for p in getattr(bat, side + "_patterns")}
            assert set(pr) == set(pb), f"{wl} {side}: key sets diverge"
            assert all(pr[k].count == pb[k].count
                       and pr[k].arrival == pb[k].arrival for k in pr), \
                f"{wl} {side}: counts/arrivals diverge"
        assert ref.compression_ratio == bat.compression_ratio
        assert (ref.n_comp_drained, ref.n_comm_drained) \
            == (bat.n_comp_drained, bat.n_comm_drained)

        t0 = time.perf_counter()
        for _ in range(reps):
            run("ref")
        us_ref = (time.perf_counter() - t0) / reps * 1e6
        t0 = time.perf_counter()
        for _ in range(reps):
            run("batched")
        us_bat = (time.perf_counter() - t0) / reps * 1e6
        rows += [
            (f"recorder_{wl}_ref_us", round(us_ref, 1),
             round(us_ref / 1e3, 2)),
            (f"recorder_{wl}_batched_us", round(us_bat, 1),
             round(us_bat / 1e3, 2)),
            (f"recorder_{wl}_batched_speedup_x", 0.0,
             round(us_ref / us_bat, 2)),
            (f"recorder_{wl}_compression_x", 0.0,
             round(ref.compression_ratio, 1)),
        ]
    return rows


# ---------------------------------------------------------------------------
# Fig 13: sketch parameter sensitivity (H, B, S, T heatmaps)
# ---------------------------------------------------------------------------

def bench_sketch_params():
    mesh = Mesh2D(4)
    sloth = Sloth(build_workload("darknet19"), mesh)
    sim = sloth.run(None, seed=0)
    rows = []
    hop = sloth.sim_cfg.hop_latency

    def ratio(p):
        rec = record(sim, p, hop_latency=hop)
        return rec.compression_ratio

    for d in (1, 2, 4):
        for m in (256, 1024, 4096):
            r = ratio(SketchParams(d=d, m=m, H=8, L=1024))
            rows.append((f"fig13_hash{d}_bucket{m}_ratio", 0.0, round(r, 1)))
    for L in (128, 512, 2048):
        for H in (2, 8, 32):
            r = ratio(SketchParams(d=2, m=1024, H=H, L=L))
            rows.append((f"fig13_size{L}_thresh{H}_ratio", 0.0, round(r, 1)))
    return rows


# ---------------------------------------------------------------------------
# Fig 14: design-space exploration, COST = ACC^α · R^β · M^γ
# ---------------------------------------------------------------------------

def bench_dse(n_samples=None):
    n_samples = n_samples or (24 if FULL else 10)
    mesh = Mesh2D(4)
    rows = []
    grid = [SketchParams(d=d, m=m, H=H, L=L)
            for d in (1, 2) for m in (256, 1024)
            for H in (4, 16) for L in (256, 1024)]
    for wl in ("darknet19", "binary_tree"):
        sloth_base = Sloth(build_workload(wl), mesh)
        healthy = sloth_base.run(None, seed=999)
        ds = effective_samples(make_dataset(mesh, n_samples, seed=3),
                               healthy.total_time,
                               _used_links(sloth_base, healthy))
        sims = [(s, sloth_base.run([s.failure] if s.failure else None,
                                   seed=100 + s.sample_id)) for s in ds]
        best = (1e30, None)
        for p in grid:
            cfg = SlothConfig(sketch=p)
            sloth = Sloth(sloth_base.graph, mesh, cfg=cfg)
            ok = n = 0
            ratio = 1.0
            for s, sim in sims:
                v = sloth.analyse(sim)
                ok += v.matches(s.failure)
                n += 1
                ratio = v.recorder.compression_ratio
            acc = max(ok / max(n, 1), 1e-3)
            mem = p.total_bytes() / 1024
            cost = (acc ** -1) * (1.0 / max(ratio, 1e-9)) * mem
            rows.append((f"fig14_{wl}_d{p.d}_m{p.m}_H{p.H}_L{p.L}_cost",
                         0.0, round(cost, 4)))
            if cost < best[0]:
                best = (cost, p)
        rows.append((f"fig14_{wl}_pareto", 0.0,
                     f"d{best[1].d}_m{best[1].m}_H{best[1].H}_L{best[1].L}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 15: FailRank convergence
# ---------------------------------------------------------------------------

def bench_failrank_convergence():
    mesh = Mesh2D(4)
    sloth = Sloth(build_workload("resnet50"), mesh)
    rows = []
    cases = [FailSlow("core", 5, 1.0, 8.0), FailSlow("core", 10, 2.0, 5.0),
             FailSlow("link", 20, 1.0, 8.0), FailSlow("link", 36, 0.5, 6.0)]
    for i, f in enumerate(cases):
        t0 = time.perf_counter()
        v = sloth.detect([f], seed=i)
        us = (time.perf_counter() - t0) * 1e6
        res = v.failrank.residuals
        rows.append((f"fig15_case{i}_iters", round(us, 1),
                     v.failrank.iterations))
        if len(res) >= 2:
            gm = (res[-1] / res[0]) ** (1 / max(len(res) - 1, 1))
            rows.append((f"fig15_case{i}_geo_rate", 0.0, round(float(gm),
                                                               3)))
    return rows


# ---------------------------------------------------------------------------
# Fig 16/17: scalability across 4×4 / 6×6 / 8×8
# ---------------------------------------------------------------------------

def bench_scalability(n_samples=None):
    """Campaign-driven Figs 16/17: the same grid evaluated at 4×4, 6×6,
    8×8 and a rectangular 8×4, with deployment artifacts (healthy run,
    probe-overhead calibration) served from the campaign's deployment
    cache."""
    n_samples = n_samples or (20 if FULL else 8)
    reps = max(2, n_samples // 2)
    workloads = ("resnet50", "darknet19")
    rows = []
    cache = C.DeploymentCache()
    for w, h in ((4, 4), (6, 6), (8, 8), (8, 4)):
        grid = C.CampaignGrid(workloads=workloads, meshes=((w, h),),
                              kinds=("core", "link"), severities=(10.0,),
                              reps=reps, campaign_seed=3)
        res = C.run_campaign(grid, cache=cache)
        for wl in workloads:
            dep = cache.get(wl, w, h)
            sub = [o for o in res.outcomes if o.workload == wl]
            m = M.aggregate(sub)
            rows.append((f"fig16_{wl}_{w}x{h}_total_s", 0.0,
                         round(dep.healthy.total_time, 2)))
            rows.append((f"fig16_{wl}_{w}x{h}_full_probe_pct", 0.0,
                         round(dep.probe_overhead * 100, 3)))
            rows.append((f"fig17_{wl}_{w}x{h}_compression_x", 0.0,
                         round(m.mean_compression, 1)))
            rows.append((f"fig17_{wl}_{w}x{h}_acc_pct", 0.0,
                         round(m.accuracy.pct(), 1)))
    return rows


# ---------------------------------------------------------------------------
# multi-failure campaigns: any-match accuracy + per-failure recall@k
# ---------------------------------------------------------------------------

def bench_multi_failure(n_samples=None):
    """Simultaneous-failure scenarios (the grid's ``n_failures`` axis):
    any-match accuracy and failure-level recall@k as k grows — gray-failure
    fleet studies report fail-slow events co-occurring, so this measures
    how gracefully localisation degrades with k."""
    n_samples = n_samples or (16 if FULL else 6)
    reps = max(2, n_samples // 2)
    rows = []
    # pre-build the deployment so the timed region covers scenario
    # execution only (same convention as bench_accuracy)
    cache = C.DeploymentCache()
    cache.get("darknet19", 4, 4)
    for nf in (1, 2, 3):
        grid = C.CampaignGrid(workloads=("darknet19",), meshes=(4,),
                              kinds=("core", "link"), severities=(10.0,),
                              n_failures=(nf,), reps=reps, campaign_seed=5)
        t0 = time.perf_counter()
        res = C.run_campaign(grid, cache=cache, workers=1)
        us = ((time.perf_counter() - t0)
              / max(len(res.outcomes), 1) * 1e6)
        m = res.metrics
        rows.append((f"multifail_k{nf}_acc_anymatch_pct", round(us, 1),
                     round(m.accuracy.pct(), 2)))
        rows.append((f"multifail_k{nf}_recall_at1_pct", 0.0,
                     round(m.recall_at(1) * 100, 2)))
        rows.append((f"multifail_k{nf}_recall_at5_pct", 0.0,
                     round(m.recall_at(5) * 100, 2)))
    return rows


# ---------------------------------------------------------------------------
# severity sweeps: accuracy/FPR/recall vs slowdown near the detection
# threshold
# ---------------------------------------------------------------------------

def bench_severity(reps=None):
    """Near-threshold severity sweep (the grid's severity axis as a
    first-class swept dimension): accuracy and recall@3 per injected
    slowdown from 1.25× (barely degraded) through 3× (the transition
    region) to the paper's 10×, via ``CampaignResult.severity_curve()``.
    Detection should trend monotonically up across the threshold —
    fail-slow severity grades the evidence, it doesn't gate it."""
    reps = reps or (6 if FULL else 3)
    cache = C.DeploymentCache()
    cache.get("darknet19", 4, 4)
    grid = C.CampaignGrid(workloads=("darknet19",), meshes=(4,),
                          kinds=("core", "link", "none"),
                          severities=("linspace:1.25:3.0:8", 10.0),
                          reps=reps, campaign_seed=9)
    t0 = time.perf_counter()
    res = C.run_campaign(grid, cache=cache, workers=1)
    us = (time.perf_counter() - t0) / max(len(res.outcomes), 1) * 1e6
    rows = []
    curve = res.severity_curve()
    for p in curve:
        # repr round-trips the float, so sweep points arbitrarily close
        # together never collapse onto one row name
        tag = repr(p.severity)
        rows.append((f"sevcurve_x{tag}_acc_pct", round(us, 1),
                     round(p.accuracy.pct(), 2)))
        rows.append((f"sevcurve_x{tag}_recall3_pct", 0.0,
                     round(p.recall_at(3) * 100, 2)))
    rows.append(("sevcurve_fpr_pct", 0.0, round(curve[0].fpr.pct(), 2)))
    lo, hi = curve[0], curve[-1]
    rows.append(("sevcurve_threshold_gain_pp", 0.0,
                 round(hi.accuracy.pct() - lo.accuracy.pct(), 2)))
    return rows


# ---------------------------------------------------------------------------
# mixed-kind multi-failure campaigns: heterogeneous truth populations
# ---------------------------------------------------------------------------

def bench_mixed_kind(reps=None):
    """Heterogeneous failure populations (the grid's ``kind='mixed'``
    axis): k simultaneous failures whose kinds are sampled from the
    core/link/router population, judged per truth kind
    (``by_truth_kind``) across every registered detector."""
    reps = reps or (8 if FULL else 4)
    detectors = D.DEFAULT_DETECTORS
    cache = C.DeploymentCache()
    cache.get("darknet19", 4, 4, detectors=detectors)
    grid = C.CampaignGrid(workloads=("darknet19",), meshes=(4,),
                          kinds=("mixed", "none"), severities=(10.0,),
                          n_failures=(2,), reps=reps, campaign_seed=13)
    t0 = time.perf_counter()
    res = C.run_campaign(grid, detectors=detectors, cache=cache, workers=1)
    us = (time.perf_counter() - t0) / max(len(res.outcomes), 1) * 1e6
    rows = []
    for name, m in res.detector_metrics.items():
        rows.append((f"mixed_{name}_acc_anymatch_pct", round(us, 1),
                     round(m.accuracy.pct(), 2)))
        rows.append((f"mixed_{name}_recall3_pct", 0.0,
                     round(m.recall_at(3) * 100, 2)))
    for kind, tk in res.by_truth_kind().items():
        rows.append((f"mixed_sloth_{kind}_recall3_pct", 0.0,
                     round(tk.recall_at(3) * 100, 2)))
        rows.append((f"mixed_sloth_{kind}_n", 0.0, tk.n_failures))
    return rows


# ---------------------------------------------------------------------------
# detect → mitigate: recovered throughput per policy on decisive failures
# ---------------------------------------------------------------------------

def bench_mitigation(reps=None):
    """Verdict-driven mitigation (``run_campaign(mitigation=...)``): every
    built-in policy judged against SLOTH verdicts on decisive 10× core
    and link failures.  Headline quantities per policy: fraction of acted
    verdicts, mean recovered fraction of the failure-induced gap, and
    post-mitigation slowdown vs the healthy makespan.  The ``none``
    control must report exactly zero recovery — anything else means the
    re-simulation is not conditioned on the plan alone."""
    from repro.mitigate.policy import DEFAULT_POLICIES
    reps = reps or (8 if FULL else 3)
    cache = C.DeploymentCache()
    cache.get("darknet19", 4, 4)
    grid = C.CampaignGrid(workloads=("darknet19",), meshes=(4,),
                          kinds=("core", "link", "none"),
                          severities=(10.0,), reps=reps, campaign_seed=17)
    t0 = time.perf_counter()
    res = C.run_campaign(grid, cache=cache, workers=1,
                         mitigation=DEFAULT_POLICIES)
    us = (time.perf_counter() - t0) / max(len(res.outcomes), 1) * 1e6
    rows = []
    for (det, pol), st in res.mitigation.items():
        rows.append((f"mitigation_{pol}_acted_pct", round(us, 1),
                     round(st.acted.pct(), 2)))
        rows.append((f"mitigation_{pol}_recovered_pct", 0.0,
                     round(st.recovered_mean * 100, 2)))
        rows.append((f"mitigation_{pol}_slowdown_x", 0.0,
                     round(st.slowdown_mean, 3)))
    ctl = res.mitigation[("sloth", "none")]
    assert ctl.recovered_mean == 0.0, \
        "'none' control recovered throughput"
    return rows


ALL = [bench_impact, bench_accuracy, bench_probe_overhead, bench_storage,
       bench_recorder, bench_sketch_params, bench_dse,
       bench_failrank_convergence, bench_scalability, bench_multi_failure,
       bench_severity, bench_mixed_kind, bench_mitigation]
