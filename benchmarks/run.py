"""Benchmark harness: one bench per paper table/figure + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV.  Env: BENCH_FULL=1 for paper-scale
datasets; BENCH_ONLY=<substring> to run a subset.
"""

from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    from benchmarks.kernels import bench_kernels
    from benchmarks.paper import ALL as PAPER_BENCHES
    from benchmarks.roofline import bench_roofline

    benches = list(PAPER_BENCHES) + [bench_kernels, bench_roofline]
    only = os.environ.get("BENCH_ONLY", "")
    print("name,us_per_call,derived")
    for bench in benches:
        if only and only not in bench.__name__:
            continue
        t0 = time.time()
        try:
            rows = bench()
        except Exception as e:  # keep the harness running
            print(f"{bench.__name__},0,EXCEPTION:{type(e).__name__}:{e}")
            continue
        for name, us, derived in rows:
            print(f"{name},{us},{derived}")
        print(f"#{bench.__name__}_wall_s,{time.time() - t0:.1f},")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
