"""Kernel micro-benchmarks: µs/call + parity vs the pure-jnp oracles.

CPU note: Pallas runs in interpret mode here, so absolute times measure the
CPU emulation, not TPU performance; the parity column is the correctness
signal and the ops are the TPU-target artifacts.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, *args, reps=3, **kw):
    fn(*args, **kw)            # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
        jax.tree.map(lambda x: x.block_until_ready()
                     if hasattr(x, "block_until_ready") else x, out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def bench_kernels():
    rows = []
    rng = jax.random.PRNGKey(0)

    # sketch_update
    from repro.core.sketch import SketchParams, split_key
    from repro.kernels.sketch_update import ops as SO, ref as SR
    p = SketchParams(d=2, m=512, H=4, L=128)
    n = 2048
    keys = (np.arange(n) % 97).astype(np.int64) * 0x9E3779B9
    lo, hi = split_key(keys)
    dur = np.random.default_rng(0).random(n).astype(np.float32)
    args = (jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(dur),
            jnp.asarray(dur * 2), jnp.asarray(np.cumsum(dur, dtype=np.float32)))
    us_p, st_p = _timeit(lambda: SO.insert(SO.make_state(p), *args,
                                           params=p, impl="pallas"))
    us_r, st_r = _timeit(lambda: SR.insert_batch(SR.make_state(p), *args,
                                                 H=p.H))
    par = int(np.array_equal(np.asarray(st_p["freq"]),
                             np.asarray(st_r["freq"])))
    rows.append(("kern_sketch_pallas_2048rec", round(us_p, 1),
                 f"parity={par}"))
    rows.append(("kern_sketch_jnpref_2048rec", round(us_r, 1), ""))

    # vectorized multi-record batch path vs per-record insertion: (a) the
    # streaming alternative — one jitted insert dispatch per record, how an
    # online monitor would feed device state — and (b) the numpy
    # Algorithm-1 oracle loop, for reference.
    from repro.core.sketch import FailSlowSketch
    t_np = np.asarray(args[4])

    def per_record_np():
        s = FailSlowSketch(p)
        s.insert_stream(keys, dur, dur * 2, t_np.astype(np.float64))
        return s
    us_np, oracle = _timeit(per_record_np, reps=1)

    n_stream = 256                      # extrapolated to the full batch

    def per_record_jnp():
        st = SO.make_state(p)
        for k in range(n_stream):
            st = SO.insert(st, *(a[k:k + 1] for a in args), params=p,
                           impl="batched")
        return st
    us_1, _ = _timeit(per_record_jnp, reps=1)
    us_1 *= n / n_stream

    us_b, st_b = _timeit(lambda: SO.insert(SO.make_state(p), *args,
                                           params=p, impl="batched"))
    par_b = int(np.array_equal(np.asarray(st_b["freq"]), oracle.freq)
                and np.array_equal(np.asarray(st_r["freq"]),
                                   np.asarray(st_b["freq"])))
    rows.append(("kern_sketch_perrecord_np_2048rec", round(us_np, 1), ""))
    rows.append(("kern_sketch_perrecord_jnp_2048rec", round(us_1, 1),
                 "extrapolated"))
    rows.append(("kern_sketch_batched_2048rec", round(us_b, 1),
                 f"parity={par_b} "
                 f"speedup_vs_perrecord={us_1 / max(us_b, 1e-9):.1f}x "
                 f"speedup_vs_numpy={us_np / max(us_b, 1e-9):.1f}x"))

    # flash attention
    from repro.kernels.flash_attention.ops import gqa_attention
    q = jax.random.normal(rng, (2, 256, 4, 64))
    k = jax.random.normal(rng, (2, 256, 2, 64))
    v = jax.random.normal(rng, (2, 256, 2, 64))
    us_p, a = _timeit(gqa_attention, q, k, v, impl="pallas")
    us_r, r = _timeit(gqa_attention, q, k, v, impl="ref")
    err = float(jnp.max(jnp.abs(a - r)))
    rows.append(("kern_flashattn_pallas_b2s256", round(us_p, 1),
                 f"maxerr={err:.1e}"))
    rows.append(("kern_flashattn_ref_b2s256", round(us_r, 1), ""))

    # ssd scan
    from repro.kernels.ssd_scan.ops import ssd
    x = jax.random.normal(rng, (2, 256, 4, 32)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(rng, (2, 256, 4))) * 0.3
    a_ = -jnp.exp(jax.random.normal(rng, (4,)) * 0.3)
    bb = jax.random.normal(rng, (2, 256, 2, 16)) * 0.4
    cc = jax.random.normal(rng, (2, 256, 2, 16)) * 0.4
    us_p, (yp, _) = _timeit(ssd, x, dt, a_, bb, cc, impl="pallas")
    us_r, (yr, _) = _timeit(ssd, x, dt, a_, bb, cc, impl="ref")
    err = float(jnp.max(jnp.abs(yp - yr)))
    rows.append(("kern_ssd_pallas_b2s256", round(us_p, 1),
                 f"maxerr={err:.1e}"))
    rows.append(("kern_ssd_ref_b2s256", round(us_r, 1), ""))

    # failrank step
    from repro.kernels.failrank_step.kernel import failrank_step
    from repro.kernels.failrank_step.ref import failrank_step_ref
    n = 512
    w = jax.random.uniform(rng, (n, n))
    w = w / w.sum(1, keepdims=True)
    l = jax.random.uniform(rng, (n, n))
    s = jax.random.uniform(rng, (n,))
    us_p, (sp, lp) = _timeit(failrank_step, w, l, s, s)
    us_r, (sr, lr) = _timeit(failrank_step_ref, w, l, s, s)
    err = max(float(jnp.max(jnp.abs(sp - sr))),
              float(jnp.max(jnp.abs(lp - lr))))
    rows.append(("kern_failrank_pallas_n512", round(us_p, 1),
                 f"maxerr={err:.1e}"))
    rows.append(("kern_failrank_ref_n512", round(us_r, 1), ""))
    return rows
