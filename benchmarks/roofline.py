"""Roofline table from the dry-run results (reads dryrun_results.json)."""

from __future__ import annotations

import json
import os

RESULTS = os.environ.get(
    "DRYRUN_RESULTS",
    os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json"))


def bench_roofline():
    rows = []
    if not os.path.exists(RESULTS):
        return [("roofline_missing", 0.0, "run repro.launch.dryrun --all")]
    data = json.load(open(RESULTS))
    n_ok = n_skip = n_err = 0
    for key in sorted(data):
        rec = data[key]
        if rec["status"] == "skipped":
            n_skip += 1
            continue
        if rec["status"] != "ok":
            n_err += 1
            rows.append((f"dryrun_{key}", 0.0, "ERROR"))
            continue
        n_ok += 1
        if "|single" in key and "|" not in key.split("|single")[-1]:
            r = rec["roofline"]
            dom = r["dominant"].replace("_s", "")
            rows.append((
                f"roofline_{rec['arch']}_{rec['shape']}", 0.0,
                f"dom={dom};c={r['compute_s']:.2e};m={r['memory_s']:.2e};"
                f"n={r['collective_s']:.2e};"
                f"useful={r['useful_flops_frac']:.3f};"
                f"GiB={rec['per_device']['peak_bytes']/2**30:.2f}"))
    rows.append(("dryrun_cells", 0.0,
                 f"ok={n_ok};skipped={n_skip};error={n_err}"))
    return rows
