"""Sharded AdamW with global-norm clipping and warmup+cosine schedule.

Functional (optax-style but self-contained): state is a pytree shaped like
the params, so it inherits the parameter PartitionSpecs (ZeRO-sharded when
FSDP is on).  ``state_dtype`` lets the ≥100B configs keep moments in bf16
(halves optimizer HBM; the update is computed in f32)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    state_dtype: Any = jnp.float32


def init_state(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = schedule(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(cfg.state_dtype),
                v_new.astype(cfg.state_dtype))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
