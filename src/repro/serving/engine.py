"""Batched serving runtime: prefill + decode loop over a request batch.

Single-host reference implementation of the serve path the dry-run lowers
at pod scale: uniform-batch prefill, greedy decode with the rolling KV /
SSM cache, simple admission queue.

Per-step timings are recorded in **separate** ``prefill_times`` /
``decode_times`` series (the legacy interleaved ``step_times`` list is
kept for compatibility): prefill steps are O(prompt·seq) and decode
steps O(1)-ish, so mixing them in one series inflated every decode
percentile computed downstream.  An optional ``step_hook(kind, dt)``
callback fires after each step (``kind`` is ``'prefill'`` or
``'decode'``) — the live telemetry tap ``launch/serve.py --telemetry``
uses to stream decode timings into the pod detector
(:class:`~repro.distributed.telemetry.StepTelemetry`).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as T


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class EngineConfig:
    batch: int = 4
    cache_len: int = 512
    dtype: object = jnp.float32


class ServeEngine:
    def __init__(self, cfg, params, ecfg: EngineConfig, step_hook=None):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.queue: list[Request] = []
        self.step_times: list[float] = []      # legacy interleaved series
        self.prefill_times: list[float] = []
        self.decode_times: list[float] = []
        self.step_hook = step_hook             # fn(kind, dt) | None

        self._prefill = jax.jit(
            lambda p, toks, frames=None: T.prefill(
                cfg, p, toks,
                T.init_cache(cfg, ecfg.batch, ecfg.cache_len,
                             dtype=ecfg.dtype),
                enc_frames=frames, remat=False))
        self._decode = jax.jit(
            lambda p, toks, cache, pos, memory=None: T.decode_step(
                cfg, p, toks, cache, pos, memory=memory))

    def submit(self, req: Request):
        self.queue.append(req)

    def _record(self, kind: str, dt: float) -> None:
        self.step_times.append(dt)
        (self.prefill_times if kind == "prefill"
         else self.decode_times).append(dt)
        if self.step_hook is not None:
            self.step_hook(kind, dt)

    def _next_batch(self) -> list[Request]:
        batch = self.queue[:self.ecfg.batch]
        self.queue = self.queue[self.ecfg.batch:]
        return batch

    def run(self, enc_frames=None) -> list[Request]:
        """Serve everything in the queue; returns completed requests."""
        done: list[Request] = []
        while self.queue:
            batch = self._next_batch()
            # pad the batch to engine batch size (replicate last request)
            while len(batch) < self.ecfg.batch:
                batch.append(Request(-1, batch[-1].prompt, 0))
            s = max(len(r.prompt) for r in batch)
            toks = np.stack([np.pad(r.prompt, (s - len(r.prompt), 0))
                             for r in batch]).astype(np.int32)
            t0 = time.perf_counter()  # lint: allow-wallclock (measured)
            args = (self.params, toks) + ((enc_frames,) if self.cfg.enc_dec
                                          else ())
            out = self._prefill(*args)
            last, cache = out[0], out[1]
            memory = out[2] if self.cfg.enc_dec else None
            nxt = jnp.argmax(last[:, -1], axis=-1)[:, None].astype(jnp.int32)
            self._record("prefill", time.perf_counter() - t0)  # lint: allow-wallclock
            max_new = max(r.max_new for r in batch)
            for k in range(max_new):
                for r, t in zip(batch, np.asarray(nxt)[:, 0]):
                    if r.rid >= 0 and len(r.out_tokens) < r.max_new:
                        r.out_tokens.append(int(t))
                t0 = time.perf_counter()  # lint: allow-wallclock
                logits, cache = self._decode(self.params, nxt, cache,
                                             jnp.int32(s + k), memory)
                nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]\
                    .astype(jnp.int32)
                self._record("decode", time.perf_counter() - t0)  # lint: allow-wallclock
            done.extend(r for r in batch if r.rid >= 0)
        return done
