"""Pallas TPU kernel: fused FailRank node+link update (one iteration).

Dense MCG form (the MCG of a pod window stack is ≤ a few thousand nodes, so
the dense matrix fits VMEM in column stripes):

    s'[v]   = (1−λ)·s0[v] + λ·Σ_u W[u,v]·s[u]          (MXU matvec)
    L'[u,v] = α·W[u,v] + β·s[u] + γ·L[u,v]             (VPU elementwise)

Grid over column stripes: each step loads W[:, j·C:(j+1)·C] and L[:, ...]
once from HBM and produces both outputs in a single pass — the fusion is
the point (the XLA path reads W twice).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Structural contract checked by repro.analysis.kernel_audit: rank-1
# grid over column stripes; each stripe writes disjoint output blocks
# and no state is aliased across steps.
AUDIT = {"grid_rank": 1, "aliased_io": False, "sequential_grid": True}


def _kernel(w_ref, l_ref, s_ref, s0_ref, s_out_ref, l_out_ref, *,
            lam: float, alpha: float, beta: float, gamma: float):
    w = w_ref[:]                # [n, C]
    s = s_ref[:]                # [n, 1]
    s0 = s0_ref[0]              # [C]
    contrib = jax.lax.dot_general(s, w, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    s_out_ref[0] = (1.0 - lam) * s0 + lam * contrib[0]
    l_out_ref[:] = alpha * w + beta * s + gamma * l_ref[:]


@functools.partial(jax.jit, static_argnames=("lam", "alpha", "beta",
                                             "gamma", "col_block",
                                             "interpret"))
def failrank_step(w, l, s, s0, *, lam=0.55, alpha=0.1, beta=0.3,
                  gamma=0.6, col_block: int = 128,
                  interpret: bool = True):
    """w/l [n,n] (w[u,v] = propagation weight), s/s0 [n] → (s', L')."""
    n = w.shape[0]
    nb = -(-n // col_block)
    pad = nb * col_block - n
    if pad:
        w = jnp.pad(w, ((0, pad), (0, pad)))
        l = jnp.pad(l, ((0, pad), (0, pad)))
        s = jnp.pad(s, (0, pad))
        s0 = jnp.pad(s0, (0, pad))
    npad = n + pad

    s_new, l_new = pl.pallas_call(
        functools.partial(_kernel, lam=lam, alpha=alpha, beta=beta,
                          gamma=gamma),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((npad, col_block), lambda j: (0, j)),
            pl.BlockSpec((npad, col_block), lambda j: (0, j)),
            pl.BlockSpec((npad, 1), lambda j: (0, 0)),
            pl.BlockSpec((1, col_block), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, col_block), lambda j: (0, j)),
            pl.BlockSpec((npad, col_block), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, npad), jnp.float32),
            jax.ShapeDtypeStruct((npad, npad), jnp.float32),
        ],
        interpret=interpret,
    )(w, l, s.reshape(npad, 1), s0.reshape(1, npad))
    return s_new[0, :n], l_new[:n, :n]
