"""Pure-jnp oracle for the fused FailRank step (dense form)."""

from __future__ import annotations

import jax.numpy as jnp


def failrank_step_ref(w, l, s, s0, *, lam=0.55, alpha=0.1, beta=0.3,
                      gamma=0.6):
    s_new = (1.0 - lam) * s0 + lam * (w.T @ s)
    l_new = alpha * w + beta * s[:, None] + gamma * l
    return s_new, l_new
