"""Public API: dense FailRank iteration over an MCG via the Pallas kernel.

``failrank_dense(mcg, params)`` mirrors ``repro.core.failrank.failrank``
(COO/XLA path) and is validated against it in the kernel tests.
"""

from __future__ import annotations

import numpy as np

from ...core.failrank import FailRankParams, _softmax_per_level
from ...core.mcg import MCG
from .kernel import failrank_step
from .ref import failrank_step_ref


def mcg_dense(mcg: MCG):
    n = mcg.n_nodes
    w = np.zeros((n, n), np.float32)
    l = np.zeros((n, n), np.float32)
    w[mcg.edge_src, mcg.edge_dst] = mcg.edge_w
    l[mcg.edge_src, mcg.edge_dst] = mcg.l0
    return w, l


def failrank_dense(mcg: MCG, params: FailRankParams = FailRankParams(),
                   impl: str = "pallas", interpret: bool = True):
    """Returns (node_scores softmaxed, raw s, raw dense L, iterations)."""
    import jax.numpy as jnp
    w, l = mcg_dense(mcg)
    w, l = jnp.asarray(w), jnp.asarray(l)
    s = jnp.asarray(mcg.s0, jnp.float32)
    s0 = s
    it = 0
    for it in range(1, params.max_iters + 1):
        if impl == "pallas":
            s_new, l_new = failrank_step(
                w, l, s, s0, lam=params.lam, alpha=params.alpha,
                beta=params.beta, gamma=params.gamma, interpret=interpret)
        else:
            s_new, l_new = failrank_step_ref(
                w, l, s, s0, lam=params.lam, alpha=params.alpha,
                beta=params.beta, gamma=params.gamma)
        delta = float(abs(s_new - s).sum() + abs(l_new - l).sum())
        s, l = s_new, l_new
        if delta < params.eps:
            break
    node_soft = _softmax_per_level(np.asarray(s, np.float64),
                                   mcg.node_window)
    return node_soft, np.asarray(s), np.asarray(l), it
