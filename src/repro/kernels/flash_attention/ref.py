"""Pure-jnp oracle for flash attention: direct masked softmax."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=None):
    """q [BH, Sq, D], k/v [BH, Skv, D] → [BH, Sq, D]."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k,
                   preferred_element_type=jnp.float32) / np.sqrt(d)
    sq, skv = q.shape[1], k.shape[1]
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(q.dtype), v)
