"""Pallas TPU flash attention (blocked online softmax).

Grid: (batch×kv-heads×q-groups, q_blocks, kv_blocks); the kv dimension is
sequential ("arbitrary") so the running max / denominator / accumulator
live in VMEM scratch across kv steps.  Causal and sliding-window masking
are applied per block pair; unlike the XLA fallback, fully-masked kv blocks
contribute nothing and the TPU kernel skips them via ``when`` (the FLOP
savings the §Perf log attributes to this kernel).

Layout: q/k/v are passed as [BH, S, D] (batch and heads pre-flattened, KV
heads broadcast to q heads by the ops wrapper) with block sizes aligned to
the MXU (q_block × d and kv_block × d tiles).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# Structural contract checked by repro.analysis.kernel_audit: rank-3
# grid (bh, q blocks, kv blocks); no aliased state — the online-softmax
# carries live in VMEM scratch, and the sequential kv axis is what
# makes that carry sound.
AUDIT = {"grid_rank": 3, "aliased_io": False, "sequential_grid": True}


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int | None,
            q_block: int, kv_block: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[:] = jnp.full_like(m_scr, NEG_INF)
        l_scr[:] = jnp.zeros_like(l_scr)
        acc_scr[:] = jnp.zeros_like(acc_scr)

    q_pos = qi * q_block + jax.lax.broadcasted_iota(
        jnp.int32, (q_block, kv_block), 0)
    k_pos = ki * kv_block + jax.lax.broadcasted_iota(
        jnp.int32, (q_block, kv_block), 1)

    run = jnp.bool_(True)
    if causal:
        run &= (ki * kv_block) <= (qi * q_block + q_block - 1)
    if window is not None:
        run &= (ki * kv_block + kv_block) > (qi * q_block - window)

    @pl.when(run)
    def _step():
        q = q_ref[0]                       # [q_block, d]
        k = k_ref[0]                       # [kv_block, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        mask = k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[:]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[:] = l_scr[:] * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[:] = acc_scr[:] * alpha + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[:] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[:] / jnp.maximum(l_scr[:], 1e-30)) \
            .astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_block",
                                             "kv_block", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, q_block=128,
                    kv_block=128, interpret=True):
    """q [BH, Sq, D], k/v [BH, Skv, D] → [BH, Sq, D]."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    nq = -(-sq // q_block)
    nk = -(-skv // kv_block)
    pq, pk = nq * q_block - sq, nk * kv_block - skv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, q_block=q_block,
                          kv_block=kv_block, kv_len=skv),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kv_block, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_block, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nq * q_block, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
