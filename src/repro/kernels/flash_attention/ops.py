"""Public wrapper: GQA-aware flash attention.

Accepts model-layout tensors (q [B,S,Hq,D], k/v [B,T,Hkv,D]), broadcasts KV
heads to query heads, flattens (batch, head) and dispatches to the Pallas
kernel or the jnp oracle.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import flash_attention
from .ref import attention_ref


def gqa_attention(q, k, v, *, causal=True, window=None, impl="pallas",
                  interpret=True, q_block=128, kv_block=128):
    b, s, hq, d = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = hq // hk
    kx = jnp.repeat(k, g, axis=2)
    vx = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kf = kx.transpose(0, 2, 1, 3).reshape(b * hq, t, d)
    vf = vx.transpose(0, 2, 1, 3).reshape(b * hq, t, d)
    if impl == "pallas":
        of = flash_attention(qf, kf, vf, causal=causal, window=window,
                             q_block=q_block, kv_block=kv_block,
                             interpret=interpret)
    else:
        of = attention_ref(qf, kf, vf, causal=causal, window=window)
    return of.reshape(b, hq, s, d).transpose(0, 2, 1, 3)
