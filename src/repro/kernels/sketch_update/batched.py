"""Vectorized multi-record Fail-Slow Sketch insertion (campaign hot path).

Same Algorithm-1 semantics as ``ref.insert_batch`` (and therefore the
``core/sketch.py`` numpy oracle), restructured for batch throughput:

* bucket indices for **all** records × all ``d`` hash tables are computed
  up front in one vectorized ``hash_all`` call (the per-record path
  re-hashes inside every step),
* Stage-1 state is packed ``[d, m, 4]`` (lo, hi, valid, freq) and the
  per-table update rule is ``vmap``-ed over the ``d`` hash tables, so one
  record costs one batched gather + one batched scatter,
* Stage-2 state is packed into an int ``[L, 5]`` (lo, hi, valid, count,
  arrival) and a float ``[L, 6]`` (sum, sumsq, val, tmin, tmax, min)
  matrix — one row scatter each per record instead of eleven vector
  scatters,
* records are applied in order by ``lax.scan`` (insertion order is
  semantically load-bearing: Stage-1 frequencies race between keys sharing
  a bucket and Stage-2 eviction is FIFO by promotion arrival).

Two extensions over the original per-record scan:

* **Drained-eviction stream** (``insert_batch_drained`` /
  ``insert_runs_vectorized`` + ``ref.make_drain``): a Stage-2 FIFO
  eviction appends the victim row to the drain buffer before the slot is
  overwritten — the numpy oracle keeps those patterns in ``self.drained``
  and merges them back in ``patterns()``, so losing them was a
  correctness divergence under eviction pressure.
* **Run-compressed insertion** (``insert_runs_vectorized``): the
  vectorized analogue of ``FailSlowSketch.insert_run`` — one scan step
  applies a whole run of ``r`` identical-key records (Stage-1 frequencies
  move by ±r with the exact promote/steal index algebra of the oracle;
  Stage-2 receives the closed-form aggregates), so instruction expansion
  never materialises per-record arrays.

The packing is an internal layout change only: inputs/outputs use the
``ref.make_state`` / ``ref.make_drain`` dict layouts, integer state is
bit-identical to the sequential reference and the float statistics
accumulate in the same float32 order.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .ref import hash_all, make_drain

_I32MAX = jnp.iinfo(jnp.int32).max

_S1_COLS = (("keys_lo", 0), ("keys_hi", 1), ("valid", 2), ("freq", 3))
_S2I_COLS = (("s2_lo", 0), ("s2_hi", 1), ("s2_valid", 2), ("s2_count", 3),
             ("s2_arrival", 4))
_S2F_COLS = (("s2_sum", 0), ("s2_sumsq", 1), ("s2_val", 2), ("s2_tmin", 3),
             ("s2_tmax", 4), ("s2_min", 5))
_DI_COLS = (("d_lo", 0), ("d_hi", 1), ("d_count", 2), ("d_arrival", 3))
_DF_COLS = (("d_sum", 0), ("d_sumsq", 1), ("d_val", 2), ("d_tmin", 3),
            ("d_tmax", 4), ("d_min", 5))


def _pack(state, drain):
    T = jnp.stack([state[k] for k, _ in _S1_COLS], axis=2)
    I = jnp.stack([state[k] for k, _ in _S2I_COLS], axis=1)
    F = jnp.stack([state[k] for k, _ in _S2F_COLS], axis=1)
    DI = jnp.stack([drain[k] for k, _ in _DI_COLS], axis=1)
    DF = jnp.stack([drain[k] for k, _ in _DF_COLS], axis=1)
    return T, I, F, state["counter"], DI, DF, drain["d_n"]


def _unpack(state, drain, carry):
    T, I, F, C, DI, DF, Dn = carry
    out = dict(state, counter=C)
    for k, col in _S1_COLS:
        out[k] = T[..., col]
    for k, col in _S2I_COLS:
        out[k] = I[:, col]
    for k, col in _S2F_COLS:
        out[k] = F[:, col]
    dout = dict(drain, d_n=Dn)
    for k, col in _DI_COLS:
        dout[k] = DI[:, col]
    for k, col in _DF_COLS:
        dout[k] = DF[:, col]
    return out, dout


def _one_table(tbl, j, lo, hi, H):
    """Stage-1 update for one packed hash-table row ``[m, 4]`` (vmapped
    over the d tables); returns (new row, promoted-on-this-table)."""
    bk = tbl[j]                                  # (lo, hi, valid, freq)
    match = (bk[2] == 1) & (bk[0] == lo) & (bk[1] == hi)
    empty = bk[2] == 0
    newf = jnp.where(match, bk[3] + 1, jnp.where(empty, 1, bk[3] - 1))
    newv = jnp.where(match | empty, 1, (newf > 0).astype(jnp.int32))
    newlo = jnp.where(empty, lo, bk[0])
    newhi = jnp.where(empty, hi, bk[1])
    newf = jnp.where((~match) & (~empty) & (newf <= 0), 0, newf)
    promoted = (match | empty) & (newf >= H)
    return tbl.at[j].set(jnp.stack([newlo, newhi, newv, newf])), promoted


def _one_table_run(tbl, j, lo, hi, r, active, H):
    """Stage-1 update for a run of ``r`` identical-key records on one
    packed table row; returns (new row, 0-based index of this table's
    first promoted record — ``r`` if the run never promotes here).

    Mirrors ``FailSlowSketch.insert_run`` exactly: a matching bucket with
    prior freq ``f0`` promotes record ``k = H − f0 − 1``; an empty bucket
    promotes ``k = H − 1``; a contested bucket absorbs ``r ≤ f0``
    decrements without promotion, while ``r > f0`` clears it (record
    ``f0`` steals the bucket) and promotes ``k = f0 + H − 1``.
    """
    bk = tbl[j]
    match = (bk[2] == 1) & (bk[0] == lo) & (bk[1] == hi)
    empty = bk[2] == 0
    f0 = bk[3]
    steal = (~match) & (~empty) & (r > f0)
    newf = jnp.where(match, f0 + r,
                     jnp.where(empty, r,
                               jnp.where(steal, r - f0, f0 - r)))
    claim = empty | steal
    newv = jnp.where(match | claim, 1, (newf > 0).astype(jnp.int32))
    newlo = jnp.where(claim, lo, bk[0])
    newhi = jnp.where(claim, hi, bk[1])
    k = jnp.where(match, H - f0 - 1,
                  jnp.where(empty, H - 1,
                            jnp.where(steal, f0 + H - 1, r)))
    row = jnp.where(active, jnp.stack([newlo, newhi, newv, newf]), bk)
    return tbl.at[j].set(row), jnp.where(active, jnp.maximum(k, 0), r)


_tables = jax.vmap(_one_table, in_axes=(0, 0, None, None, None))
_tables_run = jax.vmap(_one_table_run,
                       in_axes=(0, 0, None, None, None, None, None))


def _stage2(I, F, C, DI, DF, Dn, lo, hi, promoted,
            n, sdur, ssq, sval, tfirst, tlast, mdur):
    """Stage-2 slot selection + update for one promotion event carrying
    pre-aggregated statistics (n records; per-record steps pass n = 1).
    FIFO evictions are appended to the (DI, DF, Dn) drain stream before
    the victim row is overwritten."""
    valid = I[:, 2]
    s2_match = (valid == 1) & (I[:, 0] == lo) & (I[:, 1] == hi)
    exists = jnp.any(s2_match)
    j_upd = jnp.argmax(s2_match)
    free = valid == 0
    any_free = jnp.any(free)
    j_free = jnp.argmax(free)
    j_evict = jnp.argmin(jnp.where(valid == 1, I[:, 4], _I32MAX))
    j = jnp.where(exists, j_upd, jnp.where(any_free, j_free, j_evict))

    ri, rf = I[j], F[j]
    # drain the FIFO victim (valid row, no free slot, new key arriving);
    # the buffer write is index-clamped so an undersized buffer saturates
    # instead of scattering out of bounds
    evict = promoted & ~exists & ~any_free
    slot = jnp.minimum(Dn, DI.shape[0] - 1)
    keep = evict & (Dn < DI.shape[0])
    DI = DI.at[slot].set(jnp.where(
        keep, jnp.stack([ri[0], ri[1], ri[3], ri[4]]), DI[slot]))
    DF = DF.at[slot].set(jnp.where(keep, rf, DF[slot]))
    Dn = Dn + keep.astype(jnp.int32)

    upd_i = jnp.stack([ri[0], ri[1], 1, ri[3] + n, ri[4]])
    new_i = jnp.stack([lo, hi, 1, n, C])
    upd_f = jnp.stack([rf[0] + sdur, rf[1] + ssq, rf[2] + sval,
                       jnp.minimum(rf[3], tfirst),
                       jnp.maximum(rf[4], tlast),
                       jnp.minimum(rf[5], mdur)])
    new_f = jnp.stack([sdur, ssq, sval, tfirst, tlast, mdur])
    I = I.at[j].set(jnp.where(promoted,
                              jnp.where(exists, upd_i, new_i), ri))
    F = F.at[j].set(jnp.where(promoted,
                              jnp.where(exists, upd_f, new_f), rf))
    C = C + jnp.where(promoted & ~exists, 1, 0).astype(jnp.int32)
    return I, F, C, DI, DF, Dn


def _step(carry, xs, H: int):
    """One per-record scan step (Algorithm 1, record granularity)."""
    T, I, F, C, DI, DF, Dn = carry
    idx, lo, hi, dur, val, t = xs
    T, prom = _tables(T, idx, lo, hi, H)
    promoted = jnp.any(prom)
    I, F, C, DI, DF, Dn = _stage2(
        I, F, C, DI, DF, Dn, lo, hi, promoted,
        jnp.int32(1), dur, dur * dur, val, t, t + dur, dur)
    return (T, I, F, C, DI, DF, Dn), None


def _step_run(carry, xs, H: int):
    """One run-compressed scan step: ``r`` records of one key, starting at
    ``t0`` with stride ``dt``, each lasting ``dur``.  The first promoted
    record index is the minimum over tables (``FailSlowSketch
    .insert_run``); records ``first..r-1`` reach Stage-2 as closed-form
    aggregates."""
    T, I, F, C, DI, DF, Dn = carry
    idx, lo, hi, r, dur, val, t0, dt = xs
    active = r > 0
    T, ks = _tables_run(T, idx, lo, hi, r, active, H)
    first = jnp.minimum(jnp.min(ks), r)
    promoted = active & (first < r)
    n = r - first
    nf = n.astype(jnp.float32)
    tfirst = t0 + dt * first.astype(jnp.float32)
    tlast = t0 + dt * jnp.maximum(r - 1, 0).astype(jnp.float32) + dur
    I, F, C, DI, DF, Dn = _stage2(
        I, F, C, DI, DF, Dn, lo, hi, promoted,
        n, nf * dur, nf * dur * dur, nf * val, tfirst, tlast, dur)
    return (T, I, F, C, DI, DF, Dn), None


def _cast_records(lo, hi, dur, val, t):
    return (lo.astype(jnp.int32), hi.astype(jnp.int32),
            dur.astype(jnp.float32), val.astype(jnp.float32),
            t.astype(jnp.float32))


@partial(jax.jit, static_argnames=("H",))
def insert_batch_drained(state, drain, lo, hi, dur, val, t, *, H: int):
    """Insert a whole record batch, draining Stage-2 FIFO evictions.

    Equivalent to ``ref.insert_batch`` / per-record ``FailSlowSketch
    .insert`` calls in order, with hashing hoisted out of the sequential
    loop, the table update vectorized over ``d`` and the state packed so
    each record costs a handful of row scatters.  ``drain`` is a
    ``ref.make_drain`` buffer (size it to the batch length — one record
    evicts at most one row); returns ``(state, drain)``.
    """
    d, m = state["keys_lo"].shape
    lo, hi, dur, val, t = _cast_records(lo, hi, dur, val, t)
    idx_all = hash_all(lo, hi, d, m)             # [n, d], one shot
    carry, _ = jax.lax.scan(partial(_step, H=H), _pack(state, drain),
                            (idx_all, lo, hi, dur, val, t))
    return _unpack(state, drain, carry)


def insert_batch_vectorized(state, lo, hi, dur, val, t, *, H: int):
    """Drain-less compatibility wrapper around ``insert_batch_drained``:
    state transitions are identical (the Stage-2 tables never depended on
    the drain buffer); FIFO-evicted rows are simply discarded, as the
    original scan did.  The throwaway buffer is capacity-1 — the
    saturation clamp absorbs every eviction at O(1) carry instead of
    threading an O(n) buffer through the scan."""
    state, _ = insert_batch_drained(state, make_drain(1),
                                    lo, hi, dur, val, t, H=H)
    return state


@partial(jax.jit, static_argnames=("H",))
def insert_runs_vectorized(state, drain, lo, hi, reps, dur, val, t0, dt,
                           *, H: int):
    """Insert run-length-compressed records: run ``i`` is ``reps[i]``
    consecutive records of key ``(lo[i], hi[i])``, record ``k`` starting
    at ``t0[i] + k·dt[i]`` and lasting ``dur[i]`` with value ``val[i]``.

    The vectorized analogue of ``FailSlowSketch.insert_run`` — bit-exact
    Stage-1 tables and promotion indices, Stage-2 fed the same closed-form
    aggregates (float32 here) — so instruction expansion never
    materialises per-record arrays.  Runs with ``reps ≤ 0`` are no-ops.
    Returns ``(state, drain)``; a run evicts at most one Stage-2 row, so
    ``make_drain(len(runs))`` can never saturate.
    """
    d, m = state["keys_lo"].shape
    lo, hi, dur, val, t0 = _cast_records(lo, hi, dur, val, t0)
    reps = reps.astype(jnp.int32)
    dt = dt.astype(jnp.float32)
    idx_all = hash_all(lo, hi, d, m)             # [n, d], one shot
    carry, _ = jax.lax.scan(partial(_step_run, H=H), _pack(state, drain),
                            (idx_all, lo, hi, reps, dur, val, t0, dt))
    return _unpack(state, drain, carry)
