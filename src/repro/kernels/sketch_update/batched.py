"""Vectorized multi-record Fail-Slow Sketch insertion (campaign hot path).

Same Algorithm-1 semantics as ``ref.insert_batch`` (and therefore the
``core/sketch.py`` numpy oracle), restructured for batch throughput:

* bucket indices for **all** records × all ``d`` hash tables are computed
  up front in one vectorized ``hash_all`` call (the per-record path
  re-hashes inside every step),
* Stage-1 state is packed ``[d, m, 4]`` (lo, hi, valid, freq) and the
  per-table update rule is ``vmap``-ed over the ``d`` hash tables, so one
  record costs one batched gather + one batched scatter,
* Stage-2 state is packed into an int ``[L, 5]`` (lo, hi, valid, count,
  arrival) and a float ``[L, 6]`` (sum, sumsq, val, tmin, tmax, min)
  matrix — one row scatter each per record instead of eleven vector
  scatters,
* records are applied in order by ``lax.scan`` (insertion order is
  semantically load-bearing: Stage-1 frequencies race between keys sharing
  a bucket and Stage-2 eviction is FIFO by promotion arrival).

The packing is an internal layout change only: inputs/outputs use the
``ref.make_state`` dict layout, integer state is bit-identical to the
sequential reference and the float statistics accumulate in the same
float32 order.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .ref import hash_all

_I32MAX = jnp.iinfo(jnp.int32).max


def _one_table(tbl, j, lo, hi, H):
    """Stage-1 update for one packed hash-table row ``[m, 4]`` (vmapped
    over the d tables); returns (new row, promoted-on-this-table)."""
    bk = tbl[j]                                  # (lo, hi, valid, freq)
    match = (bk[2] == 1) & (bk[0] == lo) & (bk[1] == hi)
    empty = bk[2] == 0
    newf = jnp.where(match, bk[3] + 1, jnp.where(empty, 1, bk[3] - 1))
    newv = jnp.where(match | empty, 1, (newf > 0).astype(jnp.int32))
    newlo = jnp.where(empty, lo, bk[0])
    newhi = jnp.where(empty, hi, bk[1])
    newf = jnp.where((~match) & (~empty) & (newf <= 0), 0, newf)
    promoted = (match | empty) & (newf >= H)
    return tbl.at[j].set(jnp.stack([newlo, newhi, newv, newf])), promoted


_tables = jax.vmap(_one_table, in_axes=(0, 0, None, None, None))


def _step(carry, xs, H: int):
    T, I, F, C = carry
    idx, lo, hi, dur, val, t = xs
    T, prom = _tables(T, idx, lo, hi, H)
    promoted = jnp.any(prom)

    # ---- Stage-2: slot selection exactly as the reference --------------
    valid = I[:, 2]
    s2_match = (valid == 1) & (I[:, 0] == lo) & (I[:, 1] == hi)
    exists = jnp.any(s2_match)
    j_upd = jnp.argmax(s2_match)
    free = valid == 0
    any_free = jnp.any(free)
    j_free = jnp.argmax(free)
    j_evict = jnp.argmin(jnp.where(valid == 1, I[:, 4], _I32MAX))
    j = jnp.where(exists, j_upd, jnp.where(any_free, j_free, j_evict))

    ri, rf = I[j], F[j]
    upd_i = jnp.stack([ri[0], ri[1], 1, ri[3] + 1, ri[4]])
    new_i = jnp.stack([lo, hi, 1, 1, C])
    upd_f = jnp.stack([rf[0] + dur, rf[1] + dur * dur, rf[2] + val,
                       jnp.minimum(rf[3], t),
                       jnp.maximum(rf[4], t + dur),
                       jnp.minimum(rf[5], dur)])
    new_f = jnp.stack([dur, dur * dur, val, t, t + dur, dur])
    I = I.at[j].set(jnp.where(promoted,
                              jnp.where(exists, upd_i, new_i), ri))
    F = F.at[j].set(jnp.where(promoted,
                              jnp.where(exists, upd_f, new_f), rf))
    C = C + jnp.where(promoted & ~exists, 1, 0).astype(jnp.int32)
    return (T, I, F, C), None


@partial(jax.jit, static_argnames=("H",))
def insert_batch_vectorized(state, lo, hi, dur, val, t, *, H: int):
    """Insert a whole record batch; state layout matches ``ref.make_state``.

    Equivalent to ``ref.insert_batch`` / per-record ``FailSlowSketch
    .insert`` calls in order, with hashing hoisted out of the sequential
    loop, the table update vectorized over ``d`` and the state packed so
    each record costs a handful of row scatters.
    """
    d, m = state["keys_lo"].shape
    lo, hi = lo.astype(jnp.int32), hi.astype(jnp.int32)
    dur, val, t = (dur.astype(jnp.float32), val.astype(jnp.float32),
                   t.astype(jnp.float32))
    idx_all = hash_all(lo, hi, d, m)             # [n, d], one shot

    T = jnp.stack([state["keys_lo"], state["keys_hi"],
                   state["valid"], state["freq"]], axis=2)
    I = jnp.stack([state["s2_lo"], state["s2_hi"], state["s2_valid"],
                   state["s2_count"], state["s2_arrival"]], axis=1)
    F = jnp.stack([state["s2_sum"], state["s2_sumsq"], state["s2_val"],
                   state["s2_tmin"], state["s2_tmax"], state["s2_min"]],
                  axis=1)
    (T, I, F, C), _ = jax.lax.scan(
        partial(_step, H=H), (T, I, F, state["counter"]),
        (idx_all, lo, hi, dur, val, t))

    out = dict(state, counter=C)
    for k, col in (("keys_lo", 0), ("keys_hi", 1), ("valid", 2),
                   ("freq", 3)):
        out[k] = T[..., col]
    for k, col in (("s2_lo", 0), ("s2_hi", 1), ("s2_valid", 2),
                   ("s2_count", 3), ("s2_arrival", 4)):
        out[k] = I[:, col]
    for k, col in (("s2_sum", 0), ("s2_sumsq", 1), ("s2_val", 2),
                   ("s2_tmin", 3), ("s2_tmax", 4), ("s2_min", 5)):
        out[k] = F[:, col]
    return out
