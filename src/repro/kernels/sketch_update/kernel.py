"""Pallas TPU kernel: batched Fail-Slow Sketch insertion.

TPU mapping of the paper's hot path (every probe record flows through
Stage-1): the d×m bucket tables and the Stage-2 pattern list are pinned in
VMEM for the whole call (they are the monitor's "on-chip SRAM"), trace
records stream HBM→VMEM in blocks via the grid, and the sequential grid
preserves Algorithm 1's insertion-order semantics.  The per-record update
is scalar on the tables (d dynamic bucket probes, unrolled) and vector on
the Stage-2 list (compare/argmin over L lanes on the VPU).

State tensors are passed as inputs and aliased to the outputs
(``input_output_aliases``), so the tables persist across grid steps without
ever leaving VMEM.

Stage-2 FIFO evictions are appended to the drained-eviction stream
(``ref.make_drain`` layout, also VMEM-pinned and aliased) before the
victim row is overwritten — the deployment's DRAM write-back of patterns
leaving on-chip SRAM, mirroring the numpy oracle's ``drained`` list.
``sketch_insert(..., drain=None)`` keeps the historical drain-less
signature (evictions discarded) and returns ``state`` only; passing a
drain buffer returns ``(state, drain)``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ...core.sketch import HASH_A1, HASH_A2, HASH_B, SketchParams
from .ref import make_drain

# Structural contract checked by repro.analysis.kernel_audit: rank-1
# sequential grid streaming trace blocks, with the sketch state aliased
# input→output so it stays VMEM-resident across grid steps.  Algorithm
# 1 is order-sensitive — the sequential grid is load-bearing, and the
# auditor flags any dimension_semantics "parallel" annotation here.
AUDIT = {"grid_rank": 1, "aliased_io": True, "sequential_grid": True}

_I32MAX = np.int32(np.iinfo(np.int32).max)
_BIG = jnp.float32(3.4e38)

_STATE_KEYS = ("keys_lo", "keys_hi", "valid", "freq",
               "s2_lo", "s2_hi", "s2_valid", "s2_count",
               "s2_sum", "s2_sumsq", "s2_val",
               "s2_tmin", "s2_tmax", "s2_min", "s2_arrival", "counter")

_DRAIN_KEYS = ("d_lo", "d_hi", "d_count", "d_arrival",
               "d_sum", "d_sumsq", "d_val", "d_tmin", "d_tmax", "d_min",
               "d_n")


def _hash_scalar(lo, hi, table: int, m: int):
    a1 = jnp.int32(np.uint32(HASH_A1[table] & 0xFFFFFFFF).view(np.int32))
    a2 = jnp.int32(np.uint32(HASH_A2[table] & 0xFFFFFFFF).view(np.int32))
    b = jnp.int32(np.uint32(HASH_B[table] & 0xFFFFFFFF).view(np.int32))
    x = a1 * lo + a2 * hi + b
    x = x ^ ((x >> 16) & 0xFFFF)
    x = x * jnp.int32(0x45D9F3B)
    x = x ^ ((x >> 13) & 0x7FFFF)
    x = x & jnp.int32(0x7FFFFFFF)
    return x % m


def _kernel(lo_ref, hi_ref, dur_ref, val_ref, t_ref, act_ref,
            *state_refs,
            d: int, m: int, H: int, L: int, block: int, cap: int):
    # state arrives twice (inputs, then aliased outputs); operate on the
    # output refs — aliasing makes them carry the live state.
    (klo, khi, vld, frq,
     s2lo, s2hi, s2v, s2c, s2s, s2q, s2val, s2tmin, s2tmax, s2min,
     s2arr, counter,
     dlo, dhi, dcnt, darr, dsum, dsq, dval, dtmin, dtmax, dmin,
     dnum) = state_refs[len(state_refs) // 2:]

    def body(k, _):
        lo = lo_ref[k]
        hi = hi_ref[k]
        dur = dur_ref[k]
        val = val_ref[k]
        t = t_ref[k]
        active = act_ref[k] == 1

        promoted = jnp.bool_(False)
        for i in range(d):                      # unrolled: d is small
            idx = _hash_scalar(lo, hi, i, m)
            bk_lo = klo[i, idx]
            bk_hi = khi[i, idx]
            bk_v = vld[i, idx]
            bk_f = frq[i, idx]
            match = (bk_v == 1) & (bk_lo == lo) & (bk_hi == hi)
            empty = bk_v == 0
            newf = jnp.where(match, bk_f + 1,
                             jnp.where(empty, 1, bk_f - 1))
            newv = jnp.where(match | empty, 1,
                             (newf > 0).astype(jnp.int32))
            newf = jnp.where((~match) & (~empty) & (newf <= 0), 0, newf)
            klo[i, idx] = jnp.where(active & empty, lo, bk_lo)
            khi[i, idx] = jnp.where(active & empty, hi, bk_hi)
            vld[i, idx] = jnp.where(active, newv, bk_v)
            frq[i, idx] = jnp.where(active, newf, bk_f)
            promoted |= (match | empty) & (newf >= H)
        promoted &= active

        # ---- Stage-2 (vector over L) ----------------------------------
        v = s2v[:]
        s2_match = (v == 1) & (s2lo[:] == lo) & (s2hi[:] == hi)
        exists = jnp.any(s2_match)
        j_upd = jnp.argmax(s2_match)
        free = v == 0
        any_free = jnp.any(free)
        j_free = jnp.argmax(free)
        j_evict = jnp.argmin(jnp.where(v == 1, s2arr[:], _I32MAX))
        j = jnp.where(exists, j_upd, jnp.where(any_free, j_free, j_evict))

        # ---- drain the FIFO victim before its slot is overwritten -----
        # (index-clamped: an undersized buffer saturates, never scatters
        # out of bounds)
        evict = promoted & (~exists) & (~any_free)
        dn = dnum[0]
        slot = jnp.minimum(dn, cap - 1)
        keep = evict & (dn < cap)
        for dref, sref in ((dlo, s2lo), (dhi, s2hi), (dcnt, s2c),
                           (darr, s2arr), (dsum, s2s), (dsq, s2q),
                           (dval, s2val), (dtmin, s2tmin),
                           (dtmax, s2tmax), (dmin, s2min)):
            dref[slot] = jnp.where(keep, sref[j], dref[slot])
        dnum[0] = dn + keep.astype(jnp.int32)

        def put(ref, on_upd, on_new):
            old = ref[j]
            ref[j] = jnp.where(promoted,
                               jnp.where(exists, on_upd, on_new), old)

        cnt = s2c[j]
        put(s2lo, s2lo[j], lo)
        put(s2hi, s2hi[j], hi)
        put(s2v, 1, 1)
        put(s2c, cnt + 1, 1)
        put(s2s, s2s[j] + dur, dur)
        put(s2q, s2q[j] + dur * dur, dur * dur)
        put(s2val, s2val[j] + val, val)
        put(s2tmin, jnp.minimum(s2tmin[j], t), t)
        put(s2tmax, jnp.maximum(s2tmax[j], t + dur), t + dur)
        put(s2min, jnp.minimum(s2min[j], dur), dur)
        put(s2arr, s2arr[j], counter[0])
        counter[0] = counter[0] + jnp.where(promoted & ~exists, 1, 0)\
            .astype(jnp.int32)
        return ()

    jax.lax.fori_loop(0, block, body, ())


@partial(jax.jit, static_argnames=("params", "block", "interpret"))
def sketch_insert(state: dict, lo, hi, dur, val, t, *,
                  params: SketchParams, block: int = 256,
                  interpret: bool = True, drain: dict | None = None):
    """Insert a batch of records into the sketch state via the Pallas
    kernel.  State layout matches ``ref.make_state``.  With a
    ``ref.make_drain`` buffer, Stage-2 FIFO evictions are appended to it
    and ``(state, drain)`` is returned; without one the historical
    drain-less behaviour (evictions discarded, ``state`` returned) is
    preserved."""
    want_drain = drain is not None
    if not want_drain:
        drain = make_drain(1)
    n = lo.shape[0]
    nb = -(-n // block)
    pad = nb * block - n
    act = jnp.ones((n,), jnp.int32)
    if pad:
        z32 = jnp.zeros((pad,), jnp.int32)
        zf = jnp.zeros((pad,), jnp.float32)
        lo = jnp.concatenate([lo.astype(jnp.int32), z32])
        hi = jnp.concatenate([hi.astype(jnp.int32), z32])
        dur = jnp.concatenate([dur.astype(jnp.float32), zf])
        val = jnp.concatenate([val.astype(jnp.float32), zf])
        t = jnp.concatenate([t.astype(jnp.float32), zf])
        act = jnp.concatenate([act, z32])
    else:
        lo, hi = lo.astype(jnp.int32), hi.astype(jnp.int32)
        dur, val, t = (dur.astype(jnp.float32), val.astype(jnp.float32),
                       t.astype(jnp.float32))

    p = params
    cap = drain["d_lo"].shape[0]
    trace_spec = pl.BlockSpec((block,), lambda i: (i,))
    tbl_spec = pl.BlockSpec((p.d, p.m), lambda i: (0, 0))
    vec_spec = pl.BlockSpec((p.L,), lambda i: (0,))
    drain_spec = pl.BlockSpec((cap,), lambda i: (0,))
    one_spec = pl.BlockSpec((1,), lambda i: (0,))
    state_specs = ([tbl_spec] * 4 + [vec_spec] * 11 + [one_spec]
                   + [drain_spec] * 10 + [one_spec])

    state_in = ([state[k] if k != "counter" else state[k].reshape(1)
                 for k in _STATE_KEYS]
                + [drain[k] if k != "d_n" else drain[k].reshape(1)
                   for k in _DRAIN_KEYS])
    out_shapes = [jax.ShapeDtypeStruct(s.shape, s.dtype) for s in state_in]
    n_trace = 6

    out = pl.pallas_call(
        partial(_kernel, d=p.d, m=p.m, H=p.H, L=p.L, block=block, cap=cap),
        grid=(nb,),
        in_specs=[trace_spec] * n_trace + state_specs,
        out_specs=state_specs,
        out_shape=out_shapes,
        input_output_aliases={n_trace + i: i
                              for i in range(len(state_in))},
        interpret=interpret,
    )(lo, hi, dur, val, t, act, *state_in)
    new_state = dict(zip(_STATE_KEYS, out[:len(_STATE_KEYS)]))
    new_state["counter"] = new_state["counter"].reshape(())
    if not want_drain:
        return new_state
    new_drain = dict(zip(_DRAIN_KEYS, out[len(_STATE_KEYS):]))
    new_drain["d_n"] = new_drain["d_n"].reshape(())
    return new_state, new_drain
