"""Pure-jnp oracle for the Fail-Slow Sketch batched insertion.

Functionally identical to ``repro.core.sketch.FailSlowSketch`` (the numpy
Algorithm-1 reference): one ``lax.scan`` step per trace record.  The Pallas
kernel must match this bit-for-bit on integer state and to float tolerance
on the statistics.

State layout (arrays; L = Stage-2 capacity, d×m = Stage-1 tables):
  keys_lo/keys_hi/valid/freq        [d, m]  int32
  s2_lo/s2_hi/s2_valid/s2_count     [L]     int32
  s2_sum/s2_sumsq/s2_val            [L]     f32
  s2_tmin/s2_tmax/s2_min            [L]     f32
  s2_arrival                        [L]     int32
  counter                           []      int32 (arrival counter)

Drained-eviction stream (``make_drain``; the deployment's off-chip DRAM
write stream, mirroring ``FailSlowSketch.drained``):
  d_lo/d_hi/d_count/d_arrival       [cap]   int32
  d_sum/d_sumsq/d_val               [cap]   f32
  d_tmin/d_tmax/d_min               [cap]   f32
  d_n                               []      int32 (rows written)

A Stage-2 FIFO eviction appends the victim row to the drain buffer before
it is overwritten, so no promoted pattern is ever lost — the numpy oracle
keeps these in ``self.drained`` and merges them in ``patterns()``; without
the stream the packed-state paths silently diverge under eviction
pressure.  One batch of ``n`` records (or runs) evicts at most ``n`` rows,
so callers size the buffer with ``make_drain(n)`` per insert call.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ...core.sketch import HASH_A1, HASH_A2, HASH_B, SketchParams

_BIG = jnp.float32(3.4e38)


def make_state(p: SketchParams):
    d, m, L = p.d, p.m, p.L
    z = lambda *s: jnp.zeros(s, jnp.int32)  # noqa: E731
    zf = lambda *s: jnp.zeros(s, jnp.float32)  # noqa: E731
    return {
        "keys_lo": z(d, m), "keys_hi": z(d, m), "valid": z(d, m),
        "freq": z(d, m),
        "s2_lo": z(L), "s2_hi": z(L), "s2_valid": z(L), "s2_count": z(L),
        "s2_sum": zf(L), "s2_sumsq": zf(L), "s2_val": zf(L),
        "s2_tmin": jnp.full((L,), _BIG, jnp.float32),
        "s2_tmax": jnp.full((L,), -_BIG, jnp.float32),
        "s2_min": jnp.full((L,), _BIG, jnp.float32),
        "s2_arrival": jnp.full((L,), jnp.iinfo(jnp.int32).max, jnp.int32),
        "counter": jnp.zeros((), jnp.int32),
    }


def make_drain(capacity: int):
    """Drained-eviction buffer for ``capacity`` potential evictions (see
    the module docstring).  The capacity floor of 1 keeps every array
    indexable — ``d_n`` alone says how many rows are real."""
    c = max(int(capacity), 1)
    z = lambda: jnp.zeros((c,), jnp.int32)  # noqa: E731
    zf = lambda: jnp.zeros((c,), jnp.float32)  # noqa: E731
    return {
        "d_lo": z(), "d_hi": z(), "d_count": z(), "d_arrival": z(),
        "d_sum": zf(), "d_sumsq": zf(), "d_val": zf(),
        "d_tmin": zf(), "d_tmax": zf(), "d_min": zf(),
        "d_n": jnp.zeros((), jnp.int32),
    }


def hash_all(lo, hi, d: int, m: int):
    """Bucket index per table; int32 wraparound arithmetic (TPU-native)."""
    a1 = jnp.asarray((HASH_A1[:d] & 0xFFFFFFFF).astype(np.uint32)
                     .view(np.int32))
    a2 = jnp.asarray((HASH_A2[:d] & 0xFFFFFFFF).astype(np.uint32)
                     .view(np.int32))
    b = jnp.asarray((HASH_B[:d] & 0xFFFFFFFF).astype(np.uint32)
                    .view(np.int32))
    lo = jnp.asarray(lo, jnp.int32)[..., None]
    hi = jnp.asarray(hi, jnp.int32)[..., None]
    x = a1 * lo + a2 * hi + b                  # [..., d]
    x = x ^ ((x >> 16) & 0xFFFF)
    x = x * jnp.int32(0x45D9F3B)
    x = x ^ ((x >> 13) & 0x7FFFF)
    x = x & jnp.int32(0x7FFFFFFF)
    return x % m


def _insert_one(state, trace, *, H: int):
    lo, hi = trace["lo"], trace["hi"]
    dur, val, t = trace["dur"], trace["val"], trace["t"]
    idx = hash_all(lo, hi, *state["keys_lo"].shape)
    state, promoted = stage1_update(state, idx, lo, hi, H=H)
    return stage2_update(state, lo, hi, dur, val, t, promoted)


def stage1_update(state, idx, lo, hi, *, H: int):
    """Stage-1 bucket update for one record given its ``d`` precomputed
    bucket indices; returns (state, promoted)."""
    d = state["keys_lo"].shape[0]
    rows = jnp.arange(d)
    klo = state["keys_lo"][rows, idx]
    khi = state["keys_hi"][rows, idx]
    vld = state["valid"][rows, idx]
    frq = state["freq"][rows, idx]

    match = (vld == 1) & (klo == lo) & (khi == hi)
    empty = vld == 0
    newf = jnp.where(match, frq + 1, jnp.where(empty, 1, frq - 1))
    newv = jnp.where(match | empty, 1, (newf > 0).astype(jnp.int32))
    newlo = jnp.where(empty, lo, klo)
    newhi = jnp.where(empty, hi, khi)
    newf = jnp.where((~match) & (~empty) & (newf <= 0), 0, newf)

    state = dict(state)
    state["keys_lo"] = state["keys_lo"].at[rows, idx].set(newlo)
    state["keys_hi"] = state["keys_hi"].at[rows, idx].set(newhi)
    state["valid"] = state["valid"].at[rows, idx].set(newv)
    state["freq"] = state["freq"].at[rows, idx].set(newf)

    promoted = jnp.any((match | empty) & (newf >= H))
    return state, promoted


def stage2_update(state, lo, hi, dur, val, t, promoted):
    """Stage-2 bounded-list update for one record (vector over L);
    shared by the scan reference and the vectorized batch path so both
    are bit-identical."""
    s2_match = (state["s2_valid"] == 1) & (state["s2_lo"] == lo) \
        & (state["s2_hi"] == hi)
    exists = jnp.any(s2_match)
    j_upd = jnp.argmax(s2_match)
    any_free = jnp.any(state["s2_valid"] == 0)
    j_free = jnp.argmax(state["s2_valid"] == 0)
    j_evict = jnp.argmin(jnp.where(state["s2_valid"] == 1,
                                   state["s2_arrival"],
                                   jnp.iinfo(jnp.int32).max))
    j_new = jnp.where(any_free, j_free, j_evict)
    j = jnp.where(exists, j_upd, j_new)

    def upd(x, newval, on_new):
        return x.at[j].set(jnp.where(promoted,
                                     jnp.where(exists, newval, on_new),
                                     x[j]))

    cnt = state["s2_count"][j]
    state["s2_lo"] = upd(state["s2_lo"], state["s2_lo"][j], lo)
    state["s2_hi"] = upd(state["s2_hi"], state["s2_hi"][j], hi)
    state["s2_valid"] = upd(state["s2_valid"], 1, 1)
    state["s2_count"] = upd(state["s2_count"], cnt + 1, 1)
    state["s2_sum"] = upd(state["s2_sum"], state["s2_sum"][j] + dur, dur)
    state["s2_sumsq"] = upd(state["s2_sumsq"],
                            state["s2_sumsq"][j] + dur * dur, dur * dur)
    state["s2_val"] = upd(state["s2_val"], state["s2_val"][j] + val, val)
    state["s2_tmin"] = upd(state["s2_tmin"],
                           jnp.minimum(state["s2_tmin"][j], t), t)
    state["s2_tmax"] = upd(state["s2_tmax"],
                           jnp.maximum(state["s2_tmax"][j], t + dur),
                           t + dur)
    state["s2_min"] = upd(state["s2_min"],
                          jnp.minimum(state["s2_min"][j], dur), dur)
    state["s2_arrival"] = upd(state["s2_arrival"], state["s2_arrival"][j],
                              state["counter"])
    state["counter"] = state["counter"] + jnp.where(
        promoted & ~exists, 1, 0).astype(jnp.int32)
    return state


@partial(jax.jit, static_argnames=("H",))
def insert_batch(state, lo, hi, dur, val, t, *, H: int):
    """Sequentially insert a batch of records (lax.scan)."""
    def step(st, xs):
        lo_, hi_, d_, v_, t_ = xs
        return _insert_one(st, {"lo": lo_, "hi": hi_, "dur": d_,
                                "val": v_, "t": t_}, H=H), None
    state, _ = jax.lax.scan(step, state,
                            (lo.astype(jnp.int32), hi.astype(jnp.int32),
                             dur.astype(jnp.float32),
                             val.astype(jnp.float32),
                             t.astype(jnp.float32)))
    return state
