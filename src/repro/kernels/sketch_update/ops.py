"""Jitted public API for the sketch-update kernel.

``insert(state, traces, impl=...)`` dispatches between the Pallas kernel
(TPU target; ``interpret=True`` on CPU), the vectorized multi-record batch
path (``impl="batched"``, the campaign hot path) and the pure-jnp scan
oracle.  ``insert_runs`` is the run-compressed entry point (the
vectorized analogue of ``FailSlowSketch.insert_run``; the recorder's
on-device path).  ``patterns(state)`` decodes Stage-2 into the same
Pattern records the numpy reference produces; given a drained-eviction
buffer it merges drained partials with the live list exactly like
``FailSlowSketch.patterns(include_drained=True)``.
"""

from __future__ import annotations

import numpy as np

from ...core.sketch import Pattern, SketchParams, accumulate_pattern
from . import batched as V
from . import kernel as K
from . import ref as R


def make_state(params: SketchParams):
    return R.make_state(params)


def make_drain(capacity: int):
    """Drained-eviction buffer for up to ``capacity`` Stage-2 evictions
    (one insert call over n records/runs evicts at most n rows)."""
    return R.make_drain(capacity)


def insert(state, lo, hi, dur, val, t, *, params: SketchParams,
           impl: str = "pallas", interpret: bool = True, block: int = 256,
           drain=None):
    """Per-record batched insertion.  With ``drain`` (a ``make_drain``
    buffer), FIFO-evicted Stage-2 rows are preserved and ``(state,
    drain)`` is returned; without it evictions are discarded and only
    ``state`` returns (the historical contract, still bit-identical on
    state).  The pure-jnp scan oracle (``impl="ref"``) has no drain
    support — it exists to pin the kernels' state transitions."""
    if impl == "pallas":
        return K.sketch_insert(state, lo, hi, dur, val, t, params=params,
                               block=block, interpret=interpret,
                               drain=drain)
    if impl == "batched":
        if drain is None:
            return V.insert_batch_vectorized(state, lo, hi, dur, val, t,
                                             H=params.H)
        return V.insert_batch_drained(state, drain, lo, hi, dur, val, t,
                                      H=params.H)
    if drain is not None:
        raise ValueError("impl='ref' does not support a drain buffer")
    return R.insert_batch(state, lo, hi, dur, val, t, H=params.H)


def insert_runs(state, drain, lo, hi, reps, dur, val, t0, dt, *,
                params: SketchParams):
    """Run-compressed insertion: run ``i`` stands for ``reps[i]``
    consecutive records of key ``(lo[i], hi[i])`` starting at ``t0[i]``
    with stride ``dt[i]``.  Returns ``(state, drain)``."""
    return V.insert_runs_vectorized(state, drain, lo, hi, reps, dur, val,
                                    t0, dt, H=params.H)


_PAT_COLS = ("count", "sum", "sumsq", "val", "tmin", "tmax", "arrival",
             "min")


def _bulk_rows(merged: dict[int, Pattern], arr, pre: str, idx,
               key_tag: int):
    """Decode rows ``idx`` of a state/drain dict (field prefix ``pre``)
    into Patterns, accumulating into ``merged``.  Bulk device→host
    transfer + ``tolist`` — per-row scalar reads on device arrays would
    each sync."""
    if len(idx) == 0:
        return
    keys = (np.asarray(arr[pre + "lo"])[idx].astype(np.int64)
            + (np.asarray(arr[pre + "hi"])[idx].astype(np.int64) << 31))
    cols = [keys.tolist()] + [np.asarray(arr[pre + c])[idx].tolist()
                              for c in _PAT_COLS]
    for key, cnt, s, sq, v, tmin, tmax, arrival, mind in zip(*cols):
        accumulate_pattern(merged, Pattern(key | key_tag, cnt, s, sq, v,
                                           tmin, tmax, arrival, mind))


def drain_patterns(drain, key_tag: int = 0) -> list[Pattern]:
    """Decode ONLY a drained-eviction buffer into (partial) Patterns,
    merged per key, in eviction order.  The streaming recorder uses this
    to fold each ``insert_runs`` call's evictions into a host-side
    accumulator and then reuse a fresh drain buffer for the next chunk —
    packed sketch state stays on device across ``observe()`` calls while
    the drained stream grows off-chip, exactly like the deployment's
    DRAM write stream."""
    merged: dict[int, Pattern] = {}
    _bulk_rows(merged, drain, "d_", np.arange(int(np.asarray(
        drain["d_n"]))), key_tag)
    return sorted(merged.values(), key=lambda p: p.arrival)


def patterns(state, drain=None, key_tag: int = 0) -> list[Pattern]:
    """Decode Stage-2 (and, when given, the drained-eviction stream) into
    Pattern records, merged per key exactly like the numpy oracle's
    ``patterns(include_drained=True)`` (a drained key that re-promotes
    later appears as two partials; they merge here).

    ``key_tag`` is OR-ed into every reconstructed key: the sketch's
    (lo, hi) halves preserve key bits 0–61, so a key space tagged above
    bit 61 (the comp space, ``probes.COMP_KEY_TAG``) must have its tag
    restored by the caller, who knows which sketch it is reading.
    """
    merged: dict[int, Pattern] = {}
    if drain is not None:
        _bulk_rows(merged, drain, "d_", np.arange(int(np.asarray(
            drain["d_n"]))), key_tag)
    _bulk_rows(merged, state, "s2_",
               np.nonzero(np.asarray(state["s2_valid"]))[0], key_tag)
    return sorted(merged.values(), key=lambda p: p.arrival)
