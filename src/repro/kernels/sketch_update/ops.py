"""Jitted public API for the sketch-update kernel.

``insert(state, traces, impl=...)`` dispatches between the Pallas kernel
(TPU target; ``interpret=True`` on CPU), the vectorized multi-record batch
path (``impl="batched"``, the campaign hot path) and the pure-jnp scan
oracle.  ``patterns(state)`` decodes Stage-2 into the same Pattern records
the numpy reference produces.
"""

from __future__ import annotations

import numpy as np

from ...core.sketch import Pattern, SketchParams
from . import batched as V
from . import kernel as K
from . import ref as R


def make_state(params: SketchParams):
    return R.make_state(params)


def insert(state, lo, hi, dur, val, t, *, params: SketchParams,
           impl: str = "pallas", interpret: bool = True, block: int = 256):
    if impl == "pallas":
        return K.sketch_insert(state, lo, hi, dur, val, t, params=params,
                               block=block, interpret=interpret)
    if impl == "batched":
        return V.insert_batch_vectorized(state, lo, hi, dur, val, t,
                                         H=params.H)
    return R.insert_batch(state, lo, hi, dur, val, t, H=params.H)


def patterns(state) -> list[Pattern]:
    out = []
    valid = np.asarray(state["s2_valid"])
    for j in np.nonzero(valid)[0]:
        key = int(np.asarray(state["s2_lo"][j])) \
            + (int(np.asarray(state["s2_hi"][j])) << 31)
        out.append(Pattern(
            key=key,
            count=int(state["s2_count"][j]),
            sum_dur=float(state["s2_sum"][j]),
            sum_sq_dur=float(state["s2_sumsq"][j]),
            sum_val=float(state["s2_val"][j]),
            t_first=float(state["s2_tmin"][j]),
            t_last=float(state["s2_tmax"][j]),
            arrival=int(state["s2_arrival"][j]),
            min_dur=float(state["s2_min"][j]),
        ))
    return sorted(out, key=lambda p: p.arrival)
