"""Pallas TPU kernel: chunked Mamba-2 SSD scan.

Grid (BH, n_chunks): the chunk dimension is sequential; the inter-chunk
state S [P, N] lives in VMEM scratch across chunk steps (TPU revisiting
semantics).  Per chunk the kernel computes the intra-chunk dual form (an
MXU [Q,Q]·[Q,P] product with the decay-masked score matrix), adds the
inter-chunk contribution C·Sᵀ·exp(cum), and updates the carried state —
the same decomposition as ``repro.models.mamba2.ssd_chunked``, tiled so
chunk Q and head dim P align to the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Structural contract checked by repro.analysis.kernel_audit: rank-2
# grid (bh, chunks); the inter-chunk state lives in VMEM scratch (no
# aliasing), carried by the sequential chunk axis.
AUDIT = {"grid_rank": 2, "aliased_io": False, "sequential_grid": True}


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, out_state_ref,
            state_scr, *, chunk: int, seq_len: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[:] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)        # [Q]
    a = a_ref[0]                              # scalar
    b = b_ref[0].astype(jnp.float32)          # [Q, N]
    c = c_ref[0].astype(jnp.float32)          # [Q, N]

    # sequence mask for the padded tail
    pos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
    live = (pos < seq_len).astype(jnp.float32)
    dt = dt * live[:, 0]

    l = dt * a                                # log-decay [Q]
    cum = jnp.cumsum(l)
    cum_end = cum[-1]

    # intra-chunk dual form
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    decay = jnp.exp(jnp.minimum(cum[:, None] - cum[None, :], 0.0))
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    gmat = jnp.where(kj <= qi, scores * decay, 0.0)
    xdt = x * dt[:, None]
    y = jax.lax.dot_general(gmat, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk contribution from the carried state
    state = state_scr[:]                      # [P, N]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: S ← exp(cum_end)·S + Σ_j exp(cum_end−cum_j)·dt_j·x_j⊗B_j
    w_end = jnp.exp(cum_end - cum) * dt       # [Q]
    upd = jax.lax.dot_general(x * w_end[:, None], b,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    state_scr[:] = jnp.exp(cum_end) * state + upd

    y_ref[0] = (y * live).astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _finish():
        out_state_ref[0] = state_scr[:]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b, c, *, chunk: int = 128, interpret: bool = True):
    """x [BH,S,P], dt [BH,S], a [BH], b/c [BH,S,N] →
    (y [BH,S,P], final_state [BH,P,N])."""
    bh, s, p = x.shape
    n = b.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))

    y, state = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, seq_len=s),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk), lambda h, i: (h, i)),
            pl.BlockSpec((1,), lambda h, i: (h,)),
            pl.BlockSpec((1, chunk, n), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda h, i: (h, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, p, n), lambda h, i: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, nc * chunk, p), x.dtype),
            jax.ShapeDtypeStruct((bh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
    return y[:, :s], state
