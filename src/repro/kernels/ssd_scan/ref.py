"""Pure-jnp oracle for the Mamba-2 SSD scan: naive per-token recurrence.

    S_t = exp(dt_t·a)·S_{t-1} + dt_t·(B_t ⊗ x_t)
    y_t = C_t·S_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, a, b, c, init_state=None):
    """x [BH,S,P], dt [BH,S], a [BH], b/c [BH,S,N] →
    (y [BH,S,P], final_state [BH,P,N])."""
    bh, s, p = x.shape
    n = b.shape[-1]
    if init_state is None:
        init_state = jnp.zeros((bh, p, n), jnp.float32)

    def step(state, xs):
        xt, dtt, bt, ct = xs
        decay = jnp.exp(dtt * a)[:, None, None]
        upd = jnp.einsum("bp,bn,b->bpn", xt.astype(jnp.float32),
                         bt.astype(jnp.float32), dtt)
        state = decay * state + upd
        y = jnp.einsum("bpn,bn->bp", state, ct.astype(jnp.float32))
        return state, y

    state, ys = jax.lax.scan(
        step, init_state,
        (x.transpose(1, 0, 2), dt.transpose(1, 0),
         b.transpose(1, 0, 2), c.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2).astype(x.dtype), state
