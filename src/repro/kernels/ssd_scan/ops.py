"""Public wrapper for the SSD scan kernel (model-layout adapters)."""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import ssd_scan
from .ref import ssd_ref


def ssd(x, dt, a, b, c, *, impl: str = "pallas", chunk: int = 128,
        interpret: bool = True):
    """Model layout: x [B,S,H,P], dt [B,S,H], a [H], b/c [B,S,G,N] →
    (y [B,S,H,P], state [B,H,P,N])."""
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    bh = jnp.repeat(b, rep, axis=2)
    ch = jnp.repeat(c, rep, axis=2)
    xf = x.transpose(0, 2, 1, 3).reshape(bsz * h, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(bsz * h, s)
    af = jnp.tile(a, bsz)
    bf = bh.transpose(0, 2, 1, 3).reshape(bsz * h, s, n)
    cf = ch.transpose(0, 2, 1, 3).reshape(bsz * h, s, n)
    fn = ssd_scan if impl == "pallas" else ssd_ref
    if impl == "pallas":
        y, st = fn(xf, dtf, af, bf, cf, chunk=chunk, interpret=interpret)
    else:
        y, st = fn(xf, dtf, af, bf, cf)
    return (y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3),
            st.reshape(bsz, h, p, n))
