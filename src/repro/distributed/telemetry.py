"""Pod-level fail-slow detection: SLOTH one level up.

A TPU pod *is* a many-core accelerator: chips ↔ cores, ICI links ↔ NoC
links.  This module adapts the SLOTH pipeline to per-step training
telemetry:

  * every step, each chip reports its step compute time (the per-chip
    portion before the gradient all-reduce) and per-neighbour collective
    transfer (bytes, time) — on real hardware these come from host callbacks
    / ICI counters; in this repo the ``PodSimulator`` below generates them
    with the same statistical model as the paper's simulator;
  * records are compressed through the same Fail-Slow Sketch (the monitor
    budget per host is a few hundred KiB);
  * SL-Tracer (group outliers + EM + MCG + FailRank) localises slow chips
    or degraded ICI links, folding FailRank mass into the verdict exactly
    like ``Sloth.analyse`` (detection says *what looks slow*, FailRank
    arbitrates *which correlated anomaly is the propagation source*);
  * detection runs **live**: ``PodDetector.observe(window)`` holds the
    sketch state across windows (:class:`~repro.core.streaming
    .StreamingRecorder`) and emits one verdict per window, and
    :class:`StepTelemetry` bridges a real training/serving loop's
    measured per-step wall times into those windows — the wiring behind
    ``launch/train.py --telemetry`` and ``launch/serve.py --telemetry``;
  * ``PodMitigationPolicy`` turns verdicts into actions: data-shard
    rebalance for mild degradation, checkpoint-restart excluding the failed
    host for severe/persistent degradation (elastic re-mesh).  Severe plans
    are expressed through the shared mitigation registry
    (:mod:`repro.mitigate`): remap for slow chips, reroute for degraded
    ICI links — the same policies the campaign's recovered-throughput
    axis judges.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.detection import detect_cores, detect_links
from ..core.detectors import Verdict
from ..core.failrank import FailRankParams, attribute_links, failrank
from ..core.failures import FailSlow
from ..core.mcg import build_mcg
from ..core.recorder import RecorderOutput, record
from ..core.routing import Topology, build_topology
from ..core.simulator import SimResult
from ..core.sketch import SketchParams
from ..core.streaming import StreamingRecorder


@dataclasses.dataclass
class PodTelemetryConfig:
    mesh_w: int = 16
    mesh_h: int = 16
    # registry fabric key for the pod ('mesh' | 'torus' | 'systolic' |
    # 'het:fast2slow1' | ...): the simulator and the detector both build
    # their fabric through the topology registry from this one field, so
    # pod telemetry honours the deployment's actual fabric instead of
    # hard-coding a mesh in each class.
    topology: str = "mesh"
    window_steps: int = 32          # steps per analysis window
    sketch: SketchParams = dataclasses.field(
        default_factory=lambda: SketchParams(d=2, m=1024, H=4, L=2048))
    detect_threshold: float = 0.55
    # SL-Recorder sketch path for the pod detector ("ref" | "batched"),
    # plumbed through record()/StreamingRecorder exactly like
    # SlothConfig.recorder_impl
    recorder_impl: str = "ref"


class PodSimulator:
    """Synthetic per-step telemetry with the paper's statistical model:
    chip compute time ~ Normal, ICI transfer ~ Gamma, plus injected
    fail-slow windows."""

    def __init__(self, cfg: PodTelemetryConfig, *, step_flops: float,
                 collective_bytes: float, seed: int = 0,
                 host: int = 0):
        self.cfg = cfg
        self.mesh = build_topology(cfg.topology, cfg.mesh_w, cfg.mesh_h)
        # Host identity and mesh shape are folded into the stream key
        # the same way campaign.py keys scenarios — two hosts sharing a
        # base seed must not draw identical telemetry noise.
        self.rng = np.random.default_rng(
            [seed, host, cfg.mesh_w, cfg.mesh_h])
        self.step_flops = step_flops
        self.coll_bytes = collective_bytes
        self.chip_speed = 1.0 + 0.02 * self.rng.standard_normal(
            self.mesh.n_cores)
        self.failures: list[FailSlow] = []

    def inject(self, f: FailSlow):
        self.failures.append(f)

    def _slow(self, kind: str, loc: int, t: float) -> float:
        s = 1.0
        for f in self.failures:
            if f.kind == kind and f.location == loc \
                    and f.t0 <= t < f.t0 + f.duration:
                s *= f.slowdown
        return s

    def run_steps(self, n_steps: int, t0: float = 0.0, *,
                  step0: int = 0, chip0_durs=None,
                  base: float | None = None,
                  jitter: float | None = None) -> SimResult:
        """Telemetry for ``n_steps`` training steps as a SimResult.

        Window-by-window generation (for the streaming detector) uses
        the keyword overrides: ``step0`` continues the absolute step
        index (stage grouping spans windows), ``chip0_durs`` substitutes
        *measured* step durations for chip 0 (the local host feeding
        real timings through :class:`StepTelemetry`), ``base``
        overrides the nominal per-chip step seconds (e.g. the measured
        baseline) and ``jitter`` the peers' relative step-time noise
        (default 1%; :class:`StepTelemetry` passes the host's *measured*
        noise so real timing variance isn't mistaken for a z-outlier)
        — defaults reproduce the historical draws exactly.
        ``total_time`` is relative to ``t0``; record timestamps are
        absolute.
        """
        mesh = self.mesh
        if base is None:
            base = self.step_flops / 197e12  # nominal per-chip step seconds
        if jitter is None:
            jitter = 0.01
        comp = {k: [] for k in ("core", "node", "part", "stage", "op",
                                "flops", "t_start", "t_end")}
        comm = {k: [] for k in ("src", "dst", "stage", "bytes", "t_depart",
                                "t_arrive", "hops", "service")}
        t = t0
        # pattern keys must recur for sketch promotion: group steps into
        # 4-step stages (the sketch's H=4 promotes within one stage, and
        # each analysis window still holds >=3 stages of link evidence)
        stage_of = lambda s: s // 4  # noqa: E731
        for i in range(n_steps):
            s = step0 + i
            durs = np.empty(mesh.n_cores)
            for c in range(mesh.n_cores):
                slow = self._slow("core", c, t)
                jit = 1.0 + jitter * abs(self.rng.standard_normal())
                durs[c] = base * jit * slow / self.chip_speed[c]
            if chip0_durs is not None:
                durs[0] = chip0_durs[i]    # the host's measured step time
            for c in range(mesh.n_cores):
                comp["core"].append(c)
                comp["node"].append(s)
                comp["part"].append(0)
                comp["stage"].append(stage_of(s))
                comp["op"].append(1)
                comp["flops"].append(self.step_flops)
                comp["t_start"].append(t)
                comp["t_end"].append(t + durs[c])
            # ring all-reduce: neighbour transfers on every mesh link
            step_end = t + durs.max()
            per_link = self.coll_bytes / mesh.n_links
            svc_step = []
            for lid, (u, v) in enumerate(mesh.links):
                slow = self._slow("link", lid, t)
                g = self.rng.gamma(16.0, 1 / 16.0)
                svc = per_link * g * slow / 50e9 + 1e-6
                comm["src"].append(u)
                comm["dst"].append(v)
                comm["stage"].append(stage_of(s))
                comm["bytes"].append(per_link)
                comm["t_depart"].append(t + durs[u])
                comm["t_arrive"].append(t + durs[u] + svc)
                comm["hops"].append(1)
                comm["service"].append(svc)
                svc_step.append(svc)
            # the next step starts once the slowest link of THIS step has
            # delivered (the all-reduce barrier).  This used to read
            # ``max(c[-1] for c in [comm["service"]])`` — a max over a
            # one-element list, i.e. the *last* enumerated link's service
            # — so step boundaries (and thus window assignment) drifted
            # whenever the slowest link wasn't the last one.
            t = step_end + max(svc_step)
        return SimResult(
            total_time=t - t0,
            comp={k: np.asarray(v) for k, v in comp.items()},
            comm={k: np.asarray(v) for k, v in comm.items()},
            n_raw_records=n_steps * (self.mesh.n_cores + self.mesh.n_links))


@dataclasses.dataclass
class PodVerdict:
    flagged: bool
    kind: str | None
    location: int | None
    severity: float
    action: str       # 'none' | 'rebalance' | 'exclude_and_restart'


class PodDetector:
    """SLOTH pipeline bound to the pod topology.

    ``analyse(sim)`` is the post-hoc entry point (record the whole
    telemetry trace, then trace it); ``observe(window)`` is the live
    one — sketch state persists across calls in a
    :class:`~repro.core.streaming.StreamingRecorder` and every window
    yields a fresh verdict over the cumulative compressed history, so a
    training loop gets one verdict per ``window_steps`` without ever
    re-recording past steps.
    """

    def __init__(self, cfg: PodTelemetryConfig):
        self.cfg = cfg
        self.mesh = build_topology(cfg.topology, cfg.mesh_w, cfg.mesh_h)
        self._stream: StreamingRecorder | None = None

    def _verdict_from(self, rec: RecorderOutput,
                      total_time: float) -> PodVerdict:
        """SL-Tracer over a compressed telemetry trace.

        Folds FailRank mass into the detection probabilities exactly
        like ``Sloth.analyse`` — each candidate's probability is scaled
        by ``0.5 + normalised FailRank mass``, so among correlated
        anomalies the propagation *source* wins the verdict (the
        FailRank result used to be computed and then dropped here).
        """
        cfg = self.cfg
        cores = detect_cores(rec.comp_patterns, total_time, 4,
                             z_flag=6.0)
        links = detect_links(rec.comm_patterns, self.mesh, total_time,
                             4, hop_latency=0.0)
        n_cores = self.mesh.n_cores
        core_ev = np.zeros(n_cores)
        core_z = np.zeros(n_cores)
        for c in cores:
            core_ev[c.core] = max(core_ev[c.core], c.prob)
            core_z[c.core] = max(core_z[c.core], c.z)
        link_ev = np.zeros(self.mesh.n_links)
        link_z = np.zeros(self.mesh.n_links)
        for c in links.candidates:
            link_ev[c.link] = max(link_ev[c.link], c.prob)
            link_z[c.link] = max(link_z[c.link], c.z)
        max_core = float(core_ev.max()) if n_cores else 0.0
        max_link = float(link_ev.max()) if len(link_ev) else 0.0
        if max(max_core, max_link) < cfg.detect_threshold:
            return PodVerdict(False, None, None, 0.0, "none")

        mcg = build_mcg(rec.comm_patterns, self.mesh, total_time,
                        cores, links, 4)
        fr = failrank(mcg, FailRankParams())
        core_fr = np.zeros(n_cores)
        core_nodes = fr.raw_node_scores[:mcg.n_windows * n_cores]
        for w in range(mcg.n_windows):
            core_fr = np.maximum(
                core_fr, core_nodes[w * n_cores:(w + 1) * n_cores])
        core_fr /= max(core_fr.max(), 1e-12)
        link_fr = attribute_links(mcg, fr, links.theta)
        link_fr /= max(link_fr.max(), 1e-12)
        core_scores = core_ev * (0.5 + core_fr)
        link_scores = link_ev * (0.5 + link_fr)

        best_core = float(core_scores.max()) if n_cores else 0.0
        best_link = float(link_scores.max()) if len(link_scores) else 0.0
        if best_core >= best_link:
            c = int(np.argmax(core_scores))
            sev = float(core_z[c])
            action = "exclude_and_restart" if sev > 8 else "rebalance"
            return PodVerdict(True, "core", c, sev, action)
        lid = int(np.argmax(link_scores))
        return PodVerdict(True, "link", lid, float(link_z[lid]),
                          "reroute_or_restart")

    def analyse(self, sim: SimResult) -> PodVerdict:
        cfg = self.cfg
        rec = record(sim, cfg.sketch, instr_per_task=1, hop_latency=0.0,
                     impl=cfg.recorder_impl)
        return self._verdict_from(rec, sim.total_time)

    def observe(self, window: SimResult) -> PodVerdict:
        """Absorb one telemetry window into the resident sketch and
        return the verdict over the cumulative stream."""
        if self._stream is None:
            self._stream = StreamingRecorder(
                self.cfg.sketch, instr_per_task=1, hop_latency=0.0,
                impl=self.cfg.recorder_impl)
        self._stream.observe(window)
        return self._verdict_from(self._stream.output(),
                                  self._stream.elapsed)


@dataclasses.dataclass
class PodMitigationPolicy:
    """Turns pod verdicts into launcher actions.

    * rebalance: shrink the slow chip's data shard (returns per-shard
      weights for the pipeline);
    * exclude_and_restart: drop the host from the mesh and restart from the
      last checkpoint with a re-sharded (elastic) configuration.

    Severe plans go through the shared mitigation registry
    (:mod:`repro.mitigate`) when the pod ``mesh`` is known: the pod *is*
    the mitigation mesh (chips ↔ cores, ICI links ↔ NoC links), so the
    plan dict also carries the registry policy's resource edits —
    ``exclude_cores`` / ``avoid_links`` plus the raw
    :class:`~repro.mitigate.policy.MitigationPlan` — for the elastic
    re-mesh restart to apply (remap for slow chips, reroute for degraded
    ICI links).  ``mesh=None`` (the legacy constructor shape) returns the
    action keys alone.
    """
    n_shards: int
    mesh: Topology | None = None

    def plan(self, verdict: PodVerdict) -> dict:
        if not verdict.flagged:
            return {"action": "none"}
        if verdict.action == "rebalance" and verdict.kind == "core":
            w = np.ones(self.n_shards)
            w[verdict.location % self.n_shards] = 0.5
            return {"action": "rebalance", "shard_weights": w / w.sum()}
        out = {"action": "exclude_and_restart",
               "exclude": (verdict.kind, verdict.location)}
        if self.mesh is not None:
            from ..mitigate.policy import instantiate_policy
            sev = float(verdict.severity)
            shim = Verdict(
                True, verdict.kind, verdict.location, sev,
                flagged_resources=((verdict.kind, verdict.location, sev),))
            name = "remap" if verdict.kind == "core" else "reroute"
            p = instantiate_policy(name).plan(shim, None, self.mesh)
            out.update(policy=p.policy, exclude_cores=p.exclude_cores,
                       avoid_links=p.avoid_links, plan=p)
        return out


#: Back-compat alias: the protocol-level ``MitigationPolicy`` now lives in
#: :mod:`repro.mitigate.policy`; the pod-telemetry policy keeps its old
#: import name here.
MitigationPolicy = PodMitigationPolicy


class StepTelemetry:
    """Live bridge from a real training/serving loop to the streaming
    pod detector.

    The loop calls ``record_step(dt)`` with each step's measured wall
    time (seconds).  The local host is **chip 0** of the pod; every
    ``window_steps`` accepted steps, a telemetry window is synthesised
    (:meth:`PodSimulator.run_steps` with ``chip0_durs`` = the
    median-of-5-smoothed real measurements — isolated stragglers are
    noise, sustained bursts are fail-slow — and peers at the measured
    healthy-median baseline with the measured relative noise), streamed
    into the resident :class:`PodDetector` sketch (``observe``), and the
    window's verdict plus the :class:`PodMitigationPolicy` plan are
    returned/recorded — so a slow host shows up as a flagged ``core 0``
    verdict within one window of onset.

    ``warmup`` initial steps are discarded (the first step of a jitted
    loop is compile time, which would dwarf the baseline and false-flag
    the host immediately).
    """

    def __init__(self, cfg: PodTelemetryConfig | None = None, *,
                 n_shards: int = 4, warmup: int = 1, seed: int = 0,
                 host: int = 0, step_flops: float = 1e12,
                 collective_bytes: float = 1e8):
        self.cfg = cfg or PodTelemetryConfig(mesh_w=4, mesh_h=4,
                                             window_steps=8)
        self.detector = PodDetector(self.cfg)
        self.policy = PodMitigationPolicy(n_shards=n_shards,
                                          mesh=self.detector.mesh)
        self.pod = PodSimulator(self.cfg, step_flops=step_flops,
                                collective_bytes=collective_bytes,
                                seed=seed, host=host)
        self.warmup = warmup
        self._skipped = 0
        self._buf: list[float] = []
        self._dts: list[float] = []    # accepted history (baseline median)
        self._step = 0                 # absolute synthesised step index
        self._t = 0.0                  # absolute stream clock
        self.verdicts: list[PodVerdict] = []
        self.plans: list[dict] = []

    def record_step(self, dt: float) -> PodVerdict | None:
        """Feed one measured step duration; returns the window's verdict
        when this step completes a window, else ``None``."""
        if self._skipped < self.warmup:
            self._skipped += 1
            return None
        self._buf.append(float(dt))
        self._dts.append(float(dt))
        if len(self._buf) < self.cfg.window_steps:
            return None
        return self.flush()

    def flush(self) -> PodVerdict | None:
        """Force-analyse the buffered partial window (e.g. at loop end);
        ``None`` if nothing is buffered."""
        if not self._buf:
            return None
        dts = np.asarray(self._dts)
        # baseline and peer noise describe the *healthy* steady state:
        # steps ≥ 2× the raw median are treated as slowdown candidates
        # and excluded, so a sustained fail-slow burst neither drags the
        # baseline up nor inflates the noise it is judged against
        med0 = float(np.median(dts))
        healthy = dts[dts < 2.0 * med0]
        if not len(healthy):
            healthy = dts
        baseline = float(np.median(healthy))
        # peers carry the *measured* relative noise (robust MAD
        # estimate): real wall-time jitter — e.g. millisecond-scale
        # decode steps at ±20% — would otherwise z-flag the host
        # against unrealistically tight synthetic peers.  Floored at
        # the model's nominal 1%, capped at 10% so extreme measurement
        # noise cannot drown a decisive (≥ 2×, i.e. excluded-above)
        # slowdown
        mad = float(np.median(np.abs(healthy - baseline)))
        noise = min(max(0.01, 1.4826 * mad / max(baseline, 1e-12)), 0.1)
        # fail-slow is *sustained* degradation (seconds-to-minutes in
        # the paper, i.e. many steps): a rolling median-of-5 removes
        # isolated straggler steps and pairs (GC pauses, scheduler
        # hiccups — the dominant false-flag source in real step
        # timings) while a burst of ≥ 3 consecutive slow steps passes
        # through.  The left edge borrows real predecessor steps; the
        # right edge pads with the healthy baseline (a burst still in
        # flight at the window edge is confirmed one window later)
        buf = np.asarray(self._buf)
        n = len(buf)
        lead = dts[max(len(dts) - n - 2, 0):len(dts) - n]
        padded = np.concatenate([
            np.full(2 - len(lead), baseline), lead, buf,
            [baseline, baseline]])
        chip0 = np.array([np.median(padded[i:i + 5]) for i in range(n)])
        window = self.pod.run_steps(
            len(self._buf), t0=self._t, step0=self._step,
            chip0_durs=chip0, base=baseline, jitter=noise)
        self._step += len(self._buf)
        self._t += float(window.total_time)
        self._buf = []
        v = self.detector.observe(window)
        self.verdicts.append(v)
        self.plans.append(self.policy.plan(v))
        return v

    @property
    def flagged(self) -> bool:
        """Whether any window so far produced a flagged verdict."""
        return any(v.flagged for v in self.verdicts)
