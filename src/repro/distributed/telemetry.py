"""Pod-level fail-slow detection: SLOTH one level up.

A TPU pod *is* a many-core accelerator: chips ↔ cores, ICI links ↔ NoC
links.  This module adapts the SLOTH pipeline to per-step training
telemetry:

  * every step, each chip reports its step compute time (the per-chip
    portion before the gradient all-reduce) and per-neighbour collective
    transfer (bytes, time) — on real hardware these come from host callbacks
    / ICI counters; in this repo the ``PodSimulator`` below generates them
    with the same statistical model as the paper's simulator;
  * records are compressed through the same Fail-Slow Sketch (the monitor
    budget per host is a few hundred KiB);
  * SL-Tracer (group outliers + EM + MCG + FailRank) localises slow chips
    or degraded ICI links;
  * ``MitigationPolicy`` turns verdicts into actions: data-shard rebalance
    for mild degradation, checkpoint-restart excluding the failed host for
    severe/persistent degradation (elastic re-mesh).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.detection import detect_cores, detect_links
from ..core.failrank import FailRankParams, attribute_links, failrank
from ..core.failures import FailSlow
from ..core.mcg import build_mcg
from ..core.recorder import record
from ..core.routing import Mesh2D
from ..core.simulator import SimResult
from ..core.sketch import SketchParams


@dataclasses.dataclass
class PodTelemetryConfig:
    mesh_w: int = 16
    mesh_h: int = 16
    window_steps: int = 32          # steps per analysis window
    sketch: SketchParams = dataclasses.field(
        default_factory=lambda: SketchParams(d=2, m=1024, H=4, L=2048))
    detect_threshold: float = 0.55


class PodSimulator:
    """Synthetic per-step telemetry with the paper's statistical model:
    chip compute time ~ Normal, ICI transfer ~ Gamma, plus injected
    fail-slow windows."""

    def __init__(self, cfg: PodTelemetryConfig, *, step_flops: float,
                 collective_bytes: float, seed: int = 0):
        self.cfg = cfg
        self.mesh = Mesh2D(cfg.mesh_w, cfg.mesh_h)
        self.rng = np.random.default_rng(seed)
        self.step_flops = step_flops
        self.coll_bytes = collective_bytes
        self.chip_speed = 1.0 + 0.02 * self.rng.standard_normal(
            self.mesh.n_cores)
        self.failures: list[FailSlow] = []

    def inject(self, f: FailSlow):
        self.failures.append(f)

    def _slow(self, kind: str, loc: int, t: float) -> float:
        s = 1.0
        for f in self.failures:
            if f.kind == kind and f.location == loc \
                    and f.t0 <= t < f.t0 + f.duration:
                s *= f.slowdown
        return s

    def run_steps(self, n_steps: int, t0: float = 0.0) -> SimResult:
        """Telemetry for ``n_steps`` training steps as a SimResult."""
        mesh = self.mesh
        base = self.step_flops / 197e12     # nominal per-chip step seconds
        comp = {k: [] for k in ("core", "node", "part", "stage", "op",
                                "flops", "t_start", "t_end")}
        comm = {k: [] for k in ("src", "dst", "stage", "bytes", "t_depart",
                                "t_arrive", "hops", "service")}
        t = t0
        # pattern keys must recur for sketch promotion: group steps into
        # 4-step stages (the sketch's H=4 promotes within one stage, and
        # each analysis window still holds >=3 stages of link evidence)
        stage_of = lambda s: s // 4  # noqa: E731
        for s in range(n_steps):
            durs = np.empty(mesh.n_cores)
            for c in range(mesh.n_cores):
                slow = self._slow("core", c, t)
                jit = 1.0 + 0.01 * abs(self.rng.standard_normal())
                durs[c] = base * jit * slow / self.chip_speed[c]
                comp["core"].append(c)
                comp["node"].append(s)
                comp["part"].append(0)
                comp["stage"].append(stage_of(s))
                comp["op"].append(1)
                comp["flops"].append(self.step_flops)
                comp["t_start"].append(t)
                comp["t_end"].append(t + durs[c])
            # ring all-reduce: neighbour transfers on every mesh link
            step_end = t + durs.max()
            per_link = self.coll_bytes / mesh.n_links
            for lid, (u, v) in enumerate(mesh.links):
                slow = self._slow("link", lid, t)
                g = self.rng.gamma(16.0, 1 / 16.0)
                svc = per_link * g * slow / 50e9 + 1e-6
                comm["src"].append(u)
                comm["dst"].append(v)
                comm["stage"].append(stage_of(s))
                comm["bytes"].append(per_link)
                comm["t_depart"].append(t + durs[u])
                comm["t_arrive"].append(t + durs[u] + svc)
                comm["hops"].append(1)
                comm["service"].append(svc)
            t = step_end + max(c[-1] for c in [comm["service"]])
        return SimResult(
            total_time=t - t0,
            comp={k: np.asarray(v) for k, v in comp.items()},
            comm={k: np.asarray(v) for k, v in comm.items()},
            n_raw_records=n_steps * (self.mesh.n_cores + self.mesh.n_links))


@dataclasses.dataclass
class PodVerdict:
    flagged: bool
    kind: str | None
    location: int | None
    severity: float
    action: str       # 'none' | 'rebalance' | 'exclude_and_restart'


class PodDetector:
    """SLOTH pipeline bound to the pod topology."""

    def __init__(self, cfg: PodTelemetryConfig):
        self.cfg = cfg
        self.mesh = Mesh2D(cfg.mesh_w, cfg.mesh_h)

    def analyse(self, sim: SimResult) -> PodVerdict:
        cfg = self.cfg
        rec = record(sim, cfg.sketch, instr_per_task=1, hop_latency=0.0)
        cores = detect_cores(rec.comp_patterns, sim.total_time, 4,
                             z_flag=6.0)
        links = detect_links(rec.comm_patterns, self.mesh, sim.total_time,
                             4, hop_latency=0.0)
        mcg = build_mcg(rec.comm_patterns, self.mesh, sim.total_time,
                        cores, links, 4)
        fr = failrank(mcg, FailRankParams())
        max_core = max((c.prob for c in cores), default=0.0)
        max_link = max((c.prob for c in links.candidates), default=0.0)
        if max(max_core, max_link) < cfg.detect_threshold:
            return PodVerdict(False, None, None, 0.0, "none")
        if max_core >= max_link:
            best = max(cores, key=lambda c: c.prob)
            sev = best.z
            action = "exclude_and_restart" if sev > 8 else "rebalance"
            return PodVerdict(True, "core", best.core, float(sev), action)
        best = max(links.candidates, key=lambda c: c.prob)
        return PodVerdict(True, "link", best.link, float(best.z),
                          "reroute_or_restart")


@dataclasses.dataclass
class MitigationPolicy:
    """Turns verdicts into launcher actions.

    * rebalance: shrink the slow chip's data shard (returns per-shard
      weights for the pipeline);
    * exclude_and_restart: drop the host from the mesh and restart from the
      last checkpoint with a re-sharded (elastic) configuration.
    """
    n_shards: int

    def plan(self, verdict: PodVerdict):
        if not verdict.flagged:
            return {"action": "none"}
        if verdict.action == "rebalance" and verdict.kind == "core":
            w = np.ones(self.n_shards)
            w[verdict.location % self.n_shards] = 0.5
            return {"action": "rebalance", "shard_weights": w / w.sum()}
        return {"action": "exclude_and_restart",
                "exclude": (verdict.kind, verdict.location)}
