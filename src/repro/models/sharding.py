"""Parameter / activation / cache PartitionSpecs for the production mesh.

Conventions (Megatron-style TP + optional FSDP):
  * tensor-parallel axis "model": attention head projections, MLP ff dim,
    vocab dim of embeddings/logits, MoE expert ff (or the expert dim when
    expert-parallel is enabled);
  * data axes ("pod","data") shard the batch; with ``fsdp=True`` the "data"
    axis additionally shards the non-TP dim of every large parameter
    (ZeRO-3-style, gathered per layer inside the scan);
  * KV caches shard batch over data axes when divisible, otherwise the
    sequence dim (flash-decoding-style partial softmax, handled by SPMD).
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _rule(path: tuple[str, ...], shape: tuple[int, ...], *, fsdp, ep,
          embed_mode: str = "dmodel"):
    """PartitionSpec for one parameter leaf (without the stacked-layer axis;
    caller prepends None for the period dimension)."""
    name = path[-1]
    d = fsdp  # alias: the fsdp axis name or None
    if name in ("embed",):
        # d-model sharding keeps token lookups local (a vocab-sharded table
        # forces an SPMD "involuntary full rematerialization" of the gather
        # — measured 100×+ HBM inflation on yi-34b; see EXPERIMENTS §Perf).
        if embed_mode == "vocab":
            return P("model", d)
        return P(d, "model")
    if name in ("lm_head",):
        return P(d, "model")
    if name in ("wq", "wk", "wv"):
        return P(d, "model")
    if name == "wo":
        return P("model", d)
    if name in ("w_gate", "w_up"):
        if len(shape) == 3:      # MoE experts [E, d, ff]
            # expert-parallel: the expert dim takes the model axis, so the
            # per-expert matmuls are unsharded (no TP all-reduce inside)
            return P(ep, d, None) if ep else P(None, d, "model")
        return P(d, "model")
    if name == "w_down":
        if len(shape) == 3:      # [E, ff, d]
            return P(ep, None, d) if ep else P(None, "model", d)
        return P("model", d)
    if name == "router":
        return P(d, None)
    if name == "in_proj":
        return P(d, "model")
    if name == "out_proj":
        return P("model", d)
    if name == "conv_w":
        return P(None, "model")
    if name == "conv_b":
        return P("model")
    # norms, biases, scalars: replicated
    return P(*([None] * len(shape)))


def sanitize(spec: P, shape: tuple[int, ...], mesh) -> P:
    """Drop mesh axes that do not divide their dimension (jit argument
    shardings require exact divisibility)."""
    out = []
    for i, ax in enumerate(spec):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        out.append(ax if shape[i] % n == 0 else None)
    # pad with None for unspecified trailing dims
    out += [None] * (len(shape) - len(out))
    return P(*out)


def param_specs(params_shape, mesh, *, fsdp_axis: str | None = None,
                expert_parallel: bool = False,
                embed_mode: str = "dmodel"):
    """Pytree of PartitionSpec matching ``jax.eval_shape(init_model, ...)``.

    Leaves under 'periods'/'encoder' carry a stacked leading axis which is
    never sharded (scan slices it)."""
    ep = "model" if expert_parallel else None

    def assign(path, leaf):
        keys = tuple(p.key for p in path if hasattr(p, "key"))
        shape = leaf.shape
        stacked = any(k in ("periods", "encoder") for k in keys)
        if stacked:
            spec = _rule(keys, shape[1:], fsdp=fsdp_axis, ep=ep,
                         embed_mode=embed_mode)
            spec = P(None, *spec)
        else:
            spec = _rule(keys, shape, fsdp=fsdp_axis, ep=ep,
                         embed_mode=embed_mode)
        return sanitize(spec, shape, mesh)

    return jax.tree_util.tree_map_with_path(assign, params_shape)


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_spec(mesh, batch: int):
    """Shard the batch over as many data axes as divide it."""
    axes = []
    for a in data_axes(mesh):
        n = mesh.shape[a]
        if batch % n == 0:
            axes.append(a)
            batch //= n
    return tuple(axes)


def token_specs(mesh, batch: int):
    return P(batch_spec(mesh, batch) or None, None)


def cache_specs(cfg, cache_shape, mesh, batch: int):
    """Specs for the cache pytree (leading period axis on every leaf)."""
    model_n = mesh.shape["model"]
    dp = batch_spec(mesh, batch)
    heads_ok = cfg.n_kv_heads > 0 and cfg.n_kv_heads % model_n == 0
    shard_seq_model = not heads_ok

    def assign(path, leaf):
        keys = tuple(p.key for p in path if hasattr(p, "key"))
        name = keys[-1]
        if name in ("k", "v"):
            # [np, B, T, Hkv, Dh]
            if dp:
                spec = P(None, dp, "model" if shard_seq_model else None,
                         None if shard_seq_model else "model", None)
            else:
                # batch of 1 (long-context): shard sequence over data axes
                spec = P(None, None, data_axes(mesh) or None,
                         "model" if heads_ok else None, None)
        elif name == "pos_tab":
            spec = P(None, None)
        elif name == "pos":
            spec = P(None)
        elif name == "conv":
            spec = P(None, dp or None, None, "model")
        elif name == "ssm":
            spec = P(None, dp or None, "model", None, None)
        else:
            spec = P(*([None] * leaf.ndim))
        return sanitize(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(assign, cache_shape)
