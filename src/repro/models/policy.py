"""Activation-sharding policy (process-global, set by the launcher).

The SPMD partitioner sometimes wanders between layouts inside the layer
scan ("involuntary full rematerialization" → fully replicated activation
buffers).  Pinning the residual stream to a canonical layout at period
boundaries stops that.  Policy off (default) = no constraints, so model
code stays mesh-agnostic for tests and single-device runs.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

_POLICY: dict | None = None


def set_policy(dp_axes: tuple[str, ...] | None, tp_axis: str | None,
               seq_shard: bool = False):
    """dp_axes shard the batch; tp_axis optionally shards the boundary
    sequence dim (Megatron-SP style) when ``seq_shard``."""
    global _POLICY
    _POLICY = {"dp": dp_axes, "tp": tp_axis, "seq": seq_shard}


def clear_policy():
    global _POLICY
    _POLICY = None


def constrain_residual(x):
    """x [B, S, d] — the residual stream at period boundaries."""
    if _POLICY is None:
        return x
    dp = _POLICY["dp"] or None
    seq = _POLICY["tp"] if _POLICY["seq"] else None
    return jax.lax.with_sharding_constraint(x, P(dp, seq, None))


def constrain_state(ssm):
    """ssm [B, H, P, N] — decode state sharded over heads."""
    if _POLICY is None:
        return ssm
    return jax.lax.with_sharding_constraint(
        ssm, P(None, _POLICY["tp"], None, None))
