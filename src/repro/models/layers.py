"""Core model layers, pure-JAX (XLA path).

Attention notes:
* `attention` dispatches between a direct path (decode, short sequences), a
  KV-chunked online-softmax path (memory-safe at 32k+ prefill), and a
  *banded* path for sliding-window attention that only touches the W-wide
  KV band (keeps HLO FLOPs ∝ S·W rather than S²).
* GQA is expressed by grouping query heads over KV heads in the einsums, so
  SPMD sharding of the flattened head dim stays clean.

MoE uses sort-based token dispatch (argsort by expert id + capacity
truncation): FLOPs stay proportional to top-k, memory O(E·C·d), all ops
shard under pjit.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(cfg, x, p):
    if cfg.norm == "rms":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def init_norm(cfg, d):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm != "rms":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE and Qwen2-VL's M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x [B,S,H,D]; positions [B,S] int32."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), dtype=jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs      # [B,S,D/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    xr1 = x1 * cos - x2 * sin
    xr2 = x1 * sin + x2 * cos
    return jnp.stack([xr1, xr2], axis=-1).reshape(x.shape).astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections=(2, 3, 3)):
    """Qwen2-VL multimodal RoPE: the head-dim frequency bands are split into
    (temporal, height, width) sections, each rotated by its own position id.
    ``positions3`` [B,S,3]; for text tokens the three ids coincide."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), dtype=jnp.float32)  # [D/2]
    n = d // 2
    sec = np.array(sections, dtype=np.float64)
    bounds = np.cumsum(np.round(sec / sec.sum() * n).astype(int))
    bounds[-1] = n
    sel = np.zeros(n, dtype=np.int32)
    sel[bounds[0]:bounds[1]] = 1
    sel[bounds[1]:] = 2
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(jnp.asarray(sel)[None, None, :],
                         positions3.shape[:2] + (n,)).astype(jnp.int32),
        axis=-1)                                                  # [B,S,D/2]
    ang = pos * freqs[None, None, :]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    xr1 = x1 * cos - x2 * sin
    xr2 = x1 * sin + x2 * cos
    return jnp.stack([xr1, xr2], axis=-1).reshape(x.shape).astype(x.dtype)


def position_embed(cfg, q, k, positions):
    if cfg.pos == "rope":
        return (apply_rope(q, positions, cfg.rope_theta),
                apply_rope(k, positions, cfg.rope_theta))
    if cfg.pos == "mrope":
        pos3 = jnp.repeat(positions[..., None], 3, axis=-1)
        return (apply_mrope(q, pos3, cfg.rope_theta),
                apply_mrope(k, pos3, cfg.rope_theta))
    return q, k   # 'none' / 'learned' (added at embedding)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _direct_attention(q, k, v, q_pos, k_pos, causal, window):
    """Materialised-scores path (decode steps / small shapes)."""
    b, s, hq, d = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = hq // hk
    qg = q.reshape(b, s, hk, g, d)
    scores = jnp.einsum("bshgd,bthd->bhgst", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / np.sqrt(d)
    mask = (k_pos >= 0)[None, :]          # -1 marks empty cache slots
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v)
    return out.reshape(b, s, hq, d)


def _chunked_attention(q, k, v, q_pos, k_pos, causal, window, kv_chunk):
    """Online-softmax scan over KV chunks: O(S·chunk) live memory."""
    b, s, hq, d = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = hq // hk
    qg = q.reshape(b, s, hk, g, d)
    n_chunks = (t + kv_chunk - 1) // kv_chunk
    pad = n_chunks * kv_chunk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=2**30)
    kc = k.reshape(b, n_chunks, kv_chunk, hk, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, hk, d).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(n_chunks, kv_chunk)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, pb = xs
        scores = jnp.einsum("bshgd,bthd->bhgst", qg, kb,
                            preferred_element_type=jnp.float32) / np.sqrt(d)
        mask = jnp.ones((s, kv_chunk), bool)
        if causal:
            mask &= pb[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= pb[None, :] > q_pos[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgst,bthd->bhgsd", p.astype(q.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hk, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, g, s), jnp.float32)
    a0 = jnp.zeros((b, hk, g, s, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, hq, d)


def _banded_attention(q, k, v, q_pos, k_pos, window, q_chunk):
    """Sliding-window path: each q-chunk only reads its W-wide KV band, so
    compiled FLOPs scale with S·W (not S²)."""
    b, s, hq, d = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = hq // hk
    band = window + q_chunk
    n_q = (s + q_chunk - 1) // q_chunk
    pad_q = n_q * q_chunk - s
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_q), constant_values=-(2**30))
    if t < band:
        k = jnp.pad(k, ((0, 0), (0, band - t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, band - t), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, band - t), constant_values=2**30)
        t = band
    qg = q.reshape(b, n_q, q_chunk, hk, g, d).transpose(1, 0, 2, 3, 4, 5)
    qp = q_pos.reshape(n_q, q_chunk)

    def one_chunk(qb, qpb, ci):
        start = jnp.clip((ci + 1) * q_chunk - band, 0, t - band)
        kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
        pb = jax.lax.dynamic_slice_in_dim(k_pos, start, band, axis=0)
        scores = jnp.einsum("bshgd,bthd->bhgst", qb, kb,
                            preferred_element_type=jnp.float32) / np.sqrt(d)
        mask = (pb[None, :] <= qpb[:, None]) \
            & (pb[None, :] > qpb[:, None] - window)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        return jnp.einsum("bhgst,bthd->bshgd", probs, vb)

    out = jax.lax.map(lambda xs: one_chunk(xs[0], xs[1], xs[2]),
                      (qg, qp, jnp.arange(n_q)))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, n_q * q_chunk, hq, d)
    return out[:, :s]


def attention(q, k, v, *, q_pos, k_pos, causal=True, window=None,
              kv_chunk=1024, q_chunk=512):
    """q [B,S,Hq,D], k/v [B,T,Hkv,D], positions int32 [S]/[T] (absolute)."""
    s, t = q.shape[1], k.shape[1]
    if s == 1 or (s * t) <= (2048 * 2048):
        return _direct_attention(q, k, v, q_pos, k_pos, causal, window)
    if window is not None and t > 2 * window:
        return _banded_attention(q, k, v, q_pos, k_pos, window, q_chunk)
    return _chunked_attention(q, k, v, q_pos, k_pos, causal, window,
                              kv_chunk)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg, rng, d, ff, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    std = d ** -0.5
    if cfg.mlp == "swiglu":
        return {
            "w_gate": (jax.random.normal(k1, (d, ff)) * std).astype(dtype),
            "w_up": (jax.random.normal(k2, (d, ff)) * std).astype(dtype),
            "w_down": (jax.random.normal(k3, (ff, d)) * (ff ** -0.5)
                       ).astype(dtype),
        }
    return {
        "w_up": (jax.random.normal(k1, (d, ff)) * std).astype(dtype),
        "w_down": (jax.random.normal(k2, (ff, d)) * (ff ** -0.5)
                   ).astype(dtype),
    }


def mlp(cfg, p, x):
    if cfg.mlp == "swiglu":
        g = jax.nn.silu(x @ p["w_gate"])
        return (g * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts (sort-based dispatch)
# ---------------------------------------------------------------------------

def init_moe(cfg, rng, dtype):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    k0, k1, k2, k3 = jax.random.split(rng, 4)
    std = d ** -0.5
    return {
        "router": (jax.random.normal(k0, (d, e)) * std).astype(jnp.float32),
        "w_gate": (jax.random.normal(k1, (e, d, ff)) * std).astype(dtype),
        "w_up": (jax.random.normal(k2, (e, d, ff)) * std).astype(dtype),
        "w_down": (jax.random.normal(k3, (e, ff, d)) * (ff ** -0.5)
                   ).astype(dtype),
    }


def moe(cfg, p, x):
    """x [B,S,d] → [B,S,d], plus the load-balancing aux loss."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    tokens = b * s
    xf = x.reshape(tokens, d)

    logits = (xf.astype(jnp.float32) @ p["router"])          # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # [T,k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], e), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(density * mean_prob)

    cap = int(np.ceil(tokens * k / e * cfg.capacity_factor))
    cap = max(8, -(-cap // 8) * 8)   # pad to multiple of 8

    e_flat = gate_idx.reshape(-1)                            # [T·k]
    t_flat = jnp.repeat(jnp.arange(tokens), k)
    w_flat = gate_vals.reshape(-1)
    order = jnp.argsort(e_flat)
    se, st, sw = e_flat[order], t_flat[order], w_flat[order]
    counts = jnp.bincount(e_flat, length=e)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(tokens * k) - starts[se]
    keep = pos_in_e < cap
    slot = se * cap + jnp.where(keep, pos_in_e, 0)

    xe = jnp.zeros((e * cap, d), x.dtype)
    xe = xe.at[slot].add(jnp.where(keep[:, None], xf[st], 0))
    xe = xe.reshape(e, cap, d)

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"])
    ye = ye.reshape(e * cap, d)

    contrib = ye[slot] * (sw * keep)[:, None].astype(x.dtype)
    y = jnp.zeros((tokens, d), x.dtype).at[st].add(contrib)
    return y.reshape(b, s, d), aux
