"""Model assembly for all assigned architectures.

Layers are stacked into *periods*: the layer pattern of an architecture
repeats with period = lcm(attn_period, moe_period) (jamba: 8 = one attention
+ seven mamba layers, MoE on every other layer; dense/MoE archs: 1).  The
forward pass is a ``lax.scan`` over periods whose body applies the period's
slots in order — this keeps the lowered HLO small (one period body) and
makes per-layer FSDP gathering natural.

Three entry points:
  * ``forward_train``  — full-sequence causal forward (no cache) → logits
  * ``prefill``        — fills a KV/SSM cache, returns last-token logits
  * ``decode_step``    — one token with cache (rolling buffer for SWA)

Whisper (enc_dec) runs its encoder over stub frame embeddings and gives the
decoder per-layer cross-attention; its frontend conv stack is a stub by
assignment.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from . import mamba2 as M
from . import policy


# ---------------------------------------------------------------------------
# period structure
# ---------------------------------------------------------------------------

def period_len(cfg) -> int:
    a = cfg.attn_period if cfg.attn_period else 1
    m = cfg.moe_period if cfg.n_experts else 1
    return math.lcm(a, m)


def period_slots(cfg) -> list[tuple[str, bool]]:
    """[(kind, is_moe)] for one period."""
    return [(cfg.layer_kind(i), cfg.is_moe_layer(i))
            for i in range(period_len(cfg))]


def n_periods(cfg) -> int:
    pl = period_len(cfg)
    assert cfg.n_layers % pl == 0, (cfg.n_layers, pl)
    return cfg.n_layers // pl


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_attn(cfg, rng, dtype, cross: bool = False):
    d = cfg.d_model
    hq = cfg.n_heads * cfg.head_dim
    hk = cfg.n_kv_heads * cfg.head_dim
    k = jax.random.split(rng, 4)
    std = d ** -0.5
    p = {
        "wq": (jax.random.normal(k[0], (d, hq)) * std).astype(dtype),
        "wk": (jax.random.normal(k[1], (d, hk)) * std).astype(dtype),
        "wv": (jax.random.normal(k[2], (d, hk)) * std).astype(dtype),
        "wo": (jax.random.normal(k[3], (hq, d)) * (hq ** -0.5)).astype(dtype),
    }
    return p


def _init_slot(cfg, rng, kind, is_moe, dtype):
    ks = jax.random.split(rng, 6)
    p = {"norm1": L.init_norm(cfg, cfg.d_model)}
    if kind == "attn":
        p["attn"] = _init_attn(cfg, ks[0], dtype)
        if cfg.enc_dec:
            p["xnorm"] = L.init_norm(cfg, cfg.d_model)
            p["xattn"] = _init_attn(cfg, ks[1], dtype, cross=True)
    else:
        p["mamba"] = M.init_mamba(cfg, ks[0], dtype)
    if cfg.d_ff > 0:
        p["norm2"] = L.init_norm(cfg, cfg.d_model)
        if is_moe:
            p["moe"] = L.init_moe(cfg, ks[2], dtype)
        else:
            p["mlp"] = L.init_mlp(cfg, ks[2], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_model(cfg, rng, dtype=jnp.bfloat16):
    ks = jax.random.split(rng, 8)
    np_, slots = n_periods(cfg), period_slots(cfg)
    params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model))
                  * 0.02).astype(dtype),
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            ks[1], (cfg.d_model, cfg.vocab)) * 0.02).astype(dtype)

    def init_period(prng):
        sk = jax.random.split(prng, len(slots))
        return {f"slot{i}": _init_slot(cfg, sk[i], kind, moe, dtype)
                for i, (kind, moe) in enumerate(slots)}

    period_keys = jax.random.split(ks[2], np_)
    params["periods"] = jax.vmap(init_period)(period_keys)

    if cfg.enc_dec:
        ek = jax.random.split(ks[3], cfg.n_enc_layers + 1)

        def init_enc(prng):
            kk = jax.random.split(prng, 3)
            return {
                "norm1": L.init_norm(cfg, cfg.d_model),
                "attn": _init_attn(cfg, kk[0], dtype),
                "norm2": L.init_norm(cfg, cfg.d_model),
                "mlp": L.init_mlp(cfg, kk[1], cfg.d_model, cfg.d_ff, dtype),
            }
        params["encoder"] = jax.vmap(init_enc)(ek[:-1])
        params["enc_final_norm"] = L.init_norm(cfg, cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# pieces
# ---------------------------------------------------------------------------

def _sinusoid(positions, d):
    """Sinusoidal position embedding (whisper-style, table-free)."""
    half = d // 2
    freq = jnp.exp(-jnp.arange(half) * (np.log(10000.0) / max(half - 1, 1)))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _attn_sublayer(cfg, p, x, q_pos, k_pos, *, kv=None, cache=None,
                   causal=True):
    """Self/cross attention.  kv: source for K/V (cross-attn memory)."""
    b, s, d = x.shape
    src = kv if kv is not None else x
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (src @ p["wk"]).reshape(b, src.shape[1], cfg.n_kv_heads,
                                cfg.head_dim)
    v = (src @ p["wv"]).reshape(b, src.shape[1], cfg.n_kv_heads,
                                cfg.head_dim)
    if kv is None:
        q, k = L.position_embed(cfg, q, k, jnp.broadcast_to(
            q_pos[None], (b, s)))
    if cache is not None:
        kc, vc, pc = cache.update(k, v, q_pos)
        if s == 1:
            # decode: attend over the cache contents
            k, v, k_pos = kc, vc, pc
        else:
            # prefill: attend over the full in-flight K/V (the rolling
            # buffer only receives the tail); k_pos = q_pos
            k_pos = q_pos
    window = cfg.window if kv is None else None
    out = L.attention(q, k, v, q_pos=q_pos, k_pos=k_pos,
                      causal=causal and kv is None, window=window)
    return out.reshape(b, s, cfg.n_heads * cfg.head_dim) @ p["wo"]


@dataclasses.dataclass
class _KVView:
    """Rolling KV cache view for one attention slot."""
    k: jax.Array          # [B, T, Hkv, Dh]
    v: jax.Array
    pos_tab: jax.Array    # [T] absolute positions (-1 = empty)
    pos: jax.Array        # scalar: tokens seen so far
    new: tuple = ()

    def update(self, k_new, v_new, q_pos):
        t_max = self.k.shape[1]
        s = k_new.shape[1]
        if s >= t_max:
            # prefill larger than the buffer: keep the last t_max tokens,
            # laid out at their rolling slots (idx = pos % t_max) so later
            # decode writes overwrite the *oldest* entry
            tail_pos = q_pos[-t_max:]
            idx = tail_pos % t_max
            k = self.k.at[:, idx].set(k_new[:, -t_max:]
                                      .astype(self.k.dtype))
            v = self.v.at[:, idx].set(v_new[:, -t_max:]
                                      .astype(self.v.dtype))
            pos_tab = self.pos_tab.at[idx].set(tail_pos)
            self.new = (k, v, pos_tab)
            return k, v, pos_tab
        idx = (self.pos + jnp.arange(s)) % t_max
        k = self.k.at[:, idx].set(k_new.astype(self.k.dtype))
        v = self.v.at[:, idx].set(v_new.astype(self.v.dtype))
        pos_tab = self.pos_tab.at[idx].set(q_pos)
        self.new = (k, v, pos_tab)
        return k, v, pos_tab


def _apply_slot(cfg, p, kind, is_moe, x, q_pos, *, memory=None,
                slot_cache=None, dtype=None):
    """One layer: mixer + (cross-attn) + MLP/MoE with residuals.
    Returns (x, aux_loss, new_slot_cache)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = None
    h = L.apply_norm(cfg, x, p["norm1"])
    if kind == "attn":
        if slot_cache is not None:
            view = _KVView(slot_cache["k"], slot_cache["v"],
                           slot_cache["pos_tab"], slot_cache["pos"])
            out = _attn_sublayer(cfg, p["attn"], h, q_pos, None, cache=view)
            new_cache = {"k": view.new[0], "v": view.new[1],
                         "pos_tab": view.new[2],
                         "pos": slot_cache["pos"] + h.shape[1]}
        else:
            out = _attn_sublayer(cfg, p["attn"], h, q_pos, q_pos)
        x = x + out
        if cfg.enc_dec and memory is not None:
            hx = L.apply_norm(cfg, x, p["xnorm"])
            x = x + _attn_sublayer(
                cfg, p["xattn"], hx, q_pos,
                jnp.arange(memory.shape[1]), kv=memory, causal=False)
    else:
        state = None
        if slot_cache is not None:
            state = (slot_cache["conv"], slot_cache["ssm"])
        out, new_state = M.mamba_block(cfg, p["mamba"], h, state)
        x = x + out
        if slot_cache is not None:
            new_cache = {"conv": new_state[0],
                         "ssm": new_state[1].astype(slot_cache["ssm"].dtype),
                         "pos": slot_cache["pos"] + h.shape[1]}
    if cfg.d_ff > 0:
        h2 = L.apply_norm(cfg, x, p["norm2"])
        if is_moe:
            out2, aux = L.moe(cfg, p["moe"], h2)
        else:
            out2 = L.mlp(cfg, p["mlp"], h2)
        x = x + out2
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# encoder (whisper)
# ---------------------------------------------------------------------------

def run_encoder(cfg, params, frames):
    """frames [B, F, d]: precomputed frontend-stub embeddings."""
    x = frames + _sinusoid(jnp.arange(frames.shape[1]),
                           cfg.d_model)[None].astype(frames.dtype)
    pos = jnp.arange(frames.shape[1])

    def body(h, p):
        a = L.apply_norm(cfg, h, p["norm1"])
        h = h + _attn_sublayer(cfg, p["attn"], a, pos, pos, causal=False)
        m = L.apply_norm(cfg, h, p["norm2"])
        h = h + L.mlp(cfg, p["mlp"], m)
        return h, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["encoder"])
    return L.apply_norm(cfg, x, params["enc_final_norm"])


# ---------------------------------------------------------------------------
# main forward paths
# ---------------------------------------------------------------------------

def _embed(cfg, params, tokens, positions):
    x = params["embed"][tokens]
    if cfg.pos == "learned":
        x = x + _sinusoid(positions, cfg.d_model)[None].astype(x.dtype)
    return x


def _logits(cfg, params, x):
    x = L.apply_norm(cfg, x, params["final_norm"])
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["lm_head"]


def _scan_periods(cfg, params, x, q_pos, memory=None, caches=None,
                  remat=True):
    slots = period_slots(cfg)

    def body(carry, xs):
        h, aux = carry
        pp = xs if caches is None else xs[0]
        cc = None if caches is None else xs[1]
        h = policy.constrain_residual(h)
        new_cc = {}
        for i, (kind, moe) in enumerate(slots):
            sc = None if cc is None else cc[f"slot{i}"]
            h, a, nc = _apply_slot(cfg, pp[f"slot{i}"], kind, moe, h, q_pos,
                                   memory=memory, slot_cache=sc)
            aux = aux + a
            if nc is not None:
                if "ssm" in nc:
                    nc["ssm"] = policy.constrain_state(nc["ssm"])
                new_cc[f"slot{i}"] = nc
        h = policy.constrain_residual(h)
        return (h, aux), (new_cc if caches is not None else None)

    fn = jax.checkpoint(body,
                        policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else body
    xs = params["periods"] if caches is None else (params["periods"], caches)
    (x, aux), new_caches = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                                        xs)
    return x, aux, new_caches


def forward_train(cfg, params, tokens, enc_frames=None, remat=True):
    """tokens [B,S] → (logits [B,S,V], aux_loss)."""
    s = tokens.shape[1]
    positions = jnp.arange(s)
    x = _embed(cfg, params, tokens, positions)
    memory = None
    if cfg.enc_dec:
        memory = run_encoder(cfg, params, enc_frames)
    x, aux, _ = _scan_periods(cfg, params, x, positions, memory,
                              remat=remat)
    return _logits(cfg, params, x), aux


# -- cache construction -------------------------------------------------------

def init_cache(cfg, batch, cache_len, dtype=jnp.bfloat16,
               memory_len: int = 0):
    """Cache pytree with leading period axis (scan xs/ys layout)."""
    np_ = n_periods(cfg)
    slots = period_slots(cfg)
    t_max = cache_len if cfg.window is None else min(cache_len,
                                                     cfg.window)
    per = {}
    for i, (kind, _) in enumerate(slots):
        if kind == "attn":
            per[f"slot{i}"] = {
                "k": jnp.zeros((np_, batch, t_max, cfg.n_kv_heads,
                                cfg.head_dim), dtype),
                "v": jnp.zeros((np_, batch, t_max, cfg.n_kv_heads,
                                cfg.head_dim), dtype),
                "pos_tab": jnp.full((np_, t_max), -1, jnp.int32),
                "pos": jnp.zeros((np_,), jnp.int32),
            }
        else:
            conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
            per[f"slot{i}"] = {
                "conv": jnp.zeros((np_, batch, cfg.ssm_conv - 1, conv_dim),
                                  dtype),
                "ssm": jnp.zeros((np_, batch, cfg.ssm_heads,
                                  cfg.ssm_head_dim, cfg.ssm_state),
                                 jnp.float32),
                "pos": jnp.zeros((np_,), jnp.int32),
            }
    return per


def prefill(cfg, params, tokens, cache, enc_frames=None, remat=True):
    """Run S prompt tokens, filling ``cache``.  Returns (last_logits, cache,
    memory) — memory is the encoder output for enc-dec archs."""
    s = tokens.shape[1]
    positions = jnp.arange(s)
    x = _embed(cfg, params, tokens, positions)
    memory = None
    if cfg.enc_dec:
        memory = run_encoder(cfg, params, enc_frames)
    x, _, new_caches = _scan_periods(cfg, params, x, positions, memory,
                                     caches=cache, remat=remat)
    return _logits(cfg, params, x[:, -1:]), new_caches, memory


def decode_step(cfg, params, tokens, cache, pos, memory=None):
    """One decode step.  tokens [B,1]; pos: scalar int32 absolute position."""
    positions = jnp.full((1,), pos, jnp.int32)
    x = _embed(cfg, params, tokens, positions)
    x, _, new_caches = _scan_periods(cfg, params, x, positions, memory,
                                     caches=cache, remat=False)
    return _logits(cfg, params, x), new_caches
