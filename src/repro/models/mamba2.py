"""Mamba-2 (SSD, state-space duality) block — chunked scan + decode step.

The chunked form (chunk Q) computes, per head (state N, head dim P):

    intra:  Y[i] += Σ_{j≤i in chunk} (C_i·B_j)·exp(cum_i−cum_j)·dt_j·x_j
    state:  S_c   = exp(cum_end)·S_{c−1} + Σ_j exp(cum_end−cum_j)·dt_j·B_j⊗x_j
    inter:  Y[i] += C_i · S_{c−1} · exp(cum_i)

with cum = cumsum(dt·A) inside the chunk; the chunk recurrence runs under
``lax.scan``.  A Pallas TPU kernel of the same algorithm lives in
``repro.kernels.ssd_scan``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def init_mamba(cfg, rng, dtype):
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * g * n
    k = jax.random.split(rng, 4)
    std = d ** -0.5
    return {
        "in_proj": (jax.random.normal(k[0], (d, 2 * di + 2 * g * n + h))
                    * std).astype(dtype),
        "conv_w": (jax.random.normal(k[1], (cfg.ssm_conv, conv_dim))
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(k[2], (di, d))
                     * (di ** -0.5)).astype(dtype),
    }


def _split_proj(cfg, proj):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * g * n]
    dt = proj[..., -h:]
    return z, xbc, dt


def _causal_conv(cfg, xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv (kernel K).  conv_state [B,K-1,C] for decode."""
    k = cfg.ssm_conv
    if conv_state is None:
        pad = jnp.zeros(xbc.shape[:1] + (k - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)               # [B, S+K-1, C]
    out = sum(xp[:, i:i + xbc.shape[1]] * conv_w[i][None, None]
              for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else pad[:, :0]
    return jax.nn.silu(out + conv_b[None, None]), new_state


def ssd_chunked(x, dt, a, b_in, c_in, chunk: int = 128, init_state=None):
    """x [B,S,H,P], dt [B,S,H] (post-softplus), a [H] (negative),
    b_in/c_in [B,S,G,N].  Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    bsz, s, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    rep = h // g
    nc = (s + chunk - 1) // chunk
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0), (0, 0)))

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h).astype(jnp.float32)
    bc = b_in.reshape(bsz, nc, chunk, g, n)
    cc = c_in.reshape(bsz, nc, chunk, g, n)

    l = dtc * a[None, None, None, :]                 # log-decay per step
    cum = jnp.cumsum(l, axis=2)                      # [B,nc,Q,H]
    cum_end = cum[:, :, -1]                          # [B,nc,H]

    # intra-chunk (dual / attention-like form)
    bh = jnp.repeat(bc, rep, axis=3)                 # [B,nc,Q,H,N]
    ch = jnp.repeat(cc, rep, axis=3)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", ch, bh,
                        preferred_element_type=jnp.float32)
    # decay[b,c,h,q,k] = exp(cum_q - cum_k); clamp the exponent at 0 so the
    # (masked-out) upper triangle cannot produce inf and poison gradients
    # through the jnp.where (exact for causal entries, where cum_q ≤ cum_k).
    decay = jnp.exp(jnp.minimum(
        cum[:, :, :, None, :].transpose(0, 1, 4, 2, 3)
        - cum[:, :, None, :, :].transpose(0, 1, 4, 2, 3), 0.0))
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    gmat = jnp.where(mask[None, None, None], scores * decay, 0.0)
    xdt = xc * dtc[..., None]
    y = jnp.einsum("bchqk,bckhp->bcqhp", gmat.astype(x.dtype), xdt)

    # per-chunk aggregate state: Σ_k exp(cum_end - cum_k)·dt_k·B_k⊗x_k
    w_end = jnp.exp(cum_end[:, :, None, :] - cum)    # [B,nc,Q,H]
    s_chunk = jnp.einsum("bcqhn,bcqhp,bcqh->bchpn", bh, xdt,
                         w_end.astype(x.dtype))

    # inter-chunk recurrence
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(state, xs):
        s_c, ce = xs                                 # [B,H,P,N], [B,H]
        out_state = state                            # state entering chunk
        new = jnp.exp(ce)[:, :, None, None] * state + s_c.astype(jnp.float32)
        return new, out_state

    states_in, entry_states = jax.lax.scan(
        step, init_state,
        (s_chunk.transpose(1, 0, 2, 3, 4), cum_end.transpose(1, 0, 2)))
    entry_states = entry_states.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N]

    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", ch,
                         entry_states.astype(x.dtype),
                         jnp.exp(cum).astype(x.dtype))
    y = (y + y_inter).reshape(bsz, nc * chunk, h, p)
    return y[:, :s], states_in


def mamba_block(cfg, p, x, state=None):
    """Full Mamba-2 mixer.  x [B,S,d].  state = (conv_state, ssm_state) for
    decode (S may be 1); returns (y, new_state)."""
    di, h, g, n = cfg.d_inner, cfg.ssm_heads, cfg.ssm_groups, cfg.ssm_state
    pdim = cfg.ssm_head_dim
    proj = x @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    conv_state = state[0] if state is not None else None
    xbc, new_conv = _causal_conv(cfg, xbc, p["conv_w"], p["conv_b"],
                                 conv_state)
    xs = xbc[..., :di]
    b_in = xbc[..., di:di + g * n].reshape(*xbc.shape[:2], g, n)
    c_in = xbc[..., di + g * n:].reshape(*xbc.shape[:2], g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None])
    a = -jnp.exp(p["a_log"])
    xh = xs.reshape(*xs.shape[:2], h, pdim)

    if state is not None and x.shape[1] == 1:
        # single-token decode: direct recurrence
        ssm = state[1]                               # [B,H,P,N]
        dt1 = dt[:, 0]                               # [B,H]
        decay = jnp.exp(dt1 * a[None, :])
        bh = jnp.repeat(b_in[:, 0], h // g, axis=1)  # [B,H,N]
        ch = jnp.repeat(c_in[:, 0], h // g, axis=1)
        upd = jnp.einsum("bhp,bhn,bh->bhpn", xh[:, 0].astype(jnp.float32),
                         bh.astype(jnp.float32), dt1)
        new_ssm = decay[:, :, None, None] * ssm + upd
        y = jnp.einsum("bhpn,bhn->bhp", new_ssm,
                       ch.astype(jnp.float32))[:, None]
        y = y.astype(x.dtype)
    else:
        init = state[1] if state is not None else None
        y, new_ssm = ssd_chunked(xh, dt, a, b_in, c_in,
                                 chunk=min(128, max(16, x.shape[1])),
                                 init_state=init)

    y = y + xh * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(*x.shape[:2], di)
    y = y * jax.nn.silu(z)
    # gated RMSNorm (Mamba-2)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
         * p["norm_scale"]).astype(x.dtype)
    return y @ p["out_proj"], (new_conv, new_ssm)
