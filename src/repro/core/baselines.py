"""Five baseline fail-slow detectors (paper §IV-A), adapted to the
many-core accelerator domain as in-house implementations.  All consume the
same raw trace infrastructure (SimResult) as SLOTH for a fair comparison.

  Thres    — static 2× threshold over profiled nominal latency
  Mscope   — Microscope: dependency DAG + random-walk root-cause scoring
  IASO     — peer timeout signals → AIMD scores → DBSCAN outlier cluster
  Perseus  — polynomial regression on latency-vs-throughput, p99.9 outliers
  ADR      — sliding windows, adaptive thresholds from history percentiles
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .failures import FailSlow
from .routing import Mesh2D
from .simulator import SimResult


@dataclasses.dataclass
class BaselineVerdict:
    flagged: bool
    kind: str | None
    location: int | None
    score: float

    def matches(self, failure: FailSlow | None) -> bool:
        if failure is None:
            return not self.flagged
        return (self.flagged and self.kind == failure.kind
                and self.location == failure.location)


def _per_core_rates(sim: SimResult):
    """mean FLOPs/s per (core, stage) and per core."""
    comp = sim.comp
    dur = np.maximum(comp["t_end"] - comp["t_start"], 1e-12)
    rate = comp["flops"] / dur
    return comp["core"], comp["stage"], rate, dur


def _per_link_latency(sim: SimResult, mesh: Mesh2D):
    comm = sim.comm
    lat = {}
    for s, d, svc in zip(comm["src"], comm["dst"], comm["service"]):
        if s == d:
            continue
        for lid in mesh.route(int(s), int(d)):
            lat.setdefault(lid, []).append(svc / max(1, len(
                mesh.route(int(s), int(d)))))
    return lat


# ---------------------------------------------------------------------------
# (1) Threshold filtering
# ---------------------------------------------------------------------------

class Thres:
    """Flags any component whose latency exceeds 2× the profiled nominal."""

    name = "thres"

    def __init__(self, mesh: Mesh2D, profile: SimResult):
        cores, stages, rate, _ = _per_core_rates(profile)
        self.nominal = {}
        for c, s, r in zip(cores, stages, rate):
            self.nominal.setdefault((int(c), int(s)), []).append(r)
        self.nominal = {k: float(np.median(v))
                        for k, v in self.nominal.items()}
        link_lat = _per_link_latency(profile, mesh)
        self.link_nominal = {k: float(np.median(v))
                             for k, v in link_lat.items()}
        self.mesh = mesh

    def detect(self, sim: SimResult) -> BaselineVerdict:
        cores, stages, rate, _ = _per_core_rates(sim)
        worst, where = 1.0, None
        for c, s, r in zip(cores, stages, rate):
            nom = self.nominal.get((int(c), int(s)))
            if not nom or r <= 0:
                continue
            slow = nom / r
            if slow > worst:
                worst, where = slow, ("core", int(c))
        for lid, lats in _per_link_latency(sim, self.mesh).items():
            nom = self.link_nominal.get(lid)
            if not nom:
                continue
            slow = float(np.median(lats)) / nom
            if slow > worst:
                worst, where = slow, ("link", int(lid))
        if worst >= 2.0 and where:
            return BaselineVerdict(True, where[0], where[1], worst)
        return BaselineVerdict(False, None, None, worst)


# ---------------------------------------------------------------------------
# (2) Microscope: dependency DAG + random walk
# ---------------------------------------------------------------------------

class Mscope:
    name = "mscope"

    def __init__(self, mesh: Mesh2D, profile: SimResult):
        self.mesh = mesh
        cores, stages, rate, _ = _per_core_rates(profile)
        self.nominal = {}
        for c, s, r in zip(cores, stages, rate):
            self.nominal.setdefault(int(c), []).append(r)
        self.nominal = {k: float(np.median(v))
                        for k, v in self.nominal.items()}

    def detect(self, sim: SimResult, walks: int = 200, seed: int = 0)\
            -> BaselineVerdict:
        rng = np.random.default_rng(seed)
        cores, stages, rate, _ = _per_core_rates(sim)
        anomaly = np.zeros(self.mesh.n_cores)
        for c, r in zip(cores, rate):
            nom = self.nominal.get(int(c), 0)
            if nom > 0 and r > 0:
                anomaly[int(c)] = max(anomaly[int(c)], nom / r - 1.0)
        # service dependency graph: consumer → producer edges weighted by
        # traffic (we walk *backwards* towards root causes)
        comm = sim.comm
        w = {}
        for s, d, b in zip(comm["src"], comm["dst"], comm["bytes"]):
            if s != d:
                w[(int(d), int(s))] = w.get((int(d), int(s)), 0.0) + b
        nbr = {}
        for (d, s), b in w.items():
            nbr.setdefault(d, []).append((s, b))
        visits = np.zeros(self.mesh.n_cores)
        anomalous = np.nonzero(anomaly > 0.5)[0]
        if len(anomalous) == 0:
            return BaselineVerdict(False, None, None, 0.0)
        for _ in range(walks):
            node = int(rng.choice(anomalous))
            for _ in range(8):
                visits[node] += anomaly[node] + 0.1
                opts = nbr.get(node)
                if not opts or rng.random() < 0.2:
                    break
                probs = np.array([b * (1 + anomaly[s]) for s, b in opts])
                probs /= probs.sum()
                node = int(opts[rng.choice(len(opts), p=probs)][0])
        loc = int(np.argmax(visits))
        return BaselineVerdict(True, "core", loc, float(visits[loc]))


# ---------------------------------------------------------------------------
# (3) IASO: timeout signals → AIMD score → DBSCAN
# ---------------------------------------------------------------------------

def _dbscan_1d(x: np.ndarray, eps: float, min_pts: int = 3) -> np.ndarray:
    """1-D DBSCAN; returns cluster labels (-1 = noise)."""
    order = np.argsort(x)
    labels = np.full(len(x), -1)
    cid = -1
    prev = None
    for i in order:
        if prev is not None and x[i] - x[prev] <= eps:
            labels[i] = labels[prev] if labels[prev] >= 0 else cid
        else:
            cid += 1
        if labels[i] < 0:
            labels[i] = cid
        prev = i
    # enforce min_pts: clusters smaller than min_pts become noise
    for c in np.unique(labels):
        if (labels == c).sum() < min_pts:
            labels[labels == c] = -1
    return labels


class IASO:
    name = "iaso"

    def __init__(self, mesh: Mesh2D, profile: SimResult):
        self.mesh = mesh
        cores, stages, rate, dur = _per_core_rates(profile)
        self.expected = {}
        for c, s, d in zip(cores, stages, dur):
            self.expected.setdefault((int(c), int(s)), []).append(d)
        self.expected = {k: float(np.median(v)) * 2.0
                         for k, v in self.expected.items()}

    def detect(self, sim: SimResult) -> BaselineVerdict:
        cores, stages, rate, dur = _per_core_rates(sim)
        score = np.zeros(self.mesh.n_cores)
        order = np.argsort(sim.comp["t_start"])
        for i in order:
            c, s, d = int(cores[i]), int(stages[i]), float(dur[i])
            lim = self.expected.get((c, s))
            if lim is None:
                continue
            if d > lim:
                score[c] += 1.0          # additive increase on timeout
            else:
                score[c] *= 0.7          # multiplicative decrease
        labels = _dbscan_1d(score, eps=max(score.std(), 1e-9) * 0.5)
        # outliers = cores not in the majority cluster with high score
        if len(np.unique(labels[labels >= 0])) == 0:
            return BaselineVerdict(False, None, None, 0.0)
        major = np.bincount(labels[labels >= 0]).argmax()
        cand = [(score[i], i) for i in range(len(score))
                if labels[i] != major and score[i] > score.mean() + 2]
        if not cand:
            return BaselineVerdict(False, None, None, float(score.max()))
        sc, loc = max(cand)
        return BaselineVerdict(True, "core", int(loc), float(sc))


# ---------------------------------------------------------------------------
# (4) Perseus: regression on latency-vs-throughput
# ---------------------------------------------------------------------------

class Perseus:
    name = "perseus"

    def __init__(self, mesh: Mesh2D, profile: SimResult):
        self.mesh = mesh
        cores, stages, rate, dur = _per_core_rates(profile)
        x = np.log(np.maximum(profile.comp["flops"], 1.0))
        y = np.log(np.maximum(dur, 1e-12))
        self.poly = np.polyfit(x, y, 2)
        resid = y - np.polyval(self.poly, x)
        self.p999 = float(np.quantile(resid, 0.999))

    def detect(self, sim: SimResult) -> BaselineVerdict:
        cores = sim.comp["core"]
        x = np.log(np.maximum(sim.comp["flops"], 1.0))
        y = np.log(np.maximum(sim.comp["t_end"] - sim.comp["t_start"],
                              1e-12))
        resid = y - np.polyval(self.poly, x)
        out = resid > self.p999
        if not out.any():
            return BaselineVerdict(False, None, None,
                                   float(resid.max() - self.p999))
        counts = np.bincount(cores[out], minlength=self.mesh.n_cores)
        loc = int(np.argmax(counts))
        return BaselineVerdict(True, "core", loc, float(counts[loc]))


# ---------------------------------------------------------------------------
# (5) ADR: sliding windows with adaptive thresholds
# ---------------------------------------------------------------------------

class ADR:
    name = "adr"

    def __init__(self, mesh: Mesh2D, profile: SimResult):
        self.mesh = mesh

    def detect(self, sim: SimResult, n_windows: int = 8) -> BaselineVerdict:
        cores, stages, rate, dur = _per_core_rates(sim)
        t_mid = (sim.comp["t_start"] + sim.comp["t_end"]) / 2
        total = max(sim.total_time, 1e-9)
        win = np.clip((t_mid / total * n_windows).astype(int), 0,
                      n_windows - 1)
        worst, where = 0.0, None
        for c in range(self.mesh.n_cores):
            sel = cores == c
            if sel.sum() < 2 * n_windows:
                continue
            r = rate[sel]
            w = win[sel]
            hist = []
            for k in range(n_windows):
                vals = r[w == k]
                if len(vals) == 0:
                    continue
                cur = float(np.median(vals))
                if len(hist) >= 2:
                    thr = np.quantile(hist, 0.1)   # adaptive threshold
                    if cur < thr:
                        slow = thr / max(cur, 1e-12)
                        if slow > worst:
                            worst, where = slow, c
                hist.append(cur)
        if where is not None and worst > 1.5:
            return BaselineVerdict(True, "core", int(where), worst)
        return BaselineVerdict(False, None, None, worst)


ALL_BASELINES = [Thres, Mscope, IASO, Perseus, ADR]
