"""Five baseline fail-slow detectors (paper §IV-A), adapted to the
many-core accelerator domain as in-house implementations and registered
under the unified :class:`~repro.core.detectors.Detector` protocol:

  thres    — static 2× threshold over profiled nominal latency
  mscope   — Microscope: dependency DAG + random-walk root-cause scoring
  iaso     — peer timeout signals → AIMD scores → DBSCAN outlier cluster
  perseus  — polynomial regression on latency-vs-throughput, p99.9 outliers
  adr      — sliding windows, adaptive thresholds from history percentiles

All consume the same raw trace infrastructure (``SimResult``) as SLOTH for
a fair comparison: ``prepare(graph, mesh, profile, cfg)`` fits each
detector's nominal model against a healthy profiling run, and
``analyse(sim)`` returns the unified
:class:`~repro.core.detectors.Verdict` with the mesh attached, so
``Verdict.matches`` applies the shared router-aware judging rule (a
baseline naming any link of a slowed router is correct) and the
campaign's top-k / recall@k metrics treat baselines and SLOTH
identically.

Every baseline emits its **full ranked candidate list**: all resources
whose statistic is above (or near) the detector's decision bar, in
descending suspicion order, capped at ``max_ranked`` entries.  The top-1
entry — and therefore accuracy/FPR — is unchanged from the historical
single-entry behaviour; the tail is what makes baseline top-k / recall@k
cells non-degenerate in multi-failure and mixed-kind campaigns (a
single-entry ranking can never recall the second of two simultaneous
failures).  Rankings are reported even below the flag threshold, exactly
as SLOTH does, so near-threshold severity sweeps can measure graded
localisation; ``flagged`` / ``kind`` / ``location`` keep their old
semantics.  The old lossy ``BaselineVerdict`` 4-field verdict survives
only as a deprecation shim.
"""

from __future__ import annotations

import warnings

import numpy as np

from .detectors import Verdict, _register_builtin
from .routing import Topology
from .simulator import SimResult

__all__ = ["Thres", "Mscope", "IASO", "Perseus", "ADR", "ALL_BASELINES",
           "BaselineVerdict", "BASELINE_NAMES"]


class BaselineVerdict(Verdict):
    """Deprecated 4-field verdict.  Baselines now return the unified
    :class:`~repro.core.detectors.Verdict`; this shim keeps old
    constructor calls working (minus the literal ``(kind, location)``
    ``matches`` bug — matching is inherited, router-aware, from
    ``Verdict``)."""

    def __init__(self, flagged: bool, kind: str | None = None,
                 location: int | None = None, score: float = 0.0):
        warnings.warn(
            "BaselineVerdict is deprecated; baseline detectors return the "
            "unified repro.core.detectors.Verdict",
            DeprecationWarning, stacklevel=2)
        ranking = ([(kind, location, score)]
                   if flagged and kind is not None else [])
        super().__init__(flagged=flagged, kind=kind, location=location,
                         score=score, ranking=ranking)


def _per_core_rates(sim: SimResult):
    """mean FLOPs/s per (core, stage) and per core."""
    comp = sim.comp
    dur = np.maximum(comp["t_end"] - comp["t_start"], 1e-12)
    rate = comp["flops"] / dur
    return comp["core"], comp["stage"], rate, dur


def _per_link_latency(sim: SimResult, mesh: Topology):
    comm = sim.comm
    lat = {}
    for s, d, svc in zip(comm["src"], comm["dst"], comm["service"]):
        if s == d:
            continue
        for lid in mesh.route(int(s), int(d)):
            lat.setdefault(lid, []).append(svc / max(1, len(
                mesh.route(int(s), int(d)))))
    return lat


class _Baseline:
    """Shared life cycle for the five baselines.

    Subclasses implement ``_fit(mesh, profile)`` (nominal model from a
    healthy run) and ``analyse(sim)``.  The legacy two-argument
    constructor ``Cls(mesh, profile)`` still prepares in place; the
    registry path is ``Cls().prepare(graph, mesh, profile, cfg)``.
    """

    name = "baseline"

    def __init__(self, mesh: Topology | None = None,
                 profile: SimResult | None = None):
        self.mesh: Topology | None = None
        if mesh is not None and profile is not None:
            self.prepare(None, mesh, profile)

    def prepare(self, graph, mesh: Topology, profile: SimResult,
                cfg=None) -> "_Baseline":
        """Fit nominal models against a healthy profiling run.  ``graph``
        and ``cfg`` (a ``SlothConfig``) are accepted for protocol
        uniformity; the baselines derive everything from the trace."""
        self.mesh = mesh
        self._fit(mesh, profile)
        return self

    def _fit(self, mesh: Topology, profile: SimResult) -> None:
        raise NotImplementedError

    def analyse(self, sim: SimResult) -> Verdict:
        raise NotImplementedError

    def detect(self, sim: SimResult, **kwargs) -> Verdict:
        """Deprecated alias of :meth:`analyse`.  The old per-call tuning
        kwargs (``Mscope.detect(sim, walks=, seed=)``,
        ``ADR.detect(sim, n_windows=)``) map onto the corresponding
        instance attributes."""
        warnings.warn(
            f"{type(self).__name__}.detect() is deprecated; use "
            f".analyse()", DeprecationWarning, stacklevel=2)
        allowed = {"walks": "walks", "seed": "walk_seed",
                   "n_windows": "n_windows"}
        for k, v in kwargs.items():
            attr = allowed.get(k)
            if attr is None or not hasattr(self, attr):
                raise TypeError(f"{type(self).__name__}.detect() got an "
                                f"unexpected keyword argument {k!r}")
            setattr(self, attr, v)
        return self.analyse(sim)

    #: cap on the emitted ranking length (suspicion-ordered prefix)
    max_ranked = 16

    def _verdict(self, sim: SimResult, flagged: bool,
                 kind: str | None, location: int | None,
                 score: float, ranking=None) -> Verdict:
        """Build the unified verdict.  ``ranking`` is the full
        suspicion-ordered candidate list (truncated to ``max_ranked``);
        when omitted, the historical single-entry ranking is synthesised
        from the top-1 fields.  When flagged, the top-1 fields must agree
        with ``ranking[0]`` — the campaign judge scores accuracy on the
        former and recall@k on the latter."""
        if ranking is None:
            ranking = ([(kind, int(location), float(score))]
                       if flagged else [])
        else:
            ranking = [(k, int(l), float(v))
                       for k, l, v in ranking[:self.max_ranked]]
        return Verdict(flagged=bool(flagged), kind=kind,
                       location=(int(location) if flagged else None),
                       score=float(score), ranking=ranking,
                       total_time=float(sim.total_time), mesh=self.mesh,
                       detector=self.name)


# ---------------------------------------------------------------------------
# (1) Threshold filtering
# ---------------------------------------------------------------------------

class Thres(_Baseline):
    """Flags any component whose latency exceeds 2× the profiled nominal.

    The ranking lists *every* core and link whose observed slowdown is
    above ``rank_floor`` (near the 2× statistic), worst first — with k
    simultaneous failures each victim clears the bar independently, so
    the ranking carries all of them, not just the global worst."""

    name = "thres"
    flag_ratio = 2.0
    rank_floor = 1.25          # include near-statistic resources

    def _fit(self, mesh: Topology, profile: SimResult) -> None:
        cores, stages, rate, _ = _per_core_rates(profile)
        self.nominal = {}
        for c, s, r in zip(cores, stages, rate):
            self.nominal.setdefault((int(c), int(s)), []).append(r)
        self.nominal = {k: float(np.median(v))
                        for k, v in self.nominal.items()}
        link_lat = _per_link_latency(profile, mesh)
        self.link_nominal = {k: float(np.median(v))
                             for k, v in link_lat.items()}

    def analyse(self, sim: SimResult) -> Verdict:
        cores, stages, rate, _ = _per_core_rates(sim)
        slow_by: dict[tuple[str, int], float] = {}
        for c, s, r in zip(cores, stages, rate):
            nom = self.nominal.get((int(c), int(s)))
            if not nom or r <= 0:
                continue
            key = ("core", int(c))
            slow = nom / r
            if slow > slow_by.get(key, 0.0):
                slow_by[key] = slow
        for lid, lats in _per_link_latency(sim, self.mesh).items():
            nom = self.link_nominal.get(lid)
            if not nom:
                continue
            key = ("link", int(lid))
            slow = float(np.median(lats)) / nom
            if slow > slow_by.get(key, 0.0):
                slow_by[key] = slow
        worst = max(slow_by.values(), default=1.0)
        worst = max(worst, 1.0)
        ranking = sorted(((k, l, v) for (k, l), v in slow_by.items()
                          if v >= self.rank_floor),
                         key=lambda x: (-x[2], x[0], x[1]))
        if worst >= self.flag_ratio and ranking:
            return self._verdict(sim, True, ranking[0][0], ranking[0][1],
                                 worst, ranking)
        return self._verdict(sim, False, None, None, worst, ranking)


# ---------------------------------------------------------------------------
# (2) Microscope: dependency DAG + random walk
# ---------------------------------------------------------------------------

class Mscope(_Baseline):
    name = "mscope"
    walks = 200
    walk_seed = 0

    def _fit(self, mesh: Topology, profile: SimResult) -> None:
        cores, stages, rate, _ = _per_core_rates(profile)
        self.nominal = {}
        for c, s, r in zip(cores, stages, rate):
            self.nominal.setdefault(int(c), []).append(r)
        self.nominal = {k: float(np.median(v))
                        for k, v in self.nominal.items()}

    def analyse(self, sim: SimResult) -> Verdict:
        rng = np.random.default_rng(self.walk_seed)
        cores, stages, rate, _ = _per_core_rates(sim)
        anomaly = np.zeros(self.mesh.n_cores)
        for c, r in zip(cores, rate):
            nom = self.nominal.get(int(c), 0)
            if nom > 0 and r > 0:
                anomaly[int(c)] = max(anomaly[int(c)], nom / r - 1.0)
        # service dependency graph: consumer → producer edges weighted by
        # traffic (we walk *backwards* towards root causes)
        comm = sim.comm
        w = {}
        for s, d, b in zip(comm["src"], comm["dst"], comm["bytes"]):
            if s != d:
                w[(int(d), int(s))] = w.get((int(d), int(s)), 0.0) + b
        nbr = {}
        for (d, s), b in w.items():
            nbr.setdefault(d, []).append((s, b))
        visits = np.zeros(self.mesh.n_cores)
        anomalous = np.nonzero(anomaly > 0.5)[0]
        if len(anomalous) == 0:
            return self._verdict(sim, False, None, None, 0.0)
        for _ in range(self.walks):
            node = int(rng.choice(anomalous))
            for _ in range(8):
                visits[node] += anomaly[node] + 0.1
                opts = nbr.get(node)
                if not opts or rng.random() < 0.2:
                    break
                probs = np.array([b * (1 + anomaly[s]) for s, b in opts])
                probs /= probs.sum()
                node = int(opts[rng.choice(len(opts), p=probs)][0])
        # every visited core, most-visited first (argmax tie-break: lowest
        # index), is a root-cause candidate — the walk mass spreads over
        # all simultaneous anomaly sources
        ranking = [("core", int(c), float(visits[c]))
                   for c in sorted(np.nonzero(visits > 0)[0],
                                   key=lambda c: (-visits[c], c))]
        loc = int(np.argmax(visits))
        return self._verdict(sim, True, "core", loc, float(visits[loc]),
                             ranking)


# ---------------------------------------------------------------------------
# (3) IASO: timeout signals → AIMD score → DBSCAN
# ---------------------------------------------------------------------------

def _dbscan_1d(x: np.ndarray, eps: float, min_pts: int = 3) -> np.ndarray:
    """1-D DBSCAN; returns cluster labels (-1 = noise)."""
    order = np.argsort(x)
    labels = np.full(len(x), -1)
    cid = -1
    prev = None
    for i in order:
        if prev is not None and x[i] - x[prev] <= eps:
            labels[i] = labels[prev] if labels[prev] >= 0 else cid
        else:
            cid += 1
        if labels[i] < 0:
            labels[i] = cid
        prev = i
    # enforce min_pts: clusters smaller than min_pts become noise
    for c in np.unique(labels):
        if (labels == c).sum() < min_pts:
            labels[labels == c] = -1
    return labels


class IASO(_Baseline):
    name = "iaso"

    def _fit(self, mesh: Topology, profile: SimResult) -> None:
        cores, stages, rate, dur = _per_core_rates(profile)
        self.expected = {}
        for c, s, d in zip(cores, stages, dur):
            self.expected.setdefault((int(c), int(s)), []).append(d)
        self.expected = {k: float(np.median(v)) * 2.0
                         for k, v in self.expected.items()}

    def analyse(self, sim: SimResult) -> Verdict:
        cores, stages, rate, dur = _per_core_rates(sim)
        score = np.zeros(self.mesh.n_cores)
        order = np.argsort(sim.comp["t_start"])
        for i in order:
            c, s, d = int(cores[i]), int(stages[i]), float(dur[i])
            lim = self.expected.get((c, s))
            if lim is None:
                continue
            if d > lim:
                score[c] += 1.0          # additive increase on timeout
            else:
                score[c] *= 0.7          # multiplicative decrease
        labels = _dbscan_1d(score, eps=max(score.std(), 1e-9) * 0.5)
        # outliers = cores not in the majority cluster with high score;
        # the ranking lists outlier candidates first (max-tuple tie-break:
        # highest index), then every other core with AIMD mass, so all
        # simultaneous timeout sources stay recallable
        def _order(idxs):
            return sorted(idxs, key=lambda i: (-score[i], -i))

        if len(np.unique(labels[labels >= 0])) == 0:
            cand = []              # every cluster dissolved into noise
        else:
            major = np.bincount(labels[labels >= 0]).argmax()
            cand = [i for i in range(len(score))
                    if labels[i] != major and score[i] > score.mean() + 2]
        cand_set = set(cand)
        ordered_cand = _order(cand)
        rest = [i for i in range(len(score))
                if score[i] > 0 and i not in cand_set]
        ranking = [("core", int(i), float(score[i]))
                   for i in ordered_cand + _order(rest)]
        if not cand:               # unflagged still reports the AIMD mass
            return self._verdict(sim, False, None, None,
                                 float(score.max()), ranking)
        loc = ordered_cand[0]
        return self._verdict(sim, True, "core", int(loc),
                             float(score[loc]), ranking)


# ---------------------------------------------------------------------------
# (4) Perseus: regression on latency-vs-throughput
# ---------------------------------------------------------------------------

class Perseus(_Baseline):
    name = "perseus"

    def _fit(self, mesh: Topology, profile: SimResult) -> None:
        cores, stages, rate, dur = _per_core_rates(profile)
        x = np.log(np.maximum(profile.comp["flops"], 1.0))
        y = np.log(np.maximum(dur, 1e-12))
        self.poly = np.polyfit(x, y, 2)
        resid = y - np.polyval(self.poly, x)
        self.p999 = float(np.quantile(resid, 0.999))

    def analyse(self, sim: SimResult) -> Verdict:
        cores = sim.comp["core"]
        x = np.log(np.maximum(sim.comp["flops"], 1.0))
        y = np.log(np.maximum(sim.comp["t_end"] - sim.comp["t_start"],
                              1e-12))
        resid = y - np.polyval(self.poly, x)
        out = resid > self.p999
        if not out.any():
            return self._verdict(sim, False, None, None,
                                 float(resid.max() - self.p999))
        counts = np.bincount(cores[out], minlength=self.mesh.n_cores)
        # every core with p99.9 outlier instructions, most first (argmax
        # tie-break: lowest index) — simultaneous failures each contribute
        # their own outlier population
        ranking = [("core", int(c), float(counts[c]))
                   for c in sorted(np.nonzero(counts > 0)[0],
                                   key=lambda c: (-counts[c], c))]
        loc = int(np.argmax(counts))
        return self._verdict(sim, True, "core", loc, float(counts[loc]),
                             ranking)


# ---------------------------------------------------------------------------
# (5) ADR: sliding windows with adaptive thresholds
# ---------------------------------------------------------------------------

class ADR(_Baseline):
    name = "adr"
    n_windows = 8
    flag_ratio = 1.5
    rank_floor = 1.1           # include near-threshold window drops

    def _fit(self, mesh: Topology, profile: SimResult) -> None:
        pass                     # purely self-referential, no nominal model

    def analyse(self, sim: SimResult) -> Verdict:
        n_windows = self.n_windows
        cores, stages, rate, dur = _per_core_rates(sim)
        t_mid = (sim.comp["t_start"] + sim.comp["t_end"]) / 2
        total = max(sim.total_time, 1e-9)
        win = np.clip((t_mid / total * n_windows).astype(int), 0,
                      n_windows - 1)
        per_core: dict[int, float] = {}    # worst window drop per core
        for c in range(self.mesh.n_cores):
            sel = cores == c
            if sel.sum() < 2 * n_windows:
                continue
            r = rate[sel]
            w = win[sel]
            hist = []
            for k in range(n_windows):
                vals = r[w == k]
                if len(vals) == 0:
                    continue
                cur = float(np.median(vals))
                if len(hist) >= 2:
                    thr = np.quantile(hist, 0.1)   # adaptive threshold
                    if cur < thr:
                        slow = thr / max(cur, 1e-12)
                        if slow > per_core.get(c, 0.0):
                            per_core[c] = slow
                hist.append(cur)
        worst = max(per_core.values(), default=0.0)
        # every core whose own windows dropped below its adaptive
        # threshold, worst first (ties: lowest core id) — one entry per
        # simultaneously degraded core
        ranking = [("core", int(c), float(s))
                   for c, s in sorted(per_core.items(),
                                      key=lambda x: (-x[1], x[0]))
                   if s >= self.rank_floor]
        if worst > self.flag_ratio and ranking:
            return self._verdict(sim, True, "core", ranking[0][1], worst,
                                 ranking)
        return self._verdict(sim, False, None, None, worst, ranking)


ALL_BASELINES = [Thres, Mscope, IASO, Perseus, ADR]
BASELINE_NAMES = tuple(cls.name for cls in ALL_BASELINES)

for _cls in ALL_BASELINES:
    _register_builtin(_cls.name, _cls)
