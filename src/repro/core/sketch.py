"""SL-Recorder: the Fail-Slow Sketch (paper §III-C, Algorithm 1).

Two stages:

* **Stage-1** — ``d`` hash tables × ``m`` buckets of (pattern key, freq) with
  the majority-style insertion rule: match → freq+1 (promote to Stage-2 when
  freq ≥ H), empty → claim with freq 1, occupied by another key → freq−1
  (clear at 0).
* **Stage-2** — a bounded pattern list (≤ MAX_LENGTH, arrival-time/FIFO
  eviction) holding per-pattern compressed statistics: arrival count, sum /
  sum-of-squares of record durations, summed value (FLOPs or bytes), first
  and last timestamps.

Keys are stored as two int32 halves so the JAX / Pallas implementations
(which cannot rely on int64) are bit-identical to this reference.

This module is the *oracle*: ``kernels/sketch_update`` (pure-jnp and Pallas)
must match it exactly.  ``insert_run`` is an algebraically-exact fast path
for runs of identical keys (instruction expansion produces such runs), used
by the benchmarks; ``test_sketch.py`` proves run/record equivalence.
"""

from __future__ import annotations

import dataclasses

import numpy as np

_MASK32 = 0xFFFFFFFF

# Hash constants (shared verbatim with the JAX/Pallas kernels).  One row per
# hash table; supports up to MAX_D tables.
MAX_D = 8
HASH_A1 = np.array([0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F,
                    0x165667B1, 0xD3A2646D, 0xFD7046C5, 0xB55A4F09],
                   dtype=np.int64)
HASH_A2 = np.array([0x632BE59B, 0x9E3779B9, 0x7F4A7C15, 0xF39CC060,
                    0x1F83D9AB, 0x5BE0CD19, 0xCA62C1D6, 0x8F1BBCDC],
                   dtype=np.int64)
HASH_B = np.array([0x7ED55D16, 0xC761C23C, 0x165667B1, 0xD3A2646C,
                   0xFD7046C5, 0xB55A4F09, 0x2DEB33A5, 0x14292967],
                  dtype=np.int64)


def split_key(key: np.ndarray | int):
    """int64 pattern key → (lo, hi) int32 halves (non-negative)."""
    key = np.asarray(key, dtype=np.int64)
    lo = (key & 0x7FFFFFFF).astype(np.int32)
    hi = ((key >> 31) & 0x7FFFFFFF).astype(np.int32)
    return lo, hi


def hash_bucket(lo, hi, table: int, m: int):
    """Deterministic 32-bit mix; bit-identical in numpy int64 arithmetic
    (masked) and int32 wraparound arithmetic (the Pallas kernel), because
    the final value is masked to 31 bits before the modulus."""
    x = (HASH_A1[table] * np.int64(lo) + HASH_A2[table] * np.int64(hi)
         + HASH_B[table]) & _MASK32
    x = x ^ (x >> 16)
    x = (x * 0x45D9F3B) & _MASK32
    x = x ^ (x >> 13)
    return int((x & 0x7FFFFFFF) % m)


#: Bytes of one Stage-2 pattern slot (keys + count + 4 f32 stats + f64
#: timestamp span + arrival).  The unit for drained-pattern accounting:
#: each FIFO-evicted pattern costs exactly one slot in the off-chip
#: compressed stream, independent of L — never derive it by dividing
#: ``stage2_bytes()`` by L, which floor-truncates the moment
#: ``stage2_bytes`` gains any non-slot component.
STAGE2_SLOT_BYTES = 4 + 4 + 4 + 4 * 4 + 8 + 4


@dataclasses.dataclass(frozen=True)
class SketchParams:
    d: int = 2          # hash tables
    m: int = 1024       # buckets per table
    H: int = 8          # promotion threshold
    L: int = 1024       # Stage-2 MAX_LENGTH

    def __post_init__(self):
        if not (1 <= self.d <= MAX_D):
            raise ValueError(f"d must be in [1,{MAX_D}]")

    def stage1_bytes(self) -> int:
        return self.d * self.m * (4 + 4 + 4)      # lo, hi, freq

    def stage2_slot_bytes(self) -> int:
        """Exact bytes of one Stage-2 slot (see
        :data:`STAGE2_SLOT_BYTES`) — the per-pattern cost of the
        drained-eviction stream."""
        return STAGE2_SLOT_BYTES

    def stage2_bytes(self) -> int:
        return self.L * self.stage2_slot_bytes()

    def total_bytes(self) -> int:
        return self.stage1_bytes() + self.stage2_bytes()


@dataclasses.dataclass
class Pattern:
    key: int
    count: int          # records observed after promotion
    sum_dur: float
    sum_sq_dur: float
    sum_val: float      # FLOPs (comp) or bytes (comm)
    t_first: float
    t_last: float
    arrival: int        # monotone promotion counter (FIFO eviction order)
    min_dur: float = float("inf")   # uncongested service-time estimate

    @property
    def mean_dur(self) -> float:
        return self.sum_dur / max(self.count, 1)

    @property
    def var_dur(self) -> float:
        mu = self.mean_dur
        return max(self.sum_sq_dur / max(self.count, 1) - mu * mu, 0.0)

    @property
    def duration(self) -> float:
        return self.t_last - self.t_first


def accumulate_pattern(merged: dict[int, Pattern], p: Pattern) -> None:
    """Merge the partial pattern ``p`` into ``merged`` by key: counts and
    sums add, time bounds and ``min_dur`` extremise, ``arrival`` keeps the
    earliest.  The one definition of partial-pattern merging — shared by
    the oracle's ``patterns(include_drained=True)`` and the kernel-side
    decode (``kernels/sketch_update/ops.patterns``), whose exact agreement
    is the ref-vs-batched parity contract."""
    q = merged.get(p.key)
    if q is None:
        merged[p.key] = dataclasses.replace(p)
        return
    q.count += p.count
    q.sum_dur += p.sum_dur
    q.sum_sq_dur += p.sum_sq_dur
    q.sum_val += p.sum_val
    q.t_first = min(q.t_first, p.t_first)
    q.t_last = max(q.t_last, p.t_last)
    q.min_dur = min(q.min_dur, p.min_dur)
    q.arrival = min(q.arrival, p.arrival)


class FailSlowSketch:
    """Numpy reference implementation of Algorithm 1."""

    def __init__(self, params: SketchParams):
        self.p = params
        d, m = params.d, params.m
        self.keys_lo = np.zeros((d, m), dtype=np.int32)
        self.keys_hi = np.zeros((d, m), dtype=np.int32)
        self.valid = np.zeros((d, m), dtype=bool)
        self.freq = np.zeros((d, m), dtype=np.int64)
        self.stage2: dict[int, Pattern] = {}
        self._arrival = 0
        self.n_inserted = 0
        self.n_evicted = 0
        # Evicted patterns are drained to the off-chip compressed stream (the
        # deployment writes Stage-2 evictions to DRAM/host); analysis may
        # consume live + drained patterns.  On-chip memory is only Stage-1 +
        # the live Stage-2 list.
        self.drained: list[Pattern] = []

    # -- Stage-2 ------------------------------------------------------------
    def _stage2_touch(self, key: int, count: int, dur: float, val: float,
                      t_first: float, t_last: float, sum_dur: float,
                      sum_sq: float, sum_val: float):
        pat = self.stage2.get(key)
        if pat is not None:   # Update
            pat.count += count
            pat.sum_dur += sum_dur
            pat.sum_sq_dur += sum_sq
            pat.sum_val += sum_val
            pat.t_first = min(pat.t_first, t_first)
            pat.t_last = max(pat.t_last, t_last)
            pat.min_dur = min(pat.min_dur, dur)
            return
        if len(self.stage2) >= self.p.L:   # FIFO eviction (arrival-time)
            victim = min(self.stage2.values(), key=lambda q: q.arrival)
            del self.stage2[victim.key]
            self.drained.append(victim)
            self.n_evicted += 1
        self.stage2[key] = Pattern(key, count, sum_dur, sum_sq, sum_val,
                                   t_first, t_last, self._arrival,
                                   min_dur=dur)
        self._arrival += 1

    # -- per-record insertion (Algorithm 1, the ground truth) ---------------
    def insert(self, key: int, dur: float, val: float, t: float):
        self.n_inserted += 1
        lo, hi = split_key(key)
        lo_i, hi_i = int(lo), int(hi)
        promoted = False
        for i in range(self.p.d):
            j = hash_bucket(lo_i, hi_i, i, self.p.m)
            if self.valid[i, j] and self.keys_lo[i, j] == lo_i \
                    and self.keys_hi[i, j] == hi_i:
                self.freq[i, j] += 1
                if self.freq[i, j] >= self.p.H:
                    promoted = True
            elif not self.valid[i, j]:
                self.keys_lo[i, j] = lo_i
                self.keys_hi[i, j] = hi_i
                self.valid[i, j] = True
                self.freq[i, j] = 1
                if self.freq[i, j] >= self.p.H:
                    promoted = True
            else:
                self.freq[i, j] -= 1
                if self.freq[i, j] <= 0:
                    self.valid[i, j] = False
                    self.freq[i, j] = 0
        if promoted:
            self._stage2_touch(key, 1, dur, val, t, t + dur, dur,
                               dur * dur, val)

    # -- exact run-compressed insertion --------------------------------------
    def insert_run(self, key: int, r: int, dur: float, val: float,
                   t0: float, dt: float):
        """Equivalent to ``r`` consecutive ``insert``s of the same key where
        record k starts at ``t0 + k*dt`` and lasts ``dur``."""
        if r <= 0:
            return
        self.n_inserted += r
        lo, hi = split_key(key)
        lo_i, hi_i = int(lo), int(hi)
        first_promo = r  # index of first promoted record, r = none
        for i in range(self.p.d):
            j = hash_bucket(lo_i, hi_i, i, self.p.m)
            if self.valid[i, j] and self.keys_lo[i, j] == lo_i \
                    and self.keys_hi[i, j] == hi_i:
                f0 = int(self.freq[i, j])
                self.freq[i, j] = f0 + r
                # record k (0-based) has freq f0+k+1; promoted iff ≥ H
                k = self.p.H - f0 - 1
            elif not self.valid[i, j]:
                self.keys_lo[i, j] = lo_i
                self.keys_hi[i, j] = hi_i
                self.valid[i, j] = True
                self.freq[i, j] = r
                k = self.p.H - 1
            else:
                f0 = int(self.freq[i, j])
                if r <= f0:
                    self.freq[i, j] = f0 - r
                    if self.freq[i, j] == 0:
                        self.valid[i, j] = False
                    k = r  # never promoted on this table
                else:
                    # f0 decrements clear the bucket, record f0 claims it
                    self.keys_lo[i, j] = lo_i
                    self.keys_hi[i, j] = hi_i
                    self.valid[i, j] = True
                    self.freq[i, j] = r - f0
                    # record f0+k' has freq k'+1 → promoted iff k'+1 ≥ H
                    k = f0 + self.p.H - 1
            first_promo = min(first_promo, max(k, 0))
        if first_promo < r:
            n = r - first_promo
            ts = t0 + dt * np.arange(first_promo, r, dtype=np.float64)
            self._stage2_touch(key, n, dur, val, float(ts[0]),
                               float(ts[-1]) + dur, n * dur,
                               n * dur * dur, n * val)

    # -- bulk APIs ------------------------------------------------------------
    def insert_stream(self, keys, durs, vals, ts):
        for k, d_, v, t in zip(keys, durs, vals, ts):
            self.insert(int(k), float(d_), float(v), float(t))

    def insert_runs(self, keys, reps, durs, vals, t0s, dts):
        for k, r, d_, v, t0, dt in zip(keys, reps, durs, vals, t0s, dts):
            self.insert_run(int(k), int(r), float(d_), float(v), float(t0),
                            float(dt))

    # -- outputs ---------------------------------------------------------------
    def patterns(self, include_drained: bool = True) -> list[Pattern]:
        """Compressed trace patterns.  ``include_drained`` adds patterns that
        were FIFO-evicted to the off-chip stream; note a drained key that
        re-promotes later appears as two partial patterns (merged here)."""
        live = list(self.stage2.values())
        if not include_drained:
            return sorted(live, key=lambda p: p.arrival)
        merged: dict[int, Pattern] = {}
        for p in self.drained + live:
            accumulate_pattern(merged, p)
        return sorted(merged.values(), key=lambda p: p.arrival)

    def onchip_bytes(self) -> int:
        """SRAM-resident state: Stage-1 tables + live Stage-2 list."""
        return self.p.total_bytes()

    def compressed_bytes(self) -> int:
        """Total compressed trace: on-chip state + drained pattern
        stream, each drained pattern at exactly one Stage-2 slot
        (``stage2_slot_bytes()`` — not ``stage2_bytes() // L``, whose
        floor truncation under-counts whenever ``stage2_bytes`` is not
        an exact multiple of ``L``)."""
        return (self.p.total_bytes()
                + len(self.drained) * self.p.stage2_slot_bytes())

    def compression_ratio(self, raw_bytes: float) -> float:
        return raw_bytes / max(self.compressed_bytes(), 1)


def retention_lower_bound(N: float, f_i: float, params: SketchParams)\
        -> float:
    """Lemma 3.1: P(R_i) ≥ 1 − ((N − f_i) / (m (f_i − H)))^d.

    The result is a probability, clamped to [0, 1]: for ``N < f_i`` the
    numerator goes negative and an odd ``d`` would push ``1 − x**d``
    above 1."""
    if f_i <= params.H:
        return 0.0
    x = (N - f_i) / (params.m * (f_i - params.H))
    return min(1.0, max(0.0, 1.0 - x ** params.d))
