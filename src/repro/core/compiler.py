"""SL-Compiler: DNN-graph-guided probe insertion (paper §III-B).

Given a computation graph and its operator→core mapping, SL-Compiler decides
*where* to probe and *what* to record, fully automatically:

1. parse the graph: layer sequence, dependencies, operator types;
2. classify each operator as computation-heavy (→ Exec/Comp/Post probes) or
   communication-intensive (→ Route/Comm/Pre probes) from its FLOPs vs the
   data volume it moves;
3. emit the probe plan (a list of five-tuples + the simulator-facing
   ProbePlan) — users can still override with custom specs.
"""

from __future__ import annotations

import dataclasses

from .graph import CompGraph
from .mapping import MappedGraph
from .probes import (Fragment, InstrType, Level, Location, ProbeSpec,
                     Structure)
from .simulator import ProbePlan


@dataclasses.dataclass
class InstrumentationPlan:
    specs: list[ProbeSpec]
    # op types covered by Exec probes / comm stages covered by Route probes
    exec_ops: tuple[str, ...]
    route_stages: tuple[int, ...]
    sim_plan: ProbePlan

    def describe(self) -> str:
        return "\n".join(repr(s) for s in self.specs)


# bytes a core can move per FLOP it executes before we call the op
# communication-bound (arithmetic-intensity style threshold)
_COMM_BOUND_BYTES_PER_FLOP = 0.25


def plan_probes(graph: CompGraph, mapped: MappedGraph | None = None,
                level: Level = Level.INST,
                structure: Structure = Structure.SKETCH,
                include_mem: bool = False) -> InstrumentationPlan:
    """Analyse ``graph`` and generate the probe configuration."""
    # Step 1+2: classify operators.
    exec_ops: set[str] = set()
    route_stages: set[int] = set()
    for n in graph.nodes:
        if n.op_type in ("input", "output"):
            continue
        out_bytes = sum(e.bytes for e in graph.out_edges(n.node_id))
        in_bytes = sum(e.bytes for e in graph.in_edges(n.node_id))
        moved = out_bytes + in_bytes
        if n.flops > 0 and moved / max(n.flops, 1.0) \
                < _COMM_BOUND_BYTES_PER_FLOP:
            exec_ops.add(n.op_type)       # compute-heavy → Exec probe
        if moved > 0:
            route_stages.add(n.stage)     # data movement → Route probe

    specs = [
        ProbeSpec(Fragment.EXEC, InstrType.COMP, Location.SURROUND, level,
                  structure, target_ops=tuple(sorted(exec_ops))),
        ProbeSpec(Fragment.ROUTE, InstrType.COMM, Location.PRE, level,
                  structure),
    ]
    if include_mem:
        specs.append(ProbeSpec(Fragment.MEM, InstrType.IO, Location.POST,
                               Level.STAGE, structure))

    sim_plan = ProbePlan(comp=True, comm=True,
                         level=level.value,
                         surround=True)
    return InstrumentationPlan(specs=specs, exec_ops=tuple(sorted(exec_ops)),
                               route_stages=tuple(sorted(route_stages)),
                               sim_plan=sim_plan)


def plan_for_mode(mode: str) -> ProbePlan:
    """The three instrumentation configurations evaluated in Fig 10."""
    if mode == "comm":
        return ProbePlan(comp=False, comm=True, level="inst")
    if mode == "comp":
        return ProbePlan(comp=True, comm=False, level="inst")
    if mode == "full":
        return ProbePlan(comp=True, comm=True, level="inst")
    if mode == "none":
        return None  # type: ignore[return-value]
    raise ValueError(mode)
