"""Campaign metric aggregation (paper §IV: Table III, Figs 11/12/16/17).

Pure, deterministic reductions over per-scenario outcomes.  The campaign
runner (``campaign.py``) produces one :class:`ScenarioOutcome` per injected
(or failure-free) scenario; this module turns a list of outcomes into the
paper-style aggregates:

* **accuracy** — fraction of *positive* scenarios whose top-1 verdict names
  the injected root cause (router failures accept any link of the slowed
  router, since the detector localises at link granularity),
* **FPR** — fraction of *negative* (failure-free) scenarios that were
  flagged,
* **top-k localisation rate** — fraction of positives whose ground truth
  appears within the first k entries of the ranking (monotone in k),
* **compression ratio** and **probe overhead** means.

Binomial rates carry Wilson score confidence intervals so small grid cells
report honest uncertainty.  Everything here is plain float arithmetic in a
fixed order: identical outcome lists produce bit-identical metrics.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ScenarioOutcome:
    """Result of one campaign scenario (the exchange record between the
    runner and the aggregators)."""
    scenario_id: int
    workload: str
    mesh_w: int
    mesh_h: int
    kind: str                  # 'core' | 'link' | 'router' | 'none'
    severity: float            # injected slowdown (0.0 for 'none')
    rep: int                   # replicate index within the grid cell
    sim_seed: int              # simulator seed actually used
    # ground truth (None fields for negative samples)
    truth_location: int | None
    t0: float | None
    duration: float | None
    # verdict
    flagged: bool
    pred_kind: str | None
    pred_location: int | None
    score: float
    matched: bool              # top-1 correctness (router-aware)
    truth_rank: int | None     # 1-based rank of truth in ranking, or None
    # accounting
    compression_ratio: float
    total_time: float
    baseline_results: tuple = ()   # ((name, flagged, matched), ...)

    @property
    def positive(self) -> bool:
        return self.kind != "none"

    def cell(self) -> tuple:
        return (self.workload, self.mesh_w, self.mesh_h, self.kind,
                self.severity)


@dataclasses.dataclass(frozen=True)
class BinomialStat:
    """k successes out of n trials with a Wilson score interval."""
    successes: int
    trials: int
    z: float = 1.96

    @property
    def rate(self) -> float:
        return self.successes / self.trials if self.trials else 0.0

    @property
    def interval(self) -> tuple[float, float]:
        return wilson_interval(self.successes, self.trials, self.z)

    def pct(self) -> float:
        return 100.0 * self.rate


def wilson_interval(k: int, n: int, z: float = 1.96) -> tuple[float, float]:
    """Wilson score interval for a binomial proportion (exact at n=0)."""
    if n == 0:
        return (0.0, 1.0)
    p = k / n
    denom = 1.0 + z * z / n
    centre = (p + z * z / (2 * n)) / denom
    half = (z / denom) * math.sqrt(p * (1 - p) / n + z * z / (4 * n * n))
    return (max(0.0, centre - half), min(1.0, centre + half))


@dataclasses.dataclass(frozen=True)
class CampaignMetrics:
    """Aggregate metrics over a set of scenario outcomes."""
    n_scenarios: int
    accuracy: BinomialStat          # over positives
    fpr: BinomialStat               # over negatives
    topk: tuple[tuple[int, BinomialStat], ...]   # ((k, stat), ...)
    mean_compression: float
    mean_probe_overhead: float      # filled by the runner (per deployment)

    def topk_rate(self, k: int) -> float:
        for kk, stat in self.topk:
            if kk == k:
                return stat.rate
        raise KeyError(k)


def topk_stat(outcomes: list[ScenarioOutcome], k: int) -> BinomialStat:
    pos = [o for o in outcomes if o.positive]
    hits = sum(1 for o in pos
               if o.truth_rank is not None and o.truth_rank <= k)
    return BinomialStat(hits, len(pos))


def aggregate(outcomes: list[ScenarioOutcome],
              ks: tuple[int, ...] = (1, 3, 5),
              probe_overhead: float = 0.0) -> CampaignMetrics:
    """Reduce outcomes to campaign metrics.

    Positives feed accuracy/top-k; negatives feed FPR only — a grid cell
    with ``kind='none'`` therefore contributes zero accuracy trials.
    """
    pos = [o for o in outcomes if o.positive]
    neg = [o for o in outcomes if not o.positive]
    acc = BinomialStat(sum(o.matched for o in pos), len(pos))
    fpr = BinomialStat(sum(o.flagged for o in neg), len(neg))
    comp = [o.compression_ratio for o in outcomes]
    mean_comp = sum(comp) / len(comp) if comp else 0.0
    return CampaignMetrics(
        n_scenarios=len(outcomes),
        accuracy=acc,
        fpr=fpr,
        topk=tuple((k, topk_stat(outcomes, k)) for k in ks),
        mean_compression=mean_comp,
        mean_probe_overhead=probe_overhead,
    )


def by_cell(outcomes: list[ScenarioOutcome],
            ks: tuple[int, ...] = (1, 3, 5)) \
        -> dict[tuple, CampaignMetrics]:
    """Per-cell aggregation, keyed (workload, mesh_w, mesh_h, kind,
    severity).  Cells appear in first-occurrence (enumeration) order."""
    cells: dict[tuple, list[ScenarioOutcome]] = {}
    for o in outcomes:
        cells.setdefault(o.cell(), []).append(o)
    return {c: aggregate(v, ks=ks) for c, v in cells.items()}


def baseline_stats(outcomes: list[ScenarioOutcome]) \
        -> dict[str, tuple[BinomialStat, BinomialStat]]:
    """Per-baseline (accuracy, fpr) over outcomes that carry baseline
    verdicts (campaign run with ``baselines=True``)."""
    acc: dict[str, list[int]] = {}
    fpr: dict[str, list[int]] = {}
    for o in outcomes:
        for name, flagged, matched in o.baseline_results:
            if o.positive:
                acc.setdefault(name, []).append(int(matched))
            else:
                fpr.setdefault(name, []).append(int(flagged))
    names = sorted(set(acc) | set(fpr))
    return {n: (BinomialStat(sum(acc.get(n, [])), len(acc.get(n, []))),
                BinomialStat(sum(fpr.get(n, [])), len(fpr.get(n, []))))
            for n in names}
