"""Campaign metric aggregation (paper §IV: Table III, Figs 11/12/16/17).

Pure, deterministic reductions over per-scenario outcomes.  The campaign
runner (``campaign.py``) produces one :class:`ScenarioOutcome` per injected
(or failure-free) scenario; this module turns a list of outcomes into the
paper-style aggregates.

Unified-detector layout
-----------------------
Every scenario's trace is analysed by **all** requested detectors (the
campaign's ``detectors=("sloth", "thres", ...)`` axis), so a
:class:`ScenarioOutcome` carries one :class:`DetectorOutcome` per detector
— flagged / top-1 prediction / router-aware match / per-truth ranks / wall
time — all judged by the single rule in
:func:`repro.core.failures.judge_verdict`.  Each aggregate therefore takes
a ``detector=`` selector (default: the campaign's *primary* detector, the
first one requested), and :func:`by_detector` / :func:`detector_cells`
produce the full SLOTH-vs-baselines table in one pass.

A scenario may carry **several simultaneous injected failures** (the grid's
``n_failures`` axis): ground truth is therefore a *tuple* of truths
(``truth_locations`` / ``truth_t0s`` / ``truth_durations``, all empty for
negatives), each with its own 1-based rank in the verdict's ranking
(``truth_ranks``; ``None`` when unranked).  Failures within one scenario
may be of **different kinds** (the grid's ``kind='mixed'`` / explicit
kind-tuple entries): ``truth_kinds`` records each injected failure's kind
index-aligned with the other truth tuples, and :func:`by_truth_kind`
splits per-failure recall@k and rank statistics by that kind — so a mixed
campaign reports how well each detector localises core vs link vs router
root causes *within heterogeneous scenarios*.  Severity may likewise vary
per failure (``truth_severities`` / ``effective_truth_severities``, set by
the grid's per-failure severity tuples).  :func:`severity_curve` slices
positives by injected severity (accuracy / recall@k per severity,
negatives' FPR alongside) for near-threshold sweeps, and
:func:`severity_curve_by_mesh` splits the same curve per mesh size.  The
aggregates are:

* **accuracy (any-match)** — fraction of *positive* scenarios whose top-1
  verdict names any of the injected root causes (router failures accept any
  link of the slowed router, since detectors localise at link granularity),
* **FPR** — fraction of *negative* (failure-free) scenarios that were
  flagged,
* **top-k localisation rate** — fraction of positives with *some* ground
  truth within the first k entries of the ranking (monotone in k),
* **recall@k** — fraction of *individual injected failures* (over all
  positives) ranked within the top k; for single-failure grids this
  coincides with top-k,
* **detection latency** — on streaming campaigns
  (``run_campaign(streaming=...)``), the simulated time from the
  earliest failure onset to the first flagged streaming verdict
  (:func:`detection_latency_stats`: detected fraction with a Wilson CI,
  mean / p95 over the detected positives).  Per outcome the latency is
  ``None`` (not streamed / negative), ``inf`` (streamed, never flagged)
  or finite (detected); it is simulated time, hence deterministic and
  part of outcome equality,
* **recovered throughput** — on mitigated campaigns
  (``run_campaign(mitigation=...)``), each (detector, policy) cell's
  :class:`MitigationOutcome` per scenario reduces via
  :func:`by_mitigation` to a :class:`MitigationStat`: post-mitigation
  slowdown vs healthy, the fraction of the failure-induced gap recovered
  under correct verdicts, and the mis-mitigation penalty paid when the
  policy acted on a wrong or false verdict — all binomial rates with
  Wilson CIs,
* **compression ratio** and **probe overhead** means.  Probe overhead is
  a per-deployment quantity; the headline mean weights each deployment by
  the number of scenarios it served (``mean_probe_overhead``), with the
  unweighted per-deployment mean kept alongside
  (``mean_probe_overhead_unweighted``),
* **wall-time telemetry** — per-detector analyse time and per-scenario
  simulate time (:func:`wall_time_stats`: mean / p95 / total).  Wall
  times are measurements, not results: they are excluded from outcome
  equality so executor-equivalence comparisons stay bit-exact.

Binomial rates carry Wilson score confidence intervals so small grid cells
report honest uncertainty.  Everything here is plain float arithmetic in a
fixed order: identical outcome lists produce bit-identical metrics.
"""

from __future__ import annotations

import dataclasses
import math
import warnings

from .routing import topology_spec


@dataclasses.dataclass(frozen=True)
class DetectorOutcome:
    """One detector's judged verdict for one scenario.  Plain scalars and
    tuples only — picklable across process-pool boundaries.  ``wall_time``
    (seconds spent in ``analyse``) is telemetry and excluded from
    equality."""
    detector: str              # registry name ('sloth', 'thres', ...)
    flagged: bool
    pred_kind: str | None      # top-1 prediction ('core' | 'link')
    pred_location: int | None
    score: float
    matched: bool              # top-1 matches any truth (router-aware)
    truth_rank: int | None     # best 1-based rank over truths, or None
    # per-failure rank (int | None), aligned with the scenario's
    # truth_locations
    truth_ranks: tuple = ()
    wall_time: float = dataclasses.field(default=0.0, compare=False)
    # streaming detection latency (simulated seconds from earliest failure
    # onset to the first flagged streaming verdict): None when the
    # scenario was not streamed or is a negative sample, math.inf when
    # streamed but never flagged, finite when detected.  Deterministic
    # (simulated time, not wall time), so it participates in equality.
    detection_latency: float | None = None


@dataclasses.dataclass(frozen=True)
class MitigationOutcome:
    """One (detector, policy) mitigation attempt for one scenario.

    Produced by ``run_campaign(mitigation=...)``: the policy planned
    against the detector's verdict, the plan was applied, and the
    mitigated deployment was re-simulated over the remaining failure
    window.  ``correct`` is the judged correctness of the *acted-on
    verdict* (router-aware top-1 match for positives, not-flagged for
    negatives) — so wrong/false verdicts can be sliced out to measure the
    mis-mitigation penalty.  ``switch_time`` is the simulated stream time
    at which mitigation engaged (first streaming flag); ``None`` models a
    post-hoc restart over the full window.  All times are simulated and
    deterministic; ``wall_time`` (plan+apply+re-simulate seconds) is
    telemetry, excluded from equality.
    """
    detector: str
    policy: str
    acted: bool                # the plan edited the deployment
    correct: bool              # the verdict acted on was judged correct
    exclude_cores: tuple[int, ...]
    avoid_links: tuple[int, ...]
    healthy_time: float        # failure-free makespan (probed reference)
    failed_time: float         # un-mitigated makespan under the failures
    mitigated_time: float      # makespan after mitigation (== failed when
    #                            the policy did not act)
    switch_time: float | None = None
    wall_time: float = dataclasses.field(default=0.0, compare=False)

    @property
    def gap(self) -> float:
        """Failure-induced throughput gap (seconds lost to the failure)."""
        return self.failed_time - self.healthy_time

    @property
    def recovered_frac(self) -> float:
        """Fraction of the failure-induced gap the mitigation clawed back:
        1.0 = back to healthy, 0.0 = no change, negative = made it worse.
        Defined as 0.0 when there is no gap to recover."""
        gap = self.gap
        if gap <= 0.0:
            return 0.0
        return (self.failed_time - self.mitigated_time) / gap

    @property
    def slowdown_vs_healthy(self) -> float:
        """Post-mitigation makespan relative to the healthy reference."""
        if self.healthy_time <= 0.0:
            return 0.0
        return self.mitigated_time / self.healthy_time

    @property
    def penalty(self) -> float:
        """Relative slowdown *introduced* by acting: positive only when
        mitigation made the run slower than leaving the failure alone —
        the cost of acting on a wrong or false verdict."""
        if self.failed_time <= 0.0:
            return 0.0
        return max(0.0, self.mitigated_time / self.failed_time - 1.0)


@dataclasses.dataclass(frozen=True)
class ScenarioOutcome:
    """Result of one campaign scenario (the exchange record between the
    runner and the aggregators).  Picklable: plain scalars, tuples and
    :class:`DetectorOutcome` tuples only, so outcomes cross process
    boundaries under ``executor='process'``.  ``sim_wall_time`` is
    telemetry (excluded from equality, like ``DetectorOutcome.wall_time``).
    """
    scenario_id: int
    workload: str
    mesh_w: int
    mesh_h: int
    # 'core' | 'link' | 'router' | 'none' | 'mixed' | 'core+link'-style
    # composites (per-failure kinds are in truth_kinds)
    kind: str
    # injected slowdown (0.0 for 'none'); a tuple for per-failure severity
    # mixes (the grid's explicit-tuple severity entries, e.g. a 1.5× core
    # with a 10× link in one scenario — see ``truth_severities``)
    severity: float | tuple[float, ...]
    n_failures: int            # simultaneous injected failures (0 = 'none')
    rep: int                   # replicate index within the grid cell
    sim_seed: int              # simulator seed actually used
    # ground truth (empty tuples for negative samples), index-aligned
    truth_locations: tuple[int, ...]
    truth_t0s: tuple[float, ...]
    truth_durations: tuple[float, ...]
    # one judged verdict per requested detector, in request order (the
    # first entry is the campaign's primary detector)
    detector_results: tuple[DetectorOutcome, ...]
    # accounting
    compression_ratio: float   # recorder compression (0.0 if no detector
    #                            produced recorder artifacts)
    total_time: float
    probe_overhead: float          # of the deployment that ran the scenario
    sim_wall_time: float = dataclasses.field(default=0.0, compare=False)
    # per-failure kinds, index-aligned with truth_locations; empty both for
    # negatives and for outcomes predating the mixed-kind axis (see
    # ``effective_truth_kinds``)
    truth_kinds: tuple[str, ...] = ()
    # per-failure injected slowdowns, index-aligned with truth_locations;
    # empty for negatives and for outcomes predating per-failure severity
    # mixes (see ``effective_truth_severities``)
    truth_severities: tuple[float, ...] = ()
    # one mitigation attempt per (detector, policy) pair, detector-major
    # in request order; empty on campaigns without ``mitigation=``
    mitigation_results: tuple[MitigationOutcome, ...] = ()
    # registry fabric key ('mesh' | 'torus' | 'het:fast2slow1' | ...);
    # 'mesh' both for default fabrics and for outcomes predating the
    # topology axis.  Joined with (mesh_w, mesh_h) into the canonical
    # fabric label by ``topology_label`` / ``by_topology``.
    topology: str = "mesh"

    @property
    def positive(self) -> bool:
        return self.kind != "none"

    @property
    def effective_truth_kinds(self) -> tuple[str, ...]:
        """Per-failure kinds with the single-kind fallback: outcomes from
        homogeneous scenarios (or synthesised without ``truth_kinds``)
        report every failure as the scenario's own kind."""
        if self.truth_kinds:
            return self.truth_kinds
        return (self.kind,) * len(self.truth_locations)

    @property
    def effective_truth_severities(self) -> tuple[float, ...]:
        """Per-failure severities with the uniform-severity fallback:
        outcomes from scalar-severity scenarios (or synthesised without
        ``truth_severities``) report every failure at the scenario's own
        severity."""
        if self.truth_severities:
            return self.truth_severities
        if isinstance(self.severity, tuple):
            return tuple(float(s) for s in self.severity)
        return (float(self.severity),) * len(self.truth_locations)

    # -- primary-detector convenience views --------------------------------
    @property
    def primary(self) -> DetectorOutcome:
        return self.detector_results[0]

    @property
    def flagged(self) -> bool:
        return self.primary.flagged

    @property
    def pred_kind(self) -> str | None:
        return self.primary.pred_kind

    @property
    def pred_location(self) -> int | None:
        return self.primary.pred_location

    @property
    def score(self) -> float:
        return self.primary.score

    @property
    def matched(self) -> bool:
        return self.primary.matched

    @property
    def truth_rank(self) -> int | None:
        return self.primary.truth_rank

    @property
    def truth_ranks(self) -> tuple:
        return self.primary.truth_ranks

    @property
    def baseline_results(self) -> tuple:
        """Deprecated view: ``(name, flagged, matched)`` tuples for every
        non-primary detector (the old ``baselines=True`` payload)."""
        return tuple((d.detector, d.flagged, d.matched)
                     for d in self.detector_results[1:])

    # -- single-failure convenience views (first truth or None) ------------
    @property
    def truth_location(self) -> int | None:
        return self.truth_locations[0] if self.truth_locations else None

    @property
    def t0(self) -> float | None:
        return self.truth_t0s[0] if self.truth_t0s else None

    @property
    def duration(self) -> float | None:
        return self.truth_durations[0] if self.truth_durations else None

    def result_for(self, detector: str | None) -> DetectorOutcome:
        """This scenario's :class:`DetectorOutcome` for ``detector``
        (``None`` → primary)."""
        if detector is None:
            return self.detector_results[0]
        for d in self.detector_results:
            if d.detector == detector:
                return d
        raise KeyError(
            f"scenario {self.scenario_id} carries no verdict for "
            f"detector {detector!r}; ran: "
            f"{tuple(d.detector for d in self.detector_results)}")

    def mitigation_for(self, detector: str,
                       policy: str) -> MitigationOutcome:
        """This scenario's :class:`MitigationOutcome` for one
        (detector, policy) cell."""
        for m in self.mitigation_results:
            if m.detector == detector and m.policy == policy:
                return m
        raise KeyError(
            f"scenario {self.scenario_id} carries no mitigation outcome "
            f"for ({detector!r}, {policy!r}); ran: "
            f"{tuple((m.detector, m.policy) for m in self.mitigation_results)}")

    def cell(self) -> tuple:
        # topology is appended (not inserted) so positional consumers of
        # the historical 6 fields keep their indices
        return (self.workload, self.mesh_w, self.mesh_h, self.kind,
                self.severity, self.n_failures, self.topology)

    def deploy_key(self) -> tuple:
        return (self.workload, self.topology, self.mesh_w, self.mesh_h)

    def topology_label(self) -> str:
        """Canonical fabric spec of this scenario's deployment
        (``'mesh:4x4'``, ``'torus:8x8'``, ``'het:4x4:fast2slow1'``)."""
        return topology_spec(self.topology, self.mesh_w, self.mesh_h)


@dataclasses.dataclass(frozen=True)
class BinomialStat:
    """k successes out of n trials with a Wilson score interval."""
    successes: int
    trials: int
    z: float = 1.96

    @property
    def rate(self) -> float:
        return self.successes / self.trials if self.trials else 0.0

    @property
    def interval(self) -> tuple[float, float]:
        return wilson_interval(self.successes, self.trials, self.z)

    def pct(self) -> float:
        return 100.0 * self.rate


def wilson_interval(k: int, n: int, z: float = 1.96) -> tuple[float, float]:
    """Wilson score interval for a binomial proportion (exact at n=0)."""
    if n == 0:
        return (0.0, 1.0)
    p = k / n
    denom = 1.0 + z * z / n
    centre = (p + z * z / (2 * n)) / denom
    half = (z / denom) * math.sqrt(p * (1 - p) / n + z * z / (4 * n * n))
    return (max(0.0, centre - half), min(1.0, centre + half))


def _rate_at(pairs: tuple[tuple[int, BinomialStat], ...], k: int) -> float:
    """Look up the rate for ``k`` in a ``((k, stat), ...)`` table — the
    one accessor behind every ``topk_rate``/``recall_at``."""
    for kk, stat in pairs:
        if kk == k:
            return stat.rate
    raise KeyError(k)


@dataclasses.dataclass(frozen=True)
class LatencyStat:
    """Detection-latency summary over the streamed positive scenarios of
    a campaign: ``detected`` counts finite latencies (failure flagged
    while streaming) out of all streamed positives; ``mean``/``p95``
    summarise the finite latencies (simulated seconds from earliest
    failure onset to the first flagged streaming verdict; 0.0 when
    nothing was detected)."""
    detected: BinomialStat
    mean: float
    p95: float

    @property
    def n_measured(self) -> int:
        return self.detected.trials

    @property
    def n_detected(self) -> int:
        return self.detected.successes


def detection_latency_stats(outcomes: list[ScenarioOutcome],
                            detector: str | None = None) \
        -> LatencyStat | None:
    """Reduce streamed positives to a :class:`LatencyStat` for one
    detector (``None`` → primary); ``None`` when no positive scenario
    carries a latency measurement (non-streaming campaign)."""
    lats = [o.result_for(detector).detection_latency
            for o in outcomes if o.positive]
    lats = [x for x in lats if x is not None]
    if not lats:
        return None
    finite = [x for x in lats if math.isfinite(x)]
    return LatencyStat(
        detected=BinomialStat(len(finite), len(lats)),
        mean=sum(finite) / len(finite) if finite else 0.0,
        p95=_p95(finite))


@dataclasses.dataclass(frozen=True)
class CampaignMetrics:
    """Aggregate metrics over a set of scenario outcomes, for one
    detector."""
    n_scenarios: int
    accuracy: BinomialStat          # any-match, over positives
    fpr: BinomialStat               # over negatives
    topk: tuple[tuple[int, BinomialStat], ...]   # ((k, stat), ...)
    recall: tuple[tuple[int, BinomialStat], ...]  # per-failure recall@k
    mean_compression: float
    mean_probe_overhead: float      # weighted by per-deployment scenarios
    mean_probe_overhead_unweighted: float   # plain mean over deployments
    # detection-latency summary over streamed positives (None on
    # non-streaming campaigns)
    detection: LatencyStat | None = None

    def topk_rate(self, k: int) -> float:
        return _rate_at(self.topk, k)

    def recall_at(self, k: int) -> float:
        return _rate_at(self.recall, k)


@dataclasses.dataclass(frozen=True)
class WallTimeStat:
    """Telemetry summary of a wall-time population (seconds)."""
    mean: float
    p95: float
    total: float
    n: int


def _p95(xs: list[float]) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    return xs[max(0, math.ceil(0.95 * len(xs)) - 1)]


def detectors_in(outcomes: list[ScenarioOutcome]) -> tuple[str, ...]:
    """Detector names present in ``outcomes``, in request order."""
    return (tuple(d.detector for d in outcomes[0].detector_results)
            if outcomes else ())


def topk_stat(outcomes: list[ScenarioOutcome], k: int,
              detector: str | None = None) -> BinomialStat:
    """Scenario-level: some truth ranked within the top k."""
    pos = [o for o in outcomes if o.positive]
    hits = 0
    for o in pos:
        r = o.result_for(detector).truth_rank
        hits += int(r is not None and r <= k)
    return BinomialStat(hits, len(pos))


def deployment_overheads(outcomes: list[ScenarioOutcome]) \
        -> dict[tuple, float]:
    """Per-deployment probe overhead, keyed ``deploy_key()``, in
    first-occurrence order.  The single reduction shared by ``aggregate``
    and ``CampaignResult.probe_overheads``."""
    dep_ov: dict[tuple, float] = {}
    for o in outcomes:
        dep_ov.setdefault(o.deploy_key(), o.probe_overhead)
    return dep_ov


def recall_stat(outcomes: list[ScenarioOutcome], k: int,
                detector: str | None = None) -> BinomialStat:
    """Failure-level recall@k: each injected failure of each positive
    scenario is one trial; a hit is that failure's own truth ranked ≤ k."""
    hits = trials = 0
    for o in outcomes:
        if not o.positive:
            continue
        for r in o.result_for(detector).truth_ranks:
            trials += 1
            hits += int(r is not None and r <= k)
    return BinomialStat(hits, trials)


def aggregate(outcomes: list[ScenarioOutcome],
              ks: tuple[int, ...] = (1, 3, 5),
              detector: str | None = None) -> CampaignMetrics:
    """Reduce outcomes to campaign metrics for one detector (``None`` →
    the primary, i.e. first-requested, detector).

    Positives feed accuracy/top-k/recall; negatives feed FPR only — a grid
    cell with ``kind='none'`` therefore contributes zero accuracy trials.
    Compression is averaged over outcomes that produced recorder artifacts.
    Probe overhead is aggregated both scenario-weighted (each outcome
    contributes its deployment's overhead) and unweighted over the distinct
    deployments that appear in ``outcomes``; both are per-deployment
    quantities independent of the detector selector.
    """
    pos = [o for o in outcomes if o.positive]
    neg = [o for o in outcomes if not o.positive]
    acc = BinomialStat(sum(o.result_for(detector).matched for o in pos),
                       len(pos))
    fpr = BinomialStat(sum(o.result_for(detector).flagged for o in neg),
                       len(neg))
    comp = [o.compression_ratio for o in outcomes
            if o.compression_ratio > 0]
    mean_comp = sum(comp) / len(comp) if comp else 0.0
    ov = [o.probe_overhead for o in outcomes]
    mean_ov = sum(ov) / len(ov) if ov else 0.0
    dep_ov = deployment_overheads(outcomes)
    # sorted(): dict order reflects outcome arrival, which differs per
    # executor/shard merge — summing floats in a fixed order keeps the
    # serial == thread == process bit-identity contract.
    mean_ov_unw = (sum(sorted(dep_ov.values())) / len(dep_ov)) \
        if dep_ov else 0.0
    return CampaignMetrics(
        n_scenarios=len(outcomes),
        accuracy=acc,
        fpr=fpr,
        topk=tuple((k, topk_stat(outcomes, k, detector)) for k in ks),
        recall=tuple((k, recall_stat(outcomes, k, detector)) for k in ks),
        mean_compression=mean_comp,
        mean_probe_overhead=mean_ov,
        mean_probe_overhead_unweighted=mean_ov_unw,
        detection=detection_latency_stats(outcomes, detector),
    )


def by_detector(outcomes: list[ScenarioOutcome],
                ks: tuple[int, ...] = (1, 3, 5)) \
        -> dict[str, CampaignMetrics]:
    """Per-detector campaign metrics, in detector request order — the
    SLOTH-vs-baselines comparison table in one reduction."""
    return {name: aggregate(outcomes, ks=ks, detector=name)
            for name in detectors_in(outcomes)}


def by_cell(outcomes: list[ScenarioOutcome],
            ks: tuple[int, ...] = (1, 3, 5),
            detector: str | None = None) \
        -> dict[tuple, CampaignMetrics]:
    """Per-cell aggregation for one detector, keyed (workload, mesh_w,
    mesh_h, kind, severity, n_failures).  Cells appear in first-occurrence
    (enumeration) order."""
    cells: dict[tuple, list[ScenarioOutcome]] = {}
    for o in outcomes:
        cells.setdefault(o.cell(), []).append(o)
    return {c: aggregate(v, ks=ks, detector=detector)
            for c, v in cells.items()}


def detector_cells(outcomes: list[ScenarioOutcome],
                   ks: tuple[int, ...] = (1, 3, 5)) \
        -> dict[str, dict[tuple, CampaignMetrics]]:
    """Per-detector per-cell metrics: ``{detector: {cell: metrics}}`` —
    every accuracy/FPR/top-k number of the paper's comparison tables."""
    return {name: by_cell(outcomes, ks=ks, detector=name)
            for name in detectors_in(outcomes)}


#: Materiality floor for recovered-throughput means: positives whose
#: failure-induced gap is below this fraction of the healthy makespan are
#: excluded from ``recovered_mean``/``improved`` (a near-zero gap turns the
#: recovered fraction into amplified simulator noise), but still count in
#: ``acted`` and the slowdown mean.
MIN_GAP_FRAC = 0.01


@dataclasses.dataclass(frozen=True)
class MitigationStat:
    """Recovered-throughput summary for one (detector, policy) cell.

    Three populations, judged by the verdict the policy acted on:

    * *correct positives* (failure present, verdict matched) with a
      material gap feed ``improved`` (mitigated < failed, Wilson CI) and
      ``recovered_mean`` — the headline "fraction of the failure-induced
      gap recovered under correct verdicts";
    * *wrong/false verdicts* (mismatched positives + false-flagged
      negatives) feed ``mis_acted`` (the policy acted on bad information),
      ``worsened`` (acting made the run slower than the failure alone) and
      ``penalty_mean`` — the mis-mitigation cost;
    * all positives feed ``slowdown_mean`` (post-mitigation makespan vs
      healthy, 1.0 = full recovery).
    """
    detector: str
    policy: str
    n_positive: int
    n_negative: int
    acted: BinomialStat        # plans that edited the deployment, over all
    improved: BinomialStat     # correct material positives: mitigated < failed
    recovered_mean: float      # mean recovered_frac over that population
    slowdown_mean: float       # mean mitigated/healthy over positives
    mis_acted: BinomialStat    # wrong/false verdicts where the policy acted
    worsened: BinomialStat     # acted wrong/false: mitigated > failed
    penalty_mean: float        # mean penalty over acted wrong/false


def mitigations_in(outcomes: list[ScenarioOutcome]) \
        -> tuple[tuple[str, str], ...]:
    """(detector, policy) pairs present in ``outcomes``, in request order
    (detector-major)."""
    return (tuple((m.detector, m.policy)
                  for m in outcomes[0].mitigation_results)
            if outcomes else ())


def mitigation_stats(outcomes: list[ScenarioOutcome], detector: str,
                     policy: str) -> MitigationStat:
    """Reduce one (detector, policy) cell to a :class:`MitigationStat`."""
    pos: list[MitigationOutcome] = []
    neg: list[MitigationOutcome] = []
    for o in outcomes:
        for m in o.mitigation_results:
            if m.detector == detector and m.policy == policy:
                (pos if o.positive else neg).append(m)
    all_m = pos + neg
    material = [m for m in pos if m.correct
                and m.gap > MIN_GAP_FRAC * m.healthy_time]
    wrong = [m for m in pos + neg if not m.correct]
    wrong_acted = [m for m in wrong if m.acted]
    slowdowns = [m.slowdown_vs_healthy for m in pos]
    recovered = [m.recovered_frac for m in material]
    penalties = [m.penalty for m in wrong_acted]
    return MitigationStat(
        detector=detector,
        policy=policy,
        n_positive=len(pos),
        n_negative=len(neg),
        acted=BinomialStat(sum(m.acted for m in all_m), len(all_m)),
        improved=BinomialStat(
            sum(m.mitigated_time < m.failed_time for m in material),
            len(material)),
        recovered_mean=(sum(recovered) / len(recovered)) if recovered
        else 0.0,
        slowdown_mean=(sum(slowdowns) / len(slowdowns)) if slowdowns
        else 0.0,
        mis_acted=BinomialStat(len(wrong_acted), len(wrong)),
        worsened=BinomialStat(
            sum(m.mitigated_time > m.failed_time for m in wrong_acted),
            len(wrong_acted)),
        penalty_mean=(sum(penalties) / len(penalties)) if penalties
        else 0.0,
    )


def by_mitigation(outcomes: list[ScenarioOutcome]) \
        -> dict[tuple[str, str], MitigationStat]:
    """Per-(detector, policy) recovered-throughput table, in request
    order — the detect → mitigate analogue of :func:`by_detector`."""
    return {pair: mitigation_stats(outcomes, *pair)
            for pair in mitigations_in(outcomes)}


@dataclasses.dataclass(frozen=True)
class TruthKindMetrics:
    """Per-failure statistics for the injected failures of one truth kind
    (the ``by_truth_kind`` split of a mixed-kind campaign)."""
    kind: str                  # 'core' | 'link' | 'router'
    n_failures: int            # injected failures of this kind (trials)
    ranked: BinomialStat       # fraction of them ranked at all
    recall: tuple[tuple[int, BinomialStat], ...]   # per-failure recall@k
    mean_rank: float | None    # mean 1-based rank over the ranked subset

    def recall_at(self, k: int) -> float:
        return _rate_at(self.recall, k)


def by_truth_kind(outcomes: list[ScenarioOutcome],
                  ks: tuple[int, ...] = (1, 3, 5),
                  detector: str | None = None) \
        -> dict[str, TruthKindMetrics]:
    """Split per-failure recall@k and ranks by the *truth's* kind.

    Every injected failure of every positive scenario is one trial,
    bucketed by its own kind (``effective_truth_kinds``) — so a mixed-kind
    scenario contributes to several buckets at once, and the table answers
    "which root-cause kinds does this detector localise well inside
    heterogeneous failure populations?".  Buckets appear in canonical
    ('core', 'link', 'router') order first, then any others in
    first-occurrence order.
    """
    ranks: dict[str, list[int | None]] = {}
    for o in outcomes:
        if not o.positive:
            continue
        r = o.result_for(detector).truth_ranks
        for kind, rank in zip(o.effective_truth_kinds, r):
            ranks.setdefault(kind, []).append(rank)
    order = [k for k in ("core", "link", "router") if k in ranks]
    order += [k for k in ranks if k not in order]
    out: dict[str, TruthKindMetrics] = {}
    for kind in order:
        rs = ranks[kind]
        ranked = [r for r in rs if r is not None]
        out[kind] = TruthKindMetrics(
            kind=kind,
            n_failures=len(rs),
            ranked=BinomialStat(len(ranked), len(rs)),
            recall=tuple(
                (k, BinomialStat(sum(r <= k for r in ranked), len(rs)))
                for k in ks),
            mean_rank=(sum(ranked) / len(ranked)) if ranked else None,
        )
    return out


@dataclasses.dataclass(frozen=True)
class SeverityPoint:
    """One severity slice of a campaign: accuracy / recall over the
    positive scenarios injected at exactly this severity.  ``fpr`` is the
    campaign's negative-sample rate (negatives collapse the severity axis,
    so the same reference stat is attached to every point)."""
    severity: float | tuple[float, ...]   # tuple for per-failure mixes
    n_scenarios: int
    accuracy: BinomialStat          # any-match over this slice's positives
    fpr: BinomialStat               # campaign negatives (shared reference)
    recall: tuple[tuple[int, BinomialStat], ...]

    def recall_at(self, k: int) -> float:
        return _rate_at(self.recall, k)


def severity_curve(outcomes: list[ScenarioOutcome],
                   ks: tuple[int, ...] = (1, 3, 5),
                   detector: str | None = None) \
        -> tuple[SeverityPoint, ...]:
    """Accuracy / FPR / recall@k as a function of injected severity, in
    ascending severity order — the near-threshold sweep readout.  Each
    distinct positive severity becomes one :class:`SeverityPoint`; Wilson
    intervals come with every stat, so sparse sweep points report honest
    uncertainty."""
    neg = [o for o in outcomes if not o.positive]
    fpr = BinomialStat(sum(o.result_for(detector).flagged for o in neg),
                       len(neg))
    by_sev: dict[float | tuple, list[ScenarioOutcome]] = {}
    for o in outcomes:
        if o.positive:
            key = (tuple(float(s) for s in o.severity)
                   if isinstance(o.severity, tuple) else float(o.severity))
            by_sev.setdefault(key, []).append(o)
    points = []
    # scalar severities first (ascending), then per-failure severity
    # tuples (lexicographic)
    for sev in sorted(by_sev, key=lambda s: (isinstance(s, tuple),
                                             s if isinstance(s, tuple)
                                             else (s,))):
        outs = by_sev[sev]
        acc = BinomialStat(
            sum(o.result_for(detector).matched for o in outs), len(outs))
        hits = {k: 0 for k in ks}
        trials = 0
        for o in outs:
            for r in o.result_for(detector).truth_ranks:
                trials += 1
                for k in ks:
                    hits[k] += int(r is not None and r <= k)
        points.append(SeverityPoint(
            severity=sev, n_scenarios=len(outs), accuracy=acc, fpr=fpr,
            recall=tuple((k, BinomialStat(hits[k], trials)) for k in ks)))
    return tuple(points)


def by_topology(outcomes: list[ScenarioOutcome],
                ks: tuple[int, ...] = (1, 3, 5),
                detector: str | None = None) \
        -> dict[str, CampaignMetrics]:
    """Campaign metrics split per deployment fabric, keyed by the
    canonical topology spec (``'mesh:4x4'``, ``'torus:8x8'``,
    ``'het:4x4:fast2slow1'``) in first-occurrence order — the paper's
    cross-architecture readout.  Each fabric's FPR uses that fabric's
    own negative scenarios."""
    groups: dict[str, list[ScenarioOutcome]] = {}
    for o in outcomes:
        groups.setdefault(o.topology_label(), []).append(o)
    return {t: aggregate(v, ks=ks, detector=detector)
            for t, v in groups.items()}


def severity_curve_by_mesh(outcomes: list[ScenarioOutcome],
                           ks: tuple[int, ...] = (1, 3, 5),
                           detector: str | None = None) \
        -> dict[tuple[int, int], tuple[SeverityPoint, ...]]:
    """:func:`severity_curve` split per mesh size, keyed ``(w, h)`` in
    first-occurrence order — near-threshold behaviour per topology scale
    instead of pooled over every mesh.  Each mesh's FPR reference uses
    that mesh's own negatives."""
    groups: dict[tuple[int, int], list[ScenarioOutcome]] = {}
    for o in outcomes:
        groups.setdefault((o.mesh_w, o.mesh_h), []).append(o)
    return {m: severity_curve(v, ks=ks, detector=detector)
            for m, v in groups.items()}


def wall_time_stats(outcomes: list[ScenarioOutcome]) \
        -> dict[str, WallTimeStat]:
    """Wall-time telemetry per detector (analyse time), plus the
    ``'simulate'`` key for trace generation.  Telemetry only: these values
    vary run-to-run and never participate in outcome equality."""
    out: dict[str, WallTimeStat] = {}
    sims = [o.sim_wall_time for o in outcomes]
    if sims:
        out["simulate"] = WallTimeStat(sum(sims) / len(sims), _p95(sims),
                                       sum(sims), len(sims))
    for name in detectors_in(outcomes):
        xs = [o.result_for(name).wall_time for o in outcomes]
        out[name] = WallTimeStat(sum(xs) / len(xs), _p95(xs), sum(xs),
                                 len(xs))
    return out


def baseline_stats(outcomes: list[ScenarioOutcome]) \
        -> dict[str, tuple[BinomialStat, BinomialStat]]:
    """Deprecated: per-detector (accuracy, fpr) over the non-primary
    detectors.  Use :func:`by_detector`, which covers every detector and
    the full metric set."""
    warnings.warn("baseline_stats is deprecated; use by_detector()",
                  DeprecationWarning, stacklevel=2)
    return {name: (m.accuracy, m.fpr)
            for name, m in by_detector(outcomes).items()
            if outcomes and name != outcomes[0].primary.detector}
