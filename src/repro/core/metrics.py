"""Campaign metric aggregation (paper §IV: Table III, Figs 11/12/16/17).

Pure, deterministic reductions over per-scenario outcomes.  The campaign
runner (``campaign.py``) produces one :class:`ScenarioOutcome` per injected
(or failure-free) scenario; this module turns a list of outcomes into the
paper-style aggregates.

A scenario may carry **several simultaneous injected failures** (the grid's
``n_failures`` axis): ground truth is therefore a *tuple* of truths
(``truth_locations`` / ``truth_t0s`` / ``truth_durations``, all empty for
negatives), each with its own 1-based rank in the verdict's ranking
(``truth_ranks``; ``None`` when unranked).  The aggregates are:

* **accuracy (any-match)** — fraction of *positive* scenarios whose top-1
  verdict names any of the injected root causes (router failures accept any
  link of the slowed router, since the detector localises at link
  granularity),
* **FPR** — fraction of *negative* (failure-free) scenarios that were
  flagged,
* **top-k localisation rate** — fraction of positives with *some* ground
  truth within the first k entries of the ranking (monotone in k),
* **recall@k** — fraction of *individual injected failures* (over all
  positives) ranked within the top k; for single-failure grids this
  coincides with top-k,
* **compression ratio** and **probe overhead** means.  Probe overhead is
  a per-deployment quantity; the headline mean weights each deployment by
  the number of scenarios it served (``mean_probe_overhead``), with the
  unweighted per-deployment mean kept alongside
  (``mean_probe_overhead_unweighted``).

Binomial rates carry Wilson score confidence intervals so small grid cells
report honest uncertainty.  Everything here is plain float arithmetic in a
fixed order: identical outcome lists produce bit-identical metrics.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ScenarioOutcome:
    """Result of one campaign scenario (the exchange record between the
    runner and the aggregators).  Picklable: plain scalars and tuples only,
    so outcomes cross process boundaries under ``executor='process'``."""
    scenario_id: int
    workload: str
    mesh_w: int
    mesh_h: int
    kind: str                  # 'core' | 'link' | 'router' | 'none'
    severity: float            # injected slowdown (0.0 for 'none')
    n_failures: int            # simultaneous injected failures (0 = 'none')
    rep: int                   # replicate index within the grid cell
    sim_seed: int              # simulator seed actually used
    # ground truth (empty tuples for negative samples), index-aligned
    truth_locations: tuple[int, ...]
    truth_t0s: tuple[float, ...]
    truth_durations: tuple[float, ...]
    # verdict
    flagged: bool
    pred_kind: str | None
    pred_location: int | None
    score: float
    matched: bool              # top-1 matches any truth (router-aware)
    truth_rank: int | None     # best 1-based rank over truths, or None
    # accounting
    compression_ratio: float
    total_time: float
    probe_overhead: float          # of the deployment that ran the scenario
    # per-failure rank (int | None), aligned with truth_locations; sits
    # after the required fields only because it carries a default
    truth_ranks: tuple = ()
    baseline_results: tuple = ()   # ((name, flagged, matched), ...)

    @property
    def positive(self) -> bool:
        return self.kind != "none"

    # -- single-failure convenience views (first truth or None) ------------
    @property
    def truth_location(self) -> int | None:
        return self.truth_locations[0] if self.truth_locations else None

    @property
    def t0(self) -> float | None:
        return self.truth_t0s[0] if self.truth_t0s else None

    @property
    def duration(self) -> float | None:
        return self.truth_durations[0] if self.truth_durations else None

    def cell(self) -> tuple:
        return (self.workload, self.mesh_w, self.mesh_h, self.kind,
                self.severity, self.n_failures)

    def deploy_key(self) -> tuple:
        return (self.workload, self.mesh_w, self.mesh_h)


@dataclasses.dataclass(frozen=True)
class BinomialStat:
    """k successes out of n trials with a Wilson score interval."""
    successes: int
    trials: int
    z: float = 1.96

    @property
    def rate(self) -> float:
        return self.successes / self.trials if self.trials else 0.0

    @property
    def interval(self) -> tuple[float, float]:
        return wilson_interval(self.successes, self.trials, self.z)

    def pct(self) -> float:
        return 100.0 * self.rate


def wilson_interval(k: int, n: int, z: float = 1.96) -> tuple[float, float]:
    """Wilson score interval for a binomial proportion (exact at n=0)."""
    if n == 0:
        return (0.0, 1.0)
    p = k / n
    denom = 1.0 + z * z / n
    centre = (p + z * z / (2 * n)) / denom
    half = (z / denom) * math.sqrt(p * (1 - p) / n + z * z / (4 * n * n))
    return (max(0.0, centre - half), min(1.0, centre + half))


@dataclasses.dataclass(frozen=True)
class CampaignMetrics:
    """Aggregate metrics over a set of scenario outcomes."""
    n_scenarios: int
    accuracy: BinomialStat          # any-match, over positives
    fpr: BinomialStat               # over negatives
    topk: tuple[tuple[int, BinomialStat], ...]   # ((k, stat), ...)
    recall: tuple[tuple[int, BinomialStat], ...]  # per-failure recall@k
    mean_compression: float
    mean_probe_overhead: float      # weighted by per-deployment scenarios
    mean_probe_overhead_unweighted: float   # plain mean over deployments

    def topk_rate(self, k: int) -> float:
        for kk, stat in self.topk:
            if kk == k:
                return stat.rate
        raise KeyError(k)

    def recall_at(self, k: int) -> float:
        for kk, stat in self.recall:
            if kk == k:
                return stat.rate
        raise KeyError(k)


def topk_stat(outcomes: list[ScenarioOutcome], k: int) -> BinomialStat:
    """Scenario-level: some truth ranked within the top k."""
    pos = [o for o in outcomes if o.positive]
    hits = sum(1 for o in pos
               if o.truth_rank is not None and o.truth_rank <= k)
    return BinomialStat(hits, len(pos))


def deployment_overheads(outcomes: list[ScenarioOutcome]) \
        -> dict[tuple, float]:
    """Per-deployment probe overhead, keyed ``deploy_key()``, in
    first-occurrence order.  The single reduction shared by ``aggregate``
    and ``CampaignResult.probe_overheads``."""
    dep_ov: dict[tuple, float] = {}
    for o in outcomes:
        dep_ov.setdefault(o.deploy_key(), o.probe_overhead)
    return dep_ov


def recall_stat(outcomes: list[ScenarioOutcome], k: int) -> BinomialStat:
    """Failure-level recall@k: each injected failure of each positive
    scenario is one trial; a hit is that failure's own truth ranked ≤ k."""
    hits = trials = 0
    for o in outcomes:
        if not o.positive:
            continue
        for r in o.truth_ranks:
            trials += 1
            hits += int(r is not None and r <= k)
    return BinomialStat(hits, trials)


def aggregate(outcomes: list[ScenarioOutcome],
              ks: tuple[int, ...] = (1, 3, 5)) -> CampaignMetrics:
    """Reduce outcomes to campaign metrics.

    Positives feed accuracy/top-k/recall; negatives feed FPR only — a grid
    cell with ``kind='none'`` therefore contributes zero accuracy trials.
    Probe overhead is aggregated both scenario-weighted (each outcome
    contributes its deployment's overhead) and unweighted over the distinct
    deployments that appear in ``outcomes``.
    """
    pos = [o for o in outcomes if o.positive]
    neg = [o for o in outcomes if not o.positive]
    acc = BinomialStat(sum(o.matched for o in pos), len(pos))
    fpr = BinomialStat(sum(o.flagged for o in neg), len(neg))
    comp = [o.compression_ratio for o in outcomes]
    mean_comp = sum(comp) / len(comp) if comp else 0.0
    ov = [o.probe_overhead for o in outcomes]
    mean_ov = sum(ov) / len(ov) if ov else 0.0
    dep_ov = deployment_overheads(outcomes)
    mean_ov_unw = (sum(dep_ov.values()) / len(dep_ov)) if dep_ov else 0.0
    return CampaignMetrics(
        n_scenarios=len(outcomes),
        accuracy=acc,
        fpr=fpr,
        topk=tuple((k, topk_stat(outcomes, k)) for k in ks),
        recall=tuple((k, recall_stat(outcomes, k)) for k in ks),
        mean_compression=mean_comp,
        mean_probe_overhead=mean_ov,
        mean_probe_overhead_unweighted=mean_ov_unw,
    )


def by_cell(outcomes: list[ScenarioOutcome],
            ks: tuple[int, ...] = (1, 3, 5)) \
        -> dict[tuple, CampaignMetrics]:
    """Per-cell aggregation, keyed (workload, mesh_w, mesh_h, kind,
    severity, n_failures).  Cells appear in first-occurrence (enumeration)
    order."""
    cells: dict[tuple, list[ScenarioOutcome]] = {}
    for o in outcomes:
        cells.setdefault(o.cell(), []).append(o)
    return {c: aggregate(v, ks=ks) for c, v in cells.items()}


def baseline_stats(outcomes: list[ScenarioOutcome]) \
        -> dict[str, tuple[BinomialStat, BinomialStat]]:
    """Per-baseline (accuracy, fpr) over outcomes that carry baseline
    verdicts (campaign run with ``baselines=True``)."""
    acc: dict[str, list[int]] = {}
    fpr: dict[str, list[int]] = {}
    for o in outcomes:
        for name, flagged, matched in o.baseline_results:
            if o.positive:
                acc.setdefault(name, []).append(int(matched))
            else:
                fpr.setdefault(name, []).append(int(flagged))
    names = sorted(set(acc) | set(fpr))
    return {n: (BinomialStat(sum(acc.get(n, [])), len(acc.get(n, []))),
                BinomialStat(sum(fpr.get(n, [])), len(fpr.get(n, []))))
            for n in names}
