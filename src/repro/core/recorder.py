"""Recorder driver: simulator traces → probes → Fail-Slow Sketch patterns.

Separate sketches are kept for computation and communication traces (the
paper reports their storage separately, Figs 11/12).  Instruction expansion
is fed to the sketch as exact run-length runs (`insert_run`) for speed.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import probes as P
from .sketch import FailSlowSketch, Pattern, SketchParams
from .simulator import SimResult


@dataclasses.dataclass
class RecorderOutput:
    comp_patterns: list[Pattern]
    comm_patterns: list[Pattern]
    raw_comp_bytes: int
    raw_comm_bytes: int
    sketch_comp_bytes: int
    sketch_comm_bytes: int
    n_comp_records: int
    n_comm_records: int

    @property
    def raw_bytes(self) -> int:
        return self.raw_comp_bytes + self.raw_comm_bytes

    @property
    def sketch_bytes(self) -> int:
        return self.sketch_comp_bytes + self.sketch_comm_bytes

    @property
    def compression_ratio(self) -> float:
        return self.raw_bytes / max(self.sketch_bytes, 1)


def record(sim: SimResult, params: SketchParams,
           comm_params: SketchParams | None = None,
           instr_per_task: int = 64,
           packet_bytes: int = P.PACKET_BYTES,
           max_packets: int = 64,
           hop_latency: float = 50e-9) -> RecorderOutput:
    comm_params = comm_params or params

    comp_sketch = FailSlowSketch(params)
    comp = sim.comp
    n_comp = 0
    if len(comp["core"]):
        keys = P.comp_pattern_keys(comp)
        r = instr_per_task
        durs = (comp["t_end"] - comp["t_start"]) / r
        comp_sketch.insert_runs(keys, np.full(len(keys), r), durs,
                                comp["flops"] / r, comp["t_start"], durs)
        n_comp = len(keys) * r

    comm_sketch = FailSlowSketch(comm_params)
    comm = sim.comm
    n_comm = 0
    if len(comm["src"]):
        keys = P.comm_pattern_keys(comm)
        pk = np.clip(np.ceil(comm["bytes"] / packet_bytes).astype(np.int64),
                     1, max_packets)
        # per-packet duration uses the queue-free service time: the min over
        # a pattern's packets estimates link bandwidth, not congestion (the
        # detector's EM needs the former; backpressure is a symptom).  Each
        # packet pays the full per-hop router latency (store-and-forward),
        # while the serialisation time divides across packets.
        lat = comm["hops"] * hop_latency
        per = np.maximum(comm["service"] - lat, 0.0) / pk + lat
        wall = (comm["t_arrive"] - comm["t_depart"]) / pk
        comm_sketch.insert_runs(keys, pk, per, comm["bytes"] / pk,
                                comm["t_depart"], wall)
        n_comm = int(pk.sum())

    return RecorderOutput(
        comp_patterns=comp_sketch.patterns(),
        comm_patterns=comm_sketch.patterns(),
        raw_comp_bytes=n_comp * P.COMP_RECORD_BYTES,
        raw_comm_bytes=n_comm * P.COMM_RECORD_BYTES,
        sketch_comp_bytes=comp_sketch.compressed_bytes(),
        sketch_comm_bytes=comm_sketch.compressed_bytes(),
        n_comp_records=n_comp,
        n_comm_records=n_comm,
    )
