"""Recorder driver: simulator traces → probes → Fail-Slow Sketch patterns.

Separate sketches are kept for computation and communication traces (the
paper reports their storage separately, Figs 11/12).  Instruction expansion
is fed to the sketch as exact run-length runs (`insert_run`) for speed.

Two interchangeable sketch implementations (``record(..., impl=...)``):

* ``impl="ref"`` (default) — the per-run numpy oracle
  (:class:`~repro.core.sketch.FailSlowSketch.insert_run`), one Python
  call per run.  The ground truth, and the bit-stable historical path.
* ``impl="batched"`` — the on-device run-compressed JAX path
  (:func:`repro.kernels.sketch_update.ops.insert_runs`): one
  ``lax.scan`` over runs against the packed sketch state, with Stage-2
  FIFO evictions preserved in the drained-eviction stream — the
  deployable Algorithm-1 pipeline the paper's on-chip numbers describe.

Both paths produce the same merged (live + drained) pattern lists —
bit-identical keys / counts / arrival order and float statistics to f32
tolerance — and byte-identical compression accounting, so campaign
compression ratios are comparable across impls.

``record`` is the one-shot (post-hoc) driver; the always-on service in
:mod:`repro.core.streaming` carries sketch state across repeated
``observe(sim_chunk)`` calls and reuses the run builders here
(:func:`comp_runs` / :func:`comm_runs`), so a chunked stream feeds the
sketch the exact record sequence ``record`` would — streaming and
post-hoc outputs are bit-identical per impl by construction.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import probes as P
from .sketch import (STAGE2_SLOT_BYTES, FailSlowSketch, Pattern,
                     SketchParams, split_key)
from .simulator import SimResult

#: Valid ``record(..., impl=)`` spellings.
RECORDER_IMPLS = ("ref", "batched")


@dataclasses.dataclass
class RecorderOutput:
    comp_patterns: list[Pattern]
    comm_patterns: list[Pattern]
    raw_comp_bytes: int
    raw_comm_bytes: int
    sketch_comp_bytes: int
    sketch_comm_bytes: int
    n_comp_records: int
    n_comm_records: int
    # drained-eviction stream depth (Stage-2 FIFO victims written off-chip;
    # included in sketch_*_bytes at one STAGE2_SLOT_BYTES each)
    n_comp_drained: int = 0
    n_comm_drained: int = 0
    impl: str = "ref"

    @property
    def raw_bytes(self) -> int:
        return self.raw_comp_bytes + self.raw_comm_bytes

    @property
    def sketch_bytes(self) -> int:
        return self.sketch_comp_bytes + self.sketch_comm_bytes

    def onchip_bytes(self) -> int:
        """SRAM-resident bytes only: ``sketch_bytes`` minus the off-chip
        drained-pattern stream (each drained row costs exactly one
        Stage-2 slot).  This is the quantity the static memory model
        (:mod:`repro.analysis.memory_model`) predicts without running
        anything — the property tests assert exact agreement for both
        impls."""
        return (self.sketch_bytes
                - (self.n_comp_drained + self.n_comm_drained)
                * STAGE2_SLOT_BYTES)

    @property
    def compression_ratio(self) -> float:
        return self.raw_bytes / max(self.sketch_bytes, 1)


def comp_runs(comp, instr_per_task: int):
    """Computation trace rows → run-compressed sketch input
    ``(keys, reps, durs, vals, t0s, dts)``.  Each task expands to
    ``instr_per_task`` instruction records of equal duration; the run
    algebra (``insert_run``) makes that expansion exact without
    materialising it.  Shared by ``record`` and the streaming recorder so
    chunked observation feeds byte-identical runs."""
    keys = P.comp_pattern_keys(comp)
    r = instr_per_task
    durs = (comp["t_end"] - comp["t_start"]) / r
    return (keys, np.full(len(keys), r), durs, comp["flops"] / r,
            comp["t_start"], durs)


def comm_runs(comm, packet_bytes: int, max_packets: int,
              hop_latency: float):
    """Communication trace rows → per-packet run-compressed sketch input
    ``(keys, reps, durs, vals, t0s, dts)``.

    Per-packet duration uses the queue-free service time: the min over
    a pattern's packets estimates link bandwidth, not congestion (the
    detector's EM needs the former; backpressure is a symptom).  Each
    packet pays the full per-hop router latency (store-and-forward),
    while the serialisation time divides across packets."""
    keys = P.comm_pattern_keys(comm)
    pk = np.clip(np.ceil(comm["bytes"] / packet_bytes).astype(np.int64),
                 1, max_packets)
    lat = comm["hops"] * hop_latency
    per = np.maximum(comm["service"] - lat, 0.0) / pk + lat
    wall = (comm["t_arrive"] - comm["t_depart"]) / pk
    return keys, pk, per, comm["bytes"] / pk, comm["t_depart"], wall


def _sketch_runs_ref(params: SketchParams, keys, reps, durs, vals, t0s,
                     dts):
    """Per-run numpy oracle path."""
    sk = FailSlowSketch(params)
    sk.insert_runs(keys, reps, durs, vals, t0s, dts)
    return sk.patterns(), sk.compressed_bytes(), sk.n_evicted


def _sketch_runs_batched(params: SketchParams, keys, reps, durs, vals,
                         t0s, dts, key_tag: int):
    """On-device run-compressed path: one scan over runs, drained
    evictions preserved, keys rebuilt with the sketch-truncated tag bit
    restored (see :func:`repro.kernels.sketch_update.ops.patterns`)."""
    # lazy: keep the default ref path (and process-pool workers that only
    # use it) free of the jax import
    import jax.numpy as jnp

    from ..kernels.sketch_update import ops as sketch_ops

    lo, hi = split_key(np.asarray(keys, dtype=np.int64))
    state = sketch_ops.make_state(params)
    drain = sketch_ops.make_drain(len(keys))
    state, drain = sketch_ops.insert_runs(
        state, drain, jnp.asarray(lo), jnp.asarray(hi),
        jnp.asarray(np.asarray(reps, dtype=np.int32)),
        jnp.asarray(np.asarray(durs, dtype=np.float32)),
        jnp.asarray(np.asarray(vals, dtype=np.float32)),
        jnp.asarray(np.asarray(t0s, dtype=np.float32)),
        jnp.asarray(np.asarray(dts, dtype=np.float32)), params=params)
    pats = sketch_ops.patterns(state, drain, key_tag=key_tag)
    n_drained = int(np.asarray(drain["d_n"]))
    # byte-identical to FailSlowSketch.compressed_bytes(): on-chip state
    # plus one exact Stage-2 slot per drained pattern
    return (pats,
            params.total_bytes() + n_drained * params.stage2_slot_bytes(),
            n_drained)


def _sketch_runs(impl: str, params: SketchParams, keys, reps, durs, vals,
                 t0s, dts, key_tag: int):
    if impl == "batched":
        return _sketch_runs_batched(params, keys, reps, durs, vals, t0s,
                                    dts, key_tag)
    return _sketch_runs_ref(params, keys, reps, durs, vals, t0s, dts)


def record(sim: SimResult, params: SketchParams,
           comm_params: SketchParams | None = None,
           instr_per_task: int = 64,
           packet_bytes: int = P.PACKET_BYTES,
           max_packets: int = 64,
           hop_latency: float = 50e-9,
           impl: str = "ref") -> RecorderOutput:
    """Compress one simulated trace into comp/comm pattern lists.

    ``impl`` selects the sketch implementation (see the module
    docstring): ``"ref"`` runs the per-run numpy oracle on host;
    ``"batched"`` runs the vectorized on-device path (run-compressed
    ``lax.scan``, packed state, drained-eviction stream).  Pattern lists
    always merge live Stage-2 entries with FIFO-drained partials —
    analysis sees every promoted pattern regardless of eviction pressure
    — and ``sketch_*_bytes`` accounts the drained rows at one Stage-2
    slot each (on-chip state + the off-chip compressed stream), so the
    compression ratio is the deployable end-to-end figure on both paths.
    """
    if impl not in RECORDER_IMPLS:
        raise ValueError(f"unknown recorder impl {impl!r}; "
                         f"options: {RECORDER_IMPLS}")
    comm_params = comm_params or params

    comp = sim.comp
    n_comp = 0
    comp_patterns: list[Pattern] = []
    comp_bytes = params.total_bytes()
    n_comp_drained = 0
    if len(comp["core"]):
        runs = comp_runs(comp, instr_per_task)
        comp_patterns, comp_bytes, n_comp_drained = _sketch_runs(
            impl, params, *runs, P.COMP_KEY_TAG)
        n_comp = len(runs[0]) * instr_per_task

    comm = sim.comm
    n_comm = 0
    comm_patterns: list[Pattern] = []
    comm_bytes = comm_params.total_bytes()
    n_comm_drained = 0
    if len(comm["src"]):
        runs = comm_runs(comm, packet_bytes, max_packets, hop_latency)
        comm_patterns, comm_bytes, n_comm_drained = _sketch_runs(
            impl, comm_params, *runs, P.COMM_KEY_TAG)
        n_comm = int(runs[1].sum())

    return RecorderOutput(
        comp_patterns=comp_patterns,
        comm_patterns=comm_patterns,
        raw_comp_bytes=n_comp * P.COMP_RECORD_BYTES,
        raw_comm_bytes=n_comm * P.COMM_RECORD_BYTES,
        sketch_comp_bytes=comp_bytes,
        sketch_comm_bytes=comm_bytes,
        n_comp_records=n_comp,
        n_comm_records=n_comm,
        n_comp_drained=n_comp_drained,
        n_comm_drained=n_comm_drained,
        impl=impl,
    )
