"""Batched scenario-campaign runner — the evaluation substrate.

The paper's headline numbers (detection accuracy, FPR, compression ratio)
are *campaign* statistics: aggregates over many injected fail-slow scenarios
across workloads, failure kinds and mesh sizes.  This module turns the
single-scenario ``Sloth.detect`` into a reproducible grid evaluation.

Scenario-grid schema
--------------------
A :class:`CampaignGrid` is the cross product

    workload × mesh size × failure kind × severity × replicate

with ``kind ∈ {'core', 'link', 'router', 'none'}``.  ``'none'`` cells are
negative (failure-free) samples and collapse the severity axis — they are
enumerated once per replicate with ``severity = 0.0``.  Every scenario is
fully determined by ``(campaign_seed, workload, mesh, kind, severity,
rep)``: locations, onset time, duration and the simulator seed are drawn
from a private ``numpy`` generator keyed on exactly that tuple
(``np.random.default_rng([...])``), so there is **no global RNG state** and
the same grid always materialises bit-identical scenarios, regardless of
worker count or execution order.

Link/router placements are restricted to resources the healthy run actually
exercises (the paper: "failures occurring on unused resources are
excluded"), using the deployment's cached healthy simulation.

Metric definitions
------------------
See ``metrics.py``: accuracy = matched-top-1 rate over positives (router
truths accept any link of the slowed router, since localisation is at link
granularity); FPR = flagged rate over negatives; top-k = truth within the
first k ranking entries; compression ratio and probe overhead are averaged.
Binomial rates carry Wilson intervals.

Performance
-----------
``(workload, mesh, config)`` deployments — mapped graph, probe plan,
healthy simulation, probe-overhead calibration, optional baseline
detectors — are built once and cached (:class:`DeploymentCache`), then
shared read-only by all scenarios of the grid.  Independent scenarios are
dispatched through a thread pool (``workers=``); results are collected by
scenario index so ordering and aggregates are reproducible.
"""

from __future__ import annotations

import dataclasses
import os
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from . import baselines as B
from .failures import FailSlow
from .graph import build_workload
from .metrics import (CampaignMetrics, ScenarioOutcome, aggregate, by_cell)
from .routing import Mesh2D
from .simulator import SimResult, simulate
from .sloth import Sloth, SlothConfig, Verdict

KINDS = ("core", "link", "router", "none")


# ---------------------------------------------------------------------------
# grid + scenarios
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CampaignGrid:
    """Declarative scenario grid (see module docstring for the schema)."""
    workloads: tuple[str, ...] = ("darknet19",)
    meshes: tuple[int, ...] = (4,)          # square mesh widths
    kinds: tuple[str, ...] = KINDS
    severities: tuple[float, ...] = (10.0,)
    reps: int = 1                            # replicates per grid cell
    campaign_seed: int = 0
    max_t0_frac: float = 0.5                 # onset within healthy runtime
    min_dur_frac: float = 0.4                # duration ⊆ healthy runtime

    def __post_init__(self):
        bad = set(self.kinds) - set(KINDS)
        if bad:
            raise ValueError(f"unknown failure kinds: {sorted(bad)}")
        if self.reps < 1:
            raise ValueError("reps must be >= 1")

    def n_scenarios(self) -> int:
        per_deploy = sum(self.reps * (len(self.severities)
                                      if k != "none" else 1)
                         for k in self.kinds)
        return len(self.workloads) * len(self.meshes) * per_deploy


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One fully-enumerated grid point (location not yet materialised —
    that needs the deployment's used-resource sets)."""
    scenario_id: int
    workload: str
    mesh_w: int
    mesh_h: int
    kind: str
    severity: float
    rep: int


def enumerate_scenarios(grid: CampaignGrid) -> list[Scenario]:
    """Fixed nested-loop enumeration; scenario_id is the stable index."""
    out: list[Scenario] = []
    for wl in grid.workloads:
        for w in grid.meshes:
            for kind in grid.kinds:
                sevs = (0.0,) if kind == "none" else grid.severities
                for sev in sevs:
                    for rep in range(grid.reps):
                        out.append(Scenario(len(out), wl, w, w, kind,
                                            sev, rep))
    return out


def _scenario_rng(grid: CampaignGrid, s: Scenario) -> np.random.Generator:
    """Private per-scenario stream: keyed on the scenario coordinates, not
    on enumeration order, so sub-grids reproduce the full grid's draws."""
    wl_key = int.from_bytes(s.workload.encode()[:8].ljust(8, b"\0"), "big")
    return np.random.default_rng(
        [grid.campaign_seed, wl_key, s.mesh_w, s.mesh_h,
         KINDS.index(s.kind), int(s.severity * 1000), s.rep])


# ---------------------------------------------------------------------------
# deployment cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Deployment:
    """Shared, read-only per-(workload, mesh) artifacts."""
    sloth: Sloth
    healthy: SimResult
    used_links: tuple[int, ...]
    used_routers: tuple[int, ...]  # routers with ≥1 used incident link
    probe_overhead: float          # (t_probed / t_unprobed - 1)
    detectors: tuple = ()          # baseline detectors (optional)


class DeploymentCache:
    """(workload, mesh, config) → :class:`Deployment`, built once.

    Construction is the expensive part of the grid (graph build, mapping,
    probe planning, healthy calibration run); caching it means adding
    scenarios to a campaign costs one simulate+analyse each.
    """

    HEALTHY_SEED = 999

    def __init__(self):
        self._cache: dict[tuple, Deployment] = {}

    def get(self, workload: str, mesh_w: int, mesh_h: int,
            cfg: SlothConfig | None = None,
            baselines: bool = False) -> Deployment:
        key = (workload, mesh_w, mesh_h, repr(cfg), baselines)
        dep = self._cache.get(key)
        if dep is None:
            sloth = Sloth(build_workload(workload),
                          Mesh2D(mesh_w, mesh_h), cfg=cfg)
            healthy = sloth.run(None, seed=self.HEALTHY_SEED)
            used = set()
            for s, d in zip(healthy.comm["src"], healthy.comm["dst"]):
                if s != d:
                    used.update(sloth.mesh.route(int(s), int(d)))
            import dataclasses as dc
            probed_cfg = dc.replace(sloth.sim_cfg, seed=self.HEALTHY_SEED)
            t_none = simulate(sloth.mapped, probed_cfg,
                              probes=None).total_time
            t_full = simulate(sloth.mapped, probed_cfg,
                              probes=sloth.plan.sim_plan).total_time
            dets = tuple(cls(sloth.mesh, healthy)
                         for cls in B.ALL_BASELINES) if baselines else ()
            routers = {c for lid in used for c in sloth.mesh.links[lid]}
            dep = Deployment(sloth=sloth, healthy=healthy,
                             used_links=tuple(sorted(used)),
                             used_routers=tuple(sorted(routers)),
                             probe_overhead=t_full / t_none - 1.0,
                             detectors=dets)
            self._cache[key] = dep
        return dep


_DEFAULT_CACHE = DeploymentCache()


# ---------------------------------------------------------------------------
# materialisation + single-scenario execution
# ---------------------------------------------------------------------------

def materialise(grid: CampaignGrid, s: Scenario, dep: Deployment) \
        -> tuple[FailSlow | None, int]:
    """Derive (failure, sim_seed) for one scenario — deterministic in the
    scenario coordinates and the deployment's healthy run."""
    rng = _scenario_rng(grid, s)
    sim_seed = int(rng.integers(1 << 31))
    if s.kind == "none":
        return None, sim_seed
    mesh = dep.sloth.mesh
    if s.kind == "core":
        loc = int(rng.integers(mesh.n_cores))
    else:            # link/router — only resources carrying traffic
        pool = dep.used_links if s.kind == "link" else dep.used_routers
        if not pool:
            raise ValueError(
                f"no used {s.kind}s on {s.workload}@"
                f"{s.mesh_w}x{s.mesh_h}: the healthy run has no "
                f"cross-core traffic, so a {s.kind} fail-slow cannot "
                f"affect execution — drop this kind from the grid")
        loc = int(pool[int(rng.integers(len(pool)))])
    total = dep.healthy.total_time
    t0 = float(rng.uniform(0.0, grid.max_t0_frac * total))
    dur = float(rng.uniform(grid.min_dur_frac, 1.0) * total)
    return FailSlow(s.kind, loc, t0, dur, s.severity), sim_seed


def truth_candidates(failure: FailSlow, mesh: Mesh2D) \
        -> set[tuple[str, int]]:
    """Acceptable (kind, location) verdicts for an injected failure.  The
    detector localises at core/link granularity, so a router failure is
    correctly localised by naming any link of the slowed router."""
    if failure.kind == "router":
        return {("link", lid)
                for lid in mesh.links_of_router(failure.location)}
    return {(failure.kind, failure.location)}


def _judge(verdict: Verdict, failure: FailSlow | None, mesh: Mesh2D) \
        -> tuple[bool, int | None]:
    """(matched, truth_rank) for a verdict against ground truth."""
    if failure is None:
        return (not verdict.flagged), None
    cands = truth_candidates(failure, mesh)
    rank = None
    for i, (k, l, _) in enumerate(verdict.ranking):
        if (k, l) in cands:
            rank = i + 1
            break
    matched = bool(verdict.flagged
                   and (verdict.kind, verdict.location) in cands)
    return matched, rank


def run_scenario(grid: CampaignGrid, s: Scenario, dep: Deployment) \
        -> ScenarioOutcome:
    """Execute one scenario end-to-end against a cached deployment."""
    failure, sim_seed = materialise(grid, s, dep)
    sim = dep.sloth.run([failure] if failure else None, seed=sim_seed)
    v = dep.sloth.analyse(sim)
    matched, rank = _judge(v, failure, dep.sloth.mesh)
    cands = (truth_candidates(failure, dep.sloth.mesh)
             if failure is not None else None)
    bl = []
    for det in dep.detectors:
        bv = det.detect(sim)
        # judge baselines with the same router-aware rule as SLOTH
        # (BaselineVerdict.matches would score every router scenario as
        # a miss, since no detector emits kind='router')
        if failure is None:
            ok = not bv.flagged
        else:
            ok = bool(bv.flagged and (bv.kind, bv.location) in cands)
        bl.append((det.name, bool(bv.flagged), ok))
    return ScenarioOutcome(
        scenario_id=s.scenario_id, workload=s.workload,
        mesh_w=s.mesh_w, mesh_h=s.mesh_h, kind=s.kind,
        severity=s.severity, rep=s.rep, sim_seed=sim_seed,
        truth_location=failure.location if failure else None,
        t0=failure.t0 if failure else None,
        duration=failure.duration if failure else None,
        flagged=bool(v.flagged), pred_kind=v.kind,
        pred_location=v.location, score=float(v.score),
        matched=matched, truth_rank=rank,
        compression_ratio=float(v.recorder.compression_ratio),
        total_time=float(v.total_time),
        baseline_results=tuple(bl),
    )


# ---------------------------------------------------------------------------
# campaign driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CampaignResult:
    grid: CampaignGrid
    outcomes: list[ScenarioOutcome]
    metrics: CampaignMetrics
    cells: dict[tuple, CampaignMetrics]
    probe_overheads: dict[tuple, float]    # (workload, w, h) → overhead

    def summary(self) -> str:
        m = self.metrics
        lines = [
            f"scenarios: {m.n_scenarios}",
            f"accuracy:  {m.accuracy.pct():.2f}% "
            f"({m.accuracy.successes}/{m.accuracy.trials}, "
            f"CI [{m.accuracy.interval[0]*100:.1f}, "
            f"{m.accuracy.interval[1]*100:.1f}])",
            f"FPR:       {m.fpr.pct():.2f}% "
            f"({m.fpr.successes}/{m.fpr.trials}, "
            f"CI [{m.fpr.interval[0]*100:.1f}, "
            f"{m.fpr.interval[1]*100:.1f}])",
        ] + [
            f"top-{k}:     {stat.pct():.2f}%" for k, stat in m.topk
        ] + [
            f"compression: {m.mean_compression:.1f}x",
            f"probe overhead: {m.mean_probe_overhead*100:.3f}%",
        ]
        return "\n".join(lines)


def run_campaign(grid: CampaignGrid, *, workers: int | None = None,
                 cfg: SlothConfig | None = None, baselines: bool = False,
                 cache: DeploymentCache | None = None,
                 progress=None) -> CampaignResult:
    """Run every scenario of ``grid`` and aggregate paper-style metrics.

    ``workers`` — thread-pool width (``None`` → cpu count, ``0``/``1`` →
    serial).  Results are identical for any worker count.  ``baselines``
    additionally runs the five baseline detectors on each scenario's trace.
    ``cache`` — share deployments across campaigns (defaults to a
    process-wide cache).
    """
    cache = cache if cache is not None else _DEFAULT_CACHE
    scenarios = enumerate_scenarios(grid)

    # Build deployments serially first: construction is the expensive,
    # cache-mutating step; scenario execution then only reads shared state.
    deps: dict[tuple, Deployment] = {}
    for s in scenarios:
        k = (s.workload, s.mesh_w, s.mesh_h)
        if k not in deps:
            deps[k] = cache.get(s.workload, s.mesh_w, s.mesh_h,
                                cfg=cfg, baselines=baselines)

    def run_one(s: Scenario) -> ScenarioOutcome:
        o = run_scenario(grid, s, deps[(s.workload, s.mesh_w, s.mesh_h)])
        if progress is not None:
            progress(o)
        return o

    workers = (os.cpu_count() or 1) if workers is None else workers
    if workers > 1 and len(scenarios) > 1:
        with ThreadPoolExecutor(max_workers=workers) as pool:
            outcomes = list(pool.map(run_one, scenarios))
    else:
        outcomes = [run_one(s) for s in scenarios]

    overheads = {k: d.probe_overhead for k, d in deps.items()}
    mean_ov = sum(overheads.values()) / len(overheads) if overheads else 0.0
    return CampaignResult(
        grid=grid, outcomes=outcomes,
        metrics=aggregate(outcomes, probe_overhead=mean_ov),
        cells=by_cell(outcomes),
        probe_overheads=overheads,
    )
