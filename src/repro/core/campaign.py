"""Batched scenario-campaign runner — the evaluation substrate.

The paper's headline numbers (detection accuracy, FPR, compression ratio)
are *campaign* statistics: aggregates over many injected fail-slow scenarios
across workloads, failure kinds and mesh sizes.  This module turns
single-scenario detection into a reproducible grid evaluation, and — via
the unified detector API (:mod:`repro.core.detectors`) — into the paper's
SLOTH-vs-baselines comparison: ``run_campaign(grid, detectors=("sloth",
"thres", "mscope", "iaso", "perseus", "adr"))`` analyses every scenario's
trace with every requested detector under one judging rule and returns
per-detector accuracy / FPR / top-k / recall@k cells.

Scenario-grid schema
--------------------
A :class:`CampaignGrid` is the cross product

    workload × mesh × failure kind × severity × n_failures × replicate

with ``kind ∈ {'core', 'link', 'router', 'none', 'mixed'}`` or an explicit
per-failure kind tuple.  Mesh entries may be a square width ``W``, a
``(W, H)`` pair or a ``'WxH'`` string — they are normalised to ``(W, H)``
tuples at grid construction, so rectangular meshes (``12×8``, ``16×8``, …)
flow through scenario keys, cache keys and metric cells unchanged.  A
mesh entry may also be a full fabric spec ``'name:WxH[:variant]'``
(``'torus:8x8'``, ``'systolic:8x8'``, ``'het:4x4:fast2slow1'``) resolved
through the topology registry (:mod:`repro.core.routing`); such entries
normalise to a canonical spec string, the topology name rides through
``Scenario.topology`` / cache keys / metric cells, and
``CampaignResult.by_topology()`` splits results per fabric.  Plain
``W``/``'WxH'`` entries stay the default mesh and stay bit-identical to
the pre-topology pipeline (the RNG key only grows a topology word on
non-mesh fabrics).
``n_failures`` entries are k ≥ 1 *simultaneous* failures at k distinct
locations (ground truth becomes a set; see ``metrics.py`` for any-match
accuracy and per-failure recall@k).

Heterogeneous (mixed-kind) scenarios come in two grid spellings:

* ``kind='mixed'`` — each of the scenario's k failures samples its own
  kind by drawing without replacement from the *union population* of the
  deployment's resources (every core, every used link, every used router),
  so a failure's kind probability is proportional to that kind's live
  resource count — the heterogeneous-fleet model of the paper's §IV-A
  7:3 core:link populations, extended to routers.
* an explicit kind tuple of **two or more** components, e.g.
  ``('core', 'link')`` (equivalently the string ``'core+link'``) —
  exactly those kinds, one failure each, at distinct locations per kind.
  A tuple entry pins the scenario's failure count to its length, so it
  collapses the ``n_failures`` axis the same way ``'none'`` collapses
  severity.  A single-kind tuple is rejected as ambiguous: spell it as
  the plain kind (swept by the axis) or pin via ``n_failures=(1,)``.

``metrics.by_truth_kind`` then splits per-failure recall@k and ranks by
each *truth's* kind, so mixed campaigns report per-kind localisation
quality inside heterogeneous scenarios.  ``'none'`` cells are negative
(failure-free) samples and collapse both the severity and n_failures axes
— they are enumerated once per replicate with ``severity = 0.0`` and
``n_failures = 0``.

``kind='mixed'`` additionally honours the grid's ``mixed_weights`` knob
(e.g. ``{'core': 7, 'link': 3}`` for the paper's 7:3 core:link split):
each kind's weight is split uniformly over its placeable pool, and kinds
absent from the mapping are never drawn.  The default ``None`` keeps the
uniform union-population draw bit-identically.

Severity is a first-class swept axis: ``severities`` entries may be plain
floats, a ``'linspace:LO:HI:N'`` string or a ``('linspace', lo, hi, n)``
tuple — linspace specs expand (via ``np.linspace``) at grid construction,
which makes near-detection-threshold sweeps one-line grid edits.  A
*nested* tuple of ≥2 numbers (``severities=((1.5, 10.0),)``) is a
**per-failure severity mix**: each of the scenario's failures gets its
own slowdown (the i-th severity to the i-th drawn site — for composite
kinds that is the canonicalised component order), and the mix pins the
scenario's failure count to the tuple length the same way composite kinds
do.  Per-failure severities are carried in
``ScenarioOutcome.truth_severities``.
``CampaignResult.severity_curve()`` returns the per-severity
accuracy / FPR / recall@k readout with Wilson CIs, and
``severity_curve_by_mesh()`` splits it per mesh size.

Every scenario is fully determined by ``(campaign_seed, workload, mesh,
kind, severity, n_failures, rep)``: locations, onset times, durations and
the simulator seed are drawn from a private ``numpy`` generator keyed on
exactly that tuple (``np.random.default_rng([...])``), so there is **no
global RNG state** and the same grid always materialises bit-identical
scenarios, regardless of worker count, executor or execution order.  The
severity enters the key through its IEEE-754 bit pattern
(``np.float64(severity).view(np.uint64)``), so severities arbitrarily
close together — exactly the near-threshold sweep case — still key
distinct streams (keying on ``int(severity * 1000)`` used to collide
severities closer than 1e-3 into identical location/onset/duration
draws).

Link/router placements are restricted to resources the healthy run actually
exercises (the paper: "failures occurring on unused resources are
excluded"), using the deployment's cached healthy simulation.

Detector model
--------------
``detectors=`` names registry entries (:func:`repro.core.detectors
.get_detector`); each deployment prepares one instance per name against
its healthy profiling run, the first name being the campaign's *primary*
detector (top-level ``metrics`` / ``cells``).  Every scenario is simulated
**once** and the one trace is analysed by all detectors, so the comparison
is on identical data by construction.  Per-detector analyse wall time and
per-scenario simulate wall time are recorded as telemetry (excluded from
outcome equality, surfaced by ``CampaignResult.summary()``).  The old
``baselines: bool`` flag survives as a deprecation shim that expands to
``detectors=DEFAULT_DETECTORS``.

The SL-Recorder implementation is likewise campaign-selectable: the
``cfg`` a campaign passes keys the deployment cache, so
``run_campaign(grid, cfg=SlothConfig(recorder_impl="batched"))`` measures
the on-device batched recorder (run-compressed scan + drained-eviction
stream) against the same scenarios the default per-run oracle sees.
Compression ratios, pattern key sets, counts and eviction structure are
bit-identical across impls; verdicts agree wherever detector scores are
not within float32 rounding of a flag threshold (the batched Stage-2
statistics are f32 vs the oracle's f64), which
``examples/campaign_sweep.py --recorder-impl both`` asserts on its
decisively-failing CI grid.

Streaming axis
--------------
``run_campaign(..., streaming=N)`` replays every scenario's trace
chunk-by-chunk through the always-on detection service
(:mod:`repro.core.streaming`) instead of one-shot post-hoc analysis:
each detector exposing ``stream_analyse`` observes ``N`` time-ordered
chunks, emitting one incremental verdict per window.  The final
streamed verdict is bit-equal to the post-hoc one on both recorder
impls (same record sequence through the same resident sketch), so the
judged accuracy/FPR/recall metrics are unchanged — what streaming adds
is **detection latency**: the simulated time from the earliest failure
onset to the first flagged window, aggregated by
``metrics.detection_latency_stats`` into the campaign's
``metrics.detection`` summary.  ``examples/campaign_sweep.py
--streaming`` runs the streaming-vs-post-hoc parity gate in CI.

Mitigation axis
---------------
``run_campaign(..., mitigation=('remap', 'none'))`` closes the detect →
mitigate loop: every detector's judged verdict is handed to every named
mitigation policy (:mod:`repro.mitigate` — registered like detectors),
the policy's plan is applied to the deployment (cores excluded from the
mapping, links detoured via ``DetourMesh``), and the mitigated deployment
is re-simulated over the remaining failure window with the scenario's own
simulator seed and probe plan.  Each (detector, policy) pair yields one
``MitigationOutcome`` per scenario; ``metrics.by_mitigation`` reduces
them to recovered-throughput statistics — the fraction of the
failure-induced gap recovered under correct verdicts, the post-mitigation
slowdown vs healthy, and the mis-mitigation penalty paid when a policy
acted on a wrong or false verdict (a sharp end-to-end measure of verdict
quality).  Combined with ``streaming=N``, mitigation engages at each
detector's first flagged window, so detection latency composes with
recovery; without streaming it models a post-hoc restart.  The ``none``
policy is the control: it never acts and its recovered throughput is
exactly zero.

Execution model
---------------
``run_campaign(..., workers=N, executor='thread'|'process')``:

* ``executor='thread'`` (default) — deployments are built serially into the
  shared :class:`DeploymentCache`, then scenarios fan out over a thread
  pool.  Fine for small grids; the pure-Python simulator holds the GIL, so
  threads mostly pipeline rather than parallelise.
* ``executor='process'`` — scenarios are dispatched to a
  ``ProcessPoolExecutor``.  Only the picklable ``(grid, scenario, config,
  detector names)`` coordinates cross the process boundary; each worker
  process lazily rebuilds the deployments (and prepared detectors) it
  needs into its own module-level :class:`DeploymentCache` (construction
  is deterministic, so a rebuilt deployment is identical to the parent's).
  Custom detectors must therefore be registered at import time of their
  defining module to be resolvable inside spawned workers.  A ``cache=``
  argument is not consulted on this path.  Outcomes are collected in
  scenario order and are **bit-identical** to serial/thread execution for
  any worker count.

``workers=None`` → cpu count; ``0``/``1`` or a single-scenario grid →
serial in-process execution for either executor.

Performance
-----------
``(workload, mesh, config, detectors)`` deployments — mapped graph, probe
plan, healthy simulation, probe-overhead calibration, prepared detector
instances — are built once per cache (:class:`DeploymentCache`) and shared
read-only by all scenarios of the grid.  The cache key normalises
``cfg=None`` to the default :class:`SlothConfig`, so explicit-default and
implicit-default callers share one deployment.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import multiprocessing
import os
import time
import warnings
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor

import numpy as np

from .detectors import (DEFAULT_DETECTORS, Detector, get_detector,
                        instantiate_detector)
from .failures import FailSlow, judge_verdict, truth_candidates
from .graph import build_workload
from .metrics import (CampaignMetrics, DetectorOutcome, MitigationOutcome,
                      MitigationStat, ScenarioOutcome, SeverityPoint,
                      TruthKindMetrics, aggregate, by_detector,
                      by_mitigation, by_topology, by_truth_kind,
                      deployment_overheads,
                      detector_cells, severity_curve, severity_curve_by_mesh,
                      wall_time_stats)
from .routing import build_topology, parse_topology_spec, topology_spec
from .simulator import SimResult, simulate, simulate_mitigated
from .sloth import Sloth, SlothConfig, SlothDetector
# submodule import (not the package) so a partially-initialised
# repro.mitigate package during circular-ish import chains still resolves
from ..mitigate.policy import (get_policy, instantiate_policy,
                               work_done_frac)

__all__ = [
    "KINDS", "MIXED", "FAILURE_KINDS", "EXECUTORS", "DEFAULT_DETECTORS",
    "CampaignGrid", "Scenario", "Deployment", "DeploymentCache",
    "CampaignResult", "enumerate_scenarios", "materialise", "run_scenario",
    "run_campaign", "truth_candidates",
]

KINDS = ("core", "link", "router", "none")
MIXED = "mixed"
EXECUTORS = ("thread", "process")

#: Kinds a concrete failure may take (everything except 'none').
FAILURE_KINDS = ("core", "link", "router")


def _wall_clock() -> float:
    """The campaign's one blessed wall-clock read, feeding the
    ``compare=False`` wall-time telemetry only (``wall_time_stats``) —
    never a scenario outcome, verdict, or RNG stream.  Centralised so
    the determinism lint (``repro.analysis.lints`` rule ``wallclock``)
    has exactly one allowlisted reader to audit."""
    return time.perf_counter()  # lint: allow-wallclock


def _normalise_kind(kind) -> str:
    """Normalise a grid kind entry to its canonical string form.

    Accepts the four base kinds, ``'mixed'``, an explicit kind tuple
    (``('core', 'link')``) or its ``'core+link'`` string spelling.
    Composite entries are canonicalised into ``FAILURE_KINDS`` order, so
    ``('link', 'core')`` and ``'core+link'`` name the same scenario cell
    (and the same RNG stream).
    """
    if isinstance(kind, (tuple, list)):
        parts = tuple(str(k).lower() for k in kind)
    elif isinstance(kind, str) and "+" in kind:
        parts = tuple(p.strip().lower() for p in kind.split("+"))
    else:
        k = str(kind).lower()
        if k not in KINDS and k != MIXED:
            raise ValueError(
                f"unknown failure kind {kind!r}; use one of "
                f"{KINDS + (MIXED,)}, a 'core+link' composite or a kind "
                f"tuple")
        return k
    bad = [p for p in parts if p not in FAILURE_KINDS]
    if bad or not parts:
        raise ValueError(
            f"bad composite kind {kind!r}: components must be drawn from "
            f"{FAILURE_KINDS}")
    if len(parts) == 1:
        # a 1-tuple cannot be distinguished from the plain kind once
        # normalised, so it could not honour the pin-to-length contract —
        # demand the unambiguous spelling instead
        raise ValueError(
            f"single-kind tuple {kind!r} is ambiguous: spell it as the "
            f"plain kind {parts[0]!r} (swept by the n_failures axis) or "
            f"pin one failure with n_failures=(1,)")
    return "+".join(sorted(parts, key=FAILURE_KINDS.index))


def _kind_parts(kind: str) -> tuple[str, ...]:
    """Per-failure kinds pinned by a composite entry ('' for the rest)."""
    return tuple(kind.split("+")) if "+" in kind else ()


def _mesh_dims(mesh) -> tuple[int, int]:
    """Normalise a mesh spec — ``12`` | ``(12, 8)`` | ``'12x8'`` — to
    ``(width, height)``."""
    if isinstance(mesh, str):
        parts = mesh.lower().split("x")
        if len(parts) == 1:
            parts = parts * 2
        if len(parts) != 2 or not all(p.strip().isdigit() for p in parts):
            raise ValueError(f"bad mesh spec {mesh!r}: use 'W' or 'WxH'")
        w, h = (int(p) for p in parts)
    elif isinstance(mesh, (int, np.integer)):
        w = h = int(mesh)
    else:
        try:
            if len(mesh) != 2:
                raise ValueError
            w, h = int(mesh[0]), int(mesh[1])
        except (TypeError, ValueError):
            raise ValueError(f"bad mesh spec {mesh!r}: use W, (W, H) "
                             f"or 'WxH'") from None
    if w < 1 or h < 1:
        raise ValueError(f"mesh dimensions must be >= 1, got {w}x{h}")
    return w, h


def _normalise_mesh(mesh):
    """Normalise one grid fabric entry.

    Plain mesh spellings — ``12`` | ``(12, 8)`` | ``'12x8'`` — keep their
    historical ``(width, height)`` tuple form (so existing grids compare,
    hash and RNG-key bit-identically); registry topology specs —
    ``'torus:8x8'``, ``'systolic:8x8'``, ``'het:4x4:fast2slow1'`` — are
    canonicalised to their spec string (see
    :func:`repro.core.routing.parse_topology_spec` for the grammar).
    """
    if isinstance(mesh, str) and ":" in mesh:
        topo, w, h = parse_topology_spec(mesh)
        return topology_spec(topo, w, h)
    return _mesh_dims(mesh)


def _per_failure_severities(e) -> tuple[float, ...]:
    """Validate one per-failure severity mix entry (a tuple/list of ≥2
    slowdown factors, e.g. ``(1.5, 10.0)`` for a mild first failure with a
    severe second one)."""
    if len(e) == 1:
        # a 1-tuple would be indistinguishable from the scalar severity it
        # contains once a scenario carries one failure — demand the
        # unambiguous spelling (mirrors the single-kind-tuple rule)
        raise ValueError(
            f"single-entry severity tuple {tuple(e)!r} is ambiguous: "
            f"spell it as the plain severity {e[0]!r}")
    try:
        tup = tuple(float(x) for x in e)
    except (TypeError, ValueError):
        raise ValueError(
            f"bad severity entry {e!r}: tuple entries must be "
            f"('linspace', lo, hi, n) or a per-failure severity mix of "
            f"numbers") from None
    for x in tup:
        if not x > 0.0:
            raise ValueError(
                f"severities must be positive slowdown factors, got {x} "
                f"in {tup!r}")
    return tup


def _expand_severities(entries) -> tuple:
    """Expand a severities spec to a flat tuple of cells.

    Entries may be plain numbers, ``'linspace:LO:HI:N'`` strings,
    ``('linspace', lo, hi, n)`` tuples, or **per-failure severity mixes**
    — a tuple of ≥2 numbers like ``(1.5, 10.0)`` assigning each of a
    scenario's failures its own slowdown (the mix pins the scenario's
    failure count to the tuple length; with composite kinds the
    severities align index-wise with the canonicalised kind components).
    A per-failure mix must be *nested* (``severities=((1.5, 10.0),)``) —
    a bare top-level tuple of numbers remains a list of scalar severity
    cells.  Linspace specs expand via ``np.linspace`` so near-threshold
    sweeps are declared, not typed out.  Exact duplicates (e.g. a plain
    entry also covered by a linspace) are dropped, keeping first
    occurrence: duplicate severity cells would share one RNG stream and
    double-count bit-identical outcomes in every metric.
    """
    if isinstance(entries, (str, int, float)):
        entries = (entries,)
    elif isinstance(entries, (tuple, list)) and entries \
            and entries[0] == "linspace":
        entries = (tuple(entries),)    # a bare spec, not a list of specs
    out: list[float | tuple[float, ...]] = []
    for e in entries:
        spec = None
        if isinstance(e, str) and e.startswith("linspace"):
            spec = e.split(":")[1:]
        elif isinstance(e, (tuple, list)):
            if e and e[0] == "linspace":
                spec = list(e[1:])
            else:
                out.append(_per_failure_severities(e))
                continue
        if spec is not None:
            try:
                lo, hi, n = float(spec[0]), float(spec[1]), int(spec[2])
            except (IndexError, ValueError):
                raise ValueError(
                    f"bad severity spec {e!r}: use 'linspace:LO:HI:N' or "
                    f"('linspace', lo, hi, n)") from None
            if n < 1:
                raise ValueError(f"bad severity spec {e!r}: N must be >= 1")
            out.extend(float(x) for x in np.linspace(lo, hi, n))
        else:
            out.append(float(e))
    for s in out:
        if not isinstance(s, tuple) and not s > 0.0:
            raise ValueError(
                f"severities must be positive slowdown factors, got {s}")
    return tuple(dict.fromkeys(out))


def _normalise_detectors(detectors, baselines) -> tuple[str, ...]:
    """Resolve the ``detectors=`` request (plus the deprecated
    ``baselines=`` flag) to a validated, deduplicated name tuple."""
    if isinstance(detectors, bool):
        # a legacy positional baselines flag landing on the detectors
        # parameter (pre-unified-API call sites) — honour the shim
        # instead of failing with "'bool' object is not iterable"
        detectors, baselines = ("sloth",), detectors
    if baselines is not None:
        warnings.warn(
            "baselines= is deprecated; pass detectors=('sloth', 'thres', "
            "...) — baselines=True maps to detectors=DEFAULT_DETECTORS",
            DeprecationWarning, stacklevel=3)
        if baselines:
            detectors = DEFAULT_DETECTORS
    if isinstance(detectors, str):
        detectors = (detectors,)
    names = tuple(dict.fromkeys(str(n).lower() for n in detectors))
    if not names:
        raise ValueError("detectors must name at least one detector")
    for n in names:
        get_detector(n)          # raises KeyError for unknown names
    return names


def _normalise_policies(mitigation) -> tuple[str, ...]:
    """Resolve the ``mitigation=`` request to a validated, deduplicated
    policy-name tuple (``None``/``False``/empty → no mitigation)."""
    if mitigation is None or mitigation is False:
        return ()
    if isinstance(mitigation, str):
        mitigation = (mitigation,)
    names = tuple(dict.fromkeys(str(n).lower() for n in mitigation))
    for n in names:
        get_policy(n)            # raises KeyError for unknown names
    return names


def _normalise_mixed_weights(mw):
    """Normalise a ``mixed_weights`` spec — a ``{kind: weight}`` mapping or
    ``((kind, weight), ...)`` pairs — to canonical ``FAILURE_KINDS``-ordered
    pairs (hashable and spelling-independent).  Kinds absent from the spec
    get weight 0, i.e. are never drawn."""
    if mw is None:
        return None
    items = mw.items() if isinstance(mw, dict) else tuple(mw)
    out: dict[str, float] = {}
    for kind, wgt in items:
        k = str(kind).lower()
        if k not in FAILURE_KINDS:
            raise ValueError(
                f"mixed_weights kind {kind!r} must be one of "
                f"{FAILURE_KINDS}")
        if k in out:
            raise ValueError(f"mixed_weights repeats kind {k!r}")
        w = float(wgt)
        if not (math.isfinite(w) and w >= 0.0):
            raise ValueError(
                f"mixed_weights[{k!r}] must be a finite weight >= 0, "
                f"got {wgt!r}")
        out[k] = w
    if not out or not any(w > 0.0 for w in out.values()):
        raise ValueError("mixed_weights needs at least one positive weight")
    return tuple((k, out[k]) for k in FAILURE_KINDS if k in out)


# ---------------------------------------------------------------------------
# grid + scenarios
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CampaignGrid:
    """Declarative scenario grid (see module docstring for the schema)."""
    workloads: tuple[str, ...] = ("darknet19",)
    meshes: tuple = (4,)                     # W | (W, H) | 'WxH' entries
    kinds: tuple[str, ...] = KINDS
    severities: tuple[float, ...] = (10.0,)
    n_failures: tuple[int, ...] = (1,)       # simultaneous failures axis
    reps: int = 1                            # replicates per grid cell
    campaign_seed: int = 0
    max_t0_frac: float = 0.5                 # onset within healthy runtime
    min_dur_frac: float = 0.4                # duration ⊆ healthy runtime
    # Non-uniform kind weights for 'mixed' draws: a {kind: weight} mapping
    # (or ((kind, weight), ...) pairs), e.g. {'core': 7, 'link': 3} for the
    # paper's §IV-A 7:3 core:link population.  A kind's weight is split
    # uniformly over its placeable resources; kinds absent from the spec
    # are never drawn.  ``None`` (default) keeps the historical uniform
    # union-population draw bit-identically.
    mixed_weights: tuple | dict | None = None

    def __post_init__(self):
        # dedupe after normalisation: alias spellings ('core+link' vs
        # ('link', 'core')) would otherwise enumerate bit-identical
        # scenarios twice on one RNG stream, double-counting every metric
        kinds = tuple(dict.fromkeys(_normalise_kind(k)
                                    for k in self.kinds))
        object.__setattr__(self, "kinds", kinds)
        if self.reps < 1:
            raise ValueError("reps must be >= 1")
        if not self.n_failures or any(int(k) < 1 for k in self.n_failures):
            raise ValueError("n_failures entries must be >= 1")
        object.__setattr__(self, "meshes",
                           tuple(_normalise_mesh(m) for m in self.meshes))
        object.__setattr__(self, "severities",
                           _expand_severities(self.severities))
        object.__setattr__(self, "n_failures",
                           tuple(int(k) for k in self.n_failures))
        object.__setattr__(self, "mixed_weights",
                           _normalise_mixed_weights(self.mixed_weights))

    def _cells_for_kind(self, kind: str) -> tuple[tuple, ...]:
        """(severity, n_failures) cells swept for one kind entry: 'none'
        collapses both axes, a composite kind pins n_failures to its
        component count, and a per-failure severity mix pins n_failures
        to its own length (which must agree with a composite kind's pin)."""
        if kind == "none":
            return ((0.0, 0),)
        parts = _kind_parts(kind)
        cells: list[tuple] = []
        for sev in self.severities:
            if isinstance(sev, tuple):
                if parts and len(parts) != len(sev):
                    raise ValueError(
                        f"per-failure severity mix {sev!r} assigns "
                        f"{len(sev)} severities but composite kind "
                        f"{kind!r} pins {len(parts)} failures")
                cells.append((sev, len(sev)))
            elif parts:
                cells.append((sev, len(parts)))
            else:
                cells.extend((sev, nf) for nf in self.n_failures)
        return tuple(cells)

    def n_scenarios(self) -> int:
        per_deploy = sum(self.reps * len(self._cells_for_kind(k))
                         for k in self.kinds)
        return len(self.workloads) * len(self.meshes) * per_deploy


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One fully-enumerated grid point (locations not yet materialised —
    that needs the deployment's used-resource sets).  Picklable, so it can
    be shipped to process-pool workers."""
    scenario_id: int
    workload: str
    mesh_w: int
    mesh_h: int
    kind: str
    severity: float | tuple[float, ...]   # tuple = per-failure mix
    n_failures: int        # 0 for 'none' scenarios
    rep: int
    # registry fabric key, optionally 'name:variant' ('het:fast2slow1');
    # 'mesh' is the historical default and keeps its RNG stream and cache
    # keys bit-identical
    topology: str = "mesh"


def enumerate_scenarios(grid: CampaignGrid) -> list[Scenario]:
    """Fixed nested-loop enumeration; scenario_id is the stable index."""
    out: list[Scenario] = []
    for wl in grid.workloads:
        for mesh in grid.meshes:
            topo, w, h = parse_topology_spec(mesh)
            for kind in grid.kinds:
                for sev, nf in grid._cells_for_kind(kind):
                    for rep in range(grid.reps):
                        out.append(Scenario(len(out), wl, w, h, kind,
                                            sev, nf, rep, topo))
    return out


def _kind_key(kind: str) -> int:
    """Stable integer key for a kind: the four base kinds keep their
    historical ``KINDS`` index (so pre-mixed grids reproduce their draws);
    'mixed' and composite kinds fold their **entire** name into the key
    (SeedSequence takes arbitrary-precision entropy) — a truncated prefix
    would collide long composites like 'core+link+link' vs
    'core+link+router' back onto one RNG stream."""
    try:
        return KINDS.index(kind)
    except ValueError:
        return int.from_bytes(kind.encode().ljust(8, b"\0"), "big")


def _severity_key(severity) -> int:
    """The severity's IEEE-754 bit pattern.  Keying on the float's bits
    (not on ``int(severity * 1000)``) keeps severities closer than 1e-3 —
    the near-threshold sweep case — on distinct RNG streams.  The bit
    pattern differs from the old key for every nonzero severity, so all
    positive-scenario draws re-keyed at this fix (0.0 still keys to 0;
    'none' draws re-keyed only via the full-name workload fold in
    ``_scenario_rng``, for workload names longer than 8 bytes) — pre-fix
    campaign recordings are not comparable.

    A per-failure severity mix folds every component's bit pattern into
    one arbitrary-precision key (SeedSequence accepts big ints), prefixed
    with a domain tag so a mix can never collide with a scalar severity's
    stream."""
    if isinstance(severity, tuple):
        key = 1
        for s in severity:
            key = (key << 64) | int(np.float64(s).view(np.uint64))
        return key
    return int(np.float64(severity).view(np.uint64))


def _scenario_rng(grid: CampaignGrid, s: Scenario) -> np.random.Generator:
    """Private per-scenario stream: keyed on the scenario coordinates, not
    on enumeration order, so sub-grids reproduce the full grid's draws.
    The workload key folds the **entire** name (an 8-byte-prefix fold
    would collide e.g. 'resnet50_v1'/'resnet50_v2' onto one stream — the
    same truncation class the severity/kind keys guard against)."""
    wl_key = int.from_bytes(s.workload.encode().ljust(8, b"\0"), "big")
    key = [grid.campaign_seed, wl_key, s.mesh_w, s.mesh_h,
           _kind_key(s.kind), _severity_key(s.severity), s.n_failures,
           s.rep]
    if s.topology != "mesh":
        # Non-mesh fabrics fold their full registry key ('torus',
        # 'het:fast2slow1', ...) as an extra entropy word; the default
        # mesh keeps its historical 8-word key so pre-topology campaign
        # recordings stay bit-identical.
        key.append(int.from_bytes(s.topology.encode().ljust(8, b"\0"),
                                  "big"))
    return np.random.default_rng(key)


# ---------------------------------------------------------------------------
# deployment cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Deployment:
    """Shared, read-only per-(workload, mesh) artifacts."""
    sloth: Sloth                   # simulation host (probe plan + traces)
    healthy: SimResult
    used_links: tuple[int, ...]
    used_routers: tuple[int, ...]  # routers with ≥1 used incident link
    probe_overhead: float          # (t_probed / t_unprobed - 1)
    detectors: tuple[Detector, ...] = ()   # prepared, in request order


class DeploymentCache:
    """(workload, mesh, config, detectors) → :class:`Deployment`, built
    once.

    Construction is the expensive part of the grid (graph build, mapping,
    probe planning, healthy calibration run, detector preparation);
    caching it means adding scenarios to a campaign costs one
    simulate+analyse each.  The cache is layered: the *host* artifacts
    (SLOTH pipeline, healthy run, used-resource sets, probe-overhead
    calibration) are keyed on (workload, mesh, cfg) only, and prepared
    detector instances on (host, name) — so campaigns that differ only in
    their detector subset or order share both the host and the per-name
    detectors.  ``cfg=None`` is normalised to the default ``SlothConfig()``
    before keying, so both spellings share one deployment.
    """

    HEALTHY_SEED = 999

    def __init__(self):
        self._hosts: dict[tuple, Deployment] = {}      # detector-free
        self._detectors: dict[tuple, Detector] = {}
        self._cache: dict[tuple, Deployment] = {}

    def _host(self, workload: str, mesh_w: int, mesh_h: int,
              cfg: SlothConfig, hostkey: tuple,
              topology: str = "mesh") -> Deployment:
        host = self._hosts.get(hostkey)
        if host is None:
            sloth = Sloth(build_workload(workload),
                          build_topology(topology, mesh_w, mesh_h),
                          cfg=cfg)
            healthy = sloth.run(None, seed=self.HEALTHY_SEED)
            used = set()
            for s, d in zip(healthy.comm["src"], healthy.comm["dst"]):
                if s != d:
                    used.update(sloth.mesh.route(int(s), int(d)))
            probed_cfg = dataclasses.replace(sloth.sim_cfg,
                                             seed=self.HEALTHY_SEED)
            t_none = simulate(sloth.mapped, probed_cfg,
                              probes=None).total_time
            t_full = simulate(sloth.mapped, probed_cfg,
                              probes=sloth.plan.sim_plan).total_time
            routers = {c for lid in used for c in sloth.mesh.links[lid]}
            host = Deployment(sloth=sloth, healthy=healthy,
                              used_links=tuple(sorted(used)),
                              used_routers=tuple(sorted(routers)),
                              probe_overhead=t_full / t_none - 1.0)
            self._hosts[hostkey] = host
        return host

    def get(self, workload: str, mesh_w: int, mesh_h: int,
            cfg: SlothConfig | None = None,
            detectors=("sloth",),
            baselines: bool | None = None,
            topology: str = "mesh") -> Deployment:
        names = _normalise_detectors(detectors, baselines)
        cfg = cfg if cfg is not None else SlothConfig()
        hostkey = (workload, topology, mesh_w, mesh_h, repr(cfg))
        key = hostkey + (names,)
        dep = self._cache.get(key)
        if dep is None:
            host = self._host(workload, mesh_w, mesh_h, cfg, hostkey,
                              topology=topology)
            dets = []
            for n in names:
                det = self._detectors.get(hostkey + (n,))
                if det is None:
                    det = instantiate_detector(n)
                    if type(det) is SlothDetector:
                        # the simulation host IS a prepared SLOTH pipeline
                        # for exactly this (graph, mesh, cfg) — adopt it
                        # instead of rebuilding an identical one (prepare
                        # is deterministic, so this changes cost, not
                        # results)
                        det.pipeline = host.sloth
                    else:
                        det.prepare(host.sloth.graph, host.sloth.mesh,
                                    host.healthy, cfg)
                    self._detectors[hostkey + (n,)] = det
                dets.append(det)
            dep = dataclasses.replace(host, detectors=tuple(dets))
            self._cache[key] = dep
        return dep


_DEFAULT_CACHE = DeploymentCache()

# Per-worker-process cache for ``executor='process'``: each worker rebuilds
# the deployments it needs lazily (construction is deterministic, so the
# rebuild is identical to the parent's deployment).
_WORKER_CACHE = DeploymentCache()


# ---------------------------------------------------------------------------
# materialisation + single-scenario execution
# ---------------------------------------------------------------------------

def _kind_pools(dep: Deployment) -> dict[str, tuple[int, ...]]:
    """Placement pools per failure kind: every core, plus the links and
    routers the healthy run actually exercises (the paper: "failures
    occurring on unused resources are excluded")."""
    return {"core": tuple(range(dep.sloth.mesh.n_cores)),
            "link": dep.used_links, "router": dep.used_routers}


def _draw_sites(rng: np.random.Generator, s: Scenario, dep: Deployment,
                mixed_weights=None) -> list[tuple[str, int]]:
    """Draw ``s.n_failures`` distinct (kind, location) failure sites.

    Homogeneous kinds reproduce the historical draw sequence exactly.
    ``'mixed'`` samples without replacement from the union population of
    all placeable resources — uniformly by default (kind probability ∝
    live resource count), or with ``mixed_weights`` splitting each kind's
    weight evenly over its pool (the paper's 7:3 core:link population);
    composite kinds (``'core+link'``) draw one failure per pinned kind,
    distinct within each kind's pool.
    """
    mesh = dep.sloth.mesh
    k = s.n_failures
    parts = _kind_parts(s.kind)
    if s.kind == MIXED:
        pools = _kind_pools(dep)
        if mixed_weights is None:
            union = [(kind, int(loc)) for kind in FAILURE_KINDS
                     for loc in pools[kind]]
            if k > len(union):
                raise ValueError(
                    f"cannot place {k} distinct mixed-kind failures: only "
                    f"{len(union)} placeable resources on {s.workload}@"
                    f"{s.mesh_w}x{s.mesh_h}")
            # no p= here: rng.choice consumes the stream differently with
            # an explicit distribution, and the uniform default must stay
            # bit-identical to historical draws
            return [union[int(i)]
                    for i in rng.choice(len(union), size=k, replace=False)]
        wmap = dict(mixed_weights)
        for kind in FAILURE_KINDS:
            if wmap.get(kind, 0.0) > 0.0 and not pools[kind]:
                raise ValueError(
                    f"mixed_weights gives positive weight to {kind!r} but "
                    f"no {kind}s are placeable on {s.workload}@"
                    f"{s.mesh_w}x{s.mesh_h} — drop the kind or zero its "
                    f"weight")
        union = [(kind, int(loc)) for kind in FAILURE_KINDS
                 if wmap.get(kind, 0.0) > 0.0 for loc in pools[kind]]
        if k > len(union):
            raise ValueError(
                f"cannot place {k} distinct mixed-kind failures: only "
                f"{len(union)} placeable resources carry positive "
                f"mixed_weights on {s.workload}@{s.mesh_w}x{s.mesh_h}")
        probs = np.array([wmap[kind] / len(pools[kind])
                          for kind, _ in union], dtype=np.float64)
        probs /= probs.sum()
        return [union[int(i)]
                for i in rng.choice(len(union), size=k, replace=False,
                                    p=probs)]
    if parts:
        pools = _kind_pools(dep)
        sites: list[tuple[str, int]] = []
        for kind in FAILURE_KINDS:
            count = parts.count(kind)
            if not count:
                continue
            pool = pools[kind]
            if not pool:
                raise ValueError(
                    f"no used {kind}s on {s.workload}@"
                    f"{s.mesh_w}x{s.mesh_h}: the healthy run has no "
                    f"cross-core traffic, so a {kind} fail-slow cannot "
                    f"affect execution — drop {s.kind!r} from the grid")
            if count > len(pool):
                raise ValueError(
                    f"cannot place {count} distinct {kind} failures: only "
                    f"{len(pool)} used {kind}s on {s.workload}@"
                    f"{s.mesh_w}x{s.mesh_h}")
            sites += [(kind, int(pool[int(i)]))
                      for i in rng.choice(len(pool), size=count,
                                          replace=False)]
        return sites
    if s.kind == "core":
        if k > mesh.n_cores:
            raise ValueError(
                f"cannot place {k} distinct core failures on a "
                f"{mesh.n_cores}-core {s.mesh_w}x{s.mesh_h} mesh")
        return [("core", int(c)) for c in rng.choice(mesh.n_cores, size=k,
                                                     replace=False)]
    # link/router — only resources carrying traffic
    pool = dep.used_links if s.kind == "link" else dep.used_routers
    if not pool:
        raise ValueError(
            f"no used {s.kind}s on {s.workload}@"
            f"{s.mesh_w}x{s.mesh_h}: the healthy run has no "
            f"cross-core traffic, so a {s.kind} fail-slow cannot "
            f"affect execution — drop this kind from the grid")
    if k > len(pool):
        raise ValueError(
            f"cannot place {k} distinct {s.kind} failures: only "
            f"{len(pool)} used {s.kind}s on {s.workload}@"
            f"{s.mesh_w}x{s.mesh_h}")
    return [(s.kind, int(pool[int(i)]))
            for i in rng.choice(len(pool), size=k, replace=False)]


def materialise(grid: CampaignGrid, s: Scenario, dep: Deployment) \
        -> tuple[tuple[FailSlow, ...], int]:
    """Derive (failures, sim_seed) for one scenario — deterministic in the
    scenario coordinates and the deployment's healthy run.  ``'none'``
    scenarios yield an empty failure tuple; positive scenarios yield
    ``s.n_failures`` simultaneous failures at distinct (kind, location)
    sites — all of ``s.kind`` for homogeneous scenarios, independently
    sampled kinds for ``'mixed'`` and per-component kinds for composite
    entries — each with its own onset and duration."""
    rng = _scenario_rng(grid, s)
    sim_seed = int(rng.integers(1 << 31))
    if s.kind == "none":
        return (), sim_seed
    sites = _draw_sites(rng, s, dep, mixed_weights=grid.mixed_weights)
    total = dep.healthy.total_time
    # a per-failure severity mix assigns severities[i] to the i-th drawn
    # site (for composite kinds that is the canonicalised component
    # order); a scalar severity applies uniformly — severity assignment
    # consumes no RNG, so scalar draws are unchanged
    if isinstance(s.severity, tuple):
        sevs = s.severity
    else:
        sevs = (s.severity,) * len(sites)
    failures = []
    for (kind, loc), sv in zip(sites, sevs):
        t0 = float(rng.uniform(0.0, grid.max_t0_frac * total))
        dur = float(rng.uniform(grid.min_dur_frac, 1.0) * total)
        failures.append(FailSlow(kind, loc, t0, dur, float(sv)))
    return tuple(failures), sim_seed


def _mitigate_scenario(dep: Deployment, failures, sim: SimResult,
                       sim_seed: int, verdict, detector_name: str,
                       policy, switch_time: float | None,
                       correct: bool) -> MitigationOutcome:
    """Close the loop for one (detector, policy) pair: plan against the
    verdict, apply, re-simulate the mitigated deployment over the
    remaining failure window, and score recovery against the deployment's
    healthy reference.

    ``switch_time`` — the stream time at which mitigation engaged (the
    detector's first flagged window): the composed makespan keeps the
    work already finished by then and runs the remainder at the mitigated
    deployment's rate (the steady-state approximation for iterative
    workloads).  ``None`` models a post-hoc restart: the whole workload
    re-runs on the mitigated deployment under the full failure windows.
    A plan that does not act re-simulates nothing, so the ``none``
    control's mitigated makespan equals the failed one *exactly*.
    """
    sloth = dep.sloth
    healthy_t = float(dep.healthy.total_time)
    failed_t = float(sim.total_time)
    t0 = _wall_clock()
    plan = policy.plan(verdict, sloth.mapped, sloth.mesh, sloth.cfg)
    if not plan.acted:
        return MitigationOutcome(
            detector=detector_name, policy=policy.name, acted=False,
            correct=correct, exclude_cores=(), avoid_links=(),
            healthy_time=healthy_t, failed_time=failed_t,
            mitigated_time=failed_t, switch_time=None,
            wall_time=_wall_clock() - t0)
    mitigated = policy.apply(plan, sloth.mapped, sloth.cfg)
    sim_cfg = dataclasses.replace(sloth.sim_cfg, seed=sim_seed)
    from_t = float(switch_time) if switch_time is not None else 0.0
    re_sim = simulate_mitigated(mitigated, sim_cfg, list(failures),
                                probes=sloth.plan.sim_plan,
                                from_time=from_t)
    if switch_time is None:
        mit_t = float(re_sim.total_time)
    else:
        done = work_done_frac(sim, from_t)
        mit_t = from_t + (1.0 - done) * float(re_sim.total_time)
    return MitigationOutcome(
        detector=detector_name, policy=policy.name, acted=True,
        correct=correct, exclude_cores=plan.exclude_cores,
        avoid_links=plan.avoid_links, healthy_time=healthy_t,
        failed_time=failed_t, mitigated_time=mit_t,
        switch_time=switch_time, wall_time=_wall_clock() - t0)


def run_scenario(grid: CampaignGrid, s: Scenario, dep: Deployment,
                 streaming: int = 0,
                 mitigation: tuple[str, ...] = ()) -> ScenarioOutcome:
    """Execute one scenario end-to-end against a cached deployment: one
    simulation, analysed by every prepared detector, every verdict judged
    by the shared router-aware rule (:func:`repro.core.failures
    .judge_verdict`).

    ``streaming > 0`` replays the trace chunk-by-chunk (that many
    chunks) through every detector exposing ``stream_analyse`` instead
    of one-shot ``analyse``: the final streamed verdict — guaranteed
    equal to the post-hoc one — is judged as THE verdict, and positive
    scenarios additionally record the detection latency (stream time of
    the first flagged window minus the earliest failure onset; ``inf``
    when never flagged).  Detectors without ``stream_analyse`` fall back
    to post-hoc analysis with no latency measurement.

    ``mitigation`` names registered policies
    (:func:`repro.mitigate.get_policy`): each detector's judged verdict is
    handed to each policy and the mitigated deployment re-simulated (see
    :func:`_mitigate_scenario`) — one :class:`MitigationOutcome` per
    (detector, policy) pair, detector-major.  On streaming scenarios the
    mitigation engages at the detector's first flagged window, so
    detection latency composes with recovery; post-hoc scenarios model a
    full restart."""
    failures, sim_seed = materialise(grid, s, dep)
    policies = [instantiate_policy(p) for p in mitigation]
    t0 = _wall_clock()
    sim = dep.sloth.run(list(failures) if failures else None, seed=sim_seed)
    sim_wall = _wall_clock() - t0
    mesh = dep.sloth.mesh
    results = []
    mitigations: list[MitigationOutcome] = []
    compression = 0.0
    total_time = float(sim.total_time)
    for det in dep.detectors:
        t1 = _wall_clock()
        latency = None
        first_flag = None
        streamed = streaming > 0 and hasattr(det, "stream_analyse")
        if streamed:
            v, first_flag = det.stream_analyse(sim, n_chunks=streaming)
            if failures:
                onset = min(f.t0 for f in failures)
                latency = (float(first_flag) - onset
                           if first_flag is not None else math.inf)
        else:
            v = det.analyse(sim)
        wall = _wall_clock() - t1
        matched, rank, ranks, _ = judge_verdict(v, failures, mesh)
        if compression == 0.0 and v.recorder is not None:
            compression = float(v.recorder.compression_ratio)
        results.append(DetectorOutcome(
            detector=det.name, flagged=bool(v.flagged), pred_kind=v.kind,
            pred_location=v.location, score=float(v.score),
            matched=matched, truth_rank=rank, truth_ranks=ranks,
            wall_time=wall, detection_latency=latency))
        switch = (float(first_flag) if streamed and first_flag is not None
                  else None)
        for pol in policies:
            mitigations.append(_mitigate_scenario(
                dep, failures, sim, sim_seed, v, det.name, pol,
                switch, matched))
    return ScenarioOutcome(
        scenario_id=s.scenario_id, workload=s.workload,
        mesh_w=s.mesh_w, mesh_h=s.mesh_h, kind=s.kind,
        topology=s.topology,
        severity=s.severity, n_failures=len(failures), rep=s.rep,
        sim_seed=sim_seed,
        truth_locations=tuple(f.location for f in failures),
        truth_t0s=tuple(f.t0 for f in failures),
        truth_durations=tuple(f.duration for f in failures),
        truth_kinds=tuple(f.kind for f in failures),
        truth_severities=tuple(f.slowdown for f in failures),
        detector_results=tuple(results),
        mitigation_results=tuple(mitigations),
        compression_ratio=compression,
        total_time=total_time,
        probe_overhead=float(dep.probe_overhead),
        sim_wall_time=sim_wall,
    )


def _run_in_worker(grid: CampaignGrid, cfg: SlothConfig | None,
                   detectors: tuple[str, ...], streaming: int,
                   mitigation: tuple[str, ...],
                   s: Scenario) -> ScenarioOutcome:
    """Process-pool entry point: resolve the deployment from this worker
    process's own cache (lazily built), then run the scenario."""
    dep = _WORKER_CACHE.get(s.workload, s.mesh_w, s.mesh_h,
                            cfg=cfg, detectors=detectors,
                            topology=s.topology)
    return run_scenario(grid, s, dep, streaming=streaming,
                        mitigation=mitigation)


# ---------------------------------------------------------------------------
# campaign driver
# ---------------------------------------------------------------------------

def _sev_str(sev) -> str:
    """Render a severity cell — scalar or per-failure mix — for tables."""
    if isinstance(sev, tuple):
        return "(" + ",".join(f"{s:g}" for s in sev) + ")"
    return f"{sev:g}"


@dataclasses.dataclass
class CampaignResult:
    grid: CampaignGrid
    detectors: tuple[str, ...]             # request order; [0] is primary
    outcomes: list[ScenarioOutcome]
    metrics: CampaignMetrics               # primary detector
    cells: dict[tuple, CampaignMetrics]    # primary detector, per cell
    detector_metrics: dict[str, CampaignMetrics]
    detector_cells: dict[str, dict[tuple, CampaignMetrics]]
    probe_overheads: dict[tuple, float]    # (workload, w, h) → overhead
    # mitigation request + recovered-throughput table, empty on campaigns
    # without ``mitigation=``
    policies: tuple[str, ...] = ()
    mitigation: dict[tuple[str, str], MitigationStat] = \
        dataclasses.field(default_factory=dict)

    def severity_curve(self, detector: str | None = None,
                       ks: tuple[int, ...] = (1, 3, 5)) \
            -> tuple[SeverityPoint, ...]:
        """Accuracy / FPR / recall@k per injected severity (ascending),
        with Wilson CIs — the near-threshold sweep readout for one
        detector (``None`` → primary)."""
        return severity_curve(self.outcomes, ks=ks, detector=detector)

    def severity_curve_by_mesh(self, detector: str | None = None,
                               ks: tuple[int, ...] = (1, 3, 5)) \
            -> dict[tuple[int, int], tuple[SeverityPoint, ...]]:
        """The severity curve split per mesh size (``(w, h)`` keys) —
        near-threshold behaviour per topology scale instead of pooled."""
        return severity_curve_by_mesh(self.outcomes, ks=ks,
                                      detector=detector)

    def by_topology(self, detector: str | None = None,
                    ks: tuple[int, ...] = (1, 3, 5)) \
            -> dict[str, CampaignMetrics]:
        """Campaign metrics split per deployment fabric, keyed by the
        canonical topology spec (``'mesh:4x4'``, ``'torus:8x8'``,
        ``'het:4x4:fast2slow1'``) — the paper's cross-architecture
        readout for one detector (``None`` → primary)."""
        return by_topology(self.outcomes, ks=ks, detector=detector)

    def by_truth_kind(self, detector: str | None = None,
                      ks: tuple[int, ...] = (1, 3, 5)) \
            -> dict[str, TruthKindMetrics]:
        """Per-failure recall@k and ranks split by each truth's own kind
        — the mixed-kind campaign readout for one detector (``None`` →
        primary)."""
        return by_truth_kind(self.outcomes, ks=ks, detector=detector)

    def summary(self) -> str:
        m = self.metrics
        lines = [
            f"scenarios: {m.n_scenarios}",
            f"primary:   {self.detectors[0]}",
            f"accuracy:  {m.accuracy.pct():.2f}% "
            f"({m.accuracy.successes}/{m.accuracy.trials}, "
            f"CI [{m.accuracy.interval[0]*100:.1f}, "
            f"{m.accuracy.interval[1]*100:.1f}])",
            f"FPR:       {m.fpr.pct():.2f}% "
            f"({m.fpr.successes}/{m.fpr.trials}, "
            f"CI [{m.fpr.interval[0]*100:.1f}, "
            f"{m.fpr.interval[1]*100:.1f}])",
        ] + [
            f"top-{k}:     {stat.pct():.2f}%" for k, stat in m.topk
        ] + [
            f"recall@{k}:  {stat.pct():.2f}% "
            f"({stat.successes}/{stat.trials})" for k, stat in m.recall
        ] + [
            f"compression: {m.mean_compression:.1f}x",
            f"probe overhead: {m.mean_probe_overhead*100:.3f}% "
            f"(scenario-weighted; unweighted per-deployment "
            f"{m.mean_probe_overhead_unweighted*100:.3f}%)",
        ]
        if m.detection is not None:
            d = m.detection
            lines.append(
                f"detection latency: mean {d.mean:.4g}s p95 {d.p95:.4g}s "
                f"(detected {d.n_detected}/{d.n_measured} streamed "
                f"positives)")
        if len(self.detectors) > 1:
            lines.append("per-detector (acc / FPR / top-3 / recall@3):")
            for name, dm in self.detector_metrics.items():
                lines.append(
                    f"  {name:8s} {dm.accuracy.pct():6.2f}% "
                    f"{dm.fpr.pct():6.2f}% "
                    f"{dm.topk_rate(3)*100:6.2f}% "
                    f"{dm.recall_at(3)*100:6.2f}%")
        by_topo = self.by_topology()
        if len(by_topo) > 1:
            lines.append("per fabric (acc / FPR / recall@3):")
            for label, tm in by_topo.items():
                lines.append(
                    f"  {label:20s} {tm.accuracy.pct():6.2f}% "
                    f"{tm.fpr.pct():6.2f}% "
                    f"{tm.recall_at(3)*100:6.2f}%  (n={tm.n_scenarios})")
        if len({o.severity for o in self.outcomes if o.positive}) > 1:
            by_mesh = self.severity_curve_by_mesh()
            if len(by_mesh) > 1:
                lines.append("severity curve per mesh "
                             "(accuracy / recall@3):")
                for (w, h), pts in by_mesh.items():
                    lines.append(f"  {w}x{h}:")
                    for p in pts:
                        lines.append(
                            f"    x{_sev_str(p.severity):<8s} "
                            f"{p.accuracy.pct():6.2f}% "
                            f"{p.recall_at(3)*100:6.2f}%  "
                            f"(n={p.n_scenarios})")
            else:
                lines.append("severity curve (accuracy / recall@3):")
                for p in self.severity_curve():
                    lines.append(
                        f"  x{_sev_str(p.severity):<8s} "
                        f"{p.accuracy.pct():6.2f}% "
                        f"{p.recall_at(3)*100:6.2f}%  (n={p.n_scenarios})")
        kinds = self.by_truth_kind()
        if len(kinds) > 1:
            lines.append("per truth kind (recall@1 / recall@3 / "
                         "mean rank):")
            for kind, tk in kinds.items():
                rank = (f"{tk.mean_rank:5.2f}" if tk.mean_rank is not None
                        else "  n/a")
                lines.append(
                    f"  {kind:8s} {tk.recall_at(1)*100:6.2f}% "
                    f"{tk.recall_at(3)*100:6.2f}% {rank}  "
                    f"(n={tk.n_failures})")
        if self.mitigation:
            lines.append("mitigation (acted / recovered / slowdown vs "
                         "healthy / mis-mitigation):")
            for (det, pol), st in self.mitigation.items():
                ci = st.improved.interval
                lines.append(
                    f"  {det}x{pol:<11s} "
                    f"acted {st.acted.successes}/{st.acted.trials}  "
                    f"recovered {st.recovered_mean*100:6.1f}% "
                    f"(improved {st.improved.successes}/"
                    f"{st.improved.trials}, CI [{ci[0]*100:.0f}, "
                    f"{ci[1]*100:.0f}])  "
                    f"slowdown {st.slowdown_mean:.3f}x  "
                    f"mis-acted {st.mis_acted.successes}/"
                    f"{st.mis_acted.trials} "
                    f"penalty {st.penalty_mean*100:+.1f}%")
        wall = wall_time_stats(self.outcomes)
        if wall:
            lines.append("wall time per scenario (mean / p95):")
            for name, w in wall.items():
                lines.append(f"  {name:8s} {w.mean*1e3:8.2f}ms "
                             f"{w.p95*1e3:8.2f}ms")
        return "\n".join(lines)


#: Chunk count used when a campaign requests ``streaming=True``.
DEFAULT_STREAM_CHUNKS = 4


def run_campaign(grid: CampaignGrid, *, workers: int | None = None,
                 executor: str = "thread",
                 cfg: SlothConfig | None = None,
                 detectors=("sloth",),
                 baselines: bool | None = None,
                 streaming: bool | int = False,
                 mitigation=None,
                 cache: DeploymentCache | None = None,
                 progress=None) -> CampaignResult:
    """Run every scenario of ``grid`` and aggregate paper-style metrics.

    ``workers`` — pool width (``None`` → cpu count, ``0``/``1`` → serial).
    ``executor`` — ``'thread'`` (shared deployments, GIL-bound) or
    ``'process'`` (per-worker deployment caches, true multi-core; see the
    module docstring).  Outcomes are **bit-identical** across executors and
    worker counts.  ``detectors`` — registry names analysing every
    scenario's trace; the first is the primary detector for the top-level
    ``metrics``/``cells`` (per-detector tables are in
    ``detector_metrics``/``detector_cells``).  ``baselines`` is a
    deprecated alias: ``True`` maps to ``detectors=DEFAULT_DETECTORS``.
    ``streaming`` — replay every trace chunk-by-chunk through the
    streaming detection service instead of one-shot post-hoc analysis
    (``True`` → ``DEFAULT_STREAM_CHUNKS`` chunks, an int → that many):
    judged verdicts are unchanged (the final streamed verdict equals the
    post-hoc one by construction), and positive scenarios additionally
    report detection latency (``metrics.detection``; see
    :func:`run_scenario`).  ``mitigation`` — registered mitigation-policy
    names (a name, an iterable, or ``None``): every detector's judged
    verdict is acted on by every policy and the mitigated deployment
    re-simulated over the remaining failure window, producing the
    recovered-throughput table in ``result.mitigation`` (per
    (detector, policy), Wilson CIs; see
    :func:`repro.core.metrics.mitigation_stats`).  With streaming, the
    mitigation engages at each detector's first flagged window, so
    detection latency composes with recovery.  ``cache`` — share
    deployments across campaigns (defaults to a process-wide cache;
    ignored by process-pool workers, which keep their own).
    """
    if executor not in EXECUTORS:
        raise ValueError(f"unknown executor {executor!r}; "
                         f"options: {EXECUTORS}")
    if streaming is True:
        streaming = DEFAULT_STREAM_CHUNKS
    streaming = int(streaming)
    if streaming < 0:
        raise ValueError("streaming must be False or a chunk count >= 1")
    names = _normalise_detectors(detectors, baselines)
    pols = _normalise_policies(mitigation)
    scenarios = enumerate_scenarios(grid)
    workers = (os.cpu_count() or 1) if workers is None else workers
    parallel = workers > 1 and len(scenarios) > 1

    if executor == "process" and parallel:
        # spawn, not fork: the analysis pipeline jits through JAX, whose
        # thread pools make fork() after first use prone to deadlock.
        # Workers re-import the package cleanly (sys.path is inherited).
        ctx = multiprocessing.get_context("spawn")
        fn = functools.partial(_run_in_worker, grid, cfg, names, streaming,
                               pols)
        outcomes = []
        with ProcessPoolExecutor(max_workers=workers,
                                 mp_context=ctx) as pool:
            for o in pool.map(fn, scenarios):
                if progress is not None:
                    progress(o)
                outcomes.append(o)
    else:
        cache = cache if cache is not None else _DEFAULT_CACHE
        # Build deployments serially first: construction is the expensive,
        # cache-mutating step; scenario execution then only reads shared
        # state.
        deps: dict[tuple, Deployment] = {}
        for s in scenarios:
            k = (s.workload, s.topology, s.mesh_w, s.mesh_h)
            if k not in deps:
                deps[k] = cache.get(s.workload, s.mesh_w, s.mesh_h,
                                    cfg=cfg, detectors=names,
                                    topology=s.topology)

        def run_one(s: Scenario) -> ScenarioOutcome:
            o = run_scenario(grid, s,
                             deps[(s.workload, s.topology,
                                   s.mesh_w, s.mesh_h)],
                             streaming=streaming, mitigation=pols)
            if progress is not None:
                progress(o)
            return o

        if parallel:
            with ThreadPoolExecutor(max_workers=workers) as pool:
                outcomes = list(pool.map(run_one, scenarios))
        else:
            outcomes = [run_one(s) for s in scenarios]

    det_metrics = by_detector(outcomes)
    det_cells = detector_cells(outcomes)
    primary = names[0]
    return CampaignResult(
        grid=grid, detectors=names, outcomes=outcomes,
        metrics=(det_metrics[primary] if outcomes else aggregate(outcomes)),
        cells=det_cells.get(primary, {}),
        detector_metrics=det_metrics,
        detector_cells=det_cells,
        probe_overheads=deployment_overheads(outcomes),
        policies=pols,
        mitigation=by_mitigation(outcomes),
    )
