"""Batched scenario-campaign runner — the evaluation substrate.

The paper's headline numbers (detection accuracy, FPR, compression ratio)
are *campaign* statistics: aggregates over many injected fail-slow scenarios
across workloads, failure kinds and mesh sizes.  This module turns
single-scenario detection into a reproducible grid evaluation, and — via
the unified detector API (:mod:`repro.core.detectors`) — into the paper's
SLOTH-vs-baselines comparison: ``run_campaign(grid, detectors=("sloth",
"thres", "mscope", "iaso", "perseus", "adr"))`` analyses every scenario's
trace with every requested detector under one judging rule and returns
per-detector accuracy / FPR / top-k / recall@k cells.

Scenario-grid schema
--------------------
A :class:`CampaignGrid` is the cross product

    workload × mesh × failure kind × severity × n_failures × replicate

with ``kind ∈ {'core', 'link', 'router', 'none'}``.  Mesh entries may be a
square width ``W``, a ``(W, H)`` pair or a ``'WxH'`` string — they are
normalised to ``(W, H)`` tuples at grid construction, so rectangular meshes
(``12×8``, ``16×8``, …) flow through scenario keys, cache keys and metric
cells unchanged.  ``n_failures`` entries are k ≥ 1 *simultaneous* failures
of the scenario's kind at k distinct locations (ground truth becomes a set;
see ``metrics.py`` for any-match accuracy and per-failure recall@k).
``'none'`` cells are negative (failure-free) samples and collapse both the
severity and n_failures axes — they are enumerated once per replicate with
``severity = 0.0`` and ``n_failures = 0``.

Every scenario is fully determined by ``(campaign_seed, workload, mesh,
kind, severity, n_failures, rep)``: locations, onset times, durations and
the simulator seed are drawn from a private ``numpy`` generator keyed on
exactly that tuple (``np.random.default_rng([...])``), so there is **no
global RNG state** and the same grid always materialises bit-identical
scenarios, regardless of worker count, executor or execution order.

Link/router placements are restricted to resources the healthy run actually
exercises (the paper: "failures occurring on unused resources are
excluded"), using the deployment's cached healthy simulation.

Detector model
--------------
``detectors=`` names registry entries (:func:`repro.core.detectors
.get_detector`); each deployment prepares one instance per name against
its healthy profiling run, the first name being the campaign's *primary*
detector (top-level ``metrics`` / ``cells``).  Every scenario is simulated
**once** and the one trace is analysed by all detectors, so the comparison
is on identical data by construction.  Per-detector analyse wall time and
per-scenario simulate wall time are recorded as telemetry (excluded from
outcome equality, surfaced by ``CampaignResult.summary()``).  The old
``baselines: bool`` flag survives as a deprecation shim that expands to
``detectors=DEFAULT_DETECTORS``.

Execution model
---------------
``run_campaign(..., workers=N, executor='thread'|'process')``:

* ``executor='thread'`` (default) — deployments are built serially into the
  shared :class:`DeploymentCache`, then scenarios fan out over a thread
  pool.  Fine for small grids; the pure-Python simulator holds the GIL, so
  threads mostly pipeline rather than parallelise.
* ``executor='process'`` — scenarios are dispatched to a
  ``ProcessPoolExecutor``.  Only the picklable ``(grid, scenario, config,
  detector names)`` coordinates cross the process boundary; each worker
  process lazily rebuilds the deployments (and prepared detectors) it
  needs into its own module-level :class:`DeploymentCache` (construction
  is deterministic, so a rebuilt deployment is identical to the parent's).
  Custom detectors must therefore be registered at import time of their
  defining module to be resolvable inside spawned workers.  A ``cache=``
  argument is not consulted on this path.  Outcomes are collected in
  scenario order and are **bit-identical** to serial/thread execution for
  any worker count.

``workers=None`` → cpu count; ``0``/``1`` or a single-scenario grid →
serial in-process execution for either executor.

Performance
-----------
``(workload, mesh, config, detectors)`` deployments — mapped graph, probe
plan, healthy simulation, probe-overhead calibration, prepared detector
instances — are built once per cache (:class:`DeploymentCache`) and shared
read-only by all scenarios of the grid.  The cache key normalises
``cfg=None`` to the default :class:`SlothConfig`, so explicit-default and
implicit-default callers share one deployment.
"""

from __future__ import annotations

import dataclasses
import functools
import multiprocessing
import os
import time
import warnings
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor

import numpy as np

from .detectors import (DEFAULT_DETECTORS, Detector, get_detector,
                        instantiate_detector)
from .failures import FailSlow, judge_verdict, truth_candidates
from .graph import build_workload
from .metrics import (CampaignMetrics, DetectorOutcome, ScenarioOutcome,
                      aggregate, by_detector, deployment_overheads,
                      detector_cells, wall_time_stats)
from .routing import Mesh2D
from .simulator import SimResult, simulate
from .sloth import Sloth, SlothConfig, SlothDetector

__all__ = [
    "KINDS", "EXECUTORS", "DEFAULT_DETECTORS", "CampaignGrid", "Scenario",
    "Deployment", "DeploymentCache", "CampaignResult",
    "enumerate_scenarios", "materialise", "run_scenario", "run_campaign",
    "truth_candidates",
]

KINDS = ("core", "link", "router", "none")
EXECUTORS = ("thread", "process")


def _mesh_dims(mesh) -> tuple[int, int]:
    """Normalise a mesh spec — ``12`` | ``(12, 8)`` | ``'12x8'`` — to
    ``(width, height)``."""
    if isinstance(mesh, str):
        parts = mesh.lower().split("x")
        if len(parts) == 1:
            parts = parts * 2
        if len(parts) != 2 or not all(p.strip().isdigit() for p in parts):
            raise ValueError(f"bad mesh spec {mesh!r}: use 'W' or 'WxH'")
        w, h = (int(p) for p in parts)
    elif isinstance(mesh, (int, np.integer)):
        w = h = int(mesh)
    else:
        try:
            if len(mesh) != 2:
                raise ValueError
            w, h = int(mesh[0]), int(mesh[1])
        except (TypeError, ValueError):
            raise ValueError(f"bad mesh spec {mesh!r}: use W, (W, H) "
                             f"or 'WxH'") from None
    if w < 1 or h < 1:
        raise ValueError(f"mesh dimensions must be >= 1, got {w}x{h}")
    return w, h


def _normalise_detectors(detectors, baselines) -> tuple[str, ...]:
    """Resolve the ``detectors=`` request (plus the deprecated
    ``baselines=`` flag) to a validated, deduplicated name tuple."""
    if baselines is not None:
        warnings.warn(
            "baselines= is deprecated; pass detectors=('sloth', 'thres', "
            "...) — baselines=True maps to detectors=DEFAULT_DETECTORS",
            DeprecationWarning, stacklevel=3)
        if baselines:
            detectors = DEFAULT_DETECTORS
    if isinstance(detectors, str):
        detectors = (detectors,)
    names = tuple(dict.fromkeys(str(n).lower() for n in detectors))
    if not names:
        raise ValueError("detectors must name at least one detector")
    for n in names:
        get_detector(n)          # raises KeyError for unknown names
    return names


# ---------------------------------------------------------------------------
# grid + scenarios
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CampaignGrid:
    """Declarative scenario grid (see module docstring for the schema)."""
    workloads: tuple[str, ...] = ("darknet19",)
    meshes: tuple = (4,)                     # W | (W, H) | 'WxH' entries
    kinds: tuple[str, ...] = KINDS
    severities: tuple[float, ...] = (10.0,)
    n_failures: tuple[int, ...] = (1,)       # simultaneous failures axis
    reps: int = 1                            # replicates per grid cell
    campaign_seed: int = 0
    max_t0_frac: float = 0.5                 # onset within healthy runtime
    min_dur_frac: float = 0.4                # duration ⊆ healthy runtime

    def __post_init__(self):
        bad = set(self.kinds) - set(KINDS)
        if bad:
            raise ValueError(f"unknown failure kinds: {sorted(bad)}")
        if self.reps < 1:
            raise ValueError("reps must be >= 1")
        if not self.n_failures or any(int(k) < 1 for k in self.n_failures):
            raise ValueError("n_failures entries must be >= 1")
        object.__setattr__(self, "meshes",
                           tuple(_mesh_dims(m) for m in self.meshes))
        object.__setattr__(self, "n_failures",
                           tuple(int(k) for k in self.n_failures))

    def n_scenarios(self) -> int:
        per_deploy = sum(self.reps * (len(self.severities)
                                      * len(self.n_failures)
                                      if k != "none" else 1)
                         for k in self.kinds)
        return len(self.workloads) * len(self.meshes) * per_deploy


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One fully-enumerated grid point (locations not yet materialised —
    that needs the deployment's used-resource sets).  Picklable, so it can
    be shipped to process-pool workers."""
    scenario_id: int
    workload: str
    mesh_w: int
    mesh_h: int
    kind: str
    severity: float
    n_failures: int        # 0 for 'none' scenarios
    rep: int


def enumerate_scenarios(grid: CampaignGrid) -> list[Scenario]:
    """Fixed nested-loop enumeration; scenario_id is the stable index."""
    out: list[Scenario] = []
    for wl in grid.workloads:
        for w, h in grid.meshes:
            for kind in grid.kinds:
                sevs = (0.0,) if kind == "none" else grid.severities
                nfs = (0,) if kind == "none" else grid.n_failures
                for sev in sevs:
                    for nf in nfs:
                        for rep in range(grid.reps):
                            out.append(Scenario(len(out), wl, w, h, kind,
                                                sev, nf, rep))
    return out


def _scenario_rng(grid: CampaignGrid, s: Scenario) -> np.random.Generator:
    """Private per-scenario stream: keyed on the scenario coordinates, not
    on enumeration order, so sub-grids reproduce the full grid's draws."""
    wl_key = int.from_bytes(s.workload.encode()[:8].ljust(8, b"\0"), "big")
    return np.random.default_rng(
        [grid.campaign_seed, wl_key, s.mesh_w, s.mesh_h,
         KINDS.index(s.kind), int(s.severity * 1000), s.n_failures, s.rep])


# ---------------------------------------------------------------------------
# deployment cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Deployment:
    """Shared, read-only per-(workload, mesh) artifacts."""
    sloth: Sloth                   # simulation host (probe plan + traces)
    healthy: SimResult
    used_links: tuple[int, ...]
    used_routers: tuple[int, ...]  # routers with ≥1 used incident link
    probe_overhead: float          # (t_probed / t_unprobed - 1)
    detectors: tuple[Detector, ...] = ()   # prepared, in request order


class DeploymentCache:
    """(workload, mesh, config, detectors) → :class:`Deployment`, built
    once.

    Construction is the expensive part of the grid (graph build, mapping,
    probe planning, healthy calibration run, detector preparation);
    caching it means adding scenarios to a campaign costs one
    simulate+analyse each.  The cache is layered: the *host* artifacts
    (SLOTH pipeline, healthy run, used-resource sets, probe-overhead
    calibration) are keyed on (workload, mesh, cfg) only, and prepared
    detector instances on (host, name) — so campaigns that differ only in
    their detector subset or order share both the host and the per-name
    detectors.  ``cfg=None`` is normalised to the default ``SlothConfig()``
    before keying, so both spellings share one deployment.
    """

    HEALTHY_SEED = 999

    def __init__(self):
        self._hosts: dict[tuple, Deployment] = {}      # detector-free
        self._detectors: dict[tuple, Detector] = {}
        self._cache: dict[tuple, Deployment] = {}

    def _host(self, workload: str, mesh_w: int, mesh_h: int,
              cfg: SlothConfig, hostkey: tuple) -> Deployment:
        host = self._hosts.get(hostkey)
        if host is None:
            sloth = Sloth(build_workload(workload),
                          Mesh2D(mesh_w, mesh_h), cfg=cfg)
            healthy = sloth.run(None, seed=self.HEALTHY_SEED)
            used = set()
            for s, d in zip(healthy.comm["src"], healthy.comm["dst"]):
                if s != d:
                    used.update(sloth.mesh.route(int(s), int(d)))
            probed_cfg = dataclasses.replace(sloth.sim_cfg,
                                             seed=self.HEALTHY_SEED)
            t_none = simulate(sloth.mapped, probed_cfg,
                              probes=None).total_time
            t_full = simulate(sloth.mapped, probed_cfg,
                              probes=sloth.plan.sim_plan).total_time
            routers = {c for lid in used for c in sloth.mesh.links[lid]}
            host = Deployment(sloth=sloth, healthy=healthy,
                              used_links=tuple(sorted(used)),
                              used_routers=tuple(sorted(routers)),
                              probe_overhead=t_full / t_none - 1.0)
            self._hosts[hostkey] = host
        return host

    def get(self, workload: str, mesh_w: int, mesh_h: int,
            cfg: SlothConfig | None = None,
            detectors=("sloth",),
            baselines: bool | None = None) -> Deployment:
        names = _normalise_detectors(detectors, baselines)
        cfg = cfg if cfg is not None else SlothConfig()
        hostkey = (workload, mesh_w, mesh_h, repr(cfg))
        key = hostkey + (names,)
        dep = self._cache.get(key)
        if dep is None:
            host = self._host(workload, mesh_w, mesh_h, cfg, hostkey)
            dets = []
            for n in names:
                det = self._detectors.get(hostkey + (n,))
                if det is None:
                    det = instantiate_detector(n)
                    if type(det) is SlothDetector:
                        # the simulation host IS a prepared SLOTH pipeline
                        # for exactly this (graph, mesh, cfg) — adopt it
                        # instead of rebuilding an identical one (prepare
                        # is deterministic, so this changes cost, not
                        # results)
                        det.pipeline = host.sloth
                    else:
                        det.prepare(host.sloth.graph, host.sloth.mesh,
                                    host.healthy, cfg)
                    self._detectors[hostkey + (n,)] = det
                dets.append(det)
            dep = dataclasses.replace(host, detectors=tuple(dets))
            self._cache[key] = dep
        return dep


_DEFAULT_CACHE = DeploymentCache()

# Per-worker-process cache for ``executor='process'``: each worker rebuilds
# the deployments it needs lazily (construction is deterministic, so the
# rebuild is identical to the parent's deployment).
_WORKER_CACHE = DeploymentCache()


# ---------------------------------------------------------------------------
# materialisation + single-scenario execution
# ---------------------------------------------------------------------------

def materialise(grid: CampaignGrid, s: Scenario, dep: Deployment) \
        -> tuple[tuple[FailSlow, ...], int]:
    """Derive (failures, sim_seed) for one scenario — deterministic in the
    scenario coordinates and the deployment's healthy run.  ``'none'``
    scenarios yield an empty failure tuple; positive scenarios yield
    ``s.n_failures`` simultaneous failures of ``s.kind`` at distinct
    locations, each with its own onset and duration."""
    rng = _scenario_rng(grid, s)
    sim_seed = int(rng.integers(1 << 31))
    if s.kind == "none":
        return (), sim_seed
    mesh = dep.sloth.mesh
    k = s.n_failures
    if s.kind == "core":
        if k > mesh.n_cores:
            raise ValueError(
                f"cannot place {k} distinct core failures on a "
                f"{mesh.n_cores}-core {s.mesh_w}x{s.mesh_h} mesh")
        locs = [int(c) for c in rng.choice(mesh.n_cores, size=k,
                                           replace=False)]
    else:            # link/router — only resources carrying traffic
        pool = dep.used_links if s.kind == "link" else dep.used_routers
        if not pool:
            raise ValueError(
                f"no used {s.kind}s on {s.workload}@"
                f"{s.mesh_w}x{s.mesh_h}: the healthy run has no "
                f"cross-core traffic, so a {s.kind} fail-slow cannot "
                f"affect execution — drop this kind from the grid")
        if k > len(pool):
            raise ValueError(
                f"cannot place {k} distinct {s.kind} failures: only "
                f"{len(pool)} used {s.kind}s on {s.workload}@"
                f"{s.mesh_w}x{s.mesh_h}")
        locs = [int(pool[int(i)]) for i in rng.choice(len(pool), size=k,
                                                      replace=False)]
    total = dep.healthy.total_time
    failures = []
    for loc in locs:
        t0 = float(rng.uniform(0.0, grid.max_t0_frac * total))
        dur = float(rng.uniform(grid.min_dur_frac, 1.0) * total)
        failures.append(FailSlow(s.kind, loc, t0, dur, s.severity))
    return tuple(failures), sim_seed


def run_scenario(grid: CampaignGrid, s: Scenario, dep: Deployment) \
        -> ScenarioOutcome:
    """Execute one scenario end-to-end against a cached deployment: one
    simulation, analysed by every prepared detector, every verdict judged
    by the shared router-aware rule (:func:`repro.core.failures
    .judge_verdict`)."""
    failures, sim_seed = materialise(grid, s, dep)
    t0 = time.perf_counter()
    sim = dep.sloth.run(list(failures) if failures else None, seed=sim_seed)
    sim_wall = time.perf_counter() - t0
    mesh = dep.sloth.mesh
    results = []
    compression = 0.0
    total_time = float(sim.total_time)
    for det in dep.detectors:
        t1 = time.perf_counter()
        v = det.analyse(sim)
        wall = time.perf_counter() - t1
        matched, rank, ranks, _ = judge_verdict(v, failures, mesh)
        if compression == 0.0 and v.recorder is not None:
            compression = float(v.recorder.compression_ratio)
        results.append(DetectorOutcome(
            detector=det.name, flagged=bool(v.flagged), pred_kind=v.kind,
            pred_location=v.location, score=float(v.score),
            matched=matched, truth_rank=rank, truth_ranks=ranks,
            wall_time=wall))
    return ScenarioOutcome(
        scenario_id=s.scenario_id, workload=s.workload,
        mesh_w=s.mesh_w, mesh_h=s.mesh_h, kind=s.kind,
        severity=s.severity, n_failures=len(failures), rep=s.rep,
        sim_seed=sim_seed,
        truth_locations=tuple(f.location for f in failures),
        truth_t0s=tuple(f.t0 for f in failures),
        truth_durations=tuple(f.duration for f in failures),
        detector_results=tuple(results),
        compression_ratio=compression,
        total_time=total_time,
        probe_overhead=float(dep.probe_overhead),
        sim_wall_time=sim_wall,
    )


def _run_in_worker(grid: CampaignGrid, cfg: SlothConfig | None,
                   detectors: tuple[str, ...],
                   s: Scenario) -> ScenarioOutcome:
    """Process-pool entry point: resolve the deployment from this worker
    process's own cache (lazily built), then run the scenario."""
    dep = _WORKER_CACHE.get(s.workload, s.mesh_w, s.mesh_h,
                            cfg=cfg, detectors=detectors)
    return run_scenario(grid, s, dep)


# ---------------------------------------------------------------------------
# campaign driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CampaignResult:
    grid: CampaignGrid
    detectors: tuple[str, ...]             # request order; [0] is primary
    outcomes: list[ScenarioOutcome]
    metrics: CampaignMetrics               # primary detector
    cells: dict[tuple, CampaignMetrics]    # primary detector, per cell
    detector_metrics: dict[str, CampaignMetrics]
    detector_cells: dict[str, dict[tuple, CampaignMetrics]]
    probe_overheads: dict[tuple, float]    # (workload, w, h) → overhead

    def summary(self) -> str:
        m = self.metrics
        lines = [
            f"scenarios: {m.n_scenarios}",
            f"primary:   {self.detectors[0]}",
            f"accuracy:  {m.accuracy.pct():.2f}% "
            f"({m.accuracy.successes}/{m.accuracy.trials}, "
            f"CI [{m.accuracy.interval[0]*100:.1f}, "
            f"{m.accuracy.interval[1]*100:.1f}])",
            f"FPR:       {m.fpr.pct():.2f}% "
            f"({m.fpr.successes}/{m.fpr.trials}, "
            f"CI [{m.fpr.interval[0]*100:.1f}, "
            f"{m.fpr.interval[1]*100:.1f}])",
        ] + [
            f"top-{k}:     {stat.pct():.2f}%" for k, stat in m.topk
        ] + [
            f"recall@{k}:  {stat.pct():.2f}% "
            f"({stat.successes}/{stat.trials})" for k, stat in m.recall
        ] + [
            f"compression: {m.mean_compression:.1f}x",
            f"probe overhead: {m.mean_probe_overhead*100:.3f}% "
            f"(scenario-weighted; unweighted per-deployment "
            f"{m.mean_probe_overhead_unweighted*100:.3f}%)",
        ]
        if len(self.detectors) > 1:
            lines.append("per-detector (acc / FPR / top-3 / recall@3):")
            for name, dm in self.detector_metrics.items():
                lines.append(
                    f"  {name:8s} {dm.accuracy.pct():6.2f}% "
                    f"{dm.fpr.pct():6.2f}% "
                    f"{dm.topk_rate(3)*100:6.2f}% "
                    f"{dm.recall_at(3)*100:6.2f}%")
        wall = wall_time_stats(self.outcomes)
        if wall:
            lines.append("wall time per scenario (mean / p95):")
            for name, w in wall.items():
                lines.append(f"  {name:8s} {w.mean*1e3:8.2f}ms "
                             f"{w.p95*1e3:8.2f}ms")
        return "\n".join(lines)


def run_campaign(grid: CampaignGrid, *, workers: int | None = None,
                 executor: str = "thread",
                 cfg: SlothConfig | None = None,
                 detectors=("sloth",),
                 baselines: bool | None = None,
                 cache: DeploymentCache | None = None,
                 progress=None) -> CampaignResult:
    """Run every scenario of ``grid`` and aggregate paper-style metrics.

    ``workers`` — pool width (``None`` → cpu count, ``0``/``1`` → serial).
    ``executor`` — ``'thread'`` (shared deployments, GIL-bound) or
    ``'process'`` (per-worker deployment caches, true multi-core; see the
    module docstring).  Outcomes are **bit-identical** across executors and
    worker counts.  ``detectors`` — registry names analysing every
    scenario's trace; the first is the primary detector for the top-level
    ``metrics``/``cells`` (per-detector tables are in
    ``detector_metrics``/``detector_cells``).  ``baselines`` is a
    deprecated alias: ``True`` maps to ``detectors=DEFAULT_DETECTORS``.
    ``cache`` — share deployments across campaigns (defaults to a
    process-wide cache; ignored by process-pool workers, which keep their
    own).
    """
    if executor not in EXECUTORS:
        raise ValueError(f"unknown executor {executor!r}; "
                         f"options: {EXECUTORS}")
    names = _normalise_detectors(detectors, baselines)
    scenarios = enumerate_scenarios(grid)
    workers = (os.cpu_count() or 1) if workers is None else workers
    parallel = workers > 1 and len(scenarios) > 1

    if executor == "process" and parallel:
        # spawn, not fork: the analysis pipeline jits through JAX, whose
        # thread pools make fork() after first use prone to deadlock.
        # Workers re-import the package cleanly (sys.path is inherited).
        ctx = multiprocessing.get_context("spawn")
        fn = functools.partial(_run_in_worker, grid, cfg, names)
        outcomes = []
        with ProcessPoolExecutor(max_workers=workers,
                                 mp_context=ctx) as pool:
            for o in pool.map(fn, scenarios):
                if progress is not None:
                    progress(o)
                outcomes.append(o)
    else:
        cache = cache if cache is not None else _DEFAULT_CACHE
        # Build deployments serially first: construction is the expensive,
        # cache-mutating step; scenario execution then only reads shared
        # state.
        deps: dict[tuple, Deployment] = {}
        for s in scenarios:
            k = (s.workload, s.mesh_w, s.mesh_h)
            if k not in deps:
                deps[k] = cache.get(s.workload, s.mesh_w, s.mesh_h,
                                    cfg=cfg, detectors=names)

        def run_one(s: Scenario) -> ScenarioOutcome:
            o = run_scenario(grid, s,
                             deps[(s.workload, s.mesh_w, s.mesh_h)])
            if progress is not None:
                progress(o)
            return o

        if parallel:
            with ThreadPoolExecutor(max_workers=workers) as pool:
                outcomes = list(pool.map(run_one, scenarios))
        else:
            outcomes = [run_one(s) for s in scenarios]

    det_metrics = by_detector(outcomes)
    det_cells = detector_cells(outcomes)
    primary = names[0]
    return CampaignResult(
        grid=grid, detectors=names, outcomes=outcomes,
        metrics=(det_metrics[primary] if outcomes else aggregate(outcomes)),
        cells=det_cells.get(primary, {}),
        detector_metrics=det_metrics,
        detector_cells=det_cells,
        probe_overheads=deployment_overheads(outcomes),
    )
