"""Computation-graph IR for SLOTH workloads.

Nodes are DNN operators (conv / gemm / pool / attention / moe / ssm ...)
annotated with FLOPs and output bytes; edges carry data volumes.  This is the
graph SL-Compiler analyses for probe insertion and the mapper partitions onto
the core mesh.  Builders are provided for the paper's five evaluation
workloads (DarkNet-19, GoogLeNet, VGG-16, ResNet-50, BinaryTree) and for the
assigned LM architectures (built from an ArchConfig).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

# Operator categories used by SL-Compiler's probe planner.
COMPUTE_OPS = frozenset(
    {"conv", "gemm", "attention", "moe_expert", "ssm_scan", "pool", "norm",
     "elemwise", "embed", "router"}
)
IO_OPS = frozenset({"input", "output"})


@dataclasses.dataclass
class OpNode:
    node_id: int
    name: str
    op_type: str          # one of COMPUTE_OPS | IO_OPS
    flops: float          # forward FLOPs of the operator
    out_bytes: float      # bytes produced (activation volume)
    stage: int            # execution stage (layer index) for grouping
    attrs: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Edge:
    src: int
    dst: int
    bytes: float


class CompGraph:
    """A DAG of DNN operators."""

    def __init__(self, name: str):
        self.name = name
        self.nodes: list[OpNode] = []
        self.edges: list[Edge] = []
        self._out: dict[int, list[Edge]] = {}
        self._in: dict[int, list[Edge]] = {}

    # -- construction -----------------------------------------------------
    def add_node(self, name, op_type, flops, out_bytes, stage, **attrs):
        nid = len(self.nodes)
        self.nodes.append(OpNode(nid, name, op_type, float(flops),
                                 float(out_bytes), int(stage), attrs))
        self._out[nid] = []
        self._in[nid] = []
        return nid

    def add_edge(self, src: int, dst: int, bytes: float | None = None):
        if bytes is None:
            bytes = self.nodes[src].out_bytes
        e = Edge(src, dst, float(bytes))
        self.edges.append(e)
        self._out[src].append(e)
        self._in[dst].append(e)
        return e

    # -- queries ----------------------------------------------------------
    def out_edges(self, nid: int) -> list[Edge]:
        return self._out[nid]

    def in_edges(self, nid: int) -> list[Edge]:
        return self._in[nid]

    def topo_order(self) -> list[int]:
        indeg = {n.node_id: len(self._in[n.node_id]) for n in self.nodes}
        frontier = [nid for nid, d in indeg.items() if d == 0]
        order: list[int] = []
        while frontier:
            nid = frontier.pop()
            order.append(nid)
            for e in self._out[nid]:
                indeg[e.dst] -= 1
                if indeg[e.dst] == 0:
                    frontier.append(e.dst)
        if len(order) != len(self.nodes):
            raise ValueError(f"graph {self.name} has a cycle")
        return order

    @property
    def n_stages(self) -> int:
        return 1 + max(n.stage for n in self.nodes)

    def total_flops(self) -> float:
        return sum(n.flops for n in self.nodes)

    def __repr__(self):
        return (f"CompGraph({self.name!r}, nodes={len(self.nodes)}, "
                f"edges={len(self.edges)}, stages={self.n_stages})")


# ---------------------------------------------------------------------------
# CNN builders (paper workloads).  All are batch-64 inference graphs by
# default, matching the paper's throughput-oriented setting.
# ---------------------------------------------------------------------------

_BYTES = 2  # activations in bf16/fp16 on-chip


def _conv(g, name, stage, prev, hw, cin, cout, k, batch, stride=1):
    h = w = hw // stride
    flops = 2.0 * k * k * cin * cout * h * w * batch
    out_b = h * w * cout * batch * _BYTES
    nid = g.add_node(name, "conv", flops, out_b, stage,
                     hw=h, cin=cin, cout=cout, k=k)
    if prev is not None:
        g.add_edge(prev, nid)
    return nid, h


def _pool(g, name, stage, prev, hw, c, batch, stride=2):
    h = hw // stride
    flops = hw * hw * c * batch  # one op per input element
    out_b = h * h * c * batch * _BYTES
    nid = g.add_node(name, "pool", flops, out_b, stage, hw=h, c=c)
    g.add_edge(prev, nid)
    return nid, h


def _fc(g, name, stage, prev, fan_in, fan_out, batch):
    flops = 2.0 * fan_in * fan_out * batch
    out_b = fan_out * batch * _BYTES
    nid = g.add_node(name, "gemm", flops, out_b, stage, fan_in=fan_in,
                     fan_out=fan_out)
    g.add_edge(prev, nid)
    return nid


def build_vgg16(batch: int = 64) -> CompGraph:
    g = CompGraph("vgg16")
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]
    inp = g.add_node("input", "input", 0, 224 * 224 * 3 * batch * _BYTES, 0)
    prev, hw, cin, stage = inp, 224, 3, 1
    for i, c in enumerate(cfg):
        if c == "M":
            prev, hw = _pool(g, f"pool{stage}", stage, prev, hw, cin, batch)
        else:
            prev, hw = _conv(g, f"conv{i}", stage, prev, hw, cin, c, 3, batch)
            cin = c
        stage += 1
    prev = _fc(g, "fc6", stage, prev, 7 * 7 * 512, 4096, batch)
    prev = _fc(g, "fc7", stage + 1, prev, 4096, 4096, batch)
    prev = _fc(g, "fc8", stage + 2, prev, 4096, 1000, batch)
    out = g.add_node("output", "output", 0, 1000 * batch * _BYTES, stage + 3)
    g.add_edge(prev, out)
    return g


def build_darknet19(batch: int = 64) -> CompGraph:
    g = CompGraph("darknet19")
    # (cout, k) sequences with maxpools, per the DarkNet-19 table.
    blocks = [
        [(32, 3)], "M", [(64, 3)], "M",
        [(128, 3), (64, 1), (128, 3)], "M",
        [(256, 3), (128, 1), (256, 3)], "M",
        [(512, 3), (256, 1), (512, 3), (256, 1), (512, 3)], "M",
        [(1024, 3), (512, 1), (1024, 3), (512, 1), (1024, 3)],
    ]
    inp = g.add_node("input", "input", 0, 224 * 224 * 3 * batch * _BYTES, 0)
    prev, hw, cin, stage = inp, 224, 3, 1
    idx = 0
    for blk in blocks:
        if blk == "M":
            prev, hw = _pool(g, f"pool{stage}", stage, prev, hw, cin, batch)
            stage += 1
            continue
        for cout, k in blk:
            prev, hw = _conv(g, f"conv{idx}", stage, prev, hw, cin, cout, k,
                             batch)
            cin = cout
            stage += 1
            idx += 1
    prev, _ = _conv(g, "conv_cls", stage, prev, hw, cin, 1000, 1, batch)
    out = g.add_node("output", "output", 0, 1000 * batch * _BYTES, stage + 1)
    g.add_edge(prev, out)
    return g


def build_resnet50(batch: int = 64) -> CompGraph:
    g = CompGraph("resnet50")
    inp = g.add_node("input", "input", 0, 224 * 224 * 3 * batch * _BYTES, 0)
    prev, hw = _conv(g, "conv1", 1, inp, 224, 3, 64, 7, batch, stride=2)
    prev, hw = _pool(g, "pool1", 2, prev, hw, 64, batch)
    stage = 3
    cin = 64
    # (n_blocks, mid_channels, out_channels, first_stride)
    stages = [(3, 64, 256, 1), (4, 128, 512, 2), (6, 256, 1024, 2),
              (3, 512, 2048, 2)]
    for si, (nblk, mid, cout, stride0) in enumerate(stages):
        for b in range(nblk):
            stride = stride0 if b == 0 else 1
            skip_src = prev
            p, hw2 = _conv(g, f"s{si}b{b}_c1", stage, prev, hw, cin, mid, 1,
                           batch, stride=stride)
            p, hw2 = _conv(g, f"s{si}b{b}_c2", stage + 1, p, hw2, mid, mid, 3,
                           batch)
            p, hw2 = _conv(g, f"s{si}b{b}_c3", stage + 2, p, hw2, mid, cout,
                           1, batch)
            if b == 0:  # projection shortcut
                sp, _ = _conv(g, f"s{si}b{b}_proj", stage, skip_src, hw, cin,
                              cout, 1, batch, stride=stride)
                skip_src = sp
            add = g.add_node(f"s{si}b{b}_add", "elemwise",
                             hw2 * hw2 * cout * batch,
                             hw2 * hw2 * cout * batch * _BYTES, stage + 3)
            g.add_edge(p, add)
            g.add_edge(skip_src, add)
            prev, hw, cin = add, hw2, cout
            stage += 4
    prev = _fc(g, "fc", stage, prev, 2048, 1000, batch)
    out = g.add_node("output", "output", 0, 1000 * batch * _BYTES, stage + 1)
    g.add_edge(prev, out)
    return g


# GoogLeNet inception channel table: (1x1, 3x3red, 3x3, 5x5red, 5x5, pool_proj)
_INCEPTION = {
    "3a": (64, 96, 128, 16, 32, 32), "3b": (128, 128, 192, 32, 96, 64),
    "4a": (192, 96, 208, 16, 48, 64), "4b": (160, 112, 224, 24, 64, 64),
    "4c": (128, 128, 256, 24, 64, 64), "4d": (112, 144, 288, 32, 64, 64),
    "4e": (256, 160, 320, 32, 128, 128), "5a": (256, 160, 320, 32, 128, 128),
    "5b": (384, 192, 384, 48, 128, 128),
}


def build_googlenet(batch: int = 64) -> CompGraph:
    g = CompGraph("googlenet")
    inp = g.add_node("input", "input", 0, 224 * 224 * 3 * batch * _BYTES, 0)
    prev, hw = _conv(g, "conv1", 1, inp, 224, 3, 64, 7, batch, stride=2)
    prev, hw = _pool(g, "pool1", 2, prev, hw, 64, batch)
    prev, hw = _conv(g, "conv2a", 3, prev, hw, 64, 64, 1, batch)
    prev, hw = _conv(g, "conv2b", 4, prev, hw, 64, 192, 3, batch)
    prev, hw = _pool(g, "pool2", 5, prev, hw, 192, batch)
    cin, stage = 192, 6
    for name, (c1, c3r, c3, c5r, c5, cp) in _INCEPTION.items():
        # four parallel branches — this is the branching structure that makes
        # GoogLeNet interesting for propagation analysis.
        b1, _ = _conv(g, f"in{name}_1x1", stage, prev, hw, cin, c1, 1, batch)
        b3r, _ = _conv(g, f"in{name}_3r", stage, prev, hw, cin, c3r, 1, batch)
        b3, _ = _conv(g, f"in{name}_3x3", stage + 1, b3r, hw, c3r, c3, 3,
                      batch)
        b5r, _ = _conv(g, f"in{name}_5r", stage, prev, hw, cin, c5r, 1, batch)
        b5, _ = _conv(g, f"in{name}_5x5", stage + 1, b5r, hw, c5r, c5, 5,
                      batch)
        bp, _ = _pool(g, f"in{name}_pool", stage, prev, hw, cin, batch,
                      stride=1)
        bpp, _ = _conv(g, f"in{name}_pp", stage + 1, bp, hw, cin, cp, 1,
                       batch)
        cout = c1 + c3 + c5 + cp
        cat = g.add_node(f"in{name}_cat", "elemwise",
                         hw * hw * cout * batch,
                         hw * hw * cout * batch * _BYTES, stage + 2)
        for b in (b1, b3, b5, bpp):
            g.add_edge(b, cat)
        prev, cin = cat, cout
        stage += 3
        if name in ("3b", "4e"):
            prev, hw = _pool(g, f"pool_{name}", stage, prev, hw, cin, batch)
            stage += 1
    prev = _fc(g, "fc", stage, prev, 1024, 1000, batch)
    out = g.add_node("output", "output", 0, 1000 * batch * _BYTES, stage + 1)
    g.add_edge(prev, out)
    return g


def build_binary_tree(depth: int = 5, dim: int = 256,
                      batch: int = 64) -> CompGraph:
    """Synthetic binary-tree microbenchmark: each node is a matrix op."""
    g = CompGraph("binary_tree")
    flops = 2.0 * dim * dim * dim
    out_b = dim * dim * _BYTES * batch // 64
    roots = [g.add_node("leaf%d" % i, "gemm", flops, out_b, 0)
             for i in range(2 ** depth)]
    stage = 1
    while len(roots) > 1:
        nxt = []
        for i in range(0, len(roots), 2):
            nid = g.add_node(f"n{stage}_{i // 2}", "gemm", flops, out_b,
                             stage)
            g.add_edge(roots[i], nid)
            g.add_edge(roots[i + 1], nid)
            nxt.append(nid)
        roots = nxt
        stage += 1
    out = g.add_node("output", "output", 0, out_b, stage)
    g.add_edge(roots[0], out)
    return g


# ---------------------------------------------------------------------------
# LM-architecture builder (ties SLOTH to the assigned architectures).
# ---------------------------------------------------------------------------

def build_lm_graph(cfg, seq: int = 512, batch: int = 8,
                   max_layers: int | None = None) -> CompGraph:
    """Build an operator graph for one of the assigned LM architectures.

    ``cfg`` is a ``repro.configs.base.ArchConfig``.  Per layer we emit the
    block's operators (attention / MoE / SSM) with edges carrying activation
    volumes, so SLOTH sees the same dataflow the accelerator would run.
    """
    g = CompGraph(f"lm:{cfg.name}")
    d = cfg.d_model
    tok_bytes = seq * batch * d * _BYTES
    inp = g.add_node("embed", "embed", 2.0 * seq * batch * d,
                     tok_bytes, 0)
    prev = inp
    n_layers = cfg.n_layers if max_layers is None else min(cfg.n_layers,
                                                           max_layers)
    stage = 1
    for li in range(n_layers):
        kind = cfg.layer_kind(li)
        norm = g.add_node(f"l{li}_norm", "norm", 5.0 * seq * batch * d,
                          tok_bytes, stage)
        g.add_edge(prev, norm)
        if kind == "mamba":
            d_inner = cfg.ssm_expand * d
            proj = g.add_node(f"l{li}_inproj", "gemm",
                              2.0 * seq * batch * d * 2 * d_inner,
                              2 * tok_bytes, stage)
            g.add_edge(norm, proj)
            scan = g.add_node(f"l{li}_ssd", "ssm_scan",
                              6.0 * seq * batch * d_inner * cfg.ssm_state,
                              tok_bytes, stage + 1)
            g.add_edge(proj, scan)
            mix = g.add_node(f"l{li}_outproj", "gemm",
                             2.0 * seq * batch * d_inner * d, tok_bytes,
                             stage + 1)
            g.add_edge(scan, mix)
        else:
            h_dim = cfg.head_dim * cfg.n_heads
            kv_dim = cfg.head_dim * cfg.n_kv_heads
            qkv = g.add_node(f"l{li}_qkv", "gemm",
                             2.0 * seq * batch * d * (h_dim + 2 * kv_dim),
                             tok_bytes, stage)
            g.add_edge(norm, qkv)
            w = cfg.window if cfg.window else seq
            attn_ctx = min(seq, w)
            attn = g.add_node(f"l{li}_attn", "attention",
                              4.0 * seq * attn_ctx * batch * h_dim,
                              tok_bytes, stage + 1)
            g.add_edge(qkv, attn)
            mix = g.add_node(f"l{li}_oproj", "gemm",
                             2.0 * seq * batch * h_dim * d, tok_bytes,
                             stage + 1)
            g.add_edge(attn, mix)
        res1 = g.add_node(f"l{li}_res1", "elemwise", seq * batch * d,
                          tok_bytes, stage + 2)
        g.add_edge(mix, res1)
        g.add_edge(prev, res1)
        # FFN / MoE
        norm2 = g.add_node(f"l{li}_norm2", "norm", 5.0 * seq * batch * d,
                           tok_bytes, stage + 2)
        g.add_edge(res1, norm2)
        if cfg.is_moe_layer(li):
            router = g.add_node(f"l{li}_router", "router",
                                2.0 * seq * batch * d * cfg.n_experts,
                                seq * batch * cfg.n_experts * _BYTES,
                                stage + 3)
            g.add_edge(norm2, router)
            # each expert processes ~(top_k / n_experts) of the tokens
            frac = cfg.top_k / cfg.n_experts
            eflops = 3 * 2.0 * seq * batch * frac * d * cfg.d_ff
            agg = g.add_node(f"l{li}_moe_agg", "elemwise",
                             seq * batch * d * cfg.top_k, tok_bytes,
                             stage + 4)
            for ei in range(cfg.n_experts):
                ex = g.add_node(f"l{li}_e{ei}", "moe_expert", eflops,
                                tok_bytes * frac, stage + 3, expert=ei)
                g.add_edge(router, ex, bytes=tok_bytes * frac)
                g.add_edge(ex, agg, bytes=tok_bytes * frac)
            ffn_out = agg
        else:
            n_mats = 3 if cfg.mlp == "swiglu" else 2
            up = g.add_node(f"l{li}_ffn", "gemm",
                            n_mats * 2.0 * seq * batch * d * cfg.d_ff,
                            tok_bytes, stage + 3)
            g.add_edge(norm2, up)
            ffn_out = up
        res2 = g.add_node(f"l{li}_res2", "elemwise", seq * batch * d,
                          tok_bytes, stage + 4)
        g.add_edge(ffn_out, res2)
        g.add_edge(res1, res2)
        prev = res2
        stage += 5
    head = g.add_node("lm_head", "gemm", 2.0 * seq * batch * d * cfg.vocab,
                      seq * batch * min(cfg.vocab, 4096) * _BYTES, stage)
    g.add_edge(prev, head)
    out = g.add_node("output", "output", 0, 0, stage + 1)
    g.add_edge(head, out)
    return g


WORKLOAD_BUILDERS = {
    "darknet19": build_darknet19,
    "googlenet": build_googlenet,
    "vgg16": build_vgg16,
    "resnet50": build_resnet50,
    "binary_tree": build_binary_tree,
}


def build_workload(name: str, **kw) -> CompGraph:
    if name in WORKLOAD_BUILDERS:
        return WORKLOAD_BUILDERS[name](**kw)
    raise KeyError(f"unknown workload {name!r}; "
                   f"options: {sorted(WORKLOAD_BUILDERS)}")
