"""Fail-slow failure model and dataset generation (paper §IV-A).

A fail-slow instance is (kind, location, t0, duration, slowdown).  The
dataset mirrors the paper: 152 base instances at a 7:3 core:link split
(optionally diluted by a router share, see ``make_dataset``'s
``router_ratio``), onsets U(0, 6 s), durations **U(1, 10) s**, 10×
slowdown, scaled proportionally for larger meshes, plus an equal pool of
negative (failure-free) samples.

The duration range deserves a note: the paper's §IV-A draws failure
windows uniformly over the ~10 s run, which taken literally is U(0, 10 s).
We truncate the low end at 1 s — sub-second windows on an ≈8 s simulated
run inject so few affected records that no detector (SLOTH or baseline)
has evidence to act on, and the paper itself excludes failures that
"cannot affect execution".  U(1, 10) s is therefore the modelled
distribution everywhere: this docstring, ``make_dataset`` (whose
``min_dur``/``max_dur`` parameters expose it) and the drawn samples agree.
(The module docstring used to say "U(0, 10 s)" while the code drew
``uniform(1, 10)`` — the code's range was the intended one.)
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .routing import Topology


@dataclasses.dataclass(frozen=True)
class FailSlow:
    kind: str          # 'core' | 'link' | 'router'
    location: int      # core id, link id or router(core) id
    t0: float
    duration: float
    slowdown: float = 10.0

    def label(self) -> tuple[str, int]:
        return (self.kind, self.location)


def truth_candidates(failure: FailSlow, mesh: Topology) \
        -> set[tuple[str, int]]:
    """Acceptable (kind, location) verdicts for an injected failure.

    This is the single router-aware judging rule shared by
    ``Verdict.matches``, the campaign judge and the baseline scoring: the
    detector localises at core/link granularity, so a router failure is
    correctly localised by naming any link of the slowed router."""
    if failure.kind == "router":
        return {("link", lid)
                for lid in mesh.links_of_router(failure.location)}
    return {(failure.kind, failure.location)}


def judge_verdict(verdict, failures, mesh: Topology) \
        -> tuple[bool, int | None, tuple, set[tuple[str, int]]]:
    """(matched, best_rank, per_failure_ranks, candidate_union) for one
    verdict against a set of ground truths — the single judging rule every
    detector is scored by.

    ``verdict`` is any unified :class:`~repro.core.detectors.Verdict`
    (duck-typed: ``flagged``, ``ranking``, ``matches``).  Matching is
    router-aware via :func:`truth_candidates`: matched means the top-1
    verdict names *any* injected truth; ranks are 1-based positions of
    each truth in the ranking (``None`` when unranked); the union of
    acceptable (kind, location) answers is returned for callers that score
    auxiliary signals by the same rule.  An empty ``failures`` tuple is a
    negative sample: matched ⇔ not flagged.
    """
    if not failures:
        return (not verdict.flagged), None, (), set()
    ranks: list[int | None] = []
    union: set[tuple[str, int]] = set()
    for f in failures:
        cands = truth_candidates(f, mesh)
        union |= cands
        rank = None
        for i, (k, l, _) in enumerate(verdict.ranking):
            if (k, l) in cands:
                rank = i + 1
                break
        ranks.append(rank)
    matched = any(verdict.matches(f, mesh) for f in failures)
    ranked = [r for r in ranks if r is not None]
    return matched, (min(ranked) if ranked else None), tuple(ranks), union


@dataclasses.dataclass(frozen=True)
class Sample:
    """One evaluation sample: zero or one injected failure."""
    sample_id: int
    failure: FailSlow | None   # None → negative sample

    @property
    def positive(self) -> bool:
        return self.failure is not None


def effective_samples(samples: list[Sample], healthy_total: float,
                      used_links: set[int] | None = None,
                      mesh: Topology | None = None) -> list[Sample]:
    """Drop positive samples that cannot affect execution (the paper:
    "failures ... occurring on unused resources are excluded"): failures
    starting after the run completes, links that carry no traffic, and —
    when ``mesh`` is provided alongside ``used_links`` — routers none of
    whose adjacent links carry traffic (a router slows only its links, so
    such a failure is unobservable and would be an unwinnable positive in
    any accuracy evaluation)."""
    out = []
    for s in samples:
        f = s.failure
        if f is not None:
            if f.t0 >= healthy_total * 0.98:
                continue
            if f.kind == "link" and used_links is not None \
                    and f.location not in used_links:
                continue
            if f.kind == "router" and used_links is not None \
                    and mesh is not None \
                    and not any(lid in used_links
                                for lid in mesh.links_of_router(
                                    f.location)):
                continue
        out.append(s)
    return out


def make_dataset(mesh: Topology, n_failures: int = 152, seed: int = 7,
                 core_link_ratio: float = 0.7, max_t0: float = 6.0,
                 slowdown: float = 10.0, base_cores: int = 16,
                 n_negatives: int | None = None,
                 router_ratio: float = 0.0,
                 min_dur: float = 1.0, max_dur: float = 10.0) \
        -> list[Sample]:
    """Generate the fail-slow dataset.

    ``n_failures`` is scaled by mesh size relative to the paper's 4×4 chip
    ("for larger architectures we generate additional failures proportional
    to the expanded resource count").  Durations are **U(min_dur,
    max_dur) = U(1, 10) s** by default — see the module docstring for why
    the low end is truncated at 1 s rather than the paper's literal 0.

    ``router_ratio`` is the fraction of positives injected as router
    fail-slows (a router slows every adjacent link); the remainder keeps
    the paper's ``core_link_ratio`` core:link split.  The default of 0.0
    preserves the historical core/link-only draws bit-for-bit at any seed,
    so existing evaluations are unaffected; any positive value makes
    dataset-driven evaluation cover all three kinds that ``FailSlow``,
    ``truth_candidates`` and the campaign grid already support.
    """
    if not 0.0 <= router_ratio <= 1.0:
        raise ValueError(f"router_ratio must be in [0, 1], "
                         f"got {router_ratio}")
    rng = np.random.default_rng(seed)
    scale = mesh.n_cores / base_cores
    n_pos = max(1, int(round(n_failures * scale)))
    n_neg = n_pos if n_negatives is None else n_negatives

    samples: list[Sample] = []
    for i in range(n_pos):
        # one uniform draw decides the kind: the top router_ratio slice
        # goes to routers, the rest splits core:link at core_link_ratio —
        # with router_ratio=0 the draw sequence (and therefore every
        # sample) is identical to the historical two-kind generator
        r = rng.random()
        if r >= 1.0 - router_ratio:
            kind = "router"
            loc = int(rng.integers(mesh.n_cores))
        elif r < core_link_ratio * (1.0 - router_ratio):
            kind = "core"
            loc = int(rng.integers(mesh.n_cores))
        else:
            kind = "link"
            loc = int(rng.integers(mesh.n_links))
        t0 = float(rng.uniform(0.0, max_t0))
        dur = float(rng.uniform(min_dur, max_dur))
        samples.append(Sample(i, FailSlow(kind, loc, t0, dur, slowdown)))
    for i in range(n_neg):
        samples.append(Sample(n_pos + i, None))
    return samples
