"""Event-driven simulator of a many-core DNN accelerator.

This is the paper's evaluation platform (§IV-A): a discrete-event model of a
2D-mesh many-core accelerator executing a mapped dataflow graph, with

* per-core compute capacity  P_core ~ Normal(mu_c, sigma_c^2),
* per-hop link transfer time with multiplicative Gamma(shape, scale) jitter
  (T_link ~ Gamma), matching the paper's statistical hardware model,
* store-and-forward XY routing with per-link occupancy (contention) and
  hardware backpressure: a consumer cannot start until its inputs arrive, so
  one slow core/link stalls the dependent region of the chip,
* fail-slow injection on cores, links or routers (a router slows all its
  adjacent links), active during a [t0, t0+dur) window.  A single run may
  carry failures of *different kinds at once* (mixed-kind scenarios):
  core and link windows live in separate per-resource tables, so they
  coexist independently, and overlapping windows on one resource compound
  multiplicatively,
* probe-cost accounting so SL-Compiler's instrumentation overhead (Fig 10)
  is measurable.

Execution order is event-driven (heapq): dataflow-triggered, cores process
ready tasks serially — the paper's data-driven execution model.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from .failures import FailSlow
from .mapping import MappedGraph
from .routing import Topology

OP_TYPE_IDS = {"conv": 0, "gemm": 1, "pool": 2, "elemwise": 3, "norm": 4,
               "attention": 5, "moe_expert": 6, "ssm_scan": 7, "router": 8,
               "embed": 9, "input": 10, "output": 11}


@dataclasses.dataclass
class SimConfig:
    mu_c: float = 1e12          # mean core capacity, FLOP/s
    sigma_frac: float = 0.03    # static per-core capacity spread
    jitter_frac: float = 0.01   # per-task temporal noise
    link_bw: float = 64e9       # per-link bandwidth, B/s
    gamma_shape: float = 16.0   # link-latency Gamma shape (mean kept at 1)
    hop_latency: float = 50e-9  # per-hop router latency, s
    probe_cost: float = 10e-9   # one probe record (≈10 cycles @ 1 GHz)
    seed: int = 0


@dataclasses.dataclass
class ProbePlan:
    """What the inserted probes record (produced by SL-Compiler)."""
    comp: bool = True           # Exec/Comp probes on compute tasks
    comm: bool = True           # Route/Comm probes on messages
    level: str = "stage"        # 'stage': 1 record/task, 'inst': 4/task
    surround: bool = True       # Pre+Post (2 clock reads) vs single

    def records_per_task(self) -> int:
        return 4 if self.level == "inst" else 1

    def cost_per_record(self, probe_cost: float) -> float:
        return (2 if self.surround else 1) * probe_cost


@dataclasses.dataclass
class SimResult:
    total_time: float
    # compute trace (one row per record)
    comp: dict[str, np.ndarray]
    # communication trace (one row per flow)
    comm: dict[str, np.ndarray]
    n_raw_records: int

    def raw_trace_bytes(self) -> int:
        """Storage for the uncompressed trace (the paper's 'raw format':
        index, timestamps, operands...).  ~48B per compute record and ~56B
        per communication record."""
        return 48 * len(self.comp["core"]) + 56 * len(self.comm["src"])


def calibrate(graph_total_flops: float, n_cores: int,
              target_time: float = 8.0) -> float:
    """Pick mu_c so the healthy run takes ≈target_time simulated seconds,
    keeping U(1,10s) failure windows meaningful across workloads.  0.85 is
    the measured average core utilisation under the Gemini-like mapping
    (execution is compute-dominated; waits overlap with other tasks)."""
    return graph_total_flops / (0.85 * n_cores * target_time)


class _CoreState:
    __slots__ = ("free_at", "pending")

    def __init__(self):
        self.free_at = 0.0
        self.pending: list = []   # heap of (stage, node_id, part, task_id)


def simulate(mapped: MappedGraph, cfg: SimConfig,
             failures: list[FailSlow] | None = None,
             probes: ProbePlan | None = None) -> SimResult:
    mesh: Topology = mapped.mesh
    rng = np.random.default_rng(cfg.seed)
    failures = failures or []

    # --- static hardware state -------------------------------------------
    # Per-core baseline capacity scales with the fabric's rate class
    # (all-ones on homogeneous fabrics — multiplying by exact 1.0 keeps
    # the historical mesh capacity draws bit-identical).
    rate = np.asarray(getattr(mesh, "rate_class", 1.0), dtype=np.float64)
    cap = cfg.mu_c * rate * (1.0 + cfg.sigma_frac * rng.standard_normal(
        mesh.n_cores))
    cap = np.maximum(cap, 0.05 * cfg.mu_c * rate)
    link_bw = np.full(mesh.n_links, cfg.link_bw)

    # Each resource carries a *list* of slowdown windows: simultaneous
    # fail-slow failures may overlap on one resource (e.g. two routers
    # slowing a shared link, or two windows on the same core), and
    # overlapping active windows compound multiplicatively.
    core_fail: dict[int, list[tuple[float, float, float]]] = {}
    link_fail: dict[int, list[tuple[float, float, float]]] = {}
    for f in failures:
        win = (f.t0, f.t0 + f.duration, f.slowdown)
        if f.kind == "core":
            core_fail.setdefault(f.location, []).append(win)
        elif f.kind == "link":
            link_fail.setdefault(f.location, []).append(win)
        elif f.kind == "router":
            for lid in mesh.links_of_router(f.location):
                link_fail.setdefault(lid, []).append(win)
        else:
            raise ValueError(f.kind)

    def _active_slowdown(windows, t: float) -> float:
        s = 1.0
        for t0, t1, slow in windows:
            if t0 <= t < t1:
                s *= slow
        return s

    def core_capacity(c: int, t: float) -> float:
        ws = core_fail.get(c)
        if ws:
            return cap[c] / _active_slowdown(ws, t)
        return cap[c]

    def link_rate(lid: int, t: float) -> float:
        ws = link_fail.get(lid)
        if ws:
            return link_bw[lid] / _active_slowdown(ws, t)
        return link_bw[lid]

    # --- task graph bookkeeping -------------------------------------------
    tasks = mapped.tasks
    n_tasks = len(tasks)
    in_count = np.zeros(n_tasks, dtype=np.int64)
    out_flows: dict[int, list[int]] = {t.task_id: [] for t in tasks}
    for fi, fl in enumerate(mapped.flows):
        in_count[fl.dst_task] += 1
        out_flows[fl.src_task].append(fi)

    probe_task_cost = 0.0
    probe_msg_cost = 0.0
    n_probe_records = 0
    if probes is not None:
        per_rec = probes.cost_per_record(cfg.probe_cost)
        if probes.comp:
            probe_task_cost = probes.records_per_task() * per_rec
        if probes.comm:
            probe_msg_cost = per_rec

    cores = [_CoreState() for _ in range(mesh.n_cores)]
    link_free = np.zeros(mesh.n_links)

    # trace buffers
    tc_core, tc_node, tc_part, tc_stage, tc_op, tc_flops = \
        [], [], [], [], [], []
    tc_start, tc_end = [], []
    tm_src, tm_dst, tm_stage, tm_bytes, tm_dep, tm_arr, tm_hops = \
        [], [], [], [], [], [], []
    tm_svc = []   # queue-free service time (what per-packet minima estimate)

    heap: list = []
    seq = 0

    def push(t, kind, payload):
        nonlocal seq
        heapq.heappush(heap, (t, seq, kind, payload))
        seq += 1

    def try_start(c: int, now: float):
        st = cores[c]
        if st.free_at > now or not st.pending:
            return
        _, _, _, tid = heapq.heappop(st.pending)
        task = tasks[tid]
        t0 = max(now, st.free_at)
        capacity = core_capacity(c, t0)
        jitter = 1.0 + cfg.jitter_frac * abs(rng.standard_normal())
        dur = task.flops * jitter / capacity if task.flops > 0 else 0.0
        dur += probe_task_cost
        st.free_at = t0 + dur
        if task.flops > 0:
            n = probes.records_per_task() if probes else 1
            for k in range(n):
                tc_core.append(c)
                tc_node.append(task.node_id)
                tc_part.append(task.part)
                tc_stage.append(task.stage)
                tc_op.append(OP_TYPE_IDS.get(task.op_type, 3))
                tc_flops.append(task.flops / n)
                tc_start.append(t0 + dur * k / n)
                tc_end.append(t0 + dur * (k + 1) / n)
        push(t0 + dur, "done", tid)

    def ready(tid: int, t: float):
        task = tasks[tid]
        st = cores[task.core]
        heapq.heappush(st.pending, (task.stage, task.node_id, task.part, tid))
        try_start(task.core, max(t, st.free_at))

    for t in tasks:
        if in_count[t.task_id] == 0:
            ready(t.task_id, 0.0)

    global_nprobe = 0
    while heap:
        now, _, kind, payload = heapq.heappop(heap)
        if kind == "done":
            task = tasks[payload]
            try_start(task.core, now)
            for fi in out_flows[payload]:
                fl = mapped.flows[fi]
                t_dep = now + probe_msg_cost
                if fl.src_core == fl.dst_core:
                    t_arr, hops, svc = t_dep, 0, 0.0
                else:
                    path = mesh.route(fl.src_core, fl.dst_core)
                    hops = len(path)
                    t_cur = t_dep
                    svc = 0.0
                    g_jit = rng.gamma(cfg.gamma_shape,
                                      1.0 / cfg.gamma_shape)
                    for lid in path:
                        t_s = max(t_cur, link_free[lid])
                        dt = (fl.bytes * g_jit / link_rate(lid, t_s)
                              + cfg.hop_latency)
                        svc += dt
                        link_free[lid] = t_s + dt
                        t_cur = t_s + dt
                    t_arr = t_cur
                if probes is None or probes.comm:
                    tm_src.append(fl.src_core)
                    tm_dst.append(fl.dst_core)
                    tm_stage.append(fl.stage)
                    tm_bytes.append(fl.bytes)
                    tm_dep.append(t_dep)
                    tm_arr.append(t_arr)
                    tm_hops.append(hops)
                    tm_svc.append(svc)
                push(t_arr, "arrive", fi)
        else:  # arrive
            fl = mapped.flows[payload]
            in_count[fl.dst_task] -= 1
            if in_count[fl.dst_task] == 0:
                ready(fl.dst_task, now)

    total = 0.0
    if tc_end:
        total = max(total, max(tc_end))
    if tm_arr:
        total = max(total, max(tm_arr))

    comp = {
        "core": np.asarray(tc_core, dtype=np.int32),
        "node": np.asarray(tc_node, dtype=np.int32),
        "part": np.asarray(tc_part, dtype=np.int32),
        "stage": np.asarray(tc_stage, dtype=np.int32),
        "op": np.asarray(tc_op, dtype=np.int32),
        "flops": np.asarray(tc_flops, dtype=np.float64),
        "t_start": np.asarray(tc_start, dtype=np.float64),
        "t_end": np.asarray(tc_end, dtype=np.float64),
    }
    comm = {
        "src": np.asarray(tm_src, dtype=np.int32),
        "dst": np.asarray(tm_dst, dtype=np.int32),
        "stage": np.asarray(tm_stage, dtype=np.int32),
        "bytes": np.asarray(tm_bytes, dtype=np.float64),
        "t_depart": np.asarray(tm_dep, dtype=np.float64),
        "t_arrive": np.asarray(tm_arr, dtype=np.float64),
        "hops": np.asarray(tm_hops, dtype=np.int32),
        "service": np.asarray(tm_svc, dtype=np.float64),
    }
    return SimResult(total_time=total, comp=comp, comm=comm,
                     n_raw_records=len(tc_core) + len(tm_src))


# --- mitigation re-simulation --------------------------------------------

def clip_failures(failures: list[FailSlow] | None,
                  from_time: float) -> list[FailSlow]:
    """Remaining failure windows at ``from_time``, re-based to t=0.

    A mitigated deployment restarts its clock: a window ``[t0, t0+dur)``
    becomes ``[max(t0 - from_time, 0), end - from_time)`` and is dropped
    entirely once it has already elapsed.  ``from_time=0`` is the identity.
    """
    out: list[FailSlow] = []
    for f in failures or []:
        end = f.t0 + f.duration
        if end <= from_time:
            continue
        t0 = max(f.t0 - from_time, 0.0)
        out.append(dataclasses.replace(f, t0=t0, duration=end - from_time - t0))
    return out


def simulate_mitigated(mapped: MappedGraph, cfg: SimConfig,
                       failures: list[FailSlow] | None = None,
                       probes: ProbePlan | None = None,
                       from_time: float = 0.0) -> SimResult:
    """Re-simulate a mitigated mapping over the *remaining* failure window.

    ``mapped`` is the post-mitigation deployment (remapped tasks and/or a
    :class:`~repro.core.routing.DetourMesh`); ``from_time`` is the stream
    time at which mitigation engaged (0.0 models a post-hoc restart).  The
    still-active slowdown windows are clipped and re-based so a mitigation
    that merely sidesteps an expired failure gets no spurious credit.
    """
    return simulate(mapped, cfg, failures=clip_failures(failures, from_time),
                    probes=probes)
