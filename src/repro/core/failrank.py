"""FailRank: PageRank-inspired root-cause ranking on the MCG (§III-D3).

Node update:  s⁽ᵏ⁺¹⁾(v) = (1−λ)·s₀(v) + λ·Σ_{(u,v)∈E} w(u,v)·s⁽ᵏ⁾(u)
Edge update:  l⁽ᵏ⁺¹⁾(u,v) = α·w(u,v) + β·s⁽ᵏ⁾(u) + γ·l⁽ᵏ⁾(u,v)

with the paper's coefficients α=0.1, β=0.3, γ=0.6 and damping λ.  The
iteration stops when ‖v⁽ᵏ⁾−v⁽ᵏ⁻¹⁾‖₁ < ε (=1e-4, ≲17 iterations in the
paper); final scores are softmax-normalised within each MCG level.

Implementation: the MCG is sparse (mesh + DRAM edges), so the propagation
step is a segment-sum gather/scatter; it runs under ``jax.lax.while_loop``
and is jit-compiled.  A Pallas TPU kernel for the fused step lives in
``repro.kernels.failrank_step`` (dense blocked form); this module uses the
XLA path and returns the per-iteration residual trace for the convergence
analysis (Fig 15).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .mcg import MCG


@dataclasses.dataclass(frozen=True)
class FailRankParams:
    lam: float = 0.55          # damping λ
    alpha: float = 0.1         # edge: propagation-weight term
    beta: float = 0.3          # edge: source-node term
    gamma: float = 0.6         # edge: momentum term
    eps: float = 1e-4          # L1 convergence tolerance
    max_iters: int = 100


@dataclasses.dataclass
class FailRankResult:
    node_scores: np.ndarray        # softmax-normalised per level
    edge_scores: np.ndarray
    raw_node_scores: np.ndarray    # pre-softmax (for thresholding)
    raw_edge_scores: np.ndarray
    iterations: int
    residuals: np.ndarray          # Δ_k trace (L1), for Fig 15


@partial(jax.jit, static_argnames=("max_iters",))
def _failrank_iterate(s0, l0, w, src, dst, lam, alpha, beta, gamma, eps,
                      max_iters: int):
    n = s0.shape[0]

    def step(s, l):
        contrib = w * s[src]
        s_new = (1.0 - lam) * s0 + lam * jax.ops.segment_sum(
            contrib, dst, num_segments=n)
        l_new = alpha * w + beta * s[src] + gamma * l
        return s_new, l_new

    def cond(carry):
        _, _, k, delta, _ = carry
        return (delta >= eps) & (k < max_iters)

    def body(carry):
        s, l, k, _, res = carry
        s_new, l_new = step(s, l)
        delta = jnp.abs(s_new - s).sum() + jnp.abs(l_new - l).sum()
        res = res.at[k].set(delta)
        return s_new, l_new, k + 1, delta, res

    res0 = jnp.full((max_iters,), jnp.nan, dtype=s0.dtype)
    s, l, k, delta, res = jax.lax.while_loop(
        cond, body, (s0, l0, jnp.int32(0), jnp.asarray(jnp.inf, s0.dtype),
                     res0))
    return s, l, k, res


def _softmax_per_level(scores: np.ndarray, levels: np.ndarray) -> np.ndarray:
    out = np.zeros_like(scores)
    for lv in np.unique(levels):
        sel = levels == lv
        x = scores[sel]
        e = np.exp(x - x.max())
        out[sel] = e / e.sum()
    return out


def failrank(mcg: MCG, params: FailRankParams = FailRankParams())\
        -> FailRankResult:
    if len(mcg.edge_src) == 0:
        z = np.zeros(mcg.n_nodes)
        return FailRankResult(z, np.zeros(0), mcg.s0.copy(), np.zeros(0), 0,
                              np.zeros(0))
    s, l, k, res = _failrank_iterate(
        jnp.asarray(mcg.s0, dtype=jnp.float32),
        jnp.asarray(mcg.l0, dtype=jnp.float32),
        jnp.asarray(mcg.edge_w, dtype=jnp.float32),
        jnp.asarray(mcg.edge_src), jnp.asarray(mcg.edge_dst),
        params.lam, params.alpha, params.beta, params.gamma, params.eps,
        params.max_iters)
    s = np.asarray(s, dtype=np.float64)
    l = np.asarray(l, dtype=np.float64)
    res = np.asarray(res, dtype=np.float64)
    res = res[~np.isnan(res)]

    node_soft = _softmax_per_level(s, mcg.node_window)
    edge_levels = np.minimum(mcg.edge_src // mcg.mesh.n_cores,
                             mcg.n_windows - 1)
    edge_soft = _softmax_per_level(l, edge_levels)
    return FailRankResult(node_soft, edge_soft, s, l, int(k), res)


def attribute_links(mcg: MCG, result: FailRankResult,
                    link_theta: np.ndarray | None = None) -> np.ndarray:
    """Fold MCG edge scores back onto physical links.

    Each edge's score is attributed along its XY path; when the EM-inferred
    θ is available the blame concentrates on the path's most anomalous link
    (θ-weighted), otherwise it spreads uniformly.
    """
    n_links = mcg.mesh.n_links
    link_scores = np.zeros(n_links)
    for i, path in enumerate(mcg.edge_link_path):
        if not path:
            continue
        score = result.raw_edge_scores[i]
        if link_theta is not None:
            w = int(min(mcg.edge_src[i] // mcg.mesh.n_cores,
                        mcg.n_windows - 1))
            th = link_theta[w, path]
            share = th / max(th.sum(), 1e-300)
        else:
            share = np.full(len(path), 1.0 / len(path))
        for lid, sh in zip(path, share):
            link_scores[lid] = max(link_scores[lid], score * sh)
    return link_scores
