"""Probe abstraction (paper Table II) and instruction-level trace expansion.

A probe is a five-tuple (Fragment, Type, Location, Level, Structure):

  Fragment:  Exec | Route | Mem      — what to record
  Type:      Comp | Comm | IO        — which instructions to match
  Location:  Pre | Post | Surround   — where relative to the instruction
  Level:     Inst | Stage            — aggregation granularity
  Structure: List | Sketch           — storage backend

The simulator emits task/flow-level records; real probes fire per
*instruction* (per sample in the batch, per packet on a link).  The
``expand_*`` helpers perform that expansion so SL-Recorder ingests the same
high-rate stream an on-chip probe would produce, and the raw-format storage
accounting matches the paper's instruction-level logs.
"""

from __future__ import annotations

import dataclasses
import enum

import numpy as np


class Fragment(enum.Enum):
    EXEC = "exec"
    ROUTE = "route"
    MEM = "mem"


class InstrType(enum.Enum):
    COMP = "comp"
    COMM = "comm"
    IO = "io"


class Location(enum.Enum):
    PRE = "pre"
    POST = "post"
    SURROUND = "surround"


class Level(enum.Enum):
    INST = "inst"
    STAGE = "stage"


class Structure(enum.Enum):
    LIST = "list"
    SKETCH = "sketch"


@dataclasses.dataclass(frozen=True)
class ProbeSpec:
    fragment: Fragment
    type: InstrType
    location: Location
    level: Level
    structure: Structure
    target_ops: tuple[str, ...] = ()   # op types to match; () = all

    def as_tuple(self):
        return (self.fragment.value, self.type.value, self.location.value,
                self.level.value, self.structure.value)

    def __repr__(self):
        return "[" + ", ".join(v.capitalize() for v in self.as_tuple()) + "]"


# -- record sizes for the raw 'List' format (paper Fig 2a / §IV-D) ----------
COMP_RECORD_BYTES = 48   # index, core, stage, op, flops, t_start, t_end
COMM_RECORD_BYTES = 56   # index, src, dst, stage, bytes, t_depart, t_arrive
PACKET_BYTES = 1024      # NoC packetisation for per-packet Route probes


def expand_comp_trace(comp: dict[str, np.ndarray],
                      instr_per_task: int = 64) -> dict[str, np.ndarray]:
    """Expand task-level compute records to per-instruction records.

    Each mapped task executes ``instr_per_task`` volume-equivalent
    instructions (one per batch sample in the throughput-inference setting);
    they share a pattern key and split the task's duration and FLOPs.
    """
    n = len(comp["core"])
    if n == 0:
        return {k: v.copy() for k, v in comp.items()}
    k = instr_per_task
    rep = {key: np.repeat(v, k) for key, v in comp.items()}
    frac = np.tile(np.arange(k, dtype=np.float64), n)
    dur = np.repeat((comp["t_end"] - comp["t_start"]) / k, k)
    rep["t_start"] = rep["t_start"] + frac * dur
    rep["t_end"] = rep["t_start"] + dur
    rep["flops"] = rep["flops"] / k
    return rep


def expand_comm_trace(comm: dict[str, np.ndarray],
                      packet_bytes: int = PACKET_BYTES,
                      max_packets: int = 64) -> dict[str, np.ndarray]:
    """Expand flow-level records to per-packet records (capped per flow)."""
    n = len(comm["src"])
    if n == 0:
        return {k: v.copy() for k, v in comm.items()}
    pk = np.clip(np.ceil(comm["bytes"] / packet_bytes).astype(np.int64),
                 1, max_packets)
    rep = {key: np.repeat(v, pk) for key, v in comm.items()}
    idx = np.concatenate([np.arange(p) for p in pk]).astype(np.float64)
    per = np.repeat((comm["t_arrive"] - comm["t_depart"]) / pk, pk)
    rep["t_depart"] = rep["t_depart"] + idx * per
    rep["t_arrive"] = rep["t_depart"] + per
    rep["bytes"] = np.repeat(comm["bytes"] / pk, pk)
    return rep


def raw_bytes(comp_records: int, comm_records: int) -> int:
    return comp_records * COMP_RECORD_BYTES + comm_records * COMM_RECORD_BYTES


# -- pattern keys ------------------------------------------------------------
# A pattern identifies traces "with similar execution behaviours" (§III-C):
# compute: (core, stage, op-type, flops bucket); comm: (src, dst, volume
# bucket).  Keys are packed into int64 for the sketch.
#
# Each key space carries a distinct high type-disambiguation tag bit so a
# comp key can never alias a comm key bit-for-bit.  (Historical bug: the
# comm tag was written ``2 << 61``, which equals ``1 << 62`` — the comp
# tag — so e.g. comp(core=5, stage=1, op=0, fb=0) and comm(src=5, dst=1,
# stage=0, vb=0) collided exactly.  The spaces only meet inside shared
# decoding / FailRank consumers, so the recorder's separate sketches
# masked the aliasing.)  The comm tag sits at bit 61, inside the 62 bits
# the sketch's (lo, hi) int32 halves preserve; the comp tag at bit 62 is
# outside them and is restored from the key space by the batched recorder
# path when it rebuilds keys from sketch state.

COMP_KEY_TAG = 1 << 62
COMM_KEY_TAG = 1 << 61


def comp_pattern_keys(comp: dict[str, np.ndarray]) -> np.ndarray:
    fb = np.clip(np.log2(np.maximum(comp["flops"], 1.0)).astype(np.int64),
                 0, 63)
    return (comp["core"].astype(np.int64)
            + (comp["stage"].astype(np.int64) << 12)
            + (comp["op"].astype(np.int64) << 28)
            + (fb << 34) + COMP_KEY_TAG)


def comm_pattern_keys(comm: dict[str, np.ndarray]) -> np.ndarray:
    vb = np.clip(np.log2(np.maximum(comm["bytes"], 1.0)).astype(np.int64),
                 0, 63)
    return (comm["src"].astype(np.int64)
            + (comm["dst"].astype(np.int64) << 12)
            + (comm["stage"].astype(np.int64) << 24)
            + (vb << 40) + COMM_KEY_TAG)


def decode_comp_key(key: int) -> dict:
    return {"core": int(key & 0xFFF), "stage": int((key >> 12) & 0xFFFF),
            "op": int((key >> 28) & 0x3F)}


def decode_comm_key(key: int) -> dict:
    return {"src": int(key & 0xFFF), "dst": int((key >> 12) & 0xFFF),
            "stage": int((key >> 24) & 0xFFFF)}
