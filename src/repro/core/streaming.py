"""Streaming recorder + incremental detection service.

The paper's pitch is *live, on-chip* fail-slow detection: the sketch is
resident state that absorbs records as they happen, and verdicts are
emitted while the workload runs — not computed post-hoc over a finished
trace.  This module supplies that always-on shape:

* :class:`StreamingRecorder` — holds sketch state (a live
  :class:`~repro.core.sketch.FailSlowSketch` for ``impl="ref"``, the
  packed ``kernels/sketch_update`` state dict plus an accumulated
  drained-eviction stream for ``impl="batched"``) across repeated
  ``observe(sim_chunk)`` calls, instead of rebuilding a fresh sketch per
  :func:`~repro.core.recorder.record`.  ``output()`` materialises a
  :class:`~repro.core.recorder.RecorderOutput` with the same accounting
  ``record`` produces — for any chunking of a trace the result is
  bit-identical to one-shot recording on the same impl, because the
  chunks feed the exact same record sequence through the same run
  builders (:func:`~repro.core.recorder.comp_runs` /
  :func:`~repro.core.recorder.comm_runs`) and partial-pattern merging
  is associative (:func:`~repro.core.sketch.accumulate_pattern`).
* :class:`SlothStream` — wraps a prepared
  :class:`~repro.core.sloth.Sloth` pipeline and emits an incremental
  :class:`~repro.core.detectors.Verdict` per observed window, tracking
  ``first_flag_time`` so **detection latency** (time-to-detect after
  failure onset) is measurable as a first-class metric next to accuracy
  (see ``metrics.detection_latency_stats`` and the campaign's
  ``streaming=`` axis).
* :func:`split_sim` — splits a finished :class:`SimResult` into
  time-ordered chunks for replaying a trace through the streaming path
  (the parity harness and the campaign's streaming axis both use it).

On-chip budget: streaming holds exactly one sketch state per side
(``SketchParams.total_bytes()``, a few hundred KiB) regardless of how
many chunks are observed — evicted Stage-2 rows drain off-chip per
chunk (``ops.drain_patterns``) just as the deployment writes them to
DRAM, so observing forever never grows the SRAM-resident state.
"""

from __future__ import annotations

import math

import numpy as np

from . import probes as P
from .recorder import (RECORDER_IMPLS, RecorderOutput, comm_runs,
                       comp_runs)
from .simulator import SimResult
from .sketch import (FailSlowSketch, Pattern, SketchParams,
                     accumulate_pattern, split_key)

__all__ = ["StreamingRecorder", "SlothStream", "split_sim"]


def split_sim(sim: SimResult, n_chunks: int) -> list[SimResult]:
    """Split a finished trace into ``n_chunks`` time-ordered chunks.

    Rows are bucketed by completion time (comp ``t_end`` / comm
    ``t_arrive``) into ``n_chunks`` equal spans of the trace, then the
    bucket sequence is made monotone along each trace's row order
    (``np.maximum.accumulate``): the sketch is order-sensitive (Stage-1
    majority counters, Stage-2 FIFO arrival), so chunk concatenation
    must reproduce the original record order *exactly* — the monotone
    guard keeps it exact even where the simulator's row order and
    completion times disagree locally, while boundaries stay
    approximately time-aligned.  Empty chunks are legal (and preserved,
    so chunk ``i`` always covers span ``i``).  Each chunk's
    ``total_time`` is the running maximum completion time — the stream's
    elapsed clock at that point.
    """
    n = max(int(n_chunks), 1)
    total = max(float(sim.total_time), 1e-300)

    def buckets(ts) -> np.ndarray:
        ts = np.asarray(ts, dtype=np.float64)
        if not len(ts):
            return np.zeros(0, dtype=np.int64)
        b = np.clip((ts / total * n).astype(np.int64), 0, n - 1)
        return np.maximum.accumulate(b)

    bc = buckets(sim.comp["t_end"]) if len(sim.comp["core"]) \
        else np.zeros(0, dtype=np.int64)
    bm = buckets(sim.comm["t_arrive"]) if len(sim.comm["src"]) \
        else np.zeros(0, dtype=np.int64)
    chunks: list[SimResult] = []
    elapsed = 0.0
    for i in range(n):
        comp = {k: np.asarray(v)[bc == i] for k, v in sim.comp.items()}
        comm = {k: np.asarray(v)[bm == i] for k, v in sim.comm.items()}
        if len(comp["core"]):
            elapsed = max(elapsed, float(np.max(comp["t_end"])))
        if len(comm["src"]):
            elapsed = max(elapsed, float(np.max(comm["t_arrive"])))
        chunks.append(SimResult(
            total_time=elapsed, comp=comp, comm=comm,
            n_raw_records=len(comp["core"]) + len(comm["src"])))
    return chunks


class _SketchStream:
    """One side (comp or comm) of the streaming recorder: persistent
    sketch state + accumulated drained partials + record accounting."""

    def __init__(self, params: SketchParams, impl: str, key_tag: int):
        self.params = params
        self.impl = impl
        self.key_tag = key_tag
        self.n_records = 0
        if impl == "ref":
            self.sk = FailSlowSketch(params)
        else:
            self.state = None               # packed state, built lazily
            self.drained: dict[int, Pattern] = {}
            self.n_drained = 0

    def insert(self, keys, reps, durs, vals, t0s, dts) -> None:
        if not len(keys):
            return
        if self.impl == "ref":
            self.sk.insert_runs(keys, reps, durs, vals, t0s, dts)
            return
        # lazy jax import, mirroring recorder._sketch_runs_batched
        import jax.numpy as jnp

        from ..kernels.sketch_update import ops as sketch_ops

        if self.state is None:
            self.state = sketch_ops.make_state(self.params)
        lo, hi = split_key(np.asarray(keys, dtype=np.int64))
        # a fresh drain per chunk: one run evicts at most one Stage-2 row,
        # so len(keys) capacity always suffices; evictions are folded into
        # the host-side accumulator (the off-chip compressed stream) and
        # the buffer is discarded — on-chip state stays one sketch.
        drain = sketch_ops.make_drain(len(keys))
        self.state, drain = sketch_ops.insert_runs(
            self.state, drain, jnp.asarray(lo), jnp.asarray(hi),
            jnp.asarray(np.asarray(reps, dtype=np.int32)),
            jnp.asarray(np.asarray(durs, dtype=np.float32)),
            jnp.asarray(np.asarray(vals, dtype=np.float32)),
            jnp.asarray(np.asarray(t0s, dtype=np.float32)),
            jnp.asarray(np.asarray(dts, dtype=np.float32)),
            params=self.params)
        for pat in sketch_ops.drain_patterns(drain, key_tag=self.key_tag):
            accumulate_pattern(self.drained, pat)
        self.n_drained += int(np.asarray(drain["d_n"]))

    def patterns(self) -> list[Pattern]:
        if self.impl == "ref":
            return self.sk.patterns()
        if self.state is None:
            return []
        from ..kernels.sketch_update import ops as sketch_ops

        # drained partials accumulated first (global eviction order),
        # then the live Stage-2 rows — the same merge order the one-shot
        # ops.patterns(state, drain) decode uses, so float accumulation
        # is bit-identical to post-hoc recording.
        merged: dict[int, Pattern] = {}
        for pat in self.drained.values():
            accumulate_pattern(merged, pat)
        for pat in sketch_ops.patterns(self.state, key_tag=self.key_tag):
            accumulate_pattern(merged, pat)
        return sorted(merged.values(), key=lambda p: p.arrival)

    def drained_count(self) -> int:
        return self.sk.n_evicted if self.impl == "ref" else self.n_drained

    def compressed_bytes(self) -> int:
        if self.impl == "ref":
            return self.sk.compressed_bytes()
        # one exact Stage-2 slot per drained pattern, matching
        # FailSlowSketch.compressed_bytes and recorder._sketch_runs_batched
        return (self.params.total_bytes()
                + self.n_drained * self.params.stage2_slot_bytes())


class StreamingRecorder:
    """Always-on SL-Recorder: sketch state held across ``observe`` calls.

    The constructor mirrors :func:`~repro.core.recorder.record`'s
    keyword surface; ``observe(sim_chunk)`` absorbs one chunk of trace
    (either side may be empty) and ``output()`` materialises the
    cumulative :class:`~repro.core.recorder.RecorderOutput`.  For any
    chunking of a trace, ``output()`` after observing every chunk is
    bit-identical to ``record()`` over the whole trace on the same impl.

    ``elapsed`` tracks the stream clock: the maximum record completion
    time observed so far (chunk ``total_time`` fields are deliberately
    ignored — pod telemetry windows report window-relative durations).
    """

    def __init__(self, params: SketchParams,
                 comm_params: SketchParams | None = None, *,
                 instr_per_task: int = 64,
                 packet_bytes: int = P.PACKET_BYTES,
                 max_packets: int = 64,
                 hop_latency: float = 50e-9,
                 impl: str = "ref",
                 budget_kb: float | None = 256.0):
        if impl not in RECORDER_IMPLS:
            raise ValueError(f"unknown recorder impl {impl!r}; "
                             f"options: {RECORDER_IMPLS}")
        # static on-chip budget guard (KiB; None disables) — the
        # always-on recorder holds exactly this state forever, so an
        # over-budget geometry is rejected before the first observe()
        from ..analysis.memory_model import validate_params
        validate_params(params, comm_params, impl, budget_kb)
        self.impl = impl
        self.instr_per_task = instr_per_task
        self.packet_bytes = packet_bytes
        self.max_packets = max_packets
        self.hop_latency = hop_latency
        self._comp = _SketchStream(params, impl, P.COMP_KEY_TAG)
        self._comm = _SketchStream(comm_params or params, impl,
                                   P.COMM_KEY_TAG)
        self.elapsed = 0.0
        self.n_chunks = 0

    def observe(self, chunk: SimResult) -> None:
        """Absorb one trace chunk into the resident sketches."""
        self.n_chunks += 1
        comp = chunk.comp
        if len(comp["core"]):
            runs = comp_runs(comp, self.instr_per_task)
            self._comp.insert(*runs)
            self._comp.n_records += len(runs[0]) * self.instr_per_task
            self.elapsed = max(self.elapsed, float(np.max(comp["t_end"])))
        comm = chunk.comm
        if len(comm["src"]):
            runs = comm_runs(comm, self.packet_bytes, self.max_packets,
                             self.hop_latency)
            self._comm.insert(*runs)
            self._comm.n_records += int(runs[1].sum())
            self.elapsed = max(self.elapsed,
                               float(np.max(comm["t_arrive"])))

    def output(self) -> RecorderOutput:
        """Cumulative recorder output (same accounting as ``record``)."""
        return RecorderOutput(
            comp_patterns=self._comp.patterns(),
            comm_patterns=self._comm.patterns(),
            raw_comp_bytes=self._comp.n_records * P.COMP_RECORD_BYTES,
            raw_comm_bytes=self._comm.n_records * P.COMM_RECORD_BYTES,
            sketch_comp_bytes=self._comp.compressed_bytes(),
            sketch_comm_bytes=self._comm.compressed_bytes(),
            n_comp_records=self._comp.n_records,
            n_comm_records=self._comm.n_records,
            n_comp_drained=self._comp.drained_count(),
            n_comm_drained=self._comm.drained_count(),
            impl=self.impl,
        )


class SlothStream:
    """Incremental SLOTH: one verdict per observed window.

    Binds a :class:`StreamingRecorder` to a prepared
    :class:`~repro.core.sloth.Sloth` pipeline; every ``observe`` call
    re-analyses the cumulative compressed state
    (``Sloth.analyse_recorded``) at the stream's elapsed clock and
    returns the window's :class:`~repro.core.detectors.Verdict`.
    ``first_flag_time`` records the stream time of the first flagged
    verdict (``None`` until one fires) — subtracting the failure onset
    gives the detection latency.

    ``policy`` (a registered mitigation-policy name or a
    :class:`~repro.mitigate.policy.MitigationPolicy` instance) closes the
    detect → mitigate loop mid-stream: at the first flagged verdict the
    policy plans against it, and the plan plus its stream time land in
    ``mitigation_plan`` / ``mitigation_time`` for the operator (or the
    campaign's mid-stream re-simulation) to act on.  Planning happens
    exactly once — later flags never revise the plan, mirroring a real
    restart-once deployment.
    """

    def __init__(self, pipeline, policy=None):
        cfg = pipeline.cfg
        self.pipeline = pipeline
        self.recorder = StreamingRecorder(
            cfg.sketch, instr_per_task=cfg.instr_per_task,
            hop_latency=pipeline.sim_cfg.hop_latency,
            impl=cfg.recorder_impl,
            budget_kb=getattr(cfg, "budget_kb", 256.0))
        if isinstance(policy, str):
            # deferred import: mitigate imports core, not the reverse
            from ..mitigate.policy import instantiate_policy
            policy = instantiate_policy(policy)
        self.policy = policy
        self.mitigation_plan = None
        self.mitigation_time: float | None = None
        self.verdicts: list = []
        self.first_flag_time: float | None = None

    def observe(self, chunk: SimResult, total_time: float | None = None):
        """Absorb a chunk, analyse, return this window's Verdict.

        ``total_time`` overrides the analysis horizon (pass the trace's
        final ``total_time`` on the last chunk so the verdict matches
        post-hoc ``analyse`` exactly; default: the stream's elapsed
        clock)."""
        self.recorder.observe(chunk)
        t = self.recorder.elapsed if total_time is None else total_time
        v = self.pipeline.analyse_recorded(self.recorder.output(), t)
        if v.flagged and self.first_flag_time is None:
            self.first_flag_time = t
            if self.policy is not None:
                self.mitigation_plan = self.policy.plan(
                    v, self.pipeline.mapped, self.pipeline.mesh,
                    self.pipeline.cfg)
                if self.mitigation_plan.acted:
                    self.mitigation_time = t
        self.verdicts.append(v)
        return v

    def detection_latency(self, onset: float) -> float:
        """Stream time from ``onset`` to the first flagged verdict
        (``math.inf`` if nothing has been flagged)."""
        if self.first_flag_time is None:
            return math.inf
        return self.first_flag_time - onset
