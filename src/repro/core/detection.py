"""SL-Tracer stage 1: core-level and link-level fail-slow detection.

* Core level (§III-D1): compute patterns are partitioned by execution stage
  and grouped into volume-equivalent sets (same stage / op / FLOP bucket →
  the DP-replica structure of the mapping guarantees comparability).  Within
  a group, per-core FLOP/s is compared against the group baseline with
  robust (median/MAD) outlier detection; candidates get an initial
  fail-slow probability from the variance distribution.

* Link level (§III-D2): each communication pattern gives (volume, observed
  transfer time, src, dst); XY routing maps it to a link set.  The
  underdetermined system  A · (V θ) = T  (θ_l = 1/bw_l) is solved with an
  EM (Richardson–Lucy style multiplicative) algorithm; per-link fail-slow
  probabilities come from a Gamma model over the inferred θ.

No scipy: the regularised incomplete gamma function is implemented here
(series + continued fraction).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from . import probes as P
from .routing import Topology
from .sketch import Pattern

# ---------------------------------------------------------------------------
# special functions (scipy-free)
# ---------------------------------------------------------------------------


def _gammp(a: float, x: float) -> float:
    """Regularised lower incomplete gamma P(a, x)."""
    if x <= 0.0:
        return 0.0
    if x < a + 1.0:     # series
        ap, s, d = a, 1.0 / a, 1.0 / a
        for _ in range(200):
            ap += 1.0
            d *= x / ap
            s += d
            if abs(d) < abs(s) * 1e-12:
                break
        return s * math.exp(-x + a * math.log(x) - math.lgamma(a))
    # continued fraction for Q(a, x)
    b, c, dd, h = x + 1.0 - a, 1e308, 1.0 / (x + 1.0 - a), 1.0 / (x + 1.0 - a)
    for i in range(1, 200):
        an = -i * (i - a)
        b += 2.0
        dd = an * dd + b
        dd = b + an / c if abs(dd) < 1e-300 else dd
        c = b + an / c
        c = 1e-300 if abs(c) < 1e-300 else c
        dd = 1.0 / dd
        delta = dd * c
        h *= delta
        if abs(delta - 1.0) < 1e-12:
            break
    q = math.exp(-x + a * math.log(x) - math.lgamma(a)) * h
    return 1.0 - q


def gamma_sf(x: float, shape: float, scale: float) -> float:
    """P(X ≥ x) for X ~ Gamma(shape, scale)."""
    return 1.0 - _gammp(shape, max(x, 0.0) / scale)


# ---------------------------------------------------------------------------
# core-level detection
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CoreCandidate:
    core: int
    window: int
    prob: float
    z: float
    stage: int


def assign_window(t_mid: np.ndarray, total_time: float,
                  n_windows: int) -> np.ndarray:
    w = np.floor(t_mid / max(total_time, 1e-12) * n_windows).astype(np.int64)
    return np.clip(w, 0, n_windows - 1)


def detect_cores(patterns: list[Pattern], total_time: float,
                 n_windows: int = 4, z_flag: float = 2.5,
                 min_group: int = 3,
                 rate_scale=None) -> list[CoreCandidate]:
    """Stage-aware group outlier detection on compute patterns.

    ``rate_scale`` — optional per-core baseline-capacity multipliers (a
    fabric's :attr:`~repro.core.routing.Topology.rate_class`): observed
    FLOP/s are divided by the core's nominal rate before grouping, so a
    healthy slow-class core on a heterogeneous fabric is not flagged as a
    fail-slow outlier against its full-rate peers.  All-ones (or ``None``)
    leaves the historical rates bit-identical.
    """
    if not patterns:
        return []
    keys = np.array([p.key for p in patterns], dtype=np.int64)
    cores = (keys & 0xFFF).astype(np.int64)
    stages = ((keys >> 12) & 0xFFFF).astype(np.int64)
    group_sig = keys >> 12          # stage | op | flops-bucket (drop core)
    rate = np.array([p.sum_val / max(p.sum_dur, 1e-12) for p in patterns])
    if rate_scale is not None:
        rate = rate / np.asarray(rate_scale, dtype=np.float64)[cores]
    t_mid = np.array([(p.t_first + p.t_last) / 2 for p in patterns])
    windows = assign_window(t_mid, total_time, n_windows)

    # group by signature only (stage | op | FLOP bucket): a slow core's own
    # timestamps stretch into later windows, so windowing the *grouping*
    # would strip it from its volume-equivalent peers.  The window of the
    # resulting candidate is taken from the pattern's own mid-time.
    cands: dict[tuple[int, int], CoreCandidate] = {}
    order = np.argsort(group_sig, kind="stable")
    bounds = np.nonzero(np.diff(group_sig[order]) != 0)[0] + 1
    for grp in np.split(order, bounds):
        if len(grp) < min_group:
            continue
        r = rate[grp]
        med = np.median(r)
        mad = np.median(np.abs(r - med)) * 1.4826
        sigma = max(mad, 0.02 * med, 1e-12)
        z = (med - r) / sigma        # positive z → slower than peers
        for gi, zi in zip(grp, z):
            if zi <= 0:
                continue
            prob = 1.0 / (1.0 + math.exp(-(zi - z_flag)))
            c, w = int(cores[gi]), int(windows[gi])
            prev = cands.get((c, w))
            if prev is None or prob > prev.prob:
                cands[(c, w)] = CoreCandidate(c, w, float(prob), float(zi),
                                              int(stages[gi]))
    return sorted(cands.values(), key=lambda c: -c.prob)


# ---------------------------------------------------------------------------
# link-level detection (EM on the underdetermined path system)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LinkCandidate:
    link: int
    window: int
    prob: float
    theta: float       # inferred inverse bandwidth (s/B)
    z: float


@dataclasses.dataclass
class LinkInference:
    theta: np.ndarray          # [n_windows, n_links]
    observed: np.ndarray       # [n_windows, n_links] bool: link had traffic
    candidates: list[LinkCandidate]


def em_link_inverse_bw(A: np.ndarray, T: np.ndarray, V: np.ndarray,
                       weights: np.ndarray, hop_latency: float = 0.0,
                       iters: int = 60) -> np.ndarray:
    """EM for  T_e ≈ Σ_l A_el · V_e · θ_l.

    E-step: split each observed delay over its links proportionally to the
    current θ; M-step: re-estimate θ_l from its expected delay share.
    Multiplicative updates keep θ ≥ 0 (bandwidths are positive).
    """
    n_e, n_l = A.shape
    T = np.maximum(T - hop_latency * A.sum(axis=1), 1e-12)
    traffic = (A * (weights * V)[:, None]).sum(axis=0)        # Σ_e w V A
    seen = traffic > 0
    theta0 = float((T / np.maximum((A * V[:, None]).sum(axis=1),
                                   1e-12)).mean())
    theta = np.full(n_l, theta0)
    for _ in range(iters):
        pred = (A * V[:, None]) @ theta                        # T̂_e
        ratio = T / np.maximum(pred, 1e-300)
        # expected delay on link l: Σ_e w_e · A_el V_e θ_l · ratio_e
        num = theta * ((A * (weights * V * ratio)[:, None]).sum(axis=0))
        theta_new = num / np.maximum(traffic, 1e-300)
        theta = np.where(seen, theta_new, theta)
    # shrink poorly-observed links toward the global estimate: a link seen by
    # <~3 events has an essentially unidentified θ in the underdetermined
    # system, and the raw EM value is an artefact of the initialisation.
    if seen.any():
        n_events = (A > 0).sum(axis=0)
        lam = n_events / (n_events + 3.0)
        shrunk = lam * theta + (1 - lam) * np.median(theta[seen])
        theta = np.where(seen, shrunk, theta)
    return theta


def detect_links(patterns: list[Pattern], mesh: Topology, total_time: float,
                 n_windows: int = 4, hop_latency: float = 50e-9,
                 ratio_flag: float = 3.0, em_iters: int = 60) -> LinkInference:
    """Link-level inference in two passes.

    1. **Global EM** (the paper's underdetermined-system solver) on the
       *minimum* per-pattern transfer times (queue-free service estimates)
       gives baseline inverse bandwidths θ̄ over the whole run.
    2. **Per-window slowdown regression**: with path shares
       s_el = V_e·θ̄_l / T̂_e, a single slow link l with slowdown ρ makes
       T_e/T̂_e − 1 ≈ s_el·(ρ−1) for events crossing it, so
       δ_l(w) = Σ w_e·s_el·(ratio_e−1) / Σ w_e·s_el² is a weighted LS
       estimate of ρ−1 in window w.  This keeps the global (identifiable)
       attribution while localising anomalies in time.

    Ratios are self-normalised by each link's healthiest window, so a
    transient failure stands out even if it contaminates the global θ̄.
    A Gamma model over healthy ratios converts anomaly to probability.
    """
    n_l = mesh.n_links
    theta = np.zeros((n_windows, n_l))
    observed = np.zeros((n_windows, n_l), dtype=bool)
    cands: list[LinkCandidate] = []
    if not patterns:
        return LinkInference(theta, observed, cands)

    keys = np.array([p.key for p in patterns], dtype=np.int64)
    src = (keys & 0xFFF).astype(np.int64)
    dst = ((keys >> 12) & 0xFFF).astype(np.int64)
    min_T = np.array([p.min_dur for p in patterns])
    mean_V = np.array([p.sum_val / max(p.count, 1) for p in patterns])
    cnt = np.array([p.count for p in patterns], dtype=np.float64)
    t_mid = np.array([(p.t_first + p.t_last) / 2 for p in patterns])
    windows = assign_window(t_mid, total_time, n_windows)

    inter = np.nonzero(src != dst)[0]
    if len(inter) == 0:
        return LinkInference(theta, observed, cands)
    pairs = [(int(src[i]), int(dst[i])) for i in inter]
    A = mesh.path_matrix(pairs)                     # [events, links]
    T = np.maximum(min_T[inter] - hop_latency * A.sum(axis=1), 1e-12)
    V = mean_V[inter]
    W = cnt[inter]
    win = windows[inter]

    theta_bar = em_link_inverse_bw(A, min_T[inter], V, W, hop_latency,
                                   em_iters)
    seen_any = A.sum(axis=0) > 0
    if seen_any.any():
        # floor θ̄: the multiplicative EM can drive rarely-blamed links to 0,
        # which would make their events' predicted time vanish
        theta_bar = np.maximum(theta_bar,
                               0.05 * np.median(theta_bar[seen_any]))
    pred = (A * V[:, None]) @ theta_bar             # T̂_e
    ratio_e = np.clip(T / np.maximum(pred, 1e-300), 0.0, 50.0)
    share = (A * (V[:, None] * theta_bar[None, :])) \
        / np.maximum(pred, 1e-300)[:, None]          # s_el

    MIN_SHARE = 0.15   # only events where link l dominates carry information
    ratios = np.ones((n_windows, n_l))
    for w in range(n_windows):
        sel = np.nonzero(win == w)[0]
        if len(sel) == 0:
            continue
        for li in np.nonzero(seen_any)[0]:
            ev = sel[share[sel, li] >= MIN_SHARE]
            if len(ev) < 3:
                continue
            # per-event single-slow-link estimate, robustly aggregated
            est = np.maximum((ratio_e[ev] - 1.0) / share[ev, li] + 1.0, 0.1)
            ratios[w, li] = max(float(np.median(est)), 0.25)
            observed[w, li] = True
        theta[w] = np.where(observed[w], theta_bar * ratios[w], 0.0)

    # All links share one nominal bandwidth (the paper's Gamma bandwidth
    # model), so judge each (window, link) θ against the cross-link
    # population — an absolute comparison that works even when a failure
    # spans the link's whole observation window.
    pop_theta = float(np.median(theta_bar[seen_any]))
    norm = np.where(observed, theta / max(pop_theta, 1e-300), 1.0)

    # Gamma model over the healthy slowdown population (lower 90%)
    pool = norm[observed]
    shape = scale = None
    if len(pool) >= 8:
        lo = pool[pool <= np.quantile(pool, 0.9)]
        mu, var = float(lo.mean()), float(max(lo.var(), 1e-6))
        if mu > 0:
            shape, scale = mu * mu / var, var / mu

    for w in range(n_windows):
        for li in np.nonzero(observed[w])[0]:
            r = float(norm[w, li])
            if r <= ratio_flag * 0.6:
                continue
            prob = 1.0 / (1.0 + math.exp(-1.5 * (r - ratio_flag)))
            if shape is not None:
                # p-value of the ratio under the healthy Gamma model
                pval = gamma_sf(r, shape, scale)
                prob *= (1.0 - pval)
            cands.append(LinkCandidate(int(li), w, float(prob),
                                       float(theta[w, li]), r))
    cands.sort(key=lambda c: -c.prob)
    return LinkInference(theta, observed, cands)
