"""Fabric topologies behind one string-keyed registry.

The fabric model is a first-class :class:`Topology`: core/link identity,
deterministic routing (``route`` / ``route_avoiding``), incidence queries
(``links_of_router`` / ``neighbours``), geometric distance (``hops``), the
EM path matrix (``path_matrix``) and a per-core ``rate_class`` vector of
baseline-capacity multipliers (all-ones on homogeneous fabrics).  Link
indexing is shared by the simulator, the link-level EM detector and the MCG
builder, so a physical link has one identity everywhere.  Links are
directed: ``(u, v)`` with u, v core ids.

Built-in fabrics (registered under the same string-keyed registry idiom as
``core/detectors.py`` and ``mitigate/policy.py``):

``mesh``
    W×H 2-D mesh with directed links between 4-neighbours and XY
    (dimension-ordered) routing — the reference fabric; bit-identical to
    the historical ``Mesh2D``.
``torus``
    The mesh plus wrap-around links in both dimensions.  Routing is
    shortest-direction DOR: X first then Y, each dimension walked in the
    direction with fewer hops, ties broken towards increasing coordinates.
``systolic``
    Unidirectional row/column dataflow links (east and south only, per
    Liu's systolic-array model, arXiv 2311.16594) with edge injection:
    traffic that would have to flow backwards drains off the array edge
    and re-enters at the opposite edge's row/column head, modelled as a
    unidirectional wrap link.
``het``
    The mesh with a heterogeneous ``rate_class`` vector: a
    ``fast<A>slow<B>`` pattern assigns repeating blocks of A full-rate
    cores followed by B half-rate cores (``HET_SLOW_RATE``).

Campaign-facing spec grammar (see :func:`parse_topology_spec`)::

    4 | (4, 4) | "4x4"        -> mesh          (historical spellings)
    "mesh:8x8"                -> mesh
    "torus:8x8"               -> torus
    "systolic:8x8"            -> systolic
    "het:4x4:fast2slow1"      -> het, variant "fast2slow1"
"""

from __future__ import annotations

import re

import numpy as np

# capacity multiplier of a 'slow'-class core on heterogeneous fabrics
HET_SLOW_RATE = 0.5


class Topology:
    """Base fabric: link tables, BFS detours and the EM path matrix.

    Subclasses define the fabric by yielding directed ``(u, v)`` pairs
    from ``_enumerate_links`` (self-loops and duplicates are dropped, so
    degenerate 1- or 2-wide wrap fabrics stay well-formed) and by
    implementing ``route`` and ``hops``.  Everything else — link-id
    bijection, precomputed router incidence, deterministic
    ``route_avoiding`` BFS, ``path_matrix`` — is shared.
    """

    def __init__(self, width: int, height: int | None = None):
        self.width = int(width)
        self.height = int(height if height is not None else width)
        if self.width < 1 or self.height < 1:
            raise ValueError(f"bad fabric dims {self.width}x{self.height}")
        self.n_cores = self.width * self.height
        self._link_ids: dict[tuple[int, int], int] = {}
        links: list[tuple[int, int]] = []
        for u, v in self._enumerate_links():
            if u == v or (u, v) in self._link_ids:
                continue
            self._link_ids[(u, v)] = len(links)
            links.append((u, v))
        self.links: list[tuple[int, int]] = links
        self.n_links = len(links)
        # adjacency in link-id order: _adj[u] = [(v, link_id), ...] — the
        # deterministic exploration order for route_avoiding's BFS.
        self._adj: list[list[tuple[int, int]]] = \
            [[] for _ in range(self.n_cores)]
        # router incidence (in + out, ascending link id), precomputed so
        # links_of_router is O(degree) in the simulator/judge hot loops.
        self._incident: list[list[int]] = [[] for _ in range(self.n_cores)]
        for lid, (u, v) in enumerate(links):
            self._adj[u].append((v, lid))
            self._incident[u].append(lid)
            self._incident[v].append(lid)
        # per-core baseline-capacity multipliers (all-ones when homogeneous)
        self.rate_class: np.ndarray = self._rate_classes()

    # -- fabric definition (subclass hooks) --------------------------------
    def _enumerate_links(self):
        raise NotImplementedError

    def _rate_classes(self) -> np.ndarray:
        return np.ones(self.n_cores, dtype=np.float64)

    def route(self, src: int, dst: int) -> list[int]:
        raise NotImplementedError

    def hops(self, src: int, dst: int) -> int:
        raise NotImplementedError

    # -- coordinates -------------------------------------------------------
    def core_id(self, x: int, y: int) -> int:
        return y * self.width + x

    def coords(self, core: int) -> tuple[int, int]:
        return core % self.width, core // self.width

    def link_id(self, u: int, v: int) -> int:
        return self._link_ids[(u, v)]

    def links_of_router(self, core: int) -> list[int]:
        """All links adjacent to ``core``'s router (in and out)."""
        return list(self._incident[core])

    def neighbours(self, core: int) -> list[int]:
        """Downstream neighbour core ids, ascending (on bidirectional
        fabrics this is the full neighbour set)."""
        return sorted(v for v, _ in self._adj[core])

    def mean_degree(self) -> float:
        """Mean router incidence (in + out links per router)."""
        return 2.0 * self.n_links / max(self.n_cores, 1)

    # -- routing -----------------------------------------------------------
    def route_avoiding(self, src: int, dst: int,
                       avoid: frozenset[int] | set[int]) -> list[int] | None:
        """Shortest link-id path from ``src`` to ``dst`` avoiding ``avoid``.

        Deterministic breadth-first search: neighbours are explored in
        link-id order and each core keeps its first-discovered predecessor,
        so ties between equal-length detours always break the same way.
        Returns ``None`` when ``avoid`` disconnects the pair.
        """
        if src == dst:
            return []
        prev: dict[int, tuple[int, int] | None] = {src: None}
        frontier = [src]
        while frontier and dst not in prev:
            nxt = []
            for u in frontier:
                for v, lid in self._adj[u]:
                    if lid in avoid or v in prev:
                        continue
                    prev[v] = (u, lid)
                    nxt.append(v)
            frontier = nxt
        if dst not in prev:
            return None
        path: list[int] = []
        c = dst
        while prev[c] is not None:
            u, lid = prev[c]        # type: ignore[misc]
            path.append(lid)
            c = u
        path.reverse()
        return path

    def path_matrix(self, pairs: list[tuple[int, int]]) -> np.ndarray:
        """A[e, l] = 1 if event e's route traverses link l (EM's A matrix)."""
        A = np.zeros((len(pairs), self.n_links), dtype=np.float64)
        for i, (s, d) in enumerate(pairs):
            for lid in self.route(s, d):
                A[i, lid] = 1.0
        return A


class Mesh2D(Topology):
    """W×H core mesh with directed links between 4-neighbours and
    deterministic XY (dimension-ordered) routing."""

    def _enumerate_links(self):
        for y in range(self.height):
            for x in range(self.width):
                u = self.core_id(x, y)
                for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                    nx_, ny_ = x + dx, y + dy
                    if 0 <= nx_ < self.width and 0 <= ny_ < self.height:
                        yield u, self.core_id(nx_, ny_)

    def route(self, src: int, dst: int) -> list[int]:
        """XY route: walk X first, then Y.  Returns the link-id path."""
        if src == dst:
            return []
        x0, y0 = self.coords(src)
        x1, y1 = self.coords(dst)
        path = []
        x, y = x0, y0
        while x != x1:
            nx_ = x + (1 if x1 > x else -1)
            path.append(self.link_id(self.core_id(x, y),
                                     self.core_id(nx_, y)))
            x = nx_
        while y != y1:
            ny_ = y + (1 if y1 > y else -1)
            path.append(self.link_id(self.core_id(x, y),
                                     self.core_id(x, ny_)))
            y = ny_
        return path

    def hops(self, src: int, dst: int) -> int:
        x0, y0 = self.coords(src)
        x1, y1 = self.coords(dst)
        return abs(x1 - x0) + abs(y1 - y0)


def _wrap_step(cur: int, tgt: int, size: int) -> int:
    """Shortest wrap direction from ``cur`` to ``tgt`` on a ring of
    ``size``: +1 or -1, ties broken towards increasing coordinates."""
    fwd = (tgt - cur) % size
    bwd = (cur - tgt) % size
    return 1 if fwd <= bwd else -1


class Torus2D(Topology):
    """W×H torus: the mesh plus wrap-around links, with deterministic
    shortest-direction dimension-ordered (X then Y) routing."""

    def _enumerate_links(self):
        for y in range(self.height):
            for x in range(self.width):
                u = self.core_id(x, y)
                for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                    yield u, self.core_id((x + dx) % self.width,
                                          (y + dy) % self.height)

    def route(self, src: int, dst: int) -> list[int]:
        if src == dst:
            return []
        x0, y0 = self.coords(src)
        x1, y1 = self.coords(dst)
        path = []
        x, y = x0, y0
        while x != x1:
            nx_ = (x + _wrap_step(x, x1, self.width)) % self.width
            path.append(self.link_id(self.core_id(x, y),
                                     self.core_id(nx_, y)))
            x = nx_
        while y != y1:
            ny_ = (y + _wrap_step(y, y1, self.height)) % self.height
            path.append(self.link_id(self.core_id(x, y),
                                     self.core_id(x, ny_)))
            y = ny_
        return path

    def hops(self, src: int, dst: int) -> int:
        x0, y0 = self.coords(src)
        x1, y1 = self.coords(dst)
        dx, dy = abs(x1 - x0), abs(y1 - y0)
        return min(dx, self.width - dx) + min(dy, self.height - dy)


class Systolic2D(Topology):
    """W×H systolic array: unidirectional east/south dataflow links with
    edge injection.  A transfer that cannot flow forwards drains off the
    right/bottom edge and re-enters at the row/column head — the
    unidirectional wrap link models that drain + re-injection hop."""

    def _enumerate_links(self):
        for y in range(self.height):
            for x in range(self.width):
                u = self.core_id(x, y)
                yield u, self.core_id((x + 1) % self.width, y)
                yield u, self.core_id(x, (y + 1) % self.height)

    def route(self, src: int, dst: int) -> list[int]:
        if src == dst:
            return []
        x0, y0 = self.coords(src)
        x1, y1 = self.coords(dst)
        path = []
        x, y = x0, y0
        while x != x1:
            nx_ = (x + 1) % self.width
            path.append(self.link_id(self.core_id(x, y),
                                     self.core_id(nx_, y)))
            x = nx_
        while y != y1:
            ny_ = (y + 1) % self.height
            path.append(self.link_id(self.core_id(x, y),
                                     self.core_id(x, ny_)))
            y = ny_
        return path

    def hops(self, src: int, dst: int) -> int:
        x0, y0 = self.coords(src)
        x1, y1 = self.coords(dst)
        return (x1 - x0) % self.width + (y1 - y0) % self.height


_HET_PATTERN = re.compile(r"^fast(\d+)slow(\d+)$")


class HetMesh2D(Mesh2D):
    """The mesh fabric with heterogeneous baseline capacities.

    ``pattern`` is ``fast<A>slow<B>``: repeating blocks of A full-rate
    cores followed by B slow-class cores (rate ``HET_SLOW_RATE``), in
    core-id order.
    """

    def __init__(self, width: int, height: int | None = None,
                 pattern: str = "fast1slow1"):
        m = _HET_PATTERN.match(str(pattern))
        if not m or (int(m.group(1)) + int(m.group(2))) == 0:
            raise ValueError(
                f"bad het rate-class pattern {pattern!r}: use 'fast<A>slow<B>'"
                " with A+B >= 1 (e.g. 'fast2slow1')")
        self.pattern = str(pattern)
        self._n_fast, self._n_slow = int(m.group(1)), int(m.group(2))
        super().__init__(width, height)

    def _rate_classes(self) -> np.ndarray:
        period = self._n_fast + self._n_slow
        rates = np.ones(self.n_cores, dtype=np.float64)
        rates[np.arange(self.n_cores) % period >= self._n_fast] = \
            HET_SLOW_RATE
        return rates


class DetourTopology:
    """A fabric whose ``route()`` detours around a set of avoided links.

    Wraps any base :class:`Topology` by delegation: link identities (ids,
    count, ``links_of_router``) are the base fabric's — only path
    selection differs, so the simulator, recorder and detectors keep one
    shared link numbering across the un-mitigated and mitigated
    deployments.  Pairs that the avoided set disconnects fall back to the
    base route (the traffic still has to flow; it just keeps paying the
    slow link).
    """

    def __init__(self, base: Topology, avoid_links=()):
        self.base = base
        self.avoid: frozenset[int] = frozenset(int(l) for l in avoid_links)
        self._route_cache: dict[tuple[int, int], list[int]] = {}

    def __getattr__(self, name):
        return getattr(self.base, name)

    def route(self, src: int, dst: int) -> list[int]:
        if src == dst:
            return []
        key = (src, dst)
        path = self._route_cache.get(key)
        if path is None:
            path = self.base.route_avoiding(src, dst, self.avoid)
            if path is None:
                path = self.base.route(src, dst)
            self._route_cache[key] = path
        return path

    def path_matrix(self, pairs: list[tuple[int, int]]) -> np.ndarray:
        A = np.zeros((len(pairs), self.base.n_links), dtype=np.float64)
        for i, (s, d) in enumerate(pairs):
            for lid in self.route(s, d):
                A[i, lid] = 1.0
        return A


# back-compat spelling: the historical mesh-only detour wrapper
DetourMesh = DetourTopology


# ---------------------------------------------------------------------------
# topology registry (string-keyed, mirroring core/detectors.py)
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, type] = {}
_BUILTIN_ORDER: list[str] = []


def register_topology(name: str, topo_cls: type, *,
                      overwrite: bool = False) -> None:
    """Register a topology class under a campaign-facing name.

    ``topo_cls(width, height)`` (plus an optional trailing variant
    argument, e.g. ``HetMesh2D``'s rate-class pattern) must build the
    fabric.  Registering an existing name raises unless ``overwrite``.
    """
    key = str(name).lower()
    if not key.isidentifier():
        raise ValueError(f"bad topology name {name!r}: "
                         "use an identifier-like key (no ':' or 'WxH')")
    if not overwrite and key in _REGISTRY and _REGISTRY[key] is not topo_cls:
        raise ValueError(f"topology {key!r} already registered "
                         f"({_REGISTRY[key].__name__})")
    _REGISTRY[key] = topo_cls


def _register_builtin_topology(name: str, topo_cls: type) -> None:
    if _REGISTRY.setdefault(name, topo_cls) is topo_cls \
            and name not in _BUILTIN_ORDER:
        _BUILTIN_ORDER.append(name)


def get_topology(name: str) -> type:
    """Resolve a registered topology class by name (sans variant)."""
    key = str(name).lower().split(":", 1)[0]
    try:
        return _REGISTRY[key]
    except KeyError:
        raise KeyError(f"unknown topology {key!r}; available: "
                       f"{available_topologies()}") from None


def available_topologies() -> tuple[str, ...]:
    """Registered topology names, built-ins first, extensions appended in
    registration order."""
    rest = [k for k in _REGISTRY if k not in _BUILTIN_ORDER]
    return tuple(_BUILTIN_ORDER) + tuple(rest)


def build_topology(topology: str, width: int,
                   height: int | None = None) -> Topology:
    """Build a fabric from a ``name`` or ``name:variant`` key and dims.

    ``build_topology('mesh', 4, 4)`` is the historical ``Mesh2D(4, 4)``;
    ``build_topology('het:fast2slow1', 4, 4)`` passes the variant through
    to the registered class.
    """
    name, _, variant = str(topology).lower().partition(":")
    cls = get_topology(name)
    if variant:
        return cls(width, height, variant)
    return cls(width, height)


def _parse_dims(spec: str, what: str) -> tuple[int, int]:
    parts = spec.lower().split("x")
    if len(parts) == 1:
        parts = parts * 2
    if len(parts) != 2 or not all(p.strip().isdigit() for p in parts):
        raise ValueError(f"bad {what} {spec!r}: use 'W' or 'WxH'")
    w, h = (int(p) for p in parts)
    if w < 1 or h < 1:
        raise ValueError(f"bad {what} {spec!r}: dims must be >= 1")
    return w, h


def parse_topology_spec(spec) -> tuple[str, int, int]:
    """Normalise a campaign fabric spec to ``(topology, width, height)``.

    ``topology`` is a registry key, optionally ``name:variant``.  Accepted
    spellings: ``4`` | ``(4, 4)`` | ``'4x4'`` (the historical mesh
    spellings), ``'mesh:8x8'``, ``'torus:8x8'``, ``'systolic:8x8'`` and
    ``'het:4x4:fast2slow1'``.
    """
    if isinstance(spec, str) and ":" in spec:
        name, dims, *variant = (p.strip() for p in spec.split(":"))
        if len(variant) > 1:
            raise ValueError(f"bad topology spec {spec!r}: "
                             "use 'name:WxH' or 'name:WxH:variant'")
        get_topology(name)      # fail fast on unknown names
        w, h = _parse_dims(dims, "topology spec dims")
        topo = name.lower() + (f":{variant[0]}" if variant else "")
        if variant:
            # validate the variant eagerly (e.g. the het rate-class pattern)
            build_topology(topo, 1, 1)
        return topo, w, h
    if isinstance(spec, str):
        return ("mesh",) + _parse_dims(spec, "mesh spec")
    if isinstance(spec, (int, np.integer)):
        if int(spec) < 1:
            raise ValueError(f"bad mesh spec {spec!r}: dims must be >= 1")
        return "mesh", int(spec), int(spec)
    if isinstance(spec, (tuple, list)) and len(spec) == 2:
        w, h = (int(p) for p in spec)
        if w < 1 or h < 1:
            raise ValueError(f"bad mesh spec {spec!r}: dims must be >= 1")
        return "mesh", w, h
    raise ValueError(f"bad mesh spec {spec!r}: "
                     "use W, (W, H), 'WxH' or 'name:WxH[:variant]'")


def topology_spec(topology: str, width: int, height: int) -> str:
    """Canonical fabric label for one deployment: ``'mesh:4x4'``,
    ``'torus:8x8'``, ``'het:4x4:fast2slow1'``."""
    name, _, variant = str(topology).partition(":")
    label = f"{name}:{width}x{height}"
    return f"{label}:{variant}" if variant else label


def mesh_mean_degree(width: int, height: int) -> float:
    """Mean router incidence of the same-dims reference mesh — the degree
    baseline that the fabric-aware flag thresholds are calibrated on."""
    n_links = 2 * ((width - 1) * height + width * (height - 1))
    return 2.0 * n_links / max(width * height, 1)


_register_builtin_topology("mesh", Mesh2D)
_register_builtin_topology("torus", Torus2D)
_register_builtin_topology("systolic", Systolic2D)
_register_builtin_topology("het", HetMesh2D)
