"""Deterministic XY (dimension-ordered) routing on a 2D mesh.

Link indexing is shared by the simulator, the link-level EM detector and the
MCG builder, so that a physical link has one identity everywhere.  Links are
directed: ``(u, v)`` with u, v adjacent core ids.
"""

from __future__ import annotations

import numpy as np


class Mesh2D:
    """W×H core mesh with directed links between 4-neighbours."""

    def __init__(self, width: int, height: int | None = None):
        self.width = int(width)
        self.height = int(height if height is not None else width)
        self.n_cores = self.width * self.height
        self._link_ids: dict[tuple[int, int], int] = {}
        links = []
        for y in range(self.height):
            for x in range(self.width):
                u = self.core_id(x, y)
                for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                    nx_, ny_ = x + dx, y + dy
                    if 0 <= nx_ < self.width and 0 <= ny_ < self.height:
                        v = self.core_id(nx_, ny_)
                        self._link_ids[(u, v)] = len(links)
                        links.append((u, v))
        self.links: list[tuple[int, int]] = links
        self.n_links = len(links)

    # -- coordinates -------------------------------------------------------
    def core_id(self, x: int, y: int) -> int:
        return y * self.width + x

    def coords(self, core: int) -> tuple[int, int]:
        return core % self.width, core // self.width

    def link_id(self, u: int, v: int) -> int:
        return self._link_ids[(u, v)]

    def links_of_router(self, core: int) -> list[int]:
        """All links adjacent to ``core``'s router (in and out)."""
        return [lid for lid, (u, v) in enumerate(self.links)
                if u == core or v == core]

    # -- routing -----------------------------------------------------------
    def route(self, src: int, dst: int) -> list[int]:
        """XY route: walk X first, then Y.  Returns the link-id path."""
        if src == dst:
            return []
        x0, y0 = self.coords(src)
        x1, y1 = self.coords(dst)
        path = []
        x, y = x0, y0
        while x != x1:
            nx_ = x + (1 if x1 > x else -1)
            path.append(self.link_id(self.core_id(x, y),
                                     self.core_id(nx_, y)))
            x = nx_
        while y != y1:
            ny_ = y + (1 if y1 > y else -1)
            path.append(self.link_id(self.core_id(x, y),
                                     self.core_id(x, ny_)))
            y = ny_
        return path

    def hops(self, src: int, dst: int) -> int:
        x0, y0 = self.coords(src)
        x1, y1 = self.coords(dst)
        return abs(x1 - x0) + abs(y1 - y0)

    def path_matrix(self, pairs: list[tuple[int, int]]) -> np.ndarray:
        """A[e, l] = 1 if event e's route traverses link l (EM's A matrix)."""
        A = np.zeros((len(pairs), self.n_links), dtype=np.float64)
        for i, (s, d) in enumerate(pairs):
            for lid in self.route(s, d):
                A[i, lid] = 1.0
        return A
