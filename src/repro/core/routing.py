"""Deterministic XY (dimension-ordered) routing on a 2D mesh.

Link indexing is shared by the simulator, the link-level EM detector and the
MCG builder, so that a physical link has one identity everywhere.  Links are
directed: ``(u, v)`` with u, v adjacent core ids.
"""

from __future__ import annotations

import numpy as np


class Mesh2D:
    """W×H core mesh with directed links between 4-neighbours."""

    def __init__(self, width: int, height: int | None = None):
        self.width = int(width)
        self.height = int(height if height is not None else width)
        self.n_cores = self.width * self.height
        self._link_ids: dict[tuple[int, int], int] = {}
        links = []
        for y in range(self.height):
            for x in range(self.width):
                u = self.core_id(x, y)
                for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                    nx_, ny_ = x + dx, y + dy
                    if 0 <= nx_ < self.width and 0 <= ny_ < self.height:
                        v = self.core_id(nx_, ny_)
                        self._link_ids[(u, v)] = len(links)
                        links.append((u, v))
        self.links: list[tuple[int, int]] = links
        self.n_links = len(links)
        # adjacency in link-id order: _adj[u] = [(v, link_id), ...] — the
        # deterministic exploration order for route_avoiding's BFS.
        self._adj: list[list[tuple[int, int]]] = [[] for _ in range(self.n_cores)]
        for lid, (u, v) in enumerate(links):
            self._adj[u].append((v, lid))

    # -- coordinates -------------------------------------------------------
    def core_id(self, x: int, y: int) -> int:
        return y * self.width + x

    def coords(self, core: int) -> tuple[int, int]:
        return core % self.width, core // self.width

    def link_id(self, u: int, v: int) -> int:
        return self._link_ids[(u, v)]

    def links_of_router(self, core: int) -> list[int]:
        """All links adjacent to ``core``'s router (in and out)."""
        return [lid for lid, (u, v) in enumerate(self.links)
                if u == core or v == core]

    def neighbours(self, core: int) -> list[int]:
        """4-neighbour core ids, ascending."""
        return sorted(v for v, _ in self._adj[core])

    # -- routing -----------------------------------------------------------
    def route(self, src: int, dst: int) -> list[int]:
        """XY route: walk X first, then Y.  Returns the link-id path."""
        if src == dst:
            return []
        x0, y0 = self.coords(src)
        x1, y1 = self.coords(dst)
        path = []
        x, y = x0, y0
        while x != x1:
            nx_ = x + (1 if x1 > x else -1)
            path.append(self.link_id(self.core_id(x, y),
                                     self.core_id(nx_, y)))
            x = nx_
        while y != y1:
            ny_ = y + (1 if y1 > y else -1)
            path.append(self.link_id(self.core_id(x, y),
                                     self.core_id(x, ny_)))
            y = ny_
        return path

    def route_avoiding(self, src: int, dst: int,
                       avoid: frozenset[int] | set[int]) -> list[int] | None:
        """Shortest link-id path from ``src`` to ``dst`` avoiding ``avoid``.

        Deterministic breadth-first search: neighbours are explored in
        link-id order and each core keeps its first-discovered predecessor,
        so ties between equal-length detours always break the same way.
        Returns ``None`` when ``avoid`` disconnects the pair.
        """
        if src == dst:
            return []
        prev: dict[int, tuple[int, int] | None] = {src: None}
        frontier = [src]
        while frontier and dst not in prev:
            nxt = []
            for u in frontier:
                for v, lid in self._adj[u]:
                    if lid in avoid or v in prev:
                        continue
                    prev[v] = (u, lid)
                    nxt.append(v)
            frontier = nxt
        if dst not in prev:
            return None
        path: list[int] = []
        c = dst
        while prev[c] is not None:
            u, lid = prev[c]        # type: ignore[misc]
            path.append(lid)
            c = u
        path.reverse()
        return path

    def hops(self, src: int, dst: int) -> int:
        x0, y0 = self.coords(src)
        x1, y1 = self.coords(dst)
        return abs(x1 - x0) + abs(y1 - y0)

    def path_matrix(self, pairs: list[tuple[int, int]]) -> np.ndarray:
        """A[e, l] = 1 if event e's route traverses link l (EM's A matrix)."""
        A = np.zeros((len(pairs), self.n_links), dtype=np.float64)
        for i, (s, d) in enumerate(pairs):
            for lid in self.route(s, d):
                A[i, lid] = 1.0
        return A


class DetourMesh(Mesh2D):
    """A mesh whose ``route()`` detours around a set of avoided links.

    Link identities (ids, count, ``links_of_router``) are unchanged — only
    path selection differs, so the simulator, recorder and detectors keep one
    shared link numbering across the un-mitigated and mitigated deployments.
    Pairs that the avoided set disconnects fall back to the base XY route
    (the traffic still has to flow; it just keeps paying the slow link).
    """

    def __init__(self, base: Mesh2D, avoid_links=()):
        super().__init__(base.width, base.height)
        self.avoid: frozenset[int] = frozenset(int(l) for l in avoid_links)
        self._route_cache: dict[tuple[int, int], list[int]] = {}

    def route(self, src: int, dst: int) -> list[int]:
        if src == dst:
            return []
        key = (src, dst)
        path = self._route_cache.get(key)
        if path is None:
            path = self.route_avoiding(src, dst, self.avoid)
            if path is None:
                path = super().route(src, dst)
            self._route_cache[key] = path
        return path
