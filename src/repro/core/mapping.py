"""Gemini-like operator→core mapping.

Following Gemini [HPCA'24], each operator is spatially partitioned across a
block of cores: large ops are split into `P` volume-equivalent parts (tensor
partitions), each assigned to a distinct core.  Consecutive stages are placed
with locality (same block ordering) so most traffic is neighbour-to-neighbour
with a deterministic shuffle fan-in — the communication pattern the NoC
actually sees.

The equal-split is what gives SL-Tracer its *volume-equivalent groups*: all
parts of one operator execute identical FLOPs on different cores.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .graph import CompGraph
from .routing import Topology


@dataclasses.dataclass
class Task:
    """One partition of an operator, mapped to a core."""
    task_id: int
    node_id: int
    part: int          # partition index within the operator
    n_parts: int
    core: int
    flops: float
    stage: int
    op_type: str


@dataclasses.dataclass
class Flow:
    """One core-to-core message (a partition-to-partition dependency)."""
    src_task: int
    dst_task: int
    src_core: int
    dst_core: int
    bytes: float
    stage: int         # consumer's stage


@dataclasses.dataclass
class MappedGraph:
    graph: CompGraph
    mesh: Topology
    tasks: list[Task]
    flows: list[Flow]

    def tasks_by_core(self) -> dict[int, list[Task]]:
        by: dict[int, list[Task]] = {c: [] for c in range(self.mesh.n_cores)}
        for t in self.tasks:
            by[t.core].append(t)
        return by


def _n_parts_for(flops: float, median_flops: float, n_cores: int) -> int:
    """Big ops get the whole mesh, small ops a few cores (Gemini-style)."""
    if flops <= 0:
        return 1
    ratio = flops / max(median_flops, 1.0)
    if ratio >= 1.0:
        return n_cores
    p = max(1, int(round(n_cores * ratio)))
    # round down to a power of two for even tiling
    return 1 << (p.bit_length() - 1)


def map_graph(graph: CompGraph, mesh: Topology, shuffle_fanin: int = 2,
              seed: int = 0, max_parts: int | None = None,
              exclude_cores=()) -> MappedGraph:
    """Partition every operator into volume-equivalent parts on the mesh.

    ``shuffle_fanin`` extra producers per consumer part model the tensor
    re-layout traffic between differently partitioned stages; ``max_parts``
    caps spatial spreading (Gemini trades spreading against locality).
    ``exclude_cores`` drops cores from the placement pool (the mitigation
    path: remap the workload off verdict-flagged cores); with an empty
    exclusion the placement arithmetic is unchanged bit-for-bit.
    """
    rng = np.random.default_rng(seed)
    comp = [n.flops for n in graph.nodes if n.flops > 0]
    median_flops = float(np.median(comp)) if comp else 1.0
    excluded = frozenset(int(c) for c in exclude_cores)
    bad = sorted(excluded.difference(range(mesh.n_cores)))
    if bad:
        raise ValueError(f"exclude_cores out of range for mesh: {bad}")
    alive = [c for c in range(mesh.n_cores) if c not in excluded]
    if not alive:
        raise ValueError("exclude_cores removes every core in the mesh")
    n_cores = len(alive)

    tasks: list[Task] = []
    node_tasks: dict[int, list[int]] = {}
    # deterministic per-node core offset keeps stage blocks local but rotates
    # placement so all cores are used even by small ops.
    for nid in graph.topo_order():
        node = graph.nodes[nid]
        if node.op_type in ("input", "output"):
            p = 1
        else:
            p = _n_parts_for(node.flops, median_flops, n_cores)
            if max_parts is not None:
                p = min(p, max_parts)
        offset = (node.node_id * 7) % n_cores
        ids = []
        for part in range(p):
            core = alive[(offset + part * (n_cores // p)) % n_cores]
            t = Task(len(tasks), nid, part, p, core, node.flops / p,
                     node.stage, node.op_type)
            tasks.append(t)
            ids.append(t.task_id)
        node_tasks[nid] = ids

    flows: list[Flow] = []
    for e in graph.edges:
        src_ids, dst_ids = node_tasks[e.src], node_tasks[e.dst]
        np_src, np_dst = len(src_ids), len(dst_ids)
        for j, dt in enumerate(dst_ids):
            # aligned producer part + a deterministic shuffle fan-in
            producers = {src_ids[j % np_src]}
            for k in range(1, shuffle_fanin + 1):
                producers.add(src_ids[(j + k * max(1, np_src // 4) + 1)
                                      % np_src])
            share = e.bytes / (np_dst * len(producers))
            for st in sorted(producers):
                flows.append(Flow(
                    src_task=st, dst_task=dt,
                    src_core=tasks[st].core, dst_core=tasks[dt].core,
                    bytes=share, stage=tasks[dt].stage))
    return MappedGraph(graph=graph, mesh=mesh, tasks=tasks, flows=flows)
