# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.

# Unified detector API: the one Verdict, the Detector protocol and the
# string-keyed registry every detector (SLOTH + baselines + user
# extensions) hangs off.  Heavier layers (campaign, sloth, baselines) are
# imported explicitly by their consumers.
from .detectors import (DEFAULT_DETECTORS, Detector, Verdict,  # noqa: F401
                        available_detectors, get_detector,
                        prepare_detector, register_detector)

__all__ = [
    "DEFAULT_DETECTORS", "Detector", "Verdict", "available_detectors",
    "get_detector", "prepare_detector", "register_detector",
]
