"""Unified detector API: one :class:`Verdict`, one protocol, one registry.

The paper's headline comparison (Table III, §IV-A) judges SLOTH against
five baselines *on identical traces under one evaluation contract*.  This
module is that contract:

* :class:`Verdict` — the single verdict type every detector returns.  It
  carries a ranked candidate list, the mesh it was judged on (so
  ``matches`` is router-aware via :func:`repro.core.failures
  .truth_candidates`) and, for detectors that produce them, the recorder /
  FailRank / MCG artifacts.  Every detector — SLOTH and the baselines
  alike — emits a multi-entry suspicion-ordered ranking (all resources
  above or near its decision statistic), so the campaign judge, top-k and
  recall@k metrics apply uniformly and stay non-degenerate under
  multi-failure and mixed-kind scenarios.
* :class:`Detector` — the protocol: ``name``, ``prepare(graph, mesh,
  profile, cfg)`` (fit nominal models against a healthy profiling run,
  returns ``self``) and ``analyse(sim) → Verdict``.
* the registry — ``get_detector("sloth" | "thres" | "mscope" | "iaso" |
  "perseus" | "adr")`` resolves a factory; :func:`register_detector` adds
  user extensions.  Built-ins self-register on first lookup (lazy import
  of :mod:`.sloth` / :mod:`.baselines` avoids an import cycle).

The campaign layer (``campaign.py``) speaks only this API: a deployment
prepares one detector instance per requested name and every scenario's
trace is analysed by all of them, so ``run_campaign(grid,
detectors=("sloth", "thres", ...))`` produces the SLOTH-vs-baselines table
with no detector-specific glue.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Callable, Protocol, runtime_checkable

from .failures import FailSlow, truth_candidates
from .routing import Topology

if TYPE_CHECKING:                                    # pragma: no cover
    from .failrank import FailRankResult
    from .graph import CompGraph
    from .mcg import MCG
    from .recorder import RecorderOutput
    from .simulator import SimResult

__all__ = [
    "Verdict", "Detector", "register_detector", "get_detector",
    "available_detectors", "prepare_detector", "DEFAULT_DETECTORS",
]

#: Registry order of the built-in detectors: SLOTH first, then the five
#: baselines in the paper's Table III order.
DEFAULT_DETECTORS = ("sloth", "thres", "mscope", "iaso", "perseus", "adr")


@dataclasses.dataclass
class Verdict:
    """The one verdict type shared by every detector.

    ``ranking`` is the detector's ordered candidate list — multi-entry for
    every built-in, including the baselines, which list all resources
    above/near their statistic; ``flagged_resources`` lists every resource
    whose evidence independently clears the detector's threshold
    (multi-failure report).  ``recorder`` / ``failrank`` / ``mcg`` are
    populated by detectors that produce those artifacts (SLOTH) and
    ``None`` otherwise.
    """
    flagged: bool
    kind: str | None              # 'core' | 'link'
    location: int | None
    score: float
    ranking: list[tuple[str, int, float]] = dataclasses.field(
        default_factory=list)
    recorder: "RecorderOutput | None" = None
    failrank: "FailRankResult | None" = None
    mcg: "MCG | None" = None
    total_time: float = 0.0
    # every resource whose detection evidence clears the flag threshold,
    # sorted by raw evidence — the multi-failure report.  The verdict's
    # kind/location additionally weigh FailRank attribution, so the two
    # orderings may disagree on which resource comes first.
    flagged_resources: tuple[tuple[str, int, float], ...] = ()
    mesh: Topology | None = dataclasses.field(
        default=None, repr=False, compare=False)
    detector: str = ""            # registry name of the producing detector

    def matches(self, failure: FailSlow | None,
                mesh: Topology | None = None) -> bool:
        """Correctness of this verdict against ground truth, router-aware:
        a router truth is matched by any link of the slowed router (the
        detector only localises cores and links)."""
        if failure is None:
            return not self.flagged
        if not self.flagged:
            return False
        mesh = mesh if mesh is not None else self.mesh
        if mesh is None:
            if failure.kind == "router":
                raise ValueError(
                    "judging a router truth needs the mesh topology; pass "
                    "mesh= or use a Verdict produced by a prepared "
                    "detector")
            return (self.kind, self.location) == failure.label()
        return (self.kind, self.location) in truth_candidates(failure, mesh)


@runtime_checkable
class Detector(Protocol):
    """A fail-slow detector bound to one (workload, mesh) deployment.

    Life cycle: construct unprepared via the registry factory, then
    ``prepare(graph, mesh, profile, cfg)`` fits nominal models against a
    healthy profiling run (``profile`` is a failure-free ``SimResult`` of
    the same deployment) and returns ``self``; ``analyse(sim)`` judges one
    instrumented trace.  ``prepare`` must be deterministic in its inputs —
    the campaign's process-pool workers rebuild detectors independently
    and their verdicts must be bit-identical to the parent's.

    ``cfg`` carries implementation selection as well as thresholds: e.g.
    ``SlothConfig.recorder_impl`` chooses the SL-Recorder sketch path
    ("ref" oracle vs on-device "batched"), so the campaign layer can
    compare deployable pipelines purely through the config it hands to
    ``prepare``.
    """

    name: str

    def prepare(self, graph: "CompGraph", mesh: Topology,
                profile: "SimResult", cfg=None) -> "Detector":
        ...                                          # pragma: no cover

    def analyse(self, sim: "SimResult") -> Verdict:
        ...                                          # pragma: no cover


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], Detector]] = {}
_builtins_loaded = False


def register_detector(name: str, factory: Callable[[], Detector], *,
                      overwrite: bool = False) -> None:
    """Register ``factory`` (a zero-arg callable returning an unprepared
    detector) under ``name``.  Extension point for user detectors; the
    built-ins are pre-registered.  Note that campaign process-pool workers
    re-import modules in fresh interpreters, so a custom detector must be
    registered at import time of its defining module to be visible under
    ``executor='process'``."""
    key = str(name).lower()
    if not overwrite and key in _REGISTRY:
        raise ValueError(f"detector {key!r} is already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[key] = factory


def _register_builtin(name: str, factory: Callable[[], Detector]) -> None:
    """Registration used by the built-in modules at import time: first
    registration wins, so a user's earlier ``register_detector(name, ...,
    overwrite=True)`` override of a built-in name survives the lazy
    built-in import (and module re-imports stay idempotent)."""
    _REGISTRY.setdefault(str(name).lower(), factory)


def _ensure_builtins() -> None:
    global _builtins_loaded
    if not _builtins_loaded:
        # late import: sloth/baselines import Verdict from this module, so
        # registering them at our import time would be a cycle
        from . import baselines, sloth  # noqa: F401
        _builtins_loaded = True


def get_detector(name: str) -> Callable[[], Detector]:
    """Resolve a detector factory by registry name (case-insensitive)."""
    _ensure_builtins()
    key = str(name).lower()
    try:
        return _REGISTRY[key]
    except KeyError:
        raise KeyError(
            f"unknown detector {name!r}; available: "
            f"{available_detectors()}") from None


def available_detectors() -> tuple[str, ...]:
    """Registered detector names: built-ins first (in ``DEFAULT_DETECTORS``
    order), then user registrations in registration order."""
    _ensure_builtins()
    head = [n for n in DEFAULT_DETECTORS if n in _REGISTRY]
    tail = [n for n in _REGISTRY if n not in DEFAULT_DETECTORS]
    return tuple(head + tail)


def instantiate_detector(name: str) -> Detector:
    """Resolve ``name`` and instantiate an unprepared detector, enforcing
    the registry contract that the instance's ``.name`` equals its
    (lowercased) registry key — campaign outcome tables are keyed on
    ``.name``, so a mismatch would otherwise surface as missing-key
    errors long after registration."""
    key = str(name).lower()
    det = get_detector(key)()
    if getattr(det, "name", None) != key:
        raise ValueError(
            f"detector factory registered under {key!r} produced an "
            f"instance named {getattr(det, 'name', None)!r}; the registry "
            f"key and Detector.name must match (lowercase)")
    return det


def prepare_detector(name: str, graph: "CompGraph", mesh: Topology,
                     profile: "SimResult", cfg=None) -> Detector:
    """Convenience: resolve, instantiate and prepare in one call."""
    return instantiate_detector(name).prepare(graph, mesh, profile, cfg)
