"""Multi-level Communication Graph (paper §III-D3).

Nodes are (time-window, core) pairs plus one virtual DRAM node per window
boundary; edges are core→core communication dependencies inside a window,
weighted by traffic volume and normalised per source (Σ_out w = 1), so
w(u,v) reads as the probability that a slowdown propagates along (u,v).
Virtual DRAM nodes connect consecutive windows (the temporal dimension).

The builder also keeps, for every MCG edge, the physical XY link path and
per-link traffic so FailRank's edge scores can be attributed back to
physical links.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .detection import (CoreCandidate, LinkInference, assign_window)
from .routing import Topology
from .sketch import Pattern


@dataclasses.dataclass
class MCG:
    mesh: Topology
    n_windows: int
    n_nodes: int                     # windows*cores + windows (DRAM)
    # edges (COO): weights normalised per source node
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_w: np.ndarray
    edge_link_path: list[list[int]]  # physical links per edge ([] = virtual)
    s0: np.ndarray                   # initial node fail-slow scores
    l0: np.ndarray                   # initial edge fail-slow scores
    node_window: np.ndarray          # level of each node (for softmax)

    def node_id(self, window: int, core: int) -> int:
        return window * self.mesh.n_cores + core

    def dram_id(self, window: int) -> int:
        return self.n_windows * self.mesh.n_cores + window

    def is_core_node(self, nid: int) -> bool:
        return nid < self.n_windows * self.mesh.n_cores

    def node_core(self, nid: int) -> int:
        return nid % self.mesh.n_cores


DRAM_EDGE_WEIGHT = 0.1   # relative weight of inter-level (memory) edges


def build_mcg(comm_patterns: list[Pattern], mesh: Topology, total_time: float,
              core_cands: list[CoreCandidate], link_inf: LinkInference,
              n_windows: int = 4) -> MCG:
    n_cores = mesh.n_cores
    n_nodes = n_windows * n_cores + n_windows

    # -- aggregate traffic per (window, src, dst) ---------------------------
    traffic: dict[tuple[int, int, int], float] = {}
    if comm_patterns:
        keys = np.array([p.key for p in comm_patterns], dtype=np.int64)
        src = (keys & 0xFFF).astype(np.int64)
        dst = ((keys >> 12) & 0xFFF).astype(np.int64)
        vol = np.array([p.sum_val for p in comm_patterns])
        t_mid = np.array([(p.t_first + p.t_last) / 2 for p in comm_patterns])
        win = assign_window(t_mid, total_time, n_windows)
        for s, d, v, w in zip(src, dst, vol, win):
            if s == d:
                continue
            k = (int(w), int(s), int(d))
            traffic[k] = traffic.get(k, 0.0) + float(v)

    edge_src, edge_dst, edge_vol, paths = [], [], [], []
    for (w, s, d), v in sorted(traffic.items()):
        edge_src.append(w * n_cores + s)
        edge_dst.append(w * n_cores + d)
        edge_vol.append(v)
        paths.append(mesh.route(s, d))

    # -- virtual DRAM nodes: core(w) → DRAM(w) → core(w+1) ------------------
    mean_vol = float(np.mean(edge_vol)) if edge_vol else 1.0
    active: dict[int, set[int]] = {w: set() for w in range(n_windows)}
    for (w, s, d) in traffic:
        active[w].update((s, d))
    for w in range(n_windows - 1):
        dram = n_windows * n_cores + w
        for c in sorted(active[w]) or range(n_cores):
            edge_src.append(w * n_cores + c)
            edge_dst.append(dram)
            edge_vol.append(mean_vol * DRAM_EDGE_WEIGHT)
            paths.append([])
        nxt = sorted(active[w + 1]) or range(n_cores)
        for c in nxt:
            edge_src.append(dram)
            edge_dst.append((w + 1) * n_cores + c)
            edge_vol.append(mean_vol * DRAM_EDGE_WEIGHT)
            paths.append([])

    edge_src = np.asarray(edge_src, dtype=np.int64)
    edge_dst = np.asarray(edge_dst, dtype=np.int64)
    edge_vol = np.asarray(edge_vol, dtype=np.float64)

    # -- normalise traffic per source: Σ_{(u,·)} w = 1 ----------------------
    out_sum = np.zeros(n_nodes)
    np.add.at(out_sum, edge_src, edge_vol)
    edge_w = edge_vol / np.maximum(out_sum[edge_src], 1e-300)

    # -- initial scores ------------------------------------------------------
    s0 = np.zeros(n_nodes)
    for c in core_cands:
        s0[c.window * n_cores + c.core] = max(
            s0[c.window * n_cores + c.core], c.prob)

    link_prob = np.zeros((n_windows, mesh.n_links))
    for lc in link_inf.candidates:
        link_prob[lc.window, lc.link] = max(link_prob[lc.window, lc.link],
                                            lc.prob)
    l0 = np.zeros(len(edge_src))
    win_of_edge = np.minimum(edge_src // n_cores, n_windows - 1)
    for i, path in enumerate(paths):
        if path:
            w = int(win_of_edge[i])
            l0[i] = float(link_prob[w, path].max())

    node_window = np.concatenate([
        np.repeat(np.arange(n_windows), n_cores),
        np.arange(n_windows),
    ])
    return MCG(mesh=mesh, n_windows=n_windows, n_nodes=n_nodes,
               edge_src=edge_src, edge_dst=edge_dst, edge_w=edge_w,
               edge_link_path=paths, s0=s0, l0=l0, node_window=node_window)
