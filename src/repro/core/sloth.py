"""End-to-end SLOTH pipeline (Figure 4).

    workload + arch config + probe config + failure model
        → SL-Compiler (probe plan)
        → simulate (instrumented run)
        → SL-Recorder (Fail-Slow Sketch compression)
        → SL-Tracer (core/link detection → MCG → FailRank)
        → ranked root causes + storage/overhead accounting
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .compiler import plan_probes
from .detection import detect_cores, detect_links
from .failrank import FailRankParams, FailRankResult, attribute_links, \
    failrank
from .failures import FailSlow
from .graph import CompGraph
from .mapping import MappedGraph, map_graph
from .mcg import MCG, build_mcg
from .recorder import RecorderOutput, record
from .routing import Mesh2D
from .simulator import SimConfig, SimResult, calibrate, simulate
from .sketch import SketchParams


@dataclasses.dataclass
class SlothConfig:
    sketch: SketchParams = dataclasses.field(default_factory=SketchParams)
    failrank: FailRankParams = dataclasses.field(
        default_factory=FailRankParams)
    n_windows: int = 4
    core_z_flag: float = 6.0
    link_ratio_flag: float = 3.0
    detect_threshold: float = 0.55   # min initial prob to report a failure
    instr_per_task: int = 64


@dataclasses.dataclass
class Verdict:
    flagged: bool
    kind: str | None              # 'core' | 'link'
    location: int | None
    score: float
    ranking: list[tuple[str, int, float]]   # top candidates
    recorder: RecorderOutput
    failrank: FailRankResult
    mcg: MCG
    total_time: float

    def matches(self, failure: FailSlow | None) -> bool:
        """Correctness of this verdict against ground truth."""
        if failure is None:
            return not self.flagged
        return (self.flagged and self.kind == failure.kind
                and self.location == failure.location)


class Sloth:
    """SLOTH detector bound to one (workload graph, mesh) deployment."""

    def __init__(self, graph: CompGraph, mesh: Mesh2D,
                 cfg: SlothConfig | None = None,
                 sim_cfg: SimConfig | None = None):
        self.graph = graph
        self.mesh = mesh
        self.cfg = cfg or SlothConfig()
        self.mapped: MappedGraph = map_graph(graph, mesh)
        self.sim_cfg = sim_cfg or SimConfig(
            mu_c=calibrate(graph.total_flops(), mesh.n_cores))
        self.plan = plan_probes(graph, self.mapped)

    # -- data collection -----------------------------------------------------
    def run(self, failures: list[FailSlow] | None = None,
            seed: int = 0) -> SimResult:
        sim_cfg = dataclasses.replace(self.sim_cfg, seed=seed)
        return simulate(self.mapped, sim_cfg, failures=failures,
                        probes=self.plan.sim_plan)

    # -- analysis --------------------------------------------------------------
    def analyse(self, sim: SimResult) -> Verdict:
        cfg = self.cfg
        rec = record(sim, cfg.sketch, instr_per_task=cfg.instr_per_task,
                     hop_latency=self.sim_cfg.hop_latency)
        core_cands = detect_cores(rec.comp_patterns, sim.total_time,
                                  cfg.n_windows, cfg.core_z_flag)
        link_inf = detect_links(rec.comm_patterns, self.mesh, sim.total_time,
                                cfg.n_windows, self.sim_cfg.hop_latency,
                                cfg.link_ratio_flag)
        mcg = build_mcg(rec.comm_patterns, self.mesh, sim.total_time,
                        core_cands, link_inf, cfg.n_windows)
        fr = failrank(mcg, cfg.failrank)

        # ---- combine detection evidence with FailRank refinement ---------
        # FailRank's fixed point forgets l0 geometrically (γ^k), so the
        # final verdict multiplies each candidate's detection probability by
        # its (normalised) FailRank mass: detection says *what looks slow*,
        # FailRank arbitrates *which of the correlated anomalies is the
        # propagation source*.
        n_cores = self.mesh.n_cores
        core_ev = np.zeros(n_cores)
        for c in core_cands:
            core_ev[c.core] = max(core_ev[c.core], c.prob)
        link_ev = np.zeros(self.mesh.n_links)
        for c in link_inf.candidates:
            link_ev[c.link] = max(link_ev[c.link], c.prob)

        core_fr = np.zeros(n_cores)
        core_nodes = fr.raw_node_scores[:mcg.n_windows * n_cores]
        for w in range(mcg.n_windows):
            core_fr = np.maximum(core_fr,
                                 core_nodes[w * n_cores:(w + 1) * n_cores])
        core_fr /= max(core_fr.max(), 1e-12)
        link_fr = attribute_links(mcg, fr, link_inf.theta)
        link_fr /= max(link_fr.max(), 1e-12)

        core_scores = core_ev * (0.5 + core_fr)
        link_scores = link_ev * (0.5 + link_fr)

        max_core_p = float(core_ev.max()) if n_cores else 0.0
        max_link_p = float(link_ev.max()) if len(link_ev) else 0.0
        flagged = max(max_core_p, max_link_p) >= cfg.detect_threshold

        ranking = (
            [("core", int(c), float(core_scores[c]))
             for c in np.argsort(-core_scores)[:5] if core_scores[c] > 0]
            + [("link", int(l), float(link_scores[l]))
               for l in np.argsort(-link_scores)[:5] if link_scores[l] > 0])
        ranking.sort(key=lambda x: -x[2])

        kind = loc = None
        score = 0.0
        if flagged and ranking:
            kind, loc, score = ranking[0]
        return Verdict(flagged=flagged, kind=kind, location=loc, score=score,
                       ranking=ranking, recorder=rec, failrank=fr, mcg=mcg,
                       total_time=sim.total_time)

    def detect(self, failures: list[FailSlow] | None = None,
               seed: int = 0) -> Verdict:
        return self.analyse(self.run(failures=failures, seed=seed))
