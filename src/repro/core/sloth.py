"""SLOTH, as a registered :class:`~repro.core.detectors.Detector`.

End-to-end pipeline (Figure 4):

    workload + arch config + probe config + failure model
        → SL-Compiler (probe plan)
        → simulate (instrumented run)
        → SL-Recorder (Fail-Slow Sketch compression)
        → SL-Tracer (core/link detection → MCG → FailRank)
        → ranked root causes + storage/overhead accounting

Two entry points:

* :class:`Sloth` — the full pipeline bound to one (workload graph, mesh)
  deployment.  It both *generates* instrumented traces (``run``) and
  *analyses* them (``analyse → Verdict``); the campaign layer uses it as
  the simulation host for every detector.
* :class:`SlothDetector` — the registry adapter implementing the unified
  detector protocol (``prepare(graph, mesh, profile, cfg)`` /
  ``analyse(sim)``), registered under ``"sloth"`` so
  ``get_detector("sloth")`` and ``run_campaign(..., detectors=("sloth",
  ...))`` treat SLOTH exactly like any baseline.

Verdicts are the unified :class:`~repro.core.detectors.Verdict` (re-exported
here for compatibility): ranked candidates, mesh-aware ``matches`` and the
recorder / FailRank / MCG artifacts.

Both entry points also run *streaming*: ``Sloth.stream()`` returns an
always-on :class:`~repro.core.streaming.SlothStream` (one incremental
Verdict per observed chunk), and ``stream_analyse(sim, n_chunks)`` /
``SlothDetector.stream_analyse`` replay a finished trace through it —
the final streamed verdict equals post-hoc ``analyse`` exactly on both
recorder impls, and the first flagged window's stream time feeds the
campaign's detection-latency metric.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from .compiler import plan_probes
from .detection import detect_cores, detect_links
from .detectors import Verdict, _register_builtin
from .failrank import FailRankParams, FailRankResult, attribute_links, \
    failrank
from .failures import FailSlow
from .graph import CompGraph
from .mapping import MappedGraph, map_graph
from .mcg import MCG, build_mcg
from .recorder import RecorderOutput, record
from .routing import Topology, mesh_mean_degree
from .simulator import SimConfig, SimResult, calibrate, simulate
from .sketch import SketchParams

__all__ = ["SlothConfig", "Verdict", "Sloth", "SlothDetector"]


@dataclasses.dataclass
class SlothConfig:
    sketch: SketchParams = dataclasses.field(default_factory=SketchParams)
    failrank: FailRankParams = dataclasses.field(
        default_factory=FailRankParams)
    n_windows: int = 4
    core_z_flag: float = 6.0
    link_ratio_flag: float = 3.0
    detect_threshold: float = 0.55   # min initial prob to report a failure
    instr_per_task: int = 64
    # Recorder sketch implementation: "ref" (per-run numpy oracle, the
    # bit-stable historical path) or "batched" (on-device run-compressed
    # JAX scan with the drained-eviction stream — the deployable path).
    # Flows through record(..., impl=...) and, because the campaign's
    # DeploymentCache keys deployments on the config repr, selects the
    # recorder per campaign via run_campaign(cfg=SlothConfig(
    # recorder_impl="batched")).
    recorder_impl: str = "ref"
    # Per-chip on-chip memory budget for the recorder, in KiB (the
    # paper's "within kilobytes" regime).  Checked *statically* at
    # pipeline construction by repro.analysis.memory_model.
    # validate_config(): the comp + comm sketch footprint — paper
    # accounting for impl="ref", the larger of accounting and the packed
    # jnp state for impl="batched" — must fit, or Sloth.__init__ raises
    # MemoryBudgetError before anything runs.  Set to None to disable
    # (benchmark sweeps deliberately explore over-budget geometries).
    budget_kb: float | None = 256.0
    # -- mesh-size-aware flag scaling --------------------------------------
    # The flag thresholds are calibrated on the paper's 4×4 chip (16 cores,
    # 48 links).  The expected extreme of a *healthy* population grows with
    # the number of resources examined (≈ √(2·ln N) for the core z-scores,
    # and empirically ≈ log-linear for the link slowdown ratios), so fixed
    # thresholds false-flag on large meshes — the 12×12 ``none`` cell
    # famously flagged a healthy link at the defaults.  Scaling the flags
    # by ln(resources / reference) keeps the healthy extreme below the
    # flag at every mesh size while 10× failures stay far above it.  Set
    # the per-log coefficients to 0 to recover fixed thresholds.
    ref_cores: int = 16
    ref_links: int = 48
    core_z_per_log: float = 0.75
    link_ratio_per_log: float = 2.2
    # -- degree-aware flag scaling (non-mesh fabrics) ----------------------
    # The resource-count terms above transfer across topology classes, but
    # the link-EM's conditioning does not: wrap links (torus) and
    # unidirectional dataflow links (systolic) change how many routes each
    # link shares, smearing the per-link inverse-bandwidth estimates on
    # healthy fabrics.  The skew grows with how far the fabric's mean
    # router incidence sits from the same-dims reference mesh it was
    # calibrated on, so the link flag is padded per unit of |degree
    # difference|.  Exactly zero on every plain W×H mesh (the reference
    # class itself), keeping historical mesh thresholds bit-identical.
    link_ratio_per_degree: float = 0.45

    def flag_thresholds(self, topo) -> tuple[float, float]:
        """Resource-count + degree-aware ``(core_z, link_ratio)`` flags
        for one fabric (any registered :class:`~repro.core.routing.
        Topology`)."""
        core_z = self.effective_core_z(topo.n_cores)
        link_ratio = self.effective_link_ratio(topo.n_links)
        skew = abs(topo.mean_degree()
                   - mesh_mean_degree(topo.width, topo.height))
        return core_z, link_ratio + self.link_ratio_per_degree * skew

    def effective_core_z(self, n_cores: int) -> float:
        """Core z flag scaled for a mesh of ``n_cores`` cores."""
        excess = math.log(max(n_cores, 1) / self.ref_cores)
        return self.core_z_flag + self.core_z_per_log * max(0.0, excess)

    def effective_link_ratio(self, n_links: int) -> float:
        """Link slowdown-ratio flag scaled for a mesh of ``n_links``
        links."""
        excess = math.log(max(n_links, 1) / self.ref_links)
        return (self.link_ratio_flag
                + self.link_ratio_per_log * max(0.0, excess))


class Sloth:
    """SLOTH pipeline bound to one (workload graph, mesh) deployment."""

    name = "sloth"

    def __init__(self, graph: CompGraph, mesh: Topology,
                 cfg: SlothConfig | None = None,
                 sim_cfg: SimConfig | None = None):
        self.graph = graph
        self.mesh = mesh
        self.cfg = cfg or SlothConfig()
        # static guard: reject sketch geometries that cannot fit the
        # on-chip budget before any simulation or recording happens
        from ..analysis.memory_model import validate_config
        validate_config(self.cfg)
        self.mapped: MappedGraph = map_graph(graph, mesh)
        self.sim_cfg = sim_cfg or SimConfig(
            mu_c=calibrate(graph.total_flops(), mesh.n_cores))
        self.plan = plan_probes(graph, self.mapped)

    # -- data collection -----------------------------------------------------
    def run(self, failures: list[FailSlow] | None = None,
            seed: int = 0) -> SimResult:
        sim_cfg = dataclasses.replace(self.sim_cfg, seed=seed)
        return simulate(self.mapped, sim_cfg, failures=failures,
                        probes=self.plan.sim_plan)

    # -- analysis --------------------------------------------------------------
    def analyse(self, sim: SimResult) -> Verdict:
        """Post-hoc analysis: record the whole trace, then trace it."""
        cfg = self.cfg
        rec = record(sim, cfg.sketch, instr_per_task=cfg.instr_per_task,
                     hop_latency=self.sim_cfg.hop_latency,
                     impl=cfg.recorder_impl)
        return self.analyse_recorded(rec, sim.total_time)

    def analyse_recorded(self, rec: RecorderOutput,
                         total_time: float) -> Verdict:
        """SL-Tracer over an already-compressed trace.

        The detection half of :meth:`analyse`, split out so the
        streaming service (:class:`~repro.core.streaming.SlothStream`)
        can re-analyse a :class:`StreamingRecorder`'s cumulative output
        per window without re-recording; ``total_time`` is the analysis
        horizon (the trace's total time post-hoc, the stream's elapsed
        clock mid-stream)."""
        cfg = self.cfg
        core_z, link_ratio = cfg.flag_thresholds(self.mesh)
        core_cands = detect_cores(rec.comp_patterns, total_time,
                                  cfg.n_windows, core_z,
                                  rate_scale=getattr(self.mesh,
                                                     "rate_class", None))
        link_inf = detect_links(rec.comm_patterns, self.mesh, total_time,
                                cfg.n_windows, self.sim_cfg.hop_latency,
                                link_ratio)
        mcg = build_mcg(rec.comm_patterns, self.mesh, total_time,
                        core_cands, link_inf, cfg.n_windows)
        fr = failrank(mcg, cfg.failrank)

        # ---- combine detection evidence with FailRank refinement ---------
        # FailRank's fixed point forgets l0 geometrically (γ^k), so the
        # final verdict multiplies each candidate's detection probability by
        # its (normalised) FailRank mass: detection says *what looks slow*,
        # FailRank arbitrates *which of the correlated anomalies is the
        # propagation source*.
        n_cores = self.mesh.n_cores
        core_ev = np.zeros(n_cores)
        for c in core_cands:
            core_ev[c.core] = max(core_ev[c.core], c.prob)
        link_ev = np.zeros(self.mesh.n_links)
        for c in link_inf.candidates:
            link_ev[c.link] = max(link_ev[c.link], c.prob)

        core_fr = np.zeros(n_cores)
        core_nodes = fr.raw_node_scores[:mcg.n_windows * n_cores]
        for w in range(mcg.n_windows):
            core_fr = np.maximum(core_fr,
                                 core_nodes[w * n_cores:(w + 1) * n_cores])
        core_fr /= max(core_fr.max(), 1e-12)
        link_fr = attribute_links(mcg, fr, link_inf.theta)
        link_fr /= max(link_fr.max(), 1e-12)

        core_scores = core_ev * (0.5 + core_fr)
        link_scores = link_ev * (0.5 + link_fr)

        max_core_p = float(core_ev.max()) if n_cores else 0.0
        max_link_p = float(link_ev.max()) if len(link_ev) else 0.0
        flagged = max(max_core_p, max_link_p) >= cfg.detect_threshold

        # every resource whose detection probability independently clears
        # the threshold — with k simultaneous failures there can be several
        flagged_res = (
            [("core", int(c), float(core_ev[c]))
             for c in range(n_cores)
             if core_ev[c] >= cfg.detect_threshold]
            + [("link", int(l), float(link_ev[l]))
               for l in range(len(link_ev))
               if link_ev[l] >= cfg.detect_threshold])
        flagged_res.sort(key=lambda x: (-x[2], x[0], x[1]))

        ranking = (
            [("core", int(c), float(core_scores[c]))
             for c in np.argsort(-core_scores)[:5] if core_scores[c] > 0]
            + [("link", int(l), float(link_scores[l]))
               for l in np.argsort(-link_scores)[:5] if link_scores[l] > 0])
        ranking.sort(key=lambda x: -x[2])

        kind = loc = None
        score = 0.0
        if flagged and ranking:
            kind, loc, score = ranking[0]
        return Verdict(flagged=flagged, kind=kind, location=loc, score=score,
                       ranking=ranking, recorder=rec, failrank=fr, mcg=mcg,
                       total_time=total_time,
                       flagged_resources=tuple(flagged_res),
                       mesh=self.mesh, detector=self.name)

    def detect(self, failures: list[FailSlow] | None = None,
               seed: int = 0) -> Verdict:
        return self.analyse(self.run(failures=failures, seed=seed))

    # -- streaming -----------------------------------------------------------
    def stream(self, policy=None):
        """A fresh :class:`~repro.core.streaming.SlothStream` bound to
        this pipeline (one incremental Verdict per observed chunk).
        ``policy`` — a registered mitigation-policy name or instance —
        arms the stream to plan a mitigation at the first flag."""
        from .streaming import SlothStream
        return SlothStream(self, policy=policy)

    def stream_analyse(self, sim: SimResult, n_chunks: int = 4,
                       policy=None) -> tuple[Verdict, float | None]:
        """Replay a finished trace through the streaming service.

        Splits ``sim`` into ``n_chunks`` time-ordered chunks
        (:func:`~repro.core.streaming.split_sim`), observes them in
        order and returns ``(final verdict, first_flag_time)``.  The
        last chunk is analysed at ``sim.total_time``, so the final
        verdict equals post-hoc :meth:`analyse` of the same trace
        exactly (same impl, same cumulative sketch state);
        ``first_flag_time`` is the stream time of the earliest flagged
        window (``None`` if no window flagged).  ``policy`` arms
        mid-stream mitigation planning (see :meth:`stream`) without
        changing the return shape."""
        from .streaming import split_sim
        st = self.stream(policy=policy)
        chunks = split_sim(sim, n_chunks)
        v = None
        for i, chunk in enumerate(chunks):
            horizon = sim.total_time if i == len(chunks) - 1 else None
            v = st.observe(chunk, total_time=horizon)
        return v, st.first_flag_time


class SlothDetector:
    """Registry adapter: SLOTH under the unified detector protocol.

    ``prepare`` builds the full pipeline for the deployment (``profile`` is
    unused — SLOTH calibrates from the workload's FLOP volume, not from a
    profiling run); ``analyse`` delegates to the pipeline.
    """

    name = "sloth"

    def __init__(self):
        self.pipeline: Sloth | None = None

    def prepare(self, graph: CompGraph, mesh: Topology,
                profile: SimResult | None = None,
                cfg: SlothConfig | None = None) -> "SlothDetector":
        self.pipeline = Sloth(graph, mesh, cfg=cfg)
        return self

    def analyse(self, sim: SimResult) -> Verdict:
        if self.pipeline is None:
            raise RuntimeError("SlothDetector.analyse before prepare()")
        return self.pipeline.analyse(sim)

    def stream_analyse(self, sim: SimResult, n_chunks: int = 4,
                       policy=None) -> tuple[Verdict, float | None]:
        """Streaming protocol hook: detectors exposing this method are
        driven chunk-by-chunk on the campaign's ``streaming=`` axis and
        report detection latency (see ``campaign.run_scenario``)."""
        if self.pipeline is None:
            raise RuntimeError("SlothDetector.stream_analyse before "
                               "prepare()")
        return self.pipeline.stream_analyse(sim, n_chunks=n_chunks,
                                            policy=policy)


_register_builtin("sloth", SlothDetector)
