"""End-to-end SLOTH pipeline (Figure 4).

    workload + arch config + probe config + failure model
        → SL-Compiler (probe plan)
        → simulate (instrumented run)
        → SL-Recorder (Fail-Slow Sketch compression)
        → SL-Tracer (core/link detection → MCG → FailRank)
        → ranked root causes + storage/overhead accounting
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .compiler import plan_probes
from .detection import detect_cores, detect_links
from .failrank import FailRankParams, FailRankResult, attribute_links, \
    failrank
from .failures import FailSlow, truth_candidates
from .graph import CompGraph
from .mapping import MappedGraph, map_graph
from .mcg import MCG, build_mcg
from .recorder import RecorderOutput, record
from .routing import Mesh2D
from .simulator import SimConfig, SimResult, calibrate, simulate
from .sketch import SketchParams


@dataclasses.dataclass
class SlothConfig:
    sketch: SketchParams = dataclasses.field(default_factory=SketchParams)
    failrank: FailRankParams = dataclasses.field(
        default_factory=FailRankParams)
    n_windows: int = 4
    core_z_flag: float = 6.0
    link_ratio_flag: float = 3.0
    detect_threshold: float = 0.55   # min initial prob to report a failure
    instr_per_task: int = 64


@dataclasses.dataclass
class Verdict:
    flagged: bool
    kind: str | None              # 'core' | 'link'
    location: int | None
    score: float
    ranking: list[tuple[str, int, float]]   # top candidates
    recorder: RecorderOutput
    failrank: FailRankResult
    mcg: MCG
    total_time: float
    # every resource whose detection evidence clears the flag threshold,
    # sorted by raw evidence — the multi-failure report.  The verdict's
    # kind/location additionally weigh FailRank attribution, so the two
    # orderings may disagree on which resource comes first.
    flagged_resources: tuple[tuple[str, int, float], ...] = ()
    mesh: Mesh2D | None = dataclasses.field(
        default=None, repr=False, compare=False)

    def matches(self, failure: FailSlow | None,
                mesh: Mesh2D | None = None) -> bool:
        """Correctness of this verdict against ground truth, router-aware:
        a router truth is matched by any link of the slowed router (the
        detector only localises cores and links)."""
        if failure is None:
            return not self.flagged
        if not self.flagged:
            return False
        mesh = mesh if mesh is not None else self.mesh
        if mesh is None:
            if failure.kind == "router":
                raise ValueError(
                    "judging a router truth needs the mesh topology; pass "
                    "mesh= or use a Verdict produced by Sloth.analyse")
            return (self.kind, self.location) == failure.label()
        return (self.kind, self.location) in truth_candidates(failure, mesh)


class Sloth:
    """SLOTH detector bound to one (workload graph, mesh) deployment."""

    def __init__(self, graph: CompGraph, mesh: Mesh2D,
                 cfg: SlothConfig | None = None,
                 sim_cfg: SimConfig | None = None):
        self.graph = graph
        self.mesh = mesh
        self.cfg = cfg or SlothConfig()
        self.mapped: MappedGraph = map_graph(graph, mesh)
        self.sim_cfg = sim_cfg or SimConfig(
            mu_c=calibrate(graph.total_flops(), mesh.n_cores))
        self.plan = plan_probes(graph, self.mapped)

    # -- data collection -----------------------------------------------------
    def run(self, failures: list[FailSlow] | None = None,
            seed: int = 0) -> SimResult:
        sim_cfg = dataclasses.replace(self.sim_cfg, seed=seed)
        return simulate(self.mapped, sim_cfg, failures=failures,
                        probes=self.plan.sim_plan)

    # -- analysis --------------------------------------------------------------
    def analyse(self, sim: SimResult) -> Verdict:
        cfg = self.cfg
        rec = record(sim, cfg.sketch, instr_per_task=cfg.instr_per_task,
                     hop_latency=self.sim_cfg.hop_latency)
        core_cands = detect_cores(rec.comp_patterns, sim.total_time,
                                  cfg.n_windows, cfg.core_z_flag)
        link_inf = detect_links(rec.comm_patterns, self.mesh, sim.total_time,
                                cfg.n_windows, self.sim_cfg.hop_latency,
                                cfg.link_ratio_flag)
        mcg = build_mcg(rec.comm_patterns, self.mesh, sim.total_time,
                        core_cands, link_inf, cfg.n_windows)
        fr = failrank(mcg, cfg.failrank)

        # ---- combine detection evidence with FailRank refinement ---------
        # FailRank's fixed point forgets l0 geometrically (γ^k), so the
        # final verdict multiplies each candidate's detection probability by
        # its (normalised) FailRank mass: detection says *what looks slow*,
        # FailRank arbitrates *which of the correlated anomalies is the
        # propagation source*.
        n_cores = self.mesh.n_cores
        core_ev = np.zeros(n_cores)
        for c in core_cands:
            core_ev[c.core] = max(core_ev[c.core], c.prob)
        link_ev = np.zeros(self.mesh.n_links)
        for c in link_inf.candidates:
            link_ev[c.link] = max(link_ev[c.link], c.prob)

        core_fr = np.zeros(n_cores)
        core_nodes = fr.raw_node_scores[:mcg.n_windows * n_cores]
        for w in range(mcg.n_windows):
            core_fr = np.maximum(core_fr,
                                 core_nodes[w * n_cores:(w + 1) * n_cores])
        core_fr /= max(core_fr.max(), 1e-12)
        link_fr = attribute_links(mcg, fr, link_inf.theta)
        link_fr /= max(link_fr.max(), 1e-12)

        core_scores = core_ev * (0.5 + core_fr)
        link_scores = link_ev * (0.5 + link_fr)

        max_core_p = float(core_ev.max()) if n_cores else 0.0
        max_link_p = float(link_ev.max()) if len(link_ev) else 0.0
        flagged = max(max_core_p, max_link_p) >= cfg.detect_threshold

        # every resource whose detection probability independently clears
        # the threshold — with k simultaneous failures there can be several
        flagged_res = (
            [("core", int(c), float(core_ev[c]))
             for c in range(n_cores)
             if core_ev[c] >= cfg.detect_threshold]
            + [("link", int(l), float(link_ev[l]))
               for l in range(len(link_ev))
               if link_ev[l] >= cfg.detect_threshold])
        flagged_res.sort(key=lambda x: (-x[2], x[0], x[1]))

        ranking = (
            [("core", int(c), float(core_scores[c]))
             for c in np.argsort(-core_scores)[:5] if core_scores[c] > 0]
            + [("link", int(l), float(link_scores[l]))
               for l in np.argsort(-link_scores)[:5] if link_scores[l] > 0])
        ranking.sort(key=lambda x: -x[2])

        kind = loc = None
        score = 0.0
        if flagged and ranking:
            kind, loc, score = ranking[0]
        return Verdict(flagged=flagged, kind=kind, location=loc, score=score,
                       ranking=ranking, recorder=rec, failrank=fr, mcg=mcg,
                       total_time=sim.total_time,
                       flagged_resources=tuple(flagged_res),
                       mesh=self.mesh)

    def detect(self, failures: list[FailSlow] | None = None,
               seed: int = 0) -> Verdict:
        return self.analyse(self.run(failures=failures, seed=seed))
