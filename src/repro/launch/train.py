"""Training launcher: real training loop with fault tolerance.

Features exercised end-to-end (CPU-scale here, pod-scale by mesh swap):
  * deterministic resumable data pipeline,
  * periodic atomic checkpoints (params + optimizer + data state),
  * crash-resume: ``--resume`` restarts from the latest checkpoint,
  * elastic restart: resuming onto a different mesh re-shards arrays,
  * SLOTH pod telemetry (``--telemetry``): measured per-step wall times
    stream into the pod detector every ``--telemetry-window`` steps
    (:class:`~repro.distributed.telemetry.StepTelemetry`; the local host
    is chip 0); each window's verdict and mitigation plan are logged,
    and an ``exclude_and_restart`` plan triggers an immediate
    checkpoint.  ``--inject-slow-at/--inject-slow-steps/
    --inject-slow-factor`` scale the *reported* timings of a step range
    (training itself is unperturbed) so the detection path is
    demonstrable end-to-end; ``--expect-flagged`` turns "the injected
    slowdown was flagged" into an exit-code assertion (the CI smoke).

Usage (CPU example):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
Telemetry smoke (flags an injected 10x slow window):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --steps 24 --batch 2 --seq 32 --telemetry --telemetry-window 8 \
      --inject-slow-at 10 --inject-slow-steps 6 --expect-flagged
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import store
from ..configs.base import get_config
from ..data.pipeline import DataConfig, TokenPipeline
from ..distributed.telemetry import PodTelemetryConfig, StepTelemetry
from ..models import transformer as T
from ..optim import adamw
from . import steps as steps_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry", action="store_true",
                    help="run the SLOTH pod detector on step timings")
    ap.add_argument("--telemetry-window", type=int, default=8,
                    help="steps per streaming-detector window")
    ap.add_argument("--inject-slow-at", type=int, default=None,
                    metavar="STEP", help="scale the telemetry-reported "
                    "timing of this step onward (detection demo; training "
                    "itself is unperturbed)")
    ap.add_argument("--inject-slow-steps", type=int, default=8,
                    help="number of steps the injected slowdown lasts")
    ap.add_argument("--inject-slow-factor", type=float, default=10.0,
                    help="reported-timing multiplier for injected steps")
    ap.add_argument("--expect-flagged", action="store_true",
                    help="exit nonzero unless telemetry flagged a slow "
                    "window (CI smoke assertion)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=10,
                                total_steps=args.steps)

    rng = jax.random.PRNGKey(args.seed)
    params = T.init_model(cfg, rng, dtype=jnp.float32)
    opt_state = adamw.init_state(params, opt_cfg)

    data_cfg = DataConfig(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                          seed=args.seed)
    pipe = TokenPipeline(data_cfg)

    start_step = 0
    if args.resume and args.ckpt_dir:
        latest = store.latest_step(args.ckpt_dir)
        if latest is not None:
            (params, opt_state), extra = store.restore(
                args.ckpt_dir, latest, (params, opt_state))
            pipe = TokenPipeline.restore(data_cfg, extra["data"])
            start_step = latest
            print(f"[resume] step {latest}")

    plan = steps_mod.CellPlan(grad_accum=1, remat=False,
                              param_dtype=jnp.float32)
    train_step = jax.jit(steps_mod.make_train_step(cfg, plan, opt_cfg),
                         donate_argnums=(0, 1))

    telemetry = None
    if args.telemetry:
        tele_cfg = PodTelemetryConfig(mesh_w=4, mesh_h=4,
                                      window_steps=args.telemetry_window)
        telemetry = StepTelemetry(tele_cfg, n_shards=4, warmup=1,
                                  seed=args.seed,
                                  host=jax.process_index())

    enc_frames = None
    if cfg.enc_dec:
        enc_frames = jnp.zeros((args.batch, cfg.n_frames, cfg.d_model),
                               jnp.float32)

    losses = []
    t_begin = time.perf_counter()  # lint: allow-wallclock (telemetry)
    for step in range(start_step, args.steps):
        tokens = jnp.asarray(next(pipe))
        t0 = time.perf_counter()  # lint: allow-wallclock (measured dt)
        if cfg.enc_dec:
            params, opt_state, loss, gnorm = train_step(
                params, opt_state, tokens, enc_frames)
        else:
            params, opt_state, loss, gnorm = train_step(
                params, opt_state, tokens)
        loss = float(loss)
        losses.append(loss)
        dt = time.perf_counter() - t0  # lint: allow-wallclock
        if telemetry is not None:
            reported = dt
            if args.inject_slow_at is not None and \
                    args.inject_slow_at <= step \
                    < args.inject_slow_at + args.inject_slow_steps:
                reported *= args.inject_slow_factor
            verdict = telemetry.record_step(reported)
            if verdict is not None:
                plan = telemetry.plans[-1]
                if verdict.flagged:
                    print(f"[telemetry] step {step}: FLAGGED "
                          f"{verdict.kind} {verdict.location} "
                          f"severity {verdict.severity:.1f} -> "
                          f"{plan['action']}")
                    if plan.get("exclude_cores") or plan.get("avoid_links"):
                        # registry-backed plan (remap/reroute on the pod
                        # mesh): the resource edits the restart applies
                        print(f"[telemetry] {plan['policy']} plan: "
                              f"exclude cores "
                              f"{list(plan.get('exclude_cores', ()))}, "
                              f"avoid links "
                              f"{list(plan.get('avoid_links', ()))}")
                    if plan["action"] == "exclude_and_restart" \
                            and args.ckpt_dir:
                        path = store.save(args.ckpt_dir, step + 1,
                                          (params, opt_state),
                                          extra={"data": pipe.state(),
                                                 "loss": loss})
                        print(f"[telemetry] mitigation checkpoint {path}")
                else:
                    print(f"[telemetry] step {step}: healthy window")
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} gnorm {float(gnorm):.3f}"
                  f" {dt*1e3:.0f} ms")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            path = store.save(args.ckpt_dir, step + 1,
                              (params, opt_state),
                              extra={"data": pipe.state(),
                                     "loss": loss})
            print(f"[ckpt] {path}")
    wall = time.perf_counter() - t_begin  # lint: allow-wallclock
    if telemetry is not None:
        telemetry.flush()      # analyse any trailing partial window
        n_flagged = sum(v.flagged for v in telemetry.verdicts)
        print(f"[telemetry] {len(telemetry.verdicts)} windows, "
              f"{n_flagged} flagged")
        if args.expect_flagged and not telemetry.flagged:
            raise SystemExit(
                "telemetry smoke FAILED: no window flagged the injected "
                "slowdown")
    if losses:
        print(f"done: {args.steps - start_step} steps in {wall:.1f}s; "
              f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    else:
        print(f"nothing to do (resumed at {start_step} ≥ {args.steps})")
    return losses


if __name__ == "__main__":
    main()
