"""Trip-count-aware analysis of optimized HLO text.

``compiled.cost_analysis()`` counts every computation once, so the body of a
``while`` loop (each ``lax.scan``: the layer scan, gradient-accumulation
scan, KV-chunk scan ...) is charged one iteration.  This module parses
``compiled.as_text()``, discovers while-loop trip counts from the loop
condition's limit constant, and walks the call tree scaling costs by trip
count.  Per device it reports:

  * dot/convolution FLOPs (the MXU roofline term),
  * HBM traffic ≈ 2 × Σ op output-buffer bytes (each buffer written once
    and typically read once; fusion internals excluded),
  * per-chip collective *wire* bytes from result shapes with ring-algorithm
    multipliers: all-reduce 2×S, all-gather S, reduce-scatter n×S_out,
    all-to-all S, collective-permute S.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
                "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(
    r"\b(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|u4|s4|pred|"
    r"f8e4m3fn|f8e5m2|c64|c128)\[([0-9,]*)\]")

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

_KIND_RE = re.compile(r"\b([a-z][a-z0-9_\-]*)\(")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=\{?%?([\w.\-]+)")
_CALLS_LIST_RE = re.compile(r"(?:calls|branch_computations)=\{([^}()]*)\}")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _nbytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return max(n, 1) * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class _Op:
    kind: str
    out_bytes: int
    flops: float
    called: list
    cond: str | None
    body: str | None
    group_size: int


@dataclasses.dataclass
class _Comp:
    name: str
    ops: list
    consts: list


def _split_operands(args: str) -> list[str]:
    """Split an operand list on top-level commas only (shape dims like
    ``f32[1,3,224,224]{3,2,1,0}`` contain commas of their own)."""
    out, cur, depth = [], [], 0
    for ch in args:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return out


def _operand_dims(args: str, k: int, symshape: dict) -> list:
    """Dims of the k-th operand of an op.  Optimized HLO declares operand
    shapes inline ("dot(f32[128,128]{1,0} %a, ...)"); name-only operands
    fall back to the symbol table."""
    parts = _split_operands(args)
    if k >= len(parts):
        return []
    m = _SHAPE_RE.search(parts[k])
    if m:
        return [int(x) for x in m.group(2).split(",") if x]
    return symshape.get(parts[k].split()[-1].lstrip("%"), [])


class Analyzer:
    def __init__(self, text: str):
        self.text = text
        self.comps: dict[str, _Comp] = {}
        self._parse()

    # -- parsing -------------------------------------------------------------
    def _parse(self):
        cur = None
        symtab: dict[str, int] = {}      # op name → output elems (per comp)
        symshape: dict[str, list] = {}   # op name → dims list
        for raw in self.text.splitlines():
            s = raw.strip()
            if not s or s.startswith("//"):
                continue
            if s.endswith("{") and "->" in s and "= " not in \
                    s.split("->")[0]:
                name = s.split()[0].lstrip("%")
                if name == "ENTRY":
                    name = s.split()[1].lstrip("%")
                cur = _Comp(name, [], [])
                self.comps[name] = cur
                symtab, symshape = {}, {}
                continue
            if cur is None or "=" not in s:
                continue
            lhs, rhs = s.split("=", 1)
            opname = lhs.replace("ROOT", "").strip().lstrip("%")
            rhs = rhs.strip()
            mk = _KIND_RE.search(rhs)
            if not mk:
                continue
            kind = mk.group(1)
            result_part = rhs[:mk.start()]
            shapes = _SHAPE_RE.findall(result_part)
            out_bytes = sum(_nbytes(d, x) for d, x in shapes)
            out_elems = 0
            dims = []
            if shapes:
                dims = [int(x) for x in shapes[0][1].split(",") if x]
                out_elems = 1
                for x in dims:
                    out_elems *= x
            symtab[opname] = out_elems
            symshape[opname] = dims

            for c in _CONST_RE.findall(rhs):
                cur.consts.append(int(c))

            flops = 0.0
            if kind == "dot":
                args = rhs[mk.end():].split(")", 1)[0]
                ldims = _operand_dims(args, 0, symshape)
                mcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
                contract = 1
                if mcd and mcd.group(1):
                    for ci in mcd.group(1).split(","):
                        ci = int(ci)
                        if ci < len(ldims):
                            contract *= ldims[ci]
                flops = 2.0 * out_elems * contract
            elif kind == "convolution":
                args = rhs[mk.end():].split(")", 1)[0]
                kdims = _operand_dims(args, 1, symshape)
                kelems = 1
                for x in kdims:
                    kelems *= x
                flops = 2.0 * out_elems * max(kelems, 1)

            called = []
            ml = _CALLS_LIST_RE.search(rhs)
            if ml:
                called = [c.strip().lstrip("%") for c in
                          ml.group(1).split(",") if c.strip()]
            else:
                called = _CALLS_RE.findall(rhs)
            cond = (_COND_RE.search(rhs) or [None, None])
            body = (_BODY_RE.search(rhs) or [None, None])
            cond = cond.group(1) if hasattr(cond, "group") else None
            body = body.group(1) if hasattr(body, "group") else None

            gsize = 0
            mg = _GROUPS_LIST_RE.search(rhs)
            if mg:
                gsize = len([x for x in mg.group(1).split(",") if
                             x.strip()])
            else:
                mi = _GROUPS_IOTA_RE.search(rhs)
                if mi:
                    gsize = int(mi.group(2))
            cur.ops.append(_Op(kind, out_bytes, flops, called, cond, body,
                               gsize))

    # -- trip counts -----------------------------------------------------------
    def _trip(self, cond_name: str | None) -> int:
        if not cond_name or cond_name not in self.comps:
            return 1
        consts = [c for c in self.comps[cond_name].consts
                  if 0 < c <= 50_000_000]
        return max(consts) if consts else 1

    # -- cost walk ---------------------------------------------------------------
    def cost(self, comp_name: str, depth=0, memo=None):
        if memo is None:
            memo = {}
        if comp_name in memo:
            return memo[comp_name]
        comp = self.comps.get(comp_name)
        if comp is None or depth > 60:
            return (0.0, 0, dict.fromkeys(COLLECTIVE_KINDS, 0),
                    dict.fromkeys(COLLECTIVE_KINDS, 0))
        fl, wb = 0.0, 0
        coll = dict.fromkeys(COLLECTIVE_KINDS, 0)
        cnt = dict.fromkeys(COLLECTIVE_KINDS, 0)
        # ops that alias/forward buffers rather than writing new ones
        no_write = ("get-tuple-element", "tuple", "parameter", "bitcast",
                    "constant", "while", "iota", "after-all",
                    "opt-barrier")
        for op in comp.ops:
            fl += op.flops
            if op.kind not in no_write:
                wb += op.out_bytes
            base = op.kind.replace("-start", "")
            if base in COLLECTIVE_KINDS and not op.kind.endswith("-done"):
                n = max(op.group_size, 2)
                size = op.out_bytes
                if base == "all-reduce":
                    wire = 2 * size * (n - 1) / n
                elif base == "reduce-scatter":
                    wire = size * (n - 1)
                else:  # all-gather / all-to-all / collective-permute
                    wire = size * (n - 1) / n if base == "all-gather" \
                        else size
                coll[base] += int(wire)
                cnt[base] += 1
            if op.kind == "while":
                trip = self._trip(op.cond)
                if op.body:
                    bfl, bwb, bc, bn = self.cost(op.body, depth + 1, memo)
                    fl += bfl * trip
                    wb += bwb * trip
                    for k in COLLECTIVE_KINDS:
                        coll[k] += bc[k] * trip
                        cnt[k] += bn[k] * trip
            elif op.called and op.kind in (
                    "fusion", "call", "conditional", "map", "reduce",
                    "reduce-window", "sort", "scatter",
                    "select-and-scatter", "custom-call", "async-start"):
                for c in op.called:
                    bfl, bwb, bc, bn = self.cost(c, depth + 1, memo)
                    fl += bfl
                    if op.kind != "fusion":   # fusions write only the root
                        wb += bwb
                    for k in COLLECTIVE_KINDS:
                        coll[k] += bc[k]
                        cnt[k] += bn[k]
        res = (fl, wb, coll, cnt)
        memo[comp_name] = res
        return res

    def entry(self) -> str:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", self.text)
        return m.group(1) if m else next(iter(self.comps))

    def analyze(self) -> dict:
        fl, wb, coll, cnt = self.cost(self.entry())
        return {
            "flops": fl,
            "hbm_bytes": 2 * wb,
            "collective_bytes": coll,
            "collective_counts": cnt,
            "collective_total": sum(sorted(coll.values())),
        }


def analyze_hlo(text: str) -> dict:
    return Analyzer(text).analyze()


def top_ops(text: str, k: int = 15):
    """Profile substitute: top ops by loop-scaled write bytes and flops.

    Walks the call tree like ``Analyzer.cost`` but attributes to individual
    ops (kind + result shape), so the hillclimb can see *which* buffers
    dominate the memory term.
    """
    a = Analyzer(text)
    agg: dict[tuple, list] = {}

    def walk(comp_name, mult, depth=0, stack=()):
        comp = a.comps.get(comp_name)
        if comp is None or depth > 60 or comp_name in stack:
            return
        for op in comp.ops:
            key = (op.kind, op.out_bytes)
            rec = agg.setdefault(key, [0, 0.0, 0])
            rec[0] += op.out_bytes * mult
            rec[1] += op.flops * mult
            rec[2] += mult
            if op.kind == "while" and op.body:
                walk(op.body, mult * a._trip(op.cond), depth + 1,
                     stack + (comp_name,))
            elif op.called and op.kind in (
                    "fusion", "call", "conditional", "map", "reduce",
                    "reduce-window", "sort", "scatter",
                    "select-and-scatter", "custom-call"):
                for c in op.called:
                    walk(c, mult, depth + 1, stack + (comp_name,))

    walk(a.entry(), 1)
    rows = [(v[0], v[1], v[2], kind, size)
            for (kind, size), v in agg.items()]
    rows.sort(reverse=True)
    return rows[:k]
