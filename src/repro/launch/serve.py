"""Serving launcher: batched prefill+decode with SLOTH telemetry hooks.

Decode percentiles come from the engine's dedicated ``decode_times``
series — the old single ``step_times`` list interleaved every batch's
prefill with its decode steps, and dropping only index 0 left later
batches' (much slower) prefills inflating the "decode" p50/p99.

``--telemetry`` taps the engine's per-step hook: decode step timings
stream into the pod detector every window
(:class:`~repro.distributed.telemetry.StepTelemetry`), and each
window's verdict is printed live — a fail-slow host during decode
surfaces as a flagged ``core 0`` verdict while serving continues.

CPU example:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --requests 8 --max-new 8 --telemetry
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import get_config
from ..distributed.telemetry import PodTelemetryConfig, StepTelemetry
from ..models import transformer as T
from ..serving.engine import EngineConfig, Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry", action="store_true",
                    help="stream decode step timings into the pod "
                         "detector (one verdict per window)")
    ap.add_argument("--telemetry-window", type=int, default=8,
                    help="decode steps per streaming-detector window")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    params = T.init_model(cfg, jax.random.PRNGKey(args.seed),
                          dtype=jnp.float32)

    telemetry = hook = None
    if args.telemetry:
        telemetry = StepTelemetry(
            PodTelemetryConfig(mesh_w=4, mesh_h=4,
                               window_steps=args.telemetry_window),
            n_shards=args.batch, warmup=1, seed=args.seed,
            host=jax.process_index())

        def hook(kind, dt):
            if kind != "decode":    # prefills are not per-step samples
                return
            v = telemetry.record_step(dt)
            if v is not None and v.flagged:
                print(f"[telemetry] FLAGGED {v.kind} {v.location} "
                      f"severity {v.severity:.1f} -> "
                      f"{telemetry.plans[-1]['action']}")

    engine = ServeEngine(cfg, params,
                         EngineConfig(batch=args.batch,
                                      cache_len=args.cache_len),
                         step_hook=hook)
    # Fold host identity into the request-stream key (campaign.py
    # style) so multi-host launches don't submit identical workloads.
    rng = np.random.default_rng([args.seed, jax.process_index()])
    enc_frames = None
    if cfg.enc_dec:
        enc_frames = jnp.zeros((args.batch, cfg.n_frames, cfg.d_model),
                               jnp.float32)
    for i in range(args.requests):
        n = int(rng.integers(2, args.prompt_len + 1))
        engine.submit(Request(i, rng.integers(0, cfg.vocab, size=n)
                              .astype(np.int32), max_new=args.max_new))
    t0 = time.perf_counter()  # lint: allow-wallclock (reported only)
    done = engine.run(enc_frames=enc_frames)
    wall = time.perf_counter() - t0  # lint: allow-wallclock
    tok = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {tok} tokens, {wall:.1f}s "
          f"({tok / max(wall, 1e-9):.1f} tok/s)")
    if engine.prefill_times:
        print(f"mean prefill {np.mean(engine.prefill_times) * 1e3:.1f} ms "
              f"({len(engine.prefill_times)} batches)")
    if engine.decode_times:
        print(f"p50 decode step {np.median(engine.decode_times) * 1e3:.1f}"
              f" ms, p99 {np.quantile(engine.decode_times, 0.99) * 1e3:.1f}"
              " ms")
    if telemetry is not None:
        telemetry.flush()
        n_flagged = sum(v.flagged for v in telemetry.verdicts)
        print(f"[telemetry] {len(telemetry.verdicts)} windows, "
              f"{n_flagged} flagged")
    return done


if __name__ == "__main__":
    main()
