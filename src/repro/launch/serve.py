"""Serving launcher: batched prefill+decode with SLOTH telemetry hooks.

CPU example:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --requests 8 --max-new 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import get_config
from ..models import transformer as T
from ..serving.engine import EngineConfig, Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    params = T.init_model(cfg, jax.random.PRNGKey(args.seed),
                          dtype=jnp.float32)
    engine = ServeEngine(cfg, params,
                         EngineConfig(batch=args.batch,
                                      cache_len=args.cache_len))
    rng = np.random.default_rng(args.seed)
    enc_frames = None
    if cfg.enc_dec:
        enc_frames = jnp.zeros((args.batch, cfg.n_frames, cfg.d_model),
                               jnp.float32)
    for i in range(args.requests):
        n = int(rng.integers(2, args.prompt_len + 1))
        engine.submit(Request(i, rng.integers(0, cfg.vocab, size=n)
                              .astype(np.int32), max_new=args.max_new))
    t0 = time.perf_counter()
    done = engine.run(enc_frames=enc_frames)
    wall = time.perf_counter() - t0
    tok = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {tok} tokens, {wall:.1f}s "
          f"({tok / max(wall, 1e-9):.1f} tok/s)")
    if len(engine.step_times) > 1:
        print(f"p50 decode step {np.median(engine.step_times[1:]) * 1e3:.1f}"
              f" ms, p99 {np.quantile(engine.step_times[1:], 0.99) * 1e3:.1f}"
              " ms")
    return done


if __name__ == "__main__":
    main()
