"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the pod
axis is an outer data-parallel axis (gradient all-reduce crosses the
inter-pod links; decode shards batch across pods).

Defined as functions so importing this module never touches jax device
state; ``dryrun.py`` sets XLA_FLAGS for 512 host devices before any import.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many devices this host actually has."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
