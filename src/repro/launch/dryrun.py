import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this prints/records:
  * compiled memory analysis (bytes per device — proves it fits),
  * cost analysis (HLO FLOPs / bytes for the roofline),
  * collective bytes parsed from the optimized HLO (all-gather, all-reduce,
    reduce-scatter, all-to-all, collective-permute),
  * the three roofline terms for TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM,
    ~50 GB/s/link ICI) and the dominant bottleneck.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all          # orchestrates subprocesses
Results accumulate in dryrun_results.json (one entry per cell) so an
interrupted sweep resumes where it stopped.
"""

import argparse
import json
import re
import subprocess
import sys
import time

# hardware constants (TPU v5e)
PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link (per chip, per direction)

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "dryrun_results.json")
RESULTS_PATH = os.path.abspath(
    os.environ.get("DRYRUN_RESULTS", RESULTS_PATH))

def roofline(flops, hbm_bytes, coll_bytes, n_chips):
    t_compute = flops / (n_chips * PEAK_FLOPS)
    t_memory = hbm_bytes / (n_chips * HBM_BW)
    t_coll = coll_bytes / (n_chips * LINK_BW)
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    return terms, dom


def run_cell(arch: str, shape: str, mesh_kind: str, plan_overrides=None):
    import jax
    from ..configs.base import get_config
    from . import steps
    from .mesh import make_production_mesh

    cfg = get_config(arch)
    reason = steps.skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    plan = steps.default_plan(cfg, shape)
    if plan_overrides:
        import dataclasses as dc
        plan = dc.replace(plan, **plan_overrides)

    t0 = time.time()
    fn, args, in_sh, out_sh = steps.build_cell(cfg, shape, mesh, plan)
    donate = ()
    if shape in ("train_4k",):
        donate = (0, 1)          # params + optimizer state
    elif steps.SHAPES[shape]["kind"] == "decode":
        donate = (2,)            # KV/SSM cache updated in place
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # trip-count-aware analysis (cost_analysis counts scan bodies once)
    from .hlo_analysis import analyze_hlo
    ana = analyze_hlo(hlo)

    flops = float(ana["flops"])          # per device, loop-scaled
    flops_global = flops * n_chips
    hbm = float(ana["hbm_bytes"])
    hbm_global = hbm * n_chips
    coll = {"total": ana["collective_total"],
            "per_op": ana["collective_bytes"],
            "counts": ana["collective_counts"]}
    coll_global = coll["total"] * n_chips

    terms, dom = roofline(flops_global, hbm_global, coll_global, n_chips)

    n = cfg.param_count()
    n_active = cfg.active_param_count()
    sh = steps.SHAPES[shape]
    tokens = sh["batch"] * (sh["seq"] if sh["kind"] != "decode" else 1)
    mult = 6 if sh["kind"] == "train" else 2
    model_flops = mult * n_active * tokens

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "status": "ok",
        "n_chips": int(n_chips),
        "plan": {k: str(v) for k, v in vars(plan).items()},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "per_device": {
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            # args live for the whole step; outputs are materialised at the
            # end; peak_memory is XLA's live-set maximum for temps
            "peak_bytes": int(
                getattr(mem, "peak_memory_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0)),
            "xla_flops_1trip": float(cost.get("flops", 0.0)),
            "flops": flops, "hbm_bytes": hbm,
            "collective_bytes": coll["total"],
        },
        "collectives": {"counts": coll["counts"],
                        "bytes": coll["per_op"]},
        "roofline": {
            **{k: float(v) for k, v in terms.items()},
            "dominant": dom,
            "model_flops": model_flops,
            "useful_flops_frac": (model_flops / flops_global
                                  if flops_global else 0.0),
        },
    }
    return rec


def load_results() -> dict:
    if os.path.exists(RESULTS_PATH):
        with open(RESULTS_PATH) as f:
            return json.load(f)
    return {}


def save_result(key: str, rec: dict):
    res = load_results()
    res[key] = rec
    tmp = RESULTS_PATH + ".tmp"
    with open(tmp, "w") as f:
        json.dump(res, f, indent=1, sort_keys=True)
    os.replace(tmp, RESULTS_PATH)


def orchestrate(archs, shapes, meshes, force=False, variant="",
                plan_overrides=None):
    """Run each cell in a fresh subprocess (bounds compile-cache memory)."""
    done = load_results()
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                key = f"{arch}|{shape}|{mesh}" + (f"|{variant}" if variant
                                                  else "")
                if key in done and not force \
                        and done[key].get("status") in ("ok", "skipped"):
                    print(f"[skip cached] {key}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mesh]
                if variant:
                    cmd += ["--variant", variant]
                if plan_overrides:
                    cmd += ["--plan", json.dumps(plan_overrides)]
                print(f"[run] {key}", flush=True)
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=3600)
                if r.returncode != 0:
                    save_result(key, {"arch": arch, "shape": shape,
                                      "mesh": mesh, "status": "error",
                                      "error": r.stderr[-4000:]})
                    print(f"  ERROR (recorded): {r.stderr.splitlines()[-1] if r.stderr else '?'}")
                else:
                    print("  " + (r.stdout.strip().splitlines()[-1]
                                  if r.stdout.strip() else "ok"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--archs", default="")
    ap.add_argument("--shapes", default="")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="",
                    help="label suffix for plan-override experiments")
    ap.add_argument("--plan", default="",
                    help="JSON CellPlan overrides, e.g. "
                         "'{\"expert_parallel\": true}'")
    args = ap.parse_args()

    overrides = json.loads(args.plan) if args.plan else None

    if args.all or args.archs or args.shapes:
        from ..configs.base import list_archs
        from .steps import SHAPES
        archs = args.archs.split(",") if args.archs else list_archs()
        shapes = args.shapes.split(",") if args.shapes else list(SHAPES)
        meshes = args.meshes.split(",")
        orchestrate(archs, shapes, meshes, force=args.force,
                    variant=args.variant, plan_overrides=overrides)
        return

    rec = run_cell(args.arch, args.shape, args.mesh, overrides)
    key = f"{args.arch}|{args.shape}|{args.mesh}" + \
        (f"|{args.variant}" if args.variant else "")
    save_result(key, rec)
    if rec["status"] == "ok":
        r = rec["roofline"]
        print(json.dumps({
            "cell": key,
            "peak_GiB": round(rec["per_device"]["peak_bytes"] / 2**30, 2),
            "compute_s": f"{r['compute_s']:.3e}",
            "memory_s": f"{r['memory_s']:.3e}",
            "collective_s": f"{r['collective_s']:.3e}",
            "dominant": r["dominant"],
            "useful_flops_frac": round(r["useful_flops_frac"], 3),
            "compile_s": rec["compile_s"],
        }))
    else:
        print(json.dumps(rec))


if __name__ == "__main__":
    main()
