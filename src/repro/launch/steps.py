"""Step builders + abstract input specs for every (arch × shape) cell.

``build_cell`` returns (step_fn, input ShapeDtypeStructs, in_shardings,
out_shardings) for one cell, ready for ``jax.jit(...).lower(...)`` — used
by both the dry-run and the real launchers.

Shapes (assignment):
  train_4k     seq 4096,   global batch 256   → train_step (fwd+bwd+AdamW)
  prefill_32k  seq 32768,  global batch 32    → prefill (fills KV cache)
  decode_32k   seq 32768,  global batch 128   → serve_step (1 token, cache)
  long_500k    seq 524288, global batch 1     → serve_step, sub-quadratic
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..models import sharding as S
from ..models import transformer as T
from ..optim import adamw

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


@dataclasses.dataclass(frozen=True)
class CellPlan:
    """Tunable distribution knobs for one cell (the hillclimb levers)."""
    fsdp: bool = False
    expert_parallel: bool = False
    grad_accum: int = 1
    remat: bool = True
    param_dtype: Any = jnp.bfloat16
    opt_state_dtype: Any = jnp.float32
    seq_shard_activations: bool = False   # Megatron-SP style boundary shard
    embed_mode: str = "vocab"             # 'dmodel' was refuted (see §Perf)
    pin_activations: bool = False         # residual-stream constraints
    pad_vocab: bool = False               # pad vocab to a shardable multiple


def default_plan(cfg: ArchConfig, shape: str) -> CellPlan:
    """Baseline plan: FSDP + bf16 optimizer for the ≥30B models, gradient
    accumulation sized so boundary activations fit."""
    big = cfg.param_count() > 20e9
    huge = cfg.param_count() > 100e9
    accum = 1
    if SHAPES[shape]["kind"] == "train":
        # per-device boundary activation budget ≈ b_loc·S·d·2B per period
        accum = 8 if big else 4
    return CellPlan(fsdp=big, expert_parallel=False, grad_accum=accum,
                    opt_state_dtype=jnp.bfloat16 if huge else jnp.float32)


def skip_reason(cfg: ArchConfig, shape: str) -> str | None:
    if shape == "long_500k" and not cfg.sub_quadratic:
        return ("pure full-attention arch: 500k dense decode is the "
                "quadratic case the assignment excludes")
    return None


# ---------------------------------------------------------------------------
# loss / steps
# ---------------------------------------------------------------------------

def loss_fn(cfg, params, tokens, enc_frames=None, remat=True):
    logits, aux = T.forward_train(cfg, params, tokens,
                                  enc_frames=enc_frames, remat=remat)
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None],
                               axis=-1)[..., 0]
    ce = (logz - gold).mean()
    return ce + 0.01 * aux, ce


def make_train_step(cfg, plan: CellPlan, opt_cfg=None):
    opt_cfg = opt_cfg or adamw.AdamWConfig(state_dtype=plan.opt_state_dtype)

    def train_step(params, opt_state, tokens, enc_frames=None):
        def micro_loss(p, toks, frames):
            return loss_fn(cfg, p, toks, frames, remat=plan.remat)

        if plan.grad_accum > 1:
            a = plan.grad_accum
            b = tokens.shape[0] // a
            toks = tokens.reshape(a, b, tokens.shape[1])
            frames = None
            if enc_frames is not None:
                frames = enc_frames.reshape(a, b, *enc_frames.shape[1:])

            def acc(carry, xs):
                g_sum, l_sum = carry
                tb = xs[0]
                fb = xs[1] if enc_frames is not None else None
                (l, _), g = jax.value_and_grad(micro_loss, has_aux=True)(
                    params, tb, fb)
                return (jax.tree.map(jnp.add, g_sum, g), l_sum + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            xs = (toks, frames) if enc_frames is not None else (toks,
                                                                None)
            if enc_frames is None:
                (grads, loss), _ = jax.lax.scan(
                    lambda c, t: acc(c, (t, None)), (g0, 0.0), toks)
            else:
                (grads, loss), _ = jax.lax.scan(acc, (g0, 0.0),
                                                (toks, frames))
            grads = jax.tree.map(lambda g: g / a, grads)
            loss = loss / a
        else:
            (loss, _), grads = jax.value_and_grad(
                micro_loss, has_aux=True)(params, tokens, enc_frames)

        new_params, new_opt, stats = adamw.apply(params, grads, opt_state,
                                                 opt_cfg)
        return new_params, new_opt, loss, stats["grad_norm"]

    return train_step


def make_prefill_step(cfg, plan: CellPlan, seq: int, batch: int):
    def prefill_step(params, tokens, enc_frames=None):
        cache = T.init_cache(cfg, batch, seq, dtype=plan.param_dtype)
        last, cache, memory = T.prefill(cfg, params, tokens, cache,
                                        enc_frames=enc_frames,
                                        remat=plan.remat)
        out = (last, cache)
        return out + ((memory,) if cfg.enc_dec else ())
    return prefill_step


def make_decode_step(cfg, plan: CellPlan):
    def serve_step(params, tokens, cache, pos, memory=None):
        logits, cache = T.decode_step(cfg, params, tokens, cache, pos,
                                      memory=memory)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return next_tok.astype(jnp.int32), logits, cache
    return serve_step


# ---------------------------------------------------------------------------
# cell assembly
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def params_shape(cfg, plan: CellPlan):
    return jax.eval_shape(
        partial(T.init_model, cfg, dtype=plan.param_dtype),
        jax.random.PRNGKey(0))


def input_specs(cfg: ArchConfig, shape: str, plan: CellPlan):
    """Abstract ShapeDtypeStructs for every model input of this cell."""
    sh = SHAPES[shape]
    b, s = sh["batch"], sh["seq"]
    specs = {}
    if sh["kind"] in ("train", "prefill"):
        specs["tokens"] = _sds((b, s), jnp.int32)
        if cfg.enc_dec:
            specs["enc_frames"] = _sds((b, cfg.n_frames, cfg.d_model),
                                       plan.param_dtype)
    else:
        specs["tokens"] = _sds((b, 1), jnp.int32)
        specs["cache"] = jax.eval_shape(
            partial(T.init_cache, cfg, b, s, dtype=plan.param_dtype))
        specs["pos"] = _sds((), jnp.int32)
        if cfg.enc_dec:
            specs["memory"] = _sds((b, cfg.n_frames, cfg.d_model),
                                   plan.param_dtype)
    return specs


def build_cell(cfg: ArchConfig, shape: str, mesh, plan: CellPlan | None
               = None):
    """Returns (fn, example_args, in_shardings, out_shardings)."""
    plan = plan or default_plan(cfg, shape)
    if plan.pad_vocab and cfg.vocab % 128:
        # unshardable vocab forces a replicated embedding/lm_head — pad to
        # the next multiple of 128 (tokens never index the padding)
        cfg = dataclasses.replace(cfg, vocab=-(-cfg.vocab // 128) * 128)
    sh = SHAPES[shape]
    b = sh["batch"]
    from ..models import policy
    if plan.pin_activations:
        policy.set_policy(S.batch_spec(mesh, b) or None, "model",
                          seq_shard=plan.seq_shard_activations)
    else:
        policy.clear_policy()
    pshape = params_shape(cfg, plan)
    pspec = S.param_specs(pshape, mesh,
                          fsdp_axis="data" if plan.fsdp else None,
                          expert_parallel=plan.expert_parallel,
                          embed_mode=plan.embed_mode)
    ns = lambda spec: jax.tree.map(  # noqa: E731
        lambda sp: NamedSharding(mesh, sp), spec,
        is_leaf=lambda x: isinstance(x, P))
    tok_spec = S.token_specs(mesh, b)
    specs = input_specs(cfg, shape, plan)

    if sh["kind"] == "train":
        opt_shape = jax.eval_shape(
            partial(adamw.init_state,
                    cfg=adamw.AdamWConfig(state_dtype=plan.opt_state_dtype)),
            pshape)
        opt_spec = {"m": pspec, "v": pspec,
                    "step": P()}
        step = make_train_step(cfg, plan)
        args = (pshape, opt_shape, specs["tokens"])
        in_sh = (ns(pspec), ns(opt_spec), ns(tok_spec))
        if cfg.enc_dec:
            frame_spec = P(S.batch_spec(mesh, b) or None, None, None)
            args += (specs["enc_frames"],)
            in_sh += (ns(frame_spec),)
        out_sh = (ns(pspec), ns(opt_spec),
                  NamedSharding(mesh, P()), NamedSharding(mesh, P()))
        return step, args, in_sh, out_sh

    if sh["kind"] == "prefill":
        step = make_prefill_step(cfg, plan, sh["seq"], b)
        cache_shape = jax.eval_shape(
            partial(T.init_cache, cfg, b, sh["seq"],
                    dtype=plan.param_dtype))
        cspec = S.cache_specs(cfg, cache_shape, mesh, b)
        args = (pshape, specs["tokens"])
        in_sh = (ns(pspec), ns(tok_spec))
        logits_spec = S.sanitize(
            P(S.batch_spec(mesh, b) or None, None, "model"),
            (b, 1, cfg.vocab), mesh)
        outs = [NamedSharding(mesh, logits_spec), ns(cspec)]
        if cfg.enc_dec:
            frame_spec = P(S.batch_spec(mesh, b) or None, None, None)
            args += (specs["enc_frames"],)
            in_sh += (ns(frame_spec),)
            outs.append(NamedSharding(mesh, frame_spec))
        return step, args, in_sh, tuple(outs)

    # decode
    step = make_decode_step(cfg, plan)
    cspec = S.cache_specs(cfg, specs["cache"], mesh, b)
    args = (pshape, specs["tokens"], specs["cache"], specs["pos"])
    in_sh = (ns(pspec), ns(tok_spec), ns(cspec),
             NamedSharding(mesh, P()))
    logits_spec = S.sanitize(
        P(S.batch_spec(mesh, b) or None, None, "model"),
        (b, 1, cfg.vocab), mesh)
    out_sh = (ns(tok_spec), NamedSharding(mesh, logits_spec), ns(cspec))
    if cfg.enc_dec:
        frame_spec = P(S.batch_spec(mesh, b) or None, None, None)
        args += (specs["memory"],)
        in_sh += (ns(frame_spec),)
    return step, args, in_sh, out_sh
