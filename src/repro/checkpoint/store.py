"""Sharded, atomic checkpointing with elastic reshard-on-load.

Layout:  <dir>/step_<N>/
            manifest.json      — step, pytree structure, shapes/dtypes,
                                 data-pipeline state, mesh shape at save
            arrays.npz         — flattened leaves (single-host container;
                                 a multi-host deployment writes one shard
                                 file per host: shard_<i>.npz)
Writes go to ``<dir>/.tmp_step_<N>`` and are renamed at the end, so a crash
mid-write never corrupts the latest checkpoint.  Loading replaces device
placement entirely (elastic restart: the new mesh may differ from the mesh
at save; arrays are re-sharded via ``jax.device_put`` with the new specs).
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None,
         keep: int = 3):
    leaves, treedef = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "shapes": [list(np.shape(x)) for x in leaves],
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)              # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_"):
            try:
                out.append(int(d.split("_", 1)[1]))
            except ValueError:
                pass
    return out


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, tree_like, shardings=None):
    """Load ``step`` into the structure of ``tree_like``.  ``shardings``
    (same pytree of NamedSharding) re-shards for the *current* mesh —
    elastic restart onto a different mesh shape just passes new specs."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_like, treedef = _flatten(tree_like)
    assert manifest["n_leaves"] == len(leaves_like), \
        "checkpoint structure mismatch"
    new_leaves = []
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_like))
    for i, (like, sh) in enumerate(zip(leaves_like, shard_leaves)):
        arr = data[f"leaf_{i}"]
        arr = arr.astype(np.asarray(like).dtype) if hasattr(like, "dtype") \
            else arr
        if sh is not None:
            new_leaves.append(jax.device_put(arr, sh))
        else:
            new_leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), \
        manifest["extra"]
