"""Structural (AST) auditor for the Pallas kernels.

Walks every ``kernels/*/kernel.py`` without importing or tracing it and
checks the properties that have actually bitten this repo's kernel work
(grid/BlockSpec mismatches fail silently under ``interpret=True`` on
CPU and only explode — or worse, corrupt state — on real hardware):

* **audit contract** — each kernel module must declare a module-level
  ``AUDIT = {"grid_rank": int, "aliased_io": bool,
  "sequential_grid": bool}`` stating its intended shape; the auditor
  cross-checks the declaration against the code, so a refactor that
  changes the grid or aliasing without updating the contract is caught
  (``missing-audit-contract`` / ``audit-grid-rank-mismatch`` /
  ``audit-alias-mismatch`` / ``audit-semantics-mismatch``).
* **index-map bounds vs the grid** — every ``pl.BlockSpec`` index map
  must take exactly one argument per grid axis (``index-map-arity``),
  return one block coordinate per block-shape dimension
  (``index-map-rank``), and not offset a grid variable by a nonzero
  constant (``index-map-offset`` — ``lambda i: (i + 1,)`` reads one
  block past the end of the array on the last grid step).
* **grid-carried write races** — if any grid axis is marked
  ``"parallel"`` in ``dimension_semantics``, an output BlockSpec whose
  index map ignores that axis writes the same block from concurrent
  grid steps, and aliased input/output refs carry state that a parallel
  axis would tear (``parallel-write-race``).  The sketch kernel's
  correctness depends on the *sequential* grid preserving Algorithm 1's
  insertion order — this rule is what stops someone "optimising" it
  with a parallel grid annotation.
* **dtype-narrowing hazards** — ``dot_general`` without
  ``preferred_element_type`` accumulates in the input dtype on TPU
  (``dot-missing-preferred-type``), and explicit casts to
  ``bfloat16``/``float16`` (``narrow-float-cast``) silently diverge
  from the f32 numpy oracle, breaking ref/batched parity.

Everything here is pure ``ast`` — no JAX import, safe in any CI
container.
"""

from __future__ import annotations

import ast
from pathlib import Path

from .report import Finding

#: Required keys (and value types) of a kernel module's AUDIT contract.
AUDIT_KEYS = {"grid_rank": int, "aliased_io": bool,
              "sequential_grid": bool}

_NARROW_DTYPES = {"bfloat16", "float16"}


def _kernel_files(root: Path | None) -> list[Path]:
    if root is None:
        root = Path(__file__).resolve().parents[1]   # the repro package
    else:
        root = Path(root)
        for sub in ("src/repro", "repro"):
            if (root / sub / "kernels").is_dir():
                root = root / sub
                break
    return sorted(root.glob("kernels/*/kernel.py"))


def _rel(path: Path) -> str:
    s = str(path)
    marker = "src/repro/"
    i = s.find(marker)
    return s[i:] if i >= 0 else s


class _Scope:
    """Simple ``name → value-node`` map of one function (or module)
    body's single-target assignments, for resolving grids and spec
    lists referenced by name."""

    def __init__(self, body: list[ast.stmt]):
        self.assigns: dict[str, ast.expr] = {}
        for stmt in body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name):
                self.assigns[stmt.targets[0].id] = stmt.value

    def resolve(self, node: ast.expr, depth: int = 4) -> ast.expr:
        while isinstance(node, ast.Name) and depth > 0:
            nxt = self.assigns.get(node.id)
            if nxt is None:
                return node
            node, depth = nxt, depth - 1
        return node


def _is_call_to(node: ast.expr, name: str) -> bool:
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    return (isinstance(f, ast.Attribute) and f.attr == name) or \
        (isinstance(f, ast.Name) and f.id == name)


def _kwarg(call: ast.Call, name: str) -> ast.expr | None:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _find_dimension_semantics(call: ast.Call) -> tuple[list[str],
                                                       int] | None:
    """``dimension_semantics`` anywhere in the pallas_call's keyword
    subtree (direct kwarg or nested in ``compiler_params=...``);
    returns (axis kinds, line) if every entry is a string literal."""
    for node in ast.walk(call):
        if isinstance(node, ast.keyword) and \
                node.arg == "dimension_semantics":
            v = node.value
            if isinstance(v, (ast.Tuple, ast.List)) and all(
                    isinstance(e, ast.Constant) and isinstance(e.value,
                                                               str)
                    for e in v.elts):
                return ([e.value for e in v.elts], v.lineno)
    return None


def _lambda_param_names(lam: ast.Lambda) -> list[str]:
    return [a.arg for a in lam.args.args]


def _names_in(node: ast.expr) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _block_specs_in(tree: ast.AST) -> list[ast.Call]:
    return [n for n in ast.walk(tree) if _is_call_to(n, "BlockSpec")]


def _spec_parts(call: ast.Call) -> tuple[ast.expr | None,
                                         ast.Lambda | None]:
    """(block-shape node, index-map lambda) of one BlockSpec call."""
    shape = call.args[0] if call.args else _kwarg(call, "block_shape")
    imap = call.args[1] if len(call.args) > 1 else _kwarg(call,
                                                          "index_map")
    return shape, imap if isinstance(imap, ast.Lambda) else None


def _resolve_spec_list(node: ast.expr, scope: _Scope) \
        -> list[ast.Call] | None:
    """Best-effort resolution of an ``out_specs`` expression to its
    BlockSpec call nodes; ``None`` when anything is opaque (computed
    lists, multiplied names) — callers must then skip spec-level rules
    rather than guess."""
    node = scope.resolve(node)
    if _is_call_to(node, "BlockSpec"):
        return [node]
    if isinstance(node, (ast.List, ast.Tuple)):
        out: list[ast.Call] = []
        for e in node.elts:
            sub = _resolve_spec_list(e, scope)
            if sub is None:
                return None
            out.extend(sub)
        return out
    return None


def _audit_contract(tree: ast.Module, path: str,
                    findings: list[Finding]) -> dict | None:
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name) \
                and stmt.targets[0].id == "AUDIT":
            try:
                audit = ast.literal_eval(stmt.value)
            except (ValueError, SyntaxError):
                findings.append(Finding(
                    "kernels", "missing-audit-contract", path,
                    stmt.lineno,
                    "AUDIT must be a literal dict"))
                return None
            bad = [k for k, t in AUDIT_KEYS.items()
                   if not isinstance(audit.get(k), t)]
            if bad:
                findings.append(Finding(
                    "kernels", "missing-audit-contract", path,
                    stmt.lineno,
                    f"AUDIT missing/ill-typed keys: {bad} "
                    f"(need {sorted(AUDIT_KEYS)})"))
                return None
            return audit
    findings.append(Finding(
        "kernels", "missing-audit-contract", path, 1,
        "kernel module declares no AUDIT contract "
        "(AUDIT = {'grid_rank': ..., 'aliased_io': ..., "
        "'sequential_grid': ...})"))
    return None


def _audit_call(call: ast.Call, scope: _Scope, audit: dict | None,
                path: str, findings: list[Finding]) -> int | None:
    """Audit one ``pl.pallas_call``; returns the resolved grid rank."""
    grid_node = _kwarg(call, "grid")
    grid_rank = None
    if grid_node is not None:
        g = scope.resolve(grid_node)
        if isinstance(g, (ast.Tuple, ast.List)):
            grid_rank = len(g.elts)
    if audit is not None and grid_rank is not None \
            and audit["grid_rank"] != grid_rank:
        findings.append(Finding(
            "kernels", "audit-grid-rank-mismatch", path, call.lineno,
            f"AUDIT declares grid_rank={audit['grid_rank']} but "
            f"pallas_call uses a rank-{grid_rank} grid"))

    aliases = _kwarg(call, "input_output_aliases")
    aliased = aliases is not None and not (
        isinstance(aliases, ast.Dict) and not aliases.keys)
    if audit is not None and audit["aliased_io"] != aliased:
        findings.append(Finding(
            "kernels", "audit-alias-mismatch", path, call.lineno,
            f"AUDIT declares aliased_io={audit['aliased_io']} but "
            f"pallas_call {'uses' if aliased else 'does not use'} "
            f"input_output_aliases"))

    sem = _find_dimension_semantics(call)
    par_axes = [i for i, kind in enumerate(sem[0])
                if kind == "parallel"] if sem else []
    if audit is not None and audit["sequential_grid"] and par_axes:
        findings.append(Finding(
            "kernels", "audit-semantics-mismatch", path, sem[1],
            f"AUDIT declares sequential_grid=True but "
            f"dimension_semantics marks axes {par_axes} parallel"))

    if par_axes and aliased:
        findings.append(Finding(
            "kernels", "parallel-write-race", path, call.lineno,
            f"input_output_aliases carries state across the grid, but "
            f"axes {par_axes} are marked parallel — concurrent grid "
            f"steps would tear the aliased refs"))

    if par_axes:
        out_node = _kwarg(call, "out_specs")
        out_specs = _resolve_spec_list(out_node, scope) \
            if out_node is not None else None
        for spec in out_specs or []:
            _, imap = _spec_parts(spec)
            if imap is None:
                continue
            params = _lambda_param_names(imap)
            used = _names_in(imap.body)
            for ax in par_axes:
                if ax < len(params) and params[ax] not in used:
                    findings.append(Finding(
                        "kernels", "parallel-write-race", path,
                        imap.lineno,
                        f"output index map ignores parallel grid axis "
                        f"{ax} ({params[ax]!r}) — concurrent steps "
                        f"write the same output block"))
    return grid_rank


def _audit_specs_list(specs: list[ast.Call], grid_ranks: set[int],
                      path: str, findings: list[Finding]) -> None:
    for spec in specs:
        shape, imap = _spec_parts(spec)
        if imap is None:
            continue
        params = _lambda_param_names(imap)
        if grid_ranks and len(params) not in grid_ranks:
            findings.append(Finding(
                "kernels", "index-map-arity", path, imap.lineno,
                f"index map takes {len(params)} args but the grid has "
                f"rank {sorted(grid_ranks)} — one arg per grid axis"))
        body = imap.body
        ret = body.elts if isinstance(body, ast.Tuple) else [body]
        if isinstance(shape, (ast.Tuple, ast.List)) \
                and len(shape.elts) != len(ret):
            findings.append(Finding(
                "kernels", "index-map-rank", path, imap.lineno,
                f"block shape has {len(shape.elts)} dims but the index "
                f"map returns {len(ret)} coordinates"))
        pset = set(params)
        for el in ret:
            if isinstance(el, ast.BinOp) and \
                    isinstance(el.op, (ast.Add, ast.Sub)):
                sides = [el.left, el.right]
                has_param = any(isinstance(s, ast.Name)
                                and s.id in pset for s in sides)
                const = next((s.value for s in sides
                              if isinstance(s, ast.Constant)
                              and isinstance(s.value, int)), None)
                if has_param and const:
                    findings.append(Finding(
                        "kernels", "index-map-offset", path, el.lineno,
                        f"index map offsets a grid variable by "
                        f"{const:+d} — the last grid step indexes a "
                        f"block outside the array"))


def _audit_dtypes(tree: ast.Module, path: str,
                  findings: list[Finding]) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in ("dot_general", "dot"):
            if _kwarg(node, "preferred_element_type") is None:
                findings.append(Finding(
                    "kernels", "dot-missing-preferred-type", path,
                    node.lineno,
                    f"{node.func.attr} without preferred_element_type "
                    f"accumulates in the input dtype on TPU — pass "
                    f"preferred_element_type=jnp.float32 to keep "
                    f"ref/batched parity"))
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr == "astype" and node.args:
            tgt = node.args[0]
            if isinstance(tgt, ast.Attribute) \
                    and tgt.attr in _NARROW_DTYPES:
                findings.append(Finding(
                    "kernels", "narrow-float-cast", path, node.lineno,
                    f"explicit cast to {tgt.attr} narrows below the "
                    f"f32 the numpy oracle computes in — a silent "
                    f"parity hazard"))


def audit_source(source: str, path: str) -> list[Finding]:
    """Audit one kernel module's source text (the unit the self-test
    drives with synthetic violations)."""
    findings: list[Finding] = []
    tree = ast.parse(source)
    audit = _audit_contract(tree, path, findings)

    # innermost-scope assignment: function scopes are walked first (in
    # increasing depth order functions nest, so later entries are
    # inner), and a call/spec already claimed by an inner scope is not
    # re-audited by an outer one.
    fn_scopes = [n for n in ast.walk(tree)
                 if isinstance(n, (ast.FunctionDef,
                                   ast.AsyncFunctionDef))]
    scopes: list[tuple[ast.AST, list[ast.stmt]]] = \
        [(n, n.body) for n in reversed(fn_scopes)] + [(tree, tree.body)]
    claimed: set[int] = set()
    for scope_tree, body in scopes:
        calls = [n for n in ast.walk(scope_tree)
                 if _is_call_to(n, "pallas_call")
                 and id(n) not in claimed]
        if not calls:
            continue
        scope = _Scope(body)
        ranks: set[int] = set()
        for call in calls:
            claimed.add(id(call))
            r = _audit_call(call, scope, audit, path, findings)
            if r is not None:
                ranks.add(r)
        specs = [s for s in _block_specs_in(scope_tree)
                 if id(s) not in claimed]
        claimed.update(id(s) for s in specs)
        _audit_specs_list(specs, ranks, path, findings)
    # module-wide dtype rules
    _audit_dtypes(tree, path, findings)
    from .report import attach_symbols
    return attach_symbols(_dedupe(findings), {path: tree})


def _dedupe(findings: list[Finding]) -> list[Finding]:
    seen: set[tuple] = set()
    out = []
    for f in findings:
        k = (f.rule, f.path, f.line, f.message)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out


def check(root=None) -> list[Finding]:
    """Audit every ``kernels/*/kernel.py`` under ``root`` (default: the
    installed ``repro`` package)."""
    findings: list[Finding] = []
    files = _kernel_files(Path(root) if root else None)
    if not files:
        findings.append(Finding(
            "kernels", "no-kernels-found", str(root or "<package>"), 0,
            "found no kernels/*/kernel.py to audit"))
        return findings
    for f in files:
        findings.extend(audit_source(f.read_text(), _rel(f)))
    return findings


# One synthetic kernel tripping every rule at least once; the self-test
# asserts each rule fires on it and none fires on the real tree.
_SYNTHETIC_BAD = '''
AUDIT = {"grid_rank": 2, "aliased_io": False, "sequential_grid": True}
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def bad(x):
    def _k(x_ref, o_ref):
        o_ref[:] = jax.lax.dot_general(
            x_ref[:], x_ref[:], (((1,), (0,)), ((), ())))
        o_ref[:] = o_ref[:].astype(jnp.bfloat16)

    return pl.pallas_call(
        _k,
        grid=(4,),
        in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i + 1,))],
        out_specs=[pl.BlockSpec((8,), lambda i: (0,))],
        dimension_semantics=("parallel",),
        input_output_aliases={0: 0},
    )(x)
'''

_SYNTHETIC_RULES = (
    "audit-grid-rank-mismatch", "audit-alias-mismatch",
    "audit-semantics-mismatch", "index-map-arity", "index-map-rank",
    "index-map-offset", "parallel-write-race",
    "dot-missing-preferred-type", "narrow-float-cast",
)


def self_test() -> None:
    """Plant synthetic violations and assert every rule catches its
    own; the real tree must stay clean."""
    clean = check()
    assert clean == [], \
        "clean-tree kernel findings:\n" + "\n".join(
            f.render() for f in clean)
    bad = audit_source(_SYNTHETIC_BAD, "<synthetic>")
    got = {f.rule for f in bad}
    missing = [r for r in _SYNTHETIC_RULES if r not in got]
    assert not missing, f"rules not triggered by synthetic: {missing}"
    nocontract = audit_source("import jax\n", "<synthetic>")
    assert any(f.rule == "missing-audit-contract" for f in nocontract),\
        "missing AUDIT contract not flagged"
