"""CLI for the static-analysis passes.

Usage::

    python -m repro.analysis --check all            # human-readable
    python -m repro.analysis --check memory --json  # machine-readable
    python -m repro.analysis --self-test            # planted violations

Exit status: 0 iff the selected checks produced no findings (and, with
``--self-test``, every planted synthetic violation was caught).  CI
runs ``--check all`` and ``--self-test`` as the ``static-analysis``
job.
"""

from __future__ import annotations

import argparse
import sys

from . import (CHECKS, findings_to_json, render_findings, run_checks,
               run_self_tests)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static verification: memory budget, Pallas kernel "
                    "safety, determinism invariants.")
    ap.add_argument("--check", default="all",
                    choices=("all",) + CHECKS,
                    help="which pass to run (default: all)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON")
    ap.add_argument("--budget-kb", type=float, default=None,
                    help="override the memory pass's per-chip budget "
                         "(KiB; default: each config's own budget_kb)")
    ap.add_argument("--self-test", action="store_true",
                    help="run each pass's planted-violation self-test "
                         "instead of checking the tree")
    args = ap.parse_args(argv)

    if args.self_test:
        try:
            run_self_tests(args.check)
        except AssertionError as e:
            print(f"self-test FAILED: {e}", file=sys.stderr)
            return 1
        print(f"self-test OK ({args.check})")
        return 0

    findings = run_checks(args.check, budget_kb=args.budget_kb)
    if args.json:
        print(findings_to_json(findings, extra={"check": args.check}))
    else:
        print(render_findings(findings))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
