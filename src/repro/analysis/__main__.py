"""CLI for the static-analysis passes.

Usage::

    python -m repro.analysis --check all            # human-readable
    python -m repro.analysis --check memory --json  # machine-readable
    python -m repro.analysis --self-test            # planted violations
    python -m repro.analysis --check all \\
        --baseline analysis/baseline.json           # CI gate
    python -m repro.analysis --check all \\
        --update-baseline analysis/baseline.json    # accept current set

Exit status: 0 iff the selected checks produced no finding outside the
baseline (no ``--baseline`` means an empty baseline: every finding
fails) — and, with ``--self-test``, every planted synthetic violation
was caught.  CI runs ``--check all --baseline analysis/baseline.json``
and ``--self-test`` as the ``static-analysis`` job.

``--baseline`` accepts either a real path or a path relative to the
``repro`` package (so ``analysis/baseline.json`` works from the repo
root without knowing the src layout).
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import (CHECKS, findings_to_json, load_baseline, new_findings,
               render_findings, run_checks, run_self_tests,
               write_baseline)


def _resolve_baseline_path(spec: str) -> Path:
    """Literal path if it exists, else fall back to the package tree
    (``analysis/baseline.json`` → ``.../src/repro/analysis/baseline.json``)."""
    p = Path(spec)
    if p.exists():
        return p
    fallback = Path(__file__).resolve().parents[1] / spec
    return fallback if fallback.exists() else p


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static verification: memory budget, Pallas kernel "
                    "safety, determinism invariants, interprocedural "
                    "determinism dataflow.")
    ap.add_argument("--check", default="all",
                    choices=("all",) + CHECKS,
                    help="which pass to run (default: all)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON (includes fingerprints "
                         "and per-pass timings)")
    ap.add_argument("--budget-kb", type=float, default=None,
                    help="override the memory pass's per-chip budget "
                         "(KiB; default: each config's own budget_kb); "
                         "only valid with --check memory or all")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="accepted-findings file: only findings whose "
                         "fingerprint is absent from FILE fail")
    ap.add_argument("--update-baseline", default=None, metavar="FILE",
                    help="write the current finding set to FILE as the "
                         "new baseline and exit 0")
    ap.add_argument("--self-test", action="store_true",
                    help="run each pass's planted-violation self-test "
                         "instead of checking the tree")
    args = ap.parse_args(argv)

    if args.budget_kb is not None and args.check not in ("memory",
                                                         "all"):
        ap.error(f"--budget-kb only applies to the memory pass; "
                 f"--check {args.check} would silently ignore it")

    if args.self_test:
        try:
            run_self_tests(args.check)
        except AssertionError as e:
            print(f"self-test FAILED: {e}", file=sys.stderr)
            return 1
        print(f"self-test OK ({args.check})")
        return 0

    timings: dict[str, float] = {}
    findings = run_checks(args.check, budget_kb=args.budget_kb,
                          timings=timings)

    if args.update_baseline:
        write_baseline(args.update_baseline, findings)
        print(f"baseline written: {len(findings)} finding"
              f"{'s' if len(findings) != 1 else ''} -> "
              f"{args.update_baseline}")
        return 0

    baseline = {}
    if args.baseline:
        baseline = load_baseline(_resolve_baseline_path(args.baseline))
    new = new_findings(findings, baseline)

    if args.json:
        print(findings_to_json(findings, baseline=baseline,
                               extra={"check": args.check,
                                      "timings": timings}))
    else:
        print(render_findings(findings))
        if baseline:
            print(f"{len(findings) - len(new)} baselined, "
                  f"{len(new)} new")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
