"""Interprocedural determinism dataflow over the repro package.

PR 7's lints are file-local pattern matchers; this pass sees *across*
function and module boundaries via the AST call graph
(:mod:`.callgraph`).  Three rule families, all execution-free:

* **seed provenance** — every ``np.random.default_rng(...)`` /
  ``np.random.SeedSequence(...)`` / ``jax.random.PRNGKey(...)``
  argument must be statically traceable to a scenario seed, a config
  field, or the CLI ``--seed`` (identifiers containing ``seed``, ``key``
  or ``rng``; composites count as seeded if any component is).  A bare
  literal (``PRNGKey(0)``) in library code is a ``literal-seed``
  finding — literals of convenience belong in ``examples/`` (which this
  pass does not scan) or behind a reviewed marker/baseline entry.  An
  argument whose provenance cannot be traced — including a function
  parameter whose in-package call sites all pass untraceable values —
  is an ``unseeded-provenance`` finding: one unseeded draw silently
  corrupts resumed campaign shards.
* **dtype narrowing** — a literal f32→bf16/f16 narrowing (``astype``,
  ``jnp.bfloat16(...)`` constructors, ``dtype=jnp.float16`` kwargs)
  that crosses a module boundary into or out of the parity-critical
  dirs (``core/``, ``kernels/``, ``mitigate/``, ``distributed/``) is a
  ``cross-module-narrowing`` finding — the file-local kernel audit
  cannot see a value narrowed in one module and consumed in another,
  and ref↔batched verdict parity is asserted against an f32 oracle.
  Dynamic dtypes (``astype(dtype)`` with a parameter) are never
  flagged: dtype *policy* lives in ``models/``/``launch/`` and is not
  this rule's business.
* **reduction order** — order-sensitive float reductions on
  campaign-visible paths: ``sum()`` over ``dict.values()`` or a set
  (``unordered-sum`` — wrap in ``sorted()`` or use the order-free exact
  ``math.fsum``), and ``+=``-style accumulation onto a float inside a
  ``for`` loop over ``.values()``/``.items()``/a set
  (``unsorted-accumulation``).  This is the exact bug class that breaks
  serial == thread == process bit-identity and will break shard-resume
  merges.  Integer accumulators are exact/commutative and not flagged.

Any line can carry ``# lint: allow-<rule>`` to record a reviewed
exception; findings accepted wholesale live in the committed
``analysis/baseline.json`` (see ``analysis/README.md`` for when to
baseline vs fix vs allowlist).
"""

from __future__ import annotations

import ast
import re
from pathlib import Path

from .callgraph import (CallGraph, FunctionInfo, ModuleInfo,
                        argument_for)
from .report import Finding, attach_symbols

#: Package directories the pass does NOT scan: the analyzer itself (its
#: sources embed planted violations) — everything else in src/repro/ is
#: a campaign-visible path.
EXCLUDE_DIRS = ("analysis",)

#: Caller directories where a cross-module literal narrowing breaks the
#: f32 oracle parity contract.
PARITY_DIRS = ("core", "kernels", "mitigate", "distributed")

#: Fully-dotted RNG constructors whose argument must carry seed
#: provenance.
RNG_CTORS = {
    "numpy.random.default_rng",
    "numpy.random.SeedSequence",
    "jax.random.PRNGKey",
    "jax.random.key",
}

_SEEDISH = re.compile(r"seed|key|rng", re.IGNORECASE)
_NARROW_DTYPES = {"bfloat16", "float16"}
_ALLOW_RE = re.compile(r"#\s*lint:\s*allow-([a-z-]+)")

_MAX_TRACE_DEPTH = 6


# ---------------------------------------------------------------------------
# module discovery
# ---------------------------------------------------------------------------

def _package_root(root) -> Path:
    if root is None:
        return Path(__file__).resolve().parents[1]
    root = Path(root)
    for sub in ("src/repro", "repro"):
        if (root / sub).is_dir():
            return root / sub
    return root


def _rel(path: Path) -> str:
    s = str(path)
    i = s.find("src/repro/")
    return s[i:] if i >= 0 else s


def modules_from_disk(root=None) -> dict[str, tuple[str, str]]:
    """Dotted module name → (source, display path) for every scanned
    module under the package root."""
    pkg = _package_root(root)
    out: dict[str, tuple[str, str]] = {}
    for f in sorted(pkg.rglob("*.py")):
        rel = f.relative_to(pkg)
        if rel.parts and rel.parts[0] in EXCLUDE_DIRS:
            continue
        if "__pycache__" in rel.parts:
            continue
        dotted = "repro." + ".".join(rel.with_suffix("").parts)
        if dotted.endswith(".__init__"):
            dotted = dotted[:-len(".__init__")]
        out[dotted] = (f.read_text(), _rel(f))
    return out


def _scope_dir(path: str) -> str:
    """First package directory of a display path
    (``src/repro/core/x.py`` → ``core``; top-level modules → ``""``)."""
    parts = Path(path).parts
    for i, p in enumerate(parts):
        if p == "repro" and i + 2 < len(parts):
            return parts[i + 1]
    return ""


def _allowed_lines(source: str) -> dict[int, set[str]]:
    allowed: dict[int, set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        for m in _ALLOW_RE.finditer(line):
            allowed.setdefault(i, set()).add(m.group(1))
    return allowed


# ---------------------------------------------------------------------------
# rule family 1: seed provenance
# ---------------------------------------------------------------------------

SEEDED, LITERAL, UNKNOWN = "seeded", "literal", "unknown"


def _seedish(name: str) -> bool:
    return bool(_SEEDISH.search(name))


class _SeedTaint:
    """Classifies seed-argument expressions as SEEDED / LITERAL /
    UNKNOWN, tracing function parameters interprocedurally through the
    call graph (bounded depth, cycle-safe)."""

    def __init__(self, graph: CallGraph):
        self.graph = graph
        self._param_cache: dict[tuple[str, str], str] = {}

    def classify(self, expr: ast.expr, func: FunctionInfo | None,
                 depth: int = 0) -> str:
        if depth > _MAX_TRACE_DEPTH:
            return UNKNOWN
        if isinstance(expr, ast.Constant):
            return LITERAL
        if isinstance(expr, ast.Name):
            return self._classify_name(expr.id, func, depth)
        if isinstance(expr, ast.Attribute):
            parts = []
            node = expr
            while isinstance(node, ast.Attribute):
                parts.append(node.attr)
                node = node.value
            if isinstance(node, ast.Name):
                parts.append(node.id)
            if any(_seedish(p) for p in parts):
                return SEEDED
            return UNKNOWN
        if isinstance(expr, (ast.List, ast.Tuple, ast.Set)):
            return self._combine(
                [self.classify(e, func, depth) for e in expr.elts])
        if isinstance(expr, ast.BinOp):
            return self._combine([self.classify(expr.left, func, depth),
                                  self.classify(expr.right, func,
                                                depth)])
        if isinstance(expr, ast.UnaryOp):
            return self.classify(expr.operand, func, depth)
        if isinstance(expr, ast.Subscript):
            return self.classify(expr.value, func, depth)
        if isinstance(expr, ast.Call):
            name = expr.func.attr if isinstance(expr.func,
                                                ast.Attribute) else (
                expr.func.id if isinstance(expr.func, ast.Name) else "")
            if _seedish(name):
                return SEEDED
            args = list(expr.args) + [kw.value for kw in expr.keywords]
            if not args:
                return UNKNOWN
            return self._combine(
                [self.classify(a, func, depth) for a in args])
        if isinstance(expr, (ast.GeneratorExp, ast.ListComp)):
            return self.classify(expr.elt, func, depth)
        if isinstance(expr, ast.IfExp):
            return self._combine([self.classify(expr.body, func, depth),
                                  self.classify(expr.orelse, func,
                                                depth)])
        return UNKNOWN

    @staticmethod
    def _combine(kinds: list[str]) -> str:
        """Entropy keying: one seeded component seeds the whole
        composite (the rest are salts); all-literal stays literal;
        anything else is untraceable."""
        if SEEDED in kinds:
            return SEEDED
        if kinds and all(k == LITERAL for k in kinds):
            return LITERAL
        return UNKNOWN

    def _classify_name(self, name: str, func: FunctionInfo | None,
                       depth: int) -> str:
        if _seedish(name):
            return SEEDED
        if func is not None:
            if name in func.params:
                return self._classify_param(func, name, depth)
            local = self._local_assignment(func, name)
            if local is not None:
                return self.classify(local, func, depth + 1)
        return UNKNOWN

    @staticmethod
    def _local_assignment(func: FunctionInfo,
                          name: str) -> ast.expr | None:
        found = None
        for node in ast.walk(func.node):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and node.targets[0].id == name:
                found = node.value
        return found

    def _classify_param(self, func: FunctionInfo, param: str,
                        depth: int) -> str:
        key = (func.qualname, param)
        if key in self._param_cache:
            return self._param_cache[key]
        self._param_cache[key] = UNKNOWN    # cycle guard
        default = self._param_default(func, param)
        sites = self.graph.sites_for(func.qualname)
        kinds: list[str] = []
        for site in sites:
            arg = argument_for(site.call, func, param)
            if arg is None:
                if default is not None:
                    kinds.append(self.classify(default, site.caller,
                                               depth + 1))
                else:
                    kinds.append(UNKNOWN)
            else:
                kinds.append(self.classify(arg, site.caller, depth + 1))
        if not kinds:
            # no visible in-package call site: an exported entry point.
            # The parameter's own name is the only contract we can hold
            # it to, and non-seedish names were already screened above.
            result = UNKNOWN
        elif all(k == SEEDED for k in kinds):
            result = SEEDED
        elif LITERAL in kinds:
            result = LITERAL
        else:
            result = UNKNOWN
        self._param_cache[key] = result
        return result

    @staticmethod
    def _param_default(func: FunctionInfo,
                       param: str) -> ast.expr | None:
        args = func.node.args
        names = [a.arg for a in args.posonlyargs + args.args]
        defaults = list(args.defaults)
        if defaults:
            for name, d in zip(names[-len(defaults):], defaults):
                if name == param:
                    return d
        for a, d in zip(args.kwonlyargs, args.kw_defaults):
            if a.arg == param and d is not None:
                return d
        return None


def _rule_seed_provenance(graph: CallGraph) -> list[Finding]:
    taint = _SeedTaint(graph)
    findings: list[Finding] = []
    for mod in graph.modules.values():
        allowed = _allowed_lines(mod.source)
        for func, call in _calls_with_context(graph, mod):
            target = graph.full_target(mod, call)
            if target not in RNG_CTORS:
                continue
            args = list(call.args) + [kw.value for kw in call.keywords]
            if not args:
                continue    # zero-arg default_rng() is the lints' rule
            kind = taint._combine(
                [taint.classify(a, func) for a in args])
            short = target.rsplit(".", 1)[-1]
            if kind == LITERAL \
                    and "literal-seed" not in allowed.get(call.lineno,
                                                          ()):
                findings.append(Finding(
                    "dataflow", "literal-seed", mod.path, call.lineno,
                    f"{short}() seeded with a bare literal — literals "
                    f"of convenience belong in examples/; library code "
                    f"derives from a scenario seed, config field or "
                    f"CLI --seed"))
            elif kind == UNKNOWN \
                    and "unseeded-provenance" not in allowed.get(
                        call.lineno, ()):
                findings.append(Finding(
                    "dataflow", "unseeded-provenance", mod.path,
                    call.lineno,
                    f"{short}() argument is not statically traceable "
                    f"to a scenario seed, config field or CLI --seed "
                    f"(checked every in-package call site) — one "
                    f"unseeded draw breaks campaign bit-identity and "
                    f"shard resume"))
    return findings


def _calls_with_context(graph: CallGraph, mod: ModuleInfo):
    """(enclosing FunctionInfo | None, ast.Call) pairs, mirroring the
    call-site attribution the graph uses."""
    out: list[tuple[FunctionInfo | None, ast.Call]] = []

    def handle(stmts, caller, cls):
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{mod.name}." + \
                    (f"{cls}.{stmt.name}" if cls else stmt.name)
                handle(stmt.body, graph.functions.get(q, caller), cls)
            elif isinstance(stmt, ast.ClassDef):
                handle(stmt.body, caller, stmt.name)
            else:
                for node in ast.walk(stmt):
                    if isinstance(node, ast.Call):
                        out.append((caller, node))

    handle(mod.tree.body, None, None)
    return out


# ---------------------------------------------------------------------------
# rule family 2: cross-module dtype narrowing
# ---------------------------------------------------------------------------

def _narrows(node: ast.AST) -> int | None:
    """Line of a literal f32→bf16/f16 narrowing anywhere inside
    ``node`` (``x.astype(jnp.bfloat16)`` / ``astype("float16")``,
    ``jnp.bfloat16(x)`` constructors, ``dtype=jnp.float16`` kwargs)."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            f = sub.func
            if isinstance(f, ast.Attribute) and f.attr == "astype" \
                    and sub.args and _is_narrow_dtype(sub.args[0]):
                return sub.lineno
            if isinstance(f, ast.Attribute) \
                    and f.attr in _NARROW_DTYPES:
                return sub.lineno
            for kw in sub.keywords:
                if kw.arg == "dtype" and _is_narrow_dtype(kw.value):
                    return sub.lineno
    return None


def _is_narrow_dtype(node: ast.expr) -> bool:
    if isinstance(node, ast.Attribute) and node.attr in _NARROW_DTYPES:
        return True
    return isinstance(node, ast.Constant) \
        and node.value in _NARROW_DTYPES


def _narrow_returning(func: FunctionInfo) -> bool:
    """Does this function return a literally-narrowed value (directly
    or via a single-assignment local)?"""
    narrowed_names: set[str] = set()
    for node in ast.walk(func.node):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and _narrows(node.value) is not None:
            narrowed_names.add(node.targets[0].id)
    for node in ast.walk(func.node):
        if isinstance(node, ast.Return) and node.value is not None:
            if _narrows(node.value) is not None:
                return True
            if isinstance(node.value, ast.Name) \
                    and node.value.id in narrowed_names:
                return True
    return False


def _rule_cross_module_narrowing(graph: CallGraph) -> list[Finding]:
    findings: list[Finding] = []
    narrow_fns = {q for q, fi in graph.functions.items()
                  if _narrow_returning(fi)}
    for mod in graph.modules.values():
        if _scope_dir(mod.path) not in PARITY_DIRS:
            continue
        allowed = _allowed_lines(mod.source)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = graph.resolve_call(mod, node)
            if callee is None:
                continue
            callee_fi = graph.functions[callee]
            if callee_fi.module == mod.name:
                continue    # file-local narrowing is the kernel audit
            if "cross-module-narrowing" in allowed.get(node.lineno, ()):
                continue
            if callee in narrow_fns:
                findings.append(Finding(
                    "dataflow", "cross-module-narrowing", mod.path,
                    node.lineno,
                    f"call to {callee}() returns a value literally "
                    f"narrowed to bf16/f16 in another module — the "
                    f"f32 oracle parity contract breaks across this "
                    f"boundary"))
            arg_line = next(
                (ln for ln in
                 [_narrows(a) for a in list(node.args)
                  + [kw.value for kw in node.keywords]]
                 if ln is not None), None)
            if arg_line is not None:
                findings.append(Finding(
                    "dataflow", "cross-module-narrowing", mod.path,
                    arg_line,
                    f"argument to {callee}() is literally narrowed to "
                    f"bf16/f16 before crossing the module boundary — "
                    f"a parity hazard the file-local kernel audit "
                    f"cannot see"))
    return findings


# ---------------------------------------------------------------------------
# rule family 3: reduction order
# ---------------------------------------------------------------------------

def _is_values_call(node: ast.expr) -> bool:
    return isinstance(node, ast.Call) \
        and isinstance(node.func, ast.Attribute) \
        and node.func.attr in ("values", "items") and not node.args


def _is_set_literal(node: ast.expr) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return isinstance(node, ast.Call) \
        and isinstance(node.func, ast.Name) \
        and node.func.id in ("set", "frozenset")


def _is_sorted_wrapped(node: ast.expr) -> bool:
    return isinstance(node, ast.Call) \
        and isinstance(node.func, ast.Name) \
        and node.func.id in ("sorted", "min", "max", "len")


def _unordered_iter(node: ast.expr) -> str | None:
    """Human tag if ``node`` iterates in container-dependent order."""
    if _is_sorted_wrapped(node):
        return None
    if _is_values_call(node):
        return f"dict .{node.func.attr}()"
    if _is_set_literal(node):
        return "a set"
    return None


def _sum_source(call: ast.Call) -> ast.expr | None:
    """The iterable a builtin ``sum()`` call reduces over, unwrapping
    one generator/comprehension level."""
    if not (isinstance(call.func, ast.Name)
            and call.func.id == "sum" and call.args):
        return None
    arg = call.args[0]
    if isinstance(arg, (ast.GeneratorExp, ast.ListComp)):
        return arg.generators[0].iter if arg.generators else None
    return arg


def _float_accumulators(body: list[ast.stmt]) -> set[str]:
    """Names assigned a float literal in this statement list — the
    accumulator shapes whose in-loop ``+=`` is order-sensitive."""
    out: set[str] = set()
    for stmt in body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name) \
                and isinstance(stmt.value, ast.Constant) \
                and isinstance(stmt.value.value, float):
            out.add(stmt.targets[0].id)
    return out


def _rule_reduction_order(graph: CallGraph) -> list[Finding]:
    findings: list[Finding] = []
    for mod in graph.modules.values():
        allowed = _allowed_lines(mod.source)

        def scope_bodies():
            yield mod.tree.body
            for n in ast.walk(mod.tree):
                if isinstance(n, (ast.FunctionDef,
                                  ast.AsyncFunctionDef)):
                    yield n.body

        for body in scope_bodies():
            accs = _float_accumulators(body)
            for stmt in body:
                for node in ast.walk(stmt):
                    if isinstance(node, ast.Call):
                        src = _sum_source(node)
                        tag = _unordered_iter(src) \
                            if src is not None else None
                        if tag and "unordered-sum" not in \
                                allowed.get(node.lineno, ()):
                            findings.append(Finding(
                                "dataflow", "unordered-sum", mod.path,
                                node.lineno,
                                f"sum() over {tag} reduces floats in "
                                f"container order — shard merges "
                                f"reorder it; wrap in sorted() or use "
                                f"math.fsum"))
                    if isinstance(node, ast.For):
                        tag = _unordered_iter(node.iter)
                        if not tag:
                            continue
                        for sub in ast.walk(node):
                            if (isinstance(sub, ast.AugAssign)
                                    and isinstance(sub.op,
                                                   (ast.Add, ast.Sub,
                                                    ast.Mult))
                                    and isinstance(sub.target,
                                                   ast.Name)
                                    and sub.target.id in accs
                                    and "unsorted-accumulation" not in
                                    allowed.get(sub.lineno, ())):
                                findings.append(Finding(
                                    "dataflow", "unsorted-accumulation",
                                    mod.path, sub.lineno,
                                    f"float accumulation over {tag} "
                                    f"depends on iteration order — "
                                    f"sort the iterable or reduce with "
                                    f"math.fsum so shard-resumed "
                                    f"merges stay bit-identical"))
    return _dedupe(findings)


def _dedupe(findings: list[Finding]) -> list[Finding]:
    seen: set[tuple] = set()
    out = []
    for f in findings:
        k = (f.rule, f.path, f.line, f.message)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def analyze_modules(modules: dict[str, tuple[str, str]]) \
        -> list[Finding]:
    """Run all dataflow rules over a module set (the unit the
    self-test drives with synthetic multi-module packages)."""
    graph = CallGraph.build(modules)
    findings = (_rule_seed_provenance(graph)
                + _rule_cross_module_narrowing(graph)
                + _rule_reduction_order(graph))
    by_path: dict[str, ast.Module] = {
        m.path: m.tree for m in graph.modules.values()}
    return _dedupe(attach_symbols(findings, by_path))


def check(root=None) -> list[Finding]:
    """Dataflow-check the repo (everything under ``src/repro/`` except
    the analyzer itself)."""
    return analyze_modules(modules_from_disk(root))


# ---------------------------------------------------------------------------
# self-test
# ---------------------------------------------------------------------------

#: Synthetic multi-module package planting one violation per rule; the
#: self-test asserts each is caught and the benign shapes stay clean.
_SYNTHETIC_BAD = {
    "syn.core.rngsrc": (
        "import numpy as np\n"
        "import jax\n"
        "def make_stream(x):\n"
        "    return np.random.default_rng(x)\n"        # traced to caller
        "def shape_key():\n"
        "    return jax.random.PRNGKey(0)\n",          # literal-seed
        "src/repro/core/rngsrc.py"),
    "syn.core.rnguse": (
        "from .rngsrc import make_stream\n"
        "def draw(values):\n"
        "    n = len(values)\n"
        "    g = make_stream(n)\n"                     # unseeded-provenance
        "    return g.normal()\n",
        "src/repro/core/rnguse.py"),
    "syn.core.packer": (
        "import jax.numpy as jnp\n"
        "def pack(x):\n"
        "    y = x.astype(jnp.bfloat16)\n"
        "    return y\n",
        "src/repro/core/packer.py"),
    "syn.core.consumer": (
        "from .packer import pack\n"
        "def fold(x):\n"
        "    return pack(x) + 1\n",                    # cross-module-narrowing
        "src/repro/core/consumer.py"),
    "syn.core.merge": (
        "def total(parts):\n"
        "    return sum(parts.values())\n"             # unordered-sum
        "def accumulate(parts):\n"
        "    acc = 0.0\n"
        "    for v in parts.values():\n"
        "        acc += v\n"                           # unsorted-accumulation
        "    return acc\n",
        "src/repro/core/merge.py"),
}

#: Every shape the rules must NOT flag.
_SYNTHETIC_CLEAN = {
    "syn.core.fine": (
        "import numpy as np\n"
        "import jax\n"
        "import jax.numpy as jnp\n"
        "import math\n"
        "from .finelib import seeded_stream\n"
        "def scenario_rng(grid, s):\n"
        "    return np.random.default_rng(\n"
        "        [grid.campaign_seed, s.mesh_w, s.rep])\n"
        "def cli_rng(args):\n"
        "    return np.random.default_rng(args.seed)\n"
        "def model_key(seed):\n"
        "    return jax.random.PRNGKey(seed)\n"
        "def threaded(cfg):\n"
        "    return seeded_stream(cfg.seed)\n"
        "def widen(x):\n"
        "    return x.astype(jnp.float32)\n"
        "def dynamic(x, dtype):\n"
        "    return x.astype(dtype)\n"
        "def total(parts):\n"
        "    return sum(sorted(parts.values()))\n"
        "def exact(parts):\n"
        "    return math.fsum(parts.values())\n"
        "def count(parts):\n"
        "    n = 0\n"
        "    for v in parts.values():\n"
        "        n += 1\n"
        "    return n\n",
        "src/repro/core/fine.py"),
    "syn.core.finelib": (
        "import numpy as np\n"
        "def seeded_stream(x):\n"
        "    return np.random.default_rng(x)\n",       # all callers seeded
        "src/repro/core/finelib.py"),
}


def self_test() -> None:
    """Plant one synthetic violation per rule and assert each is
    caught, the benign shapes stay clean, and every real-tree finding
    is carried by the shipped baseline (no un-reviewed drift)."""
    bad = analyze_modules(dict(_SYNTHETIC_BAD))
    got = {f.rule for f in bad}
    expect = {"literal-seed", "unseeded-provenance",
              "cross-module-narrowing", "unordered-sum",
              "unsorted-accumulation"}
    missing = expect - got
    assert not missing, \
        f"dataflow rules not triggered by synthetic: {sorted(missing)}"
    prov = [f for f in bad if f.rule == "unseeded-provenance"]
    assert any("rngsrc" in f.path for f in prov), \
        "interprocedural trace must land the finding at the rng " \
        "constructor, not (only) the call site"
    clean = analyze_modules(dict(_SYNTHETIC_CLEAN))
    assert clean == [], \
        "false positives on benign shapes:\n" + "\n".join(
            f.render() for f in clean)

    from .report import load_baseline
    baseline = load_baseline()
    real = check()
    new = [f for f in real if f.fingerprint not in baseline]
    assert new == [], \
        "real-tree dataflow findings missing from analysis/" \
        "baseline.json (fix, allowlist, or --update-baseline):\n" \
        + "\n".join(f"{f.render()}  fp={f.fingerprint}" for f in new)
