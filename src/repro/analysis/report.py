"""Shared finding/report types for the static-analysis passes.

Every pass (:mod:`.memory_model`, :mod:`.kernel_audit`, :mod:`.lints`)
reduces to a list of :class:`Finding` records; the CLI
(``python -m repro.analysis``) renders them for humans (one
``path:line: [pass/rule] message`` per finding) or as JSON, and exits
nonzero iff any finding survived.  Keeping the record type dumb and
shared means a new pass only has to produce findings — reporting, JSON
and the exit-code contract come for free.
"""

from __future__ import annotations

import dataclasses
import json


@dataclasses.dataclass(frozen=True)
class Finding:
    """One static-analysis violation.

    ``check`` names the pass (``memory`` | ``kernels`` | ``lints``),
    ``rule`` the specific invariant within it (stable kebab-case
    identifiers — CI logs and allowlists key on them), ``path``/``line``
    the location (``line == 0`` for whole-config findings with no source
    anchor, e.g. a memory-budget overrun).
    """
    check: str
    rule: str
    path: str
    line: int
    message: str

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.check}/{self.rule}] {self.message}"


def render_findings(findings: list[Finding]) -> str:
    """Human-readable report: one line per finding plus a tally."""
    lines = [f.render() for f in findings]
    n = len(findings)
    lines.append(f"{n} finding{'s' if n != 1 else ''}")
    return "\n".join(lines)


def findings_to_json(findings: list[Finding], *, extra=None) -> str:
    """Machine-readable report (the CLI's ``--json`` output)."""
    doc = {
        "findings": [dataclasses.asdict(f) for f in findings],
        "count": len(findings),
        "ok": not findings,
    }
    if extra:
        doc.update(extra)
    return json.dumps(doc, indent=2, sort_keys=True)
