"""Shared finding/report types for the static-analysis passes.

Every pass (:mod:`.memory_model`, :mod:`.kernel_audit`, :mod:`.lints`,
:mod:`.dataflow`) reduces to a list of :class:`Finding` records; the
CLI (``python -m repro.analysis``) renders them for humans (one
``path:line: [pass/rule] message`` per finding) or as JSON, and exits
nonzero iff any finding survived the baseline.  Keeping the record
type dumb and shared means a new pass only has to produce findings —
reporting, JSON, fingerprints and the exit-code contract come for
free.

JSON schema (``--json``)::

    {
      "findings": [
        {
          "check":       "dataflow",          # pass name
          "rule":        "unordered-sum",     # stable kebab-case rule
          "path":        "src/repro/...py",   # repo-relative path
          "line":        515,                 # 0 = no source anchor
          "symbol":      "Metrics.summary",   # enclosing def/class
                                              # qualname, "" if none
          "message":     "...",
          "fingerprint": "9f3a1c...",         # 16-hex stable id
          "baselined":   false                # carried by --baseline?
        }, ...
      ],
      "count":  3,        # total findings
      "new":    1,        # findings NOT in the baseline (drive exit 1)
      "ok":     false,    # new == 0
      "timings": {"memory": 0.01, ...}        # per-pass seconds
    }

Fingerprints hash ``check | rule | path | symbol`` (falling back to the
message when no enclosing symbol exists, e.g. whole-config memory
findings) — deliberately **not** the line number, so a finding survives
unrelated edits that shift lines, and a baseline entry keeps matching
until the offending symbol itself is touched.

Baseline files (``--baseline`` / ``--update-baseline``) are JSON::

    {"version": 1, "fingerprints": {"<fp>": "<path>: [pass/rule] ..."}}

The value is human context only; matching keys on fingerprints alone.
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
from pathlib import Path

BASELINE_VERSION = 1

#: The committed repo baseline, resolved relative to this package so it
#: works regardless of the CLI's working directory.
SHIPPED_BASELINE = Path(__file__).resolve().parent / "baseline.json"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One static-analysis violation.

    ``check`` names the pass (``memory`` | ``kernels`` | ``lints`` |
    ``dataflow``), ``rule`` the specific invariant within it (stable
    kebab-case identifiers — CI logs and allowlists key on them),
    ``path``/``line`` the location (``line == 0`` for whole-config
    findings with no source anchor, e.g. a memory-budget overrun),
    ``symbol`` the innermost enclosing function/class qualname (used by
    :attr:`fingerprint` so findings survive line shifts).
    """
    check: str
    rule: str
    path: str
    line: int
    message: str
    symbol: str = ""

    @property
    def fingerprint(self) -> str:
        """Stable 16-hex id: hash of pass + rule + path + enclosing
        symbol (message as fallback anchor) — line-independent."""
        anchor = self.symbol or self.message
        raw = f"{self.check}|{self.rule}|{self.path}|{anchor}"
        return hashlib.sha256(raw.encode()).hexdigest()[:16]

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.check}/{self.rule}] {self.message}"


def render_findings(findings: list[Finding]) -> str:
    """Human-readable report: one line per finding plus a tally."""
    lines = [f.render() for f in findings]
    n = len(findings)
    lines.append(f"{n} finding{'s' if n != 1 else ''}")
    return "\n".join(lines)


def findings_to_json(findings: list[Finding], *, extra=None,
                     baseline: dict | None = None) -> str:
    """Machine-readable report (the CLI's ``--json`` output); schema in
    the module docstring."""
    baseline = baseline or {}
    rows = []
    for f in findings:
        row = dataclasses.asdict(f)
        row["fingerprint"] = f.fingerprint
        row["baselined"] = f.fingerprint in baseline
        rows.append(row)
    new = sum(1 for r in rows if not r["baselined"])
    doc = {
        "findings": rows,
        "count": len(findings),
        "new": new,
        "ok": new == 0,
    }
    if extra:
        doc.update(extra)
    return json.dumps(doc, indent=2, sort_keys=True)


# ---------------------------------------------------------------------------
# symbol attribution (drives fingerprint stability)
# ---------------------------------------------------------------------------

def symbol_table(tree: ast.Module) -> list[tuple[int, int, str]]:
    """(start, end, qualname) spans for every def/class, innermost
    last so :func:`symbol_at` can take the tightest match."""
    spans: list[tuple[int, int, str]] = []

    def visit(stmts, prefix):
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                q = f"{prefix}.{stmt.name}" if prefix else stmt.name
                spans.append((stmt.lineno,
                              stmt.end_lineno or stmt.lineno, q))
                visit(stmt.body, q)

    visit(tree.body, "")
    return spans


def symbol_at(spans: list[tuple[int, int, str]], line: int) -> str:
    """Innermost enclosing def/class qualname for a line ('' at module
    level)."""
    best = ""
    best_width = None
    for start, end, name in spans:
        if start <= line <= end:
            width = end - start
            if best_width is None or width <= best_width:
                best, best_width = name, width
    return best


def attach_symbols(findings: list[Finding],
                   trees: dict[str, ast.Module]) -> list[Finding]:
    """Fill in ``symbol`` for findings whose path has a parsed tree
    (no-op for findings that already carry one or have no anchor)."""
    tables = {p: symbol_table(t) for p, t in trees.items()}
    out = []
    for f in findings:
        if not f.symbol and f.line and f.path in tables:
            f = dataclasses.replace(
                f, symbol=symbol_at(tables[f.path], f.line))
        out.append(f)
    return out


# ---------------------------------------------------------------------------
# baseline workflow
# ---------------------------------------------------------------------------

def load_baseline(path=None) -> dict[str, str]:
    """fingerprint → context from a baseline file.  ``path=None`` loads
    the committed repo baseline; a missing file is an empty baseline."""
    p = Path(path) if path is not None else SHIPPED_BASELINE
    if not p.is_file():
        return {}
    doc = json.loads(p.read_text())
    if doc.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"unsupported baseline version {doc.get('version')!r} "
            f"in {p} (expected {BASELINE_VERSION})")
    return dict(doc.get("fingerprints", {}))


def write_baseline(path, findings: list[Finding]) -> None:
    """Accept the current finding set wholesale (``--update-baseline``)."""
    doc = {
        "version": BASELINE_VERSION,
        "fingerprints": {
            f.fingerprint: f"{f.path}: [{f.check}/{f.rule}] "
                           f"{f.symbol or f.message}"
            for f in sorted(findings,
                            key=lambda f: (f.path, f.line, f.rule))
        },
    }
    Path(path).write_text(json.dumps(doc, indent=2, sort_keys=True)
                          + "\n")


def new_findings(findings: list[Finding],
                 baseline: dict[str, str]) -> list[Finding]:
    """Findings whose fingerprint the baseline does not carry — the
    only ones that fail CI."""
    return [f for f in findings if f.fingerprint not in baseline]
