"""Static verification of the repo's deployment and determinism claims.

Four execution-free passes, one CLI (``python -m repro.analysis
--check all [--json] [--baseline FILE]``; exit 0 iff no finding
outside the baseline):

* :mod:`.memory_model` — closed-form per-chip footprint of the recorder
  (Stage-1 tables, Stage-2 slots, drain buffer, packed/Pallas layouts)
  checked against ``budget_kb``; also the construction-time guards
  ``validate_config`` / ``validate_params`` wired into ``Sloth`` and
  ``StreamingRecorder``.
* :mod:`.kernel_audit` — AST audit of every ``kernels/*/kernel.py``:
  AUDIT contracts, BlockSpec index-map bounds vs the grid, grid-carried
  write races on aliased refs, dtype-narrowing hazards.
* :mod:`.lints` — determinism lints over ``core/``, ``kernels/``,
  ``mitigate/``, ``distributed/``, ``launch/``, ``serving/`` and
  ``data/``: unseeded RNG, wall-clock reads, unregistered detector
  classes, order-sensitive set iteration.
* :mod:`.dataflow` — interprocedural analysis over the call graph
  (:mod:`.callgraph`): seed-provenance taint for every RNG
  construction, cross-module f32→bf16/f16 narrowing, and
  order-sensitive float reductions (``sum`` over dict/set values,
  unsorted loop accumulation).

Each pass exposes ``check() -> list[Finding]`` and a ``self_test()``
that plants synthetic violations and asserts they are caught (run via
``python -m repro.analysis --self-test``; also covered by
``tests/test_analysis.py``).  Accepted pre-existing findings live in
the committed ``analysis/baseline.json`` keyed by line-independent
fingerprints (see :mod:`.report`); ``--baseline`` makes only
*new*-fingerprint findings fail, ``--update-baseline`` re-accepts the
current set.
"""

from .memory_model import (DEFAULT_BUDGET_KB,  # noqa: F401
                           MemoryBudgetError, memory_report,
                           validate_config, validate_params)
from .report import (Finding, findings_to_json,  # noqa: F401
                     load_baseline, new_findings, render_findings,
                     write_baseline)

__all__ = [
    "DEFAULT_BUDGET_KB", "MemoryBudgetError", "memory_report",
    "validate_config", "validate_params", "Finding",
    "findings_to_json", "render_findings", "load_baseline",
    "write_baseline", "new_findings", "run_checks", "CHECKS",
]

#: Check name → module path; ``--check all`` runs them in this order.
CHECKS = ("memory", "kernels", "lints", "dataflow")


def _pass_module(name: str):
    if name == "memory":
        from . import memory_model
        return memory_model
    if name == "kernels":
        from . import kernel_audit
        return kernel_audit
    if name == "lints":
        from . import lints
        return lints
    if name == "dataflow":
        from . import dataflow
        return dataflow
    raise ValueError(f"unknown check {name!r}; options: "
                     f"{CHECKS + ('all',)}")


def run_checks(which: str = "all", root=None,
               budget_kb: float | None = None,
               timings: dict | None = None) -> list[Finding]:
    """Run one pass (or all) and return the combined findings.  Pass a
    dict as ``timings`` to receive per-pass wall seconds (the CLI's
    ``--json`` cost tracking)."""
    import time
    names = CHECKS if which == "all" else (which,)
    findings: list[Finding] = []
    for name in names:
        mod = _pass_module(name)
        t0 = time.perf_counter()  # lint: allow-wallclock
        if name == "memory":
            findings.extend(mod.check(root, budget_kb=budget_kb))
        else:
            findings.extend(mod.check(root))
        if timings is not None:
            timings[name] = round(time.perf_counter() - t0, 4)  # lint: allow-wallclock
    return findings


def run_self_tests(which: str = "all") -> None:
    """Run each pass's planted-violation self-test (raises
    AssertionError on the first failure)."""
    names = CHECKS if which == "all" else (which,)
    for name in names:
        _pass_module(name).self_test()
