"""AST lints encoding the ROADMAP's standing determinism invariants.

The campaign's bit-identity contract (serial == thread == process, and
seed → scenario derivation) survives only if nothing inside the core
pipeline consults ambient nondeterminism.  These lints make the
contract machine-checked over ``core/``, ``kernels/``, ``mitigate/``,
``distributed/``, ``launch/``, ``serving/`` and ``data/``:

* ``unseeded-rng`` — module-level ``np.random.*`` calls (the legacy
  global generator), zero-argument ``np.random.default_rng()``, and
  stdlib ``random.*`` calls.  All randomness must flow from an
  explicitly seeded ``Generator``.
* ``wallclock`` — ``time.time/perf_counter/monotonic/process_time``
  and ``datetime.now/utcnow``: wall-clock reads inside the pipeline
  make outputs run-dependent.  Telemetry that *reports* wall time (and
  never feeds results) is allowlisted with a ``# lint: allow-wallclock``
  marker on the offending line — ``campaign._wall_clock`` is the one
  blessed reader.
* ``unregistered-detector`` — a public detector-shaped class (a ``name``
  string attribute plus both ``prepare`` and ``analyse`` methods) or
  mitigation-policy-shaped class (``name`` plus both ``plan`` and
  ``apply``) that never reaches its registry
  (``register_detector`` / ``_register_builtin`` for detectors,
  ``register_policy`` / ``_register_builtin_policy`` for policies)
  or topology-shaped class (concrete ``route`` + ``hops`` methods —
  the fabric surface ``core.routing`` registers behind
  ``register_topology`` / ``_register_builtin_topology``, alongside
  ``links_of_router``/``n_cores`` from the shared base) that never
  reaches its registry grows a side API the campaign can't see; the
  resolver follows both direct registration calls and the
  ``ALL_BASELINES``-style pattern (a module list of classes swept by a
  ``for`` loop that registers each).  Abstract fabric shells (``route``
  and ``hops`` both just ``raise NotImplementedError``) and delegating
  wrappers (``route`` without ``hops``, like ``DetourTopology``) are
  not registrable fabrics and stay exempt.
* ``set-iteration`` — materialising a ``set`` in an order-sensitive
  position (``list()``/``tuple()``/``enumerate()``, a ``for`` loop, or
  a list/generator comprehension).  Python set order varies with hash
  seeding across processes, so any ranking or aggregation fed this way
  breaks process-pool bit-identity; wrap in ``sorted()`` (or reduce
  with an order-free ``min``/``max``/``sum``/``len``/``any``/``all``)
  instead.  Dict iteration is insertion-ordered and deterministic, so
  it is not flagged.

Any line can carry ``# lint: allow-<rule>`` to record a reviewed,
deliberate exception (see ROADMAP "Machine-enforced invariants");
findings accepted wholesale live in the committed
``analysis/baseline.json`` instead (``analysis/README.md`` explains
when to use which).
"""

from __future__ import annotations

import ast
import re
from pathlib import Path

from .report import Finding

#: Directories (relative to the repro package) each lint sweeps.
#: ``mitigate`` is in every scope: policies feed re-simulated campaign
#: outcomes, so they carry the same determinism contract as ``core``.
#: PR 9 widened every scope to the launch/serving/data surface —
#: telemetry streams and serving traces feed campaign-comparable
#: verdicts, so they carry the contract too.
_FULL_SCOPE = ("core", "kernels", "mitigate", "distributed", "launch",
               "serving", "data")
RNG_SCOPE = _FULL_SCOPE
WALLCLOCK_SCOPE = _FULL_SCOPE
DETECTOR_SCOPE = _FULL_SCOPE
SET_SCOPE = _FULL_SCOPE

_WALLCLOCK_TIME_FNS = {"time", "perf_counter", "monotonic",
                       "process_time"}
_WALLCLOCK_DT_FNS = {"now", "utcnow", "today"}
_LEGACY_NP_RANDOM_OK = {"Generator", "default_rng", "SeedSequence",
                        "PCG64", "Philox", "BitGenerator"}
_REGISTER_FNS = {"register_detector", "_register_builtin",
                 "register_policy", "_register_builtin_policy",
                 "register_topology", "_register_builtin_topology"}
_ORDER_FREE = {"sorted", "min", "max", "sum", "len", "any", "all",
               "set", "frozenset"}
_ORDERED_CONSUMERS = {"list", "tuple", "enumerate"}

_ALLOW_RE = re.compile(r"#\s*lint:\s*allow-([a-z-]+)")


def _package_root(root) -> Path:
    if root is None:
        return Path(__file__).resolve().parents[1]
    root = Path(root)
    for sub in ("src/repro", "repro"):
        if (root / sub).is_dir():
            return root / sub
    return root


def _rel(path: Path) -> str:
    s = str(path)
    i = s.find("src/repro/")
    return s[i:] if i >= 0 else s


def _files(pkg: Path, scopes: tuple[str, ...]) -> list[Path]:
    out: list[Path] = []
    for scope in scopes:
        d = pkg / scope
        if d.is_dir():
            out.extend(sorted(d.rglob("*.py")))
    return out


def _allowed_lines(source: str) -> dict[int, set[str]]:
    """Line → set of rules allowlisted by ``# lint: allow-<rule>``."""
    allowed: dict[int, set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        for m in _ALLOW_RE.finditer(line):
            allowed.setdefault(i, set()).add(m.group(1))
    return allowed


def _suppressed(allowed: dict[int, set[str]], line: int,
                rule: str) -> bool:
    return rule in allowed.get(line, ())


def _dotted(node: ast.expr) -> str | None:
    """``a.b.c`` attribute chains → "a.b.c" (None for anything else)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _imported_names(tree: ast.Module) -> dict[str, str]:
    """Local alias → imported module/name ("np" → "numpy")."""
    imp: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                imp[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                imp[a.asname or a.name] = f"{node.module}.{a.name}"
    return imp


# -- rule: unseeded-rng ------------------------------------------------------

def _lint_rng(tree: ast.Module, source: str, path: str) \
        -> list[Finding]:
    findings: list[Finding] = []
    allowed = _allowed_lines(source)
    imports = _imported_names(tree)
    np_aliases = {a for a, mod in imports.items() if mod == "numpy"}
    random_aliases = {a for a, mod in imports.items() if mod == "random"}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if dotted is None:
            continue
        head, _, rest = dotted.partition(".")
        hit = None
        if head in np_aliases and rest.startswith("random."):
            fn = rest.split(".", 1)[1]
            if fn == "default_rng":
                if not node.args and not node.keywords:
                    hit = (f"{dotted}() without a seed draws OS "
                           f"entropy")
            elif fn not in _LEGACY_NP_RANDOM_OK:
                hit = (f"{dotted} uses the unseeded global numpy "
                       f"generator")
        elif head in random_aliases and rest:
            if rest != "Random" and not rest.startswith("Random."):
                hit = f"{dotted} uses the unseeded stdlib generator"
        if hit and not _suppressed(allowed, node.lineno, "rng"):
            findings.append(Finding(
                "lints", "unseeded-rng", path, node.lineno,
                hit + " — derive from a seeded np.random.Generator"))
    return findings


# -- rule: wallclock ---------------------------------------------------------

def _lint_wallclock(tree: ast.Module, source: str, path: str) \
        -> list[Finding]:
    findings: list[Finding] = []
    allowed = _allowed_lines(source)
    imports = _imported_names(tree)
    time_aliases = {a for a, mod in imports.items() if mod == "time"}
    dt_aliases = {a for a, mod in imports.items()
                  if mod in ("datetime", "datetime.datetime")}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if dotted is None or "." not in dotted:
            continue
        head, _, rest = dotted.partition(".")
        fn = rest.split(".")[-1]
        hit = (head in time_aliases and fn in _WALLCLOCK_TIME_FNS) or \
            (head in dt_aliases and fn in _WALLCLOCK_DT_FNS)
        if hit and not _suppressed(allowed, node.lineno, "wallclock"):
            findings.append(Finding(
                "lints", "wallclock", path, node.lineno,
                f"{dotted}() reads the wall clock inside the pipeline "
                f"— outputs become run-dependent; telemetry-only "
                f"readers get '# lint: allow-wallclock'"))
    return findings


# -- rule: unregistered-detector ---------------------------------------------

def _detector_classes(tree: ast.Module) \
        -> list[tuple[ast.ClassDef, str]]:
    """Public classes matching a registry duck type, tagged with which:
    a string ``name`` attribute plus ``prepare`` + ``analyse``
    (``"detector"``, the shape ``core.detectors`` registers), or plus
    ``plan`` + ``apply`` (``"policy"``, the shape ``mitigate.policy``
    registers), or concrete ``route`` + ``hops`` methods
    (``"topology"``, the fabric shape ``core.routing`` registers —
    no ``name`` attribute required).  Abstract fabric shells (both
    methods just ``raise NotImplementedError``) are base classes, not
    registrable fabrics, and are skipped."""
    out = []
    for node in tree.body:
        if not isinstance(node, ast.ClassDef) or \
                node.name.startswith("_"):
            continue
        has_name = any(
            isinstance(s, ast.Assign) and len(s.targets) == 1
            and isinstance(s.targets[0], ast.Name)
            and s.targets[0].id == "name"
            and isinstance(s.value, ast.Constant)
            and isinstance(s.value.value, str)
            for s in node.body)
        defs = {s.name: s for s in node.body
                if isinstance(s, (ast.FunctionDef,
                                  ast.AsyncFunctionDef))}
        methods = set(defs)
        if has_name and {"prepare", "analyse"} <= methods:
            out.append((node, "detector"))
        elif has_name and {"plan", "apply"} <= methods:
            out.append((node, "policy"))
        elif {"route", "hops"} <= methods and not all(
                _is_abstract_stub(defs[m]) for m in ("route", "hops")):
            out.append((node, "topology"))
    return out


def _is_abstract_stub(fn: ast.FunctionDef) -> bool:
    """True when a method body is nothing but ``raise
    NotImplementedError`` (after an optional docstring)."""
    body = fn.body
    if body and isinstance(body[0], ast.Expr) \
            and isinstance(body[0].value, ast.Constant) \
            and isinstance(body[0].value.value, str):
        body = body[1:]
    if len(body) != 1 or not isinstance(body[0], ast.Raise):
        return False
    exc = body[0].exc
    if isinstance(exc, ast.Call):
        exc = exc.func
    return isinstance(exc, ast.Name) and exc.id == "NotImplementedError"


def _registered_names(tree: ast.Module) -> set[str]:
    """Names that reach a registration call: direct arguments, plus
    names inside list/tuple literals that a ``for`` loop sweeps into a
    registration call (the ``ALL_BASELINES`` pattern)."""
    module_lists: dict[str, list[str]] = {}
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name) \
                and isinstance(stmt.value, (ast.List, ast.Tuple)):
            module_lists[stmt.targets[0].id] = [
                e.id for e in stmt.value.elts
                if isinstance(e, ast.Name)]

    registered: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            fname = node.func.attr \
                if isinstance(node.func, ast.Attribute) else (
                    node.func.id if isinstance(node.func, ast.Name)
                    else None)
            if fname in _REGISTER_FNS:
                for arg in list(node.args) + \
                        [kw.value for kw in node.keywords]:
                    for sub in ast.walk(arg):
                        if isinstance(sub, ast.Name):
                            registered.add(sub.id)
        if isinstance(node, ast.For):
            body_regs = any(
                isinstance(c, ast.Call) and (
                    (isinstance(c.func, ast.Attribute)
                     and c.func.attr in _REGISTER_FNS)
                    or (isinstance(c.func, ast.Name)
                        and c.func.id in _REGISTER_FNS))
                for b in node.body for c in ast.walk(b))
            if not body_regs:
                continue
            it = node.iter
            if isinstance(it, ast.Name) and it.id in module_lists:
                registered.update(module_lists[it.id])
            elif isinstance(it, (ast.List, ast.Tuple)):
                registered.update(e.id for e in it.elts
                                  if isinstance(e, ast.Name))
    return registered


def _lint_detectors(tree: ast.Module, source: str, path: str) \
        -> list[Finding]:
    classes = _detector_classes(tree)
    if not classes:
        return []
    registered = _registered_names(tree)
    allowed = _allowed_lines(source)
    shapes = {
        "detector": ("detector-shaped (name + prepare + analyse)",
                     "register_detector / _register_builtin"),
        "policy": ("mitigation-policy-shaped (name + plan + apply)",
                   "register_policy / _register_builtin_policy"),
        "topology": ("topology-shaped (concrete route + hops)",
                     "register_topology / _register_builtin_topology"),
    }
    findings = []
    for cls, kind in classes:
        if cls.name in registered:
            continue
        if _suppressed(allowed, cls.lineno, "unregistered-detector"):
            continue
        shape, fns = shapes[kind]
        findings.append(Finding(
            "lints", "unregistered-detector", path, cls.lineno,
            f"class {cls.name} is {shape} but never reaches {fns} — "
            f"side APIs bypass the campaign"))
    return findings


# -- rule: set-iteration -----------------------------------------------------

def _lint_set_iteration(tree: ast.Module, source: str, path: str) \
        -> list[Finding]:
    findings: list[Finding] = []
    allowed = _allowed_lines(source)

    def scope_bodies():
        yield tree.body
        for n in ast.walk(tree):
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield n.body

    for body in scope_bodies():
        set_names: set[str] = set()
        for stmt in body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name) \
                    and _is_set_expr(stmt.value, set()):
                set_names.add(stmt.targets[0].id)
        if not set_names and not any(
                _is_set_expr(n, set()) for s in body
                for n in ast.walk(s)):
            continue
        for stmt in body:
            for node in ast.walk(stmt):
                line, why = _ordered_set_use(node, set_names)
                if why and not _suppressed(allowed, line,
                                           "set-iteration"):
                    findings.append(Finding(
                        "lints", "set-iteration", path, line,
                        why + " — set order varies with hash seeding "
                        "across processes; wrap in sorted() or reduce "
                        "order-free"))
    return _dedupe(findings)


def _is_set_expr(node: ast.expr, set_names: set[str]) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    return isinstance(node, ast.Name) and node.id in set_names


def _ordered_set_use(node: ast.AST, set_names: set[str]) \
        -> tuple[int, str | None]:
    """(line, message) if ``node`` consumes a set in an order-sensitive
    way; (0, None) otherwise."""
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in _ORDERED_CONSUMERS and node.args \
            and _is_set_expr(node.args[0], set_names):
        return (node.lineno,
                f"{node.func.id}() over a set materialises arbitrary "
                f"order")
    if isinstance(node, ast.For) and _is_set_expr(node.iter,
                                                  set_names):
        return (node.lineno, "for-loop iterates a set directly")
    if isinstance(node, (ast.ListComp, ast.GeneratorExp)):
        for gen in node.generators:
            if _is_set_expr(gen.iter, set_names):
                return (node.lineno,
                        "comprehension iterates a set into an ordered "
                        "result")
    return (0, None)


def _dedupe(findings: list[Finding]) -> list[Finding]:
    seen: set[tuple] = set()
    out = []
    for f in findings:
        k = (f.rule, f.path, f.line)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out


# -- driver ------------------------------------------------------------------

_RULES = (
    (_lint_rng, RNG_SCOPE),
    (_lint_wallclock, WALLCLOCK_SCOPE),
    (_lint_detectors, DETECTOR_SCOPE),
    (_lint_set_iteration, SET_SCOPE),
)


def lint_source(source: str, path: str) -> list[Finding]:
    """Run every rule on one module's source (scope-independent; the
    unit the self-test drives)."""
    tree = ast.parse(source)
    findings: list[Finding] = []
    for rule, _scope in _RULES:
        findings.extend(rule(tree, source, path))
    return findings


def check(root=None) -> list[Finding]:
    """Lint the repo: each file parsed once, every in-scope rule run on
    it, enclosing symbols attached for stable fingerprints."""
    pkg = _package_root(root)
    findings: list[Finding] = []
    trees: dict[str, ast.Module] = {}
    all_files: dict[Path, set[int]] = {}
    for i, (_rule, scopes) in enumerate(_RULES):
        for f in _files(pkg, scopes):
            all_files.setdefault(f, set()).add(i)
    for f in sorted(all_files):
        src = f.read_text()
        rel = _rel(f)
        try:
            tree = ast.parse(src)
        except SyntaxError as e:
            findings.append(Finding(
                "lints", "syntax-error", rel, e.lineno or 0,
                f"unparsable module: {e.msg}"))
            continue
        trees[rel] = tree
        for i in sorted(all_files[f]):
            findings.extend(_RULES[i][0](tree, src, rel))
    from .report import attach_symbols
    return attach_symbols(_dedupe_all(findings), trees)


def _dedupe_all(findings: list[Finding]) -> list[Finding]:
    seen: set[tuple] = set()
    out = []
    for f in findings:
        k = (f.rule, f.path, f.line, f.message)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out


# -- self-test ---------------------------------------------------------------

_SYNTHETIC = {
    "unseeded-rng": (
        "import numpy as np\nimport random\n"
        "x = np.random.rand(4)\n"
        "g = np.random.default_rng()\n"
        "y = random.random()\n"),
    "wallclock": (
        "import time\n"
        "def f():\n"
        "    return time.perf_counter()\n"),
    "unregistered-detector": (
        "class Rogue:\n"
        "    name = 'rogue'\n"
        "    def prepare(self, graph, mesh, profile=None, cfg=None):\n"
        "        return self\n"
        "    def analyse(self, sim):\n"
        "        return None\n"
        "class RoguePolicy:\n"
        "    name = 'roguepol'\n"
        "    def plan(self, verdict, mapped, mesh, cfg=None):\n"
        "        return None\n"
        "    def apply(self, plan, mapped, cfg=None):\n"
        "        return mapped\n"
        "class RogueTopo:\n"
        "    def route(self, src, dst):\n"
        "        return []\n"
        "    def hops(self, src, dst):\n"
        "        return 0\n"),
    "set-iteration": (
        "def f(xs):\n"
        "    used = set(xs)\n"
        "    return list(used)\n"),
}

_SYNTHETIC_CLEAN = (
    # every shape the rules must NOT flag
    "import time\nimport numpy as np\n"
    "from .detectors import _register_builtin\n"
    "def now():\n"
    "    return time.perf_counter()  # lint: allow-wallclock\n"
    "def noise(rng):\n"
    "    return rng.normal() + np.random.default_rng(7).normal()\n"
    "class Fine:\n"
    "    name = 'fine'\n"
    "    def prepare(self, *a, **k):\n"
    "        return self\n"
    "    def analyse(self, sim):\n"
    "        return None\n"
    "ALL = [Fine]\n"
    "for _cls in ALL:\n"
    "    _register_builtin(_cls.name, _cls)\n"
    "class FinePolicy:\n"
    "    name = 'finepol'\n"
    "    def plan(self, verdict, mapped, mesh, cfg=None):\n"
    "        return None\n"
    "    def apply(self, plan, mapped, cfg=None):\n"
    "        return mapped\n"
    "register_policy('finepol', FinePolicy)\n"
    "class FineTopo:\n"
    "    def route(self, src, dst):\n"
    "        return []\n"
    "    def hops(self, src, dst):\n"
    "        return 0\n"
    "register_topology('finetopo', FineTopo)\n"
    "class AbstractFabric:\n"
    "    def route(self, src, dst):\n"
    "        raise NotImplementedError\n"
    "    def hops(self, src, dst):\n"
    "        raise NotImplementedError\n"
    "class DetourWrapper:\n"
    "    def route(self, src, dst):\n"
    "        return list(self.base.route(src, dst))\n"
    "def g(xs, links):\n"
    "    used = set(xs)\n"
    "    routers = {c for lid in used for c in links[lid]}\n"
    "    return tuple(sorted(used)), tuple(sorted(routers))\n")


def self_test() -> None:
    """Plant one synthetic violation per rule and assert it is caught;
    assert the allowlisted/registered/sorted shapes stay clean and
    every real-tree finding is carried by the shipped baseline."""
    from .report import load_baseline
    baseline = load_baseline()
    new = [f for f in check() if f.fingerprint not in baseline]
    assert new == [], \
        "lint findings missing from analysis/baseline.json (fix, " \
        "allowlist, or --update-baseline):\n" + "\n".join(
            f"{f.render()}  fp={f.fingerprint}" for f in new)
    for rule, src in _SYNTHETIC.items():
        got = {f.rule for f in lint_source(src, "<synthetic>")}
        assert rule in got, \
            f"rule {rule} not triggered (got {got or 'nothing'})"
    planted = lint_source(_SYNTHETIC["unregistered-detector"],
                          "<synthetic>")
    caught = {f.message.split()[1] for f in planted}
    assert {"Rogue", "RoguePolicy", "RogueTopo"} <= caught, \
        f"all three registry duck types must be caught (got {caught})"
    benign = lint_source(_SYNTHETIC_CLEAN, "<synthetic-clean>")
    assert benign == [], \
        "false positives on benign shapes:\n" + "\n".join(
            f.render() for f in benign)
