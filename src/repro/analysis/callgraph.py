"""Module-aware call graph over the repro package (pure AST).

The interprocedural dataflow pass (:mod:`.dataflow`) needs to answer
questions no file-local lint can: *who calls this function, and with
what argument expressions?*  This module builds that index without
importing anything — every module is parsed once, imports (including
relative ``from ..core import x`` forms) are resolved to dotted module
paths, and calls whose target statically resolves to another in-package
function or class constructor become edges carrying the original
``ast.Call`` node, so a taint analysis can walk from a formal parameter
back to every actual argument in the package.

Resolution is deliberately conservative: only targets we can name
statically (direct calls, imported names, ``module.attr`` chains,
``self.method`` inside a class, and ``Class(...)`` constructors mapping
to ``Class.__init__``) produce edges.  Dynamic dispatch produces *no*
edge — callers must treat "no edge" as "unknown", never as "safe".
"""

from __future__ import annotations

import ast
import dataclasses


@dataclasses.dataclass
class FunctionInfo:
    """One function or method, addressable by qualified name
    (``pkg.module.fn`` or ``pkg.module.Class.method``)."""
    qualname: str
    module: str
    path: str
    node: ast.FunctionDef | ast.AsyncFunctionDef
    class_name: str | None = None

    @property
    def params(self) -> list[str]:
        """Positional parameter names, ``self``/``cls`` stripped for
        methods."""
        names = [a.arg for a in self.node.args.posonlyargs
                 + self.node.args.args]
        if self.class_name and names and names[0] in ("self", "cls"):
            names = names[1:]
        return names


@dataclasses.dataclass
class ModuleInfo:
    name: str
    path: str
    source: str
    tree: ast.Module
    imports: dict[str, str]     # local alias -> dotted target


@dataclasses.dataclass
class CallSite:
    """One resolved call: ``call`` appears inside ``caller`` (or at
    module level when ``caller`` is None) and targets ``callee``."""
    callee: str
    call: ast.Call
    module: str
    caller: FunctionInfo | None


def _resolve_import_module(current: str, node: ast.ImportFrom) -> str:
    """Dotted module an ``ImportFrom`` refers to, resolving relative
    levels against the importing module's own dotted name."""
    if node.level == 0:
        return node.module or ""
    parts = current.split(".")
    base = parts[:len(parts) - node.level]
    if node.module:
        base.append(node.module)
    return ".".join(base)


def module_imports(name: str, tree: ast.Module) -> dict[str, str]:
    """Local alias → fully-dotted imported target for one module."""
    imports: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                imports[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom):
            mod = _resolve_import_module(name, node)
            for a in node.names:
                target = f"{mod}.{a.name}" if mod else a.name
                imports[a.asname or a.name] = target
    return imports


def _dotted(node: ast.expr) -> str | None:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class CallGraph:
    """Functions, classes and resolved call edges over a module set."""

    def __init__(self):
        self.modules: dict[str, ModuleInfo] = {}
        self.functions: dict[str, FunctionInfo] = {}
        self._sites: dict[str, list[CallSite]] = {}

    # -- construction --------------------------------------------------------

    @classmethod
    def build(cls, modules: dict[str, tuple[str, str]]) -> "CallGraph":
        """``modules`` maps dotted module name → (source, display path).
        Unparsable modules are skipped (the lint pass reports those)."""
        g = cls()
        for name, (source, path) in sorted(modules.items()):
            try:
                tree = ast.parse(source)
            except SyntaxError:
                continue
            g.modules[name] = ModuleInfo(
                name, path, source, tree, module_imports(name, tree))
        for mod in g.modules.values():
            g._collect_functions(mod)
        for mod in g.modules.values():
            g._collect_calls(mod)
        return g

    def _collect_functions(self, mod: ModuleInfo) -> None:
        for stmt in mod.tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{mod.name}.{stmt.name}"
                self.functions[q] = FunctionInfo(q, mod.name, mod.path,
                                                 stmt)
            elif isinstance(stmt, ast.ClassDef):
                for sub in stmt.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        q = f"{mod.name}.{stmt.name}.{sub.name}"
                        self.functions[q] = FunctionInfo(
                            q, mod.name, mod.path, sub,
                            class_name=stmt.name)

    def _collect_calls(self, mod: ModuleInfo) -> None:
        # Attribute every call to its innermost *named* enclosing
        # function (module-level calls get caller=None).  Defs nested
        # inside statement bodies attribute to the outer function —
        # coarse but sound for taint purposes.
        def handle(stmts, caller: FunctionInfo | None,
                   cls: str | None) -> None:
            for stmt in stmts:
                if isinstance(stmt, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    q = f"{mod.name}." + \
                        (f"{cls}.{stmt.name}" if cls else stmt.name)
                    handle(stmt.body, self.functions.get(q, caller),
                           cls)
                elif isinstance(stmt, ast.ClassDef):
                    handle(stmt.body, caller, stmt.name)
                else:
                    for node in ast.walk(stmt):
                        if isinstance(node, ast.Call):
                            callee = self.resolve_call(mod, node, cls)
                            if callee is not None:
                                self._sites.setdefault(callee, [])\
                                    .append(CallSite(callee, node,
                                                     mod.name, caller))

        handle(mod.tree.body, None, None)

    # -- queries -------------------------------------------------------------

    def resolve_call(self, mod: ModuleInfo, call: ast.Call,
                     cls: str | None = None) -> str | None:
        """Qualified name of an in-package function/constructor this
        call targets, or None when the target is dynamic/external."""
        dotted = _dotted(call.func)
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        if head == "self" and cls and rest and "." not in rest:
            return self._known(f"{mod.name}.{cls}.{rest}")
        if not rest and f"{mod.name}.{head}" in self.functions:
            return f"{mod.name}.{head}"
        target = mod.imports.get(head)
        if target is None:
            if not rest:
                return self._known_ctor(f"{mod.name}.{head}")
            return None
        full = f"{target}.{rest}" if rest else target
        return self._known(full) or self._known_ctor(full)

    def _known(self, qualname: str) -> str | None:
        return qualname if qualname in self.functions else None

    def _known_ctor(self, qualname: str) -> str | None:
        init = f"{qualname}.__init__"
        return init if init in self.functions else None

    def sites_for(self, qualname: str) -> list[CallSite]:
        return self._sites.get(qualname, [])

    def full_target(self, mod: ModuleInfo, call: ast.Call) -> str | None:
        """Fully-dotted (possibly external) target of a call, with the
        head alias resolved through the module's imports —
        ``np.random.default_rng`` → ``numpy.random.default_rng``."""
        dotted = _dotted(call.func)
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        target = mod.imports.get(head, head)
        return f"{target}.{rest}" if rest else target


def argument_for(call: ast.Call, func: FunctionInfo,
                 param: str) -> ast.expr | None:
    """The actual argument expression bound to ``param`` at this call
    site (positional or keyword), or None if unbound/starred."""
    params = func.params
    if param not in params:
        return None
    for kw in call.keywords:
        if kw.arg == param:
            return kw.value
    idx = params.index(param)
    if idx < len(call.args):
        arg = call.args[idx]
        if not isinstance(arg, ast.Starred):
            return arg
    return None
