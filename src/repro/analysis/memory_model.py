"""Static (execution-free) model of the recorder's on-chip footprint.

SLOTH's headline claim is that on-chip detection is practical because the
Fail-Slow Sketch fits in kilobytes of SRAM.  This module makes that claim
*checkable at construction time*: closed-form byte counts for every
resident structure, derived only from :class:`~repro.core.sketch.
SketchParams` / ``SlothConfig`` — no arrays are allocated, no JAX is
imported.

Three layers of accounting, each matching a measured quantity exactly
(property-tested in ``tests/test_analysis.py``):

* **Paper accounting** (``accounting_bytes`` = ``SketchParams.
  total_bytes()``): Stage-1 ``d×m`` (lo, hi, freq) entries at 12 B plus
  Stage-2 ``L`` slots at :data:`~repro.core.sketch.STAGE2_SLOT_BYTES`
  each — the figure every compression ratio in the campaign uses, and
  what ``RecorderOutput.onchip_bytes()`` reports.
* **Ref-impl arrays** (``ref_stage1_nbytes``): the numpy oracle's actual
  Stage-1 array ``nbytes`` (int32 lo + int32 hi + bool valid + int64
  freq = 17 B/bucket).
* **Batched-impl arrays** (``packed_state_bytes`` / ``drain_bytes`` /
  ``pallas_vmem_bytes``): the packed jnp state of
  ``kernels/sketch_update/ref.make_state`` (4 int32 ``[d,m]`` tables +
  11 ``[L]`` vectors (5 int32, 6 f32) + a scalar counter), the
  drained-eviction buffer of ``make_drain`` (10 ``[cap]`` arrays + a
  scalar), and the Pallas kernel's full VMEM-resident set (those two
  plus the 6 streamed trace arrays of one ``block``) — mirroring the
  ``BlockSpec`` shapes in ``kernels/sketch_update/kernel.py``.

The budget check (:func:`validate_config` / :func:`validate_params`)
gates the *persistent* per-chip footprint: for each side (comp + comm)
the larger of the accounting bytes and — on ``impl="batched"`` — the
packed-state bytes, summed, against ``budget_kb`` KiB.  It is wired into
``Sloth.__init__`` and ``StreamingRecorder.__init__`` so an over-budget
geometry is rejected before any trace is recorded.
"""

from __future__ import annotations

from ..core.sketch import STAGE2_SLOT_BYTES, SketchParams
from .report import Finding

#: Default per-chip budget, KiB (1 KiB = 1024 B).  The paper's recorder
#: operates in the hundreds-of-KiB SRAM regime (Figs 11/12 report
#: per-side sketch storage well under this); the repo's default geometry
#: (d=2, m=1024, L=1024, both sides) uses 128 KiB accounting / ~152 KiB
#: packed — comfortably inside, while leaving headroom for the pod
#: telemetry geometry (L=2048, ~240 KiB packed).
DEFAULT_BUDGET_KB = 256.0

#: Bytes per Stage-1 bucket in the paper accounting (lo + hi + freq).
STAGE1_ENTRY_BYTES = 4 + 4 + 4

#: Bytes per Stage-1 bucket in the numpy oracle's actual arrays
#: (int32 lo + int32 hi + bool valid + int64 freq).
REF_STAGE1_ENTRY_BYTES = 4 + 4 + 1 + 8

#: Bytes per Stage-1 bucket in the packed jnp state (4 int32 tables).
PACKED_STAGE1_ENTRY_BYTES = 4 * 4

#: Bytes per Stage-2 slot in the packed jnp state (5 int32 + 6 f32
#: vectors: lo, hi, valid, count, arrival / sum, sumsq, val, tmin, tmax,
#: min).
PACKED_STAGE2_SLOT_BYTES = 5 * 4 + 6 * 4

#: Bytes per drained-eviction row (10 × 4 B arrays in ``make_drain``).
DRAIN_ROW_BYTES = 10 * 4

#: Streamed trace arrays in the Pallas kernel: lo, hi, act (int32) +
#: dur, val, t (f32) — bytes per record of one grid block.
TRACE_RECORD_BYTES = 6 * 4


class MemoryBudgetError(ValueError):
    """A sketch geometry exceeds the configured on-chip byte budget."""


# -- closed forms ------------------------------------------------------------

def accounting_bytes(p: SketchParams) -> int:
    """Paper accounting for one side: Stage-1 + Stage-2
    (= ``p.total_bytes()``, restated here as the model's ground truth)."""
    return (p.d * p.m * STAGE1_ENTRY_BYTES
            + p.L * STAGE2_SLOT_BYTES)


def ref_stage1_nbytes(p: SketchParams) -> int:
    """Summed ``nbytes`` of the numpy oracle's Stage-1 arrays
    (``FailSlowSketch.keys_lo/keys_hi/valid/freq``)."""
    return p.d * p.m * REF_STAGE1_ENTRY_BYTES


def packed_state_bytes(p: SketchParams) -> int:
    """Summed ``nbytes`` of ``kernels/sketch_update/ref.make_state(p)``:
    4 Stage-1 tables, 11 Stage-2 vectors, the scalar arrival counter."""
    return (p.d * p.m * PACKED_STAGE1_ENTRY_BYTES
            + p.L * PACKED_STAGE2_SLOT_BYTES
            + 4)


def drain_bytes(capacity: int) -> int:
    """Summed ``nbytes`` of ``kernels/sketch_update/ref.make_drain``:
    10 per-row arrays (capacity floored at 1) plus the scalar ``d_n``."""
    return max(int(capacity), 1) * DRAIN_ROW_BYTES + 4


def pallas_vmem_bytes(p: SketchParams, *, block: int = 256,
                      drain_capacity: int = 256) -> int:
    """VMEM-resident set of one ``sketch_insert`` call: the streamed
    trace block (``trace_spec`` × 6 arrays), the aliased packed state
    (pinned across the sequential grid), and the drain buffer.  Derived
    from the BlockSpec shapes in ``kernels/sketch_update/kernel.py``."""
    return (block * TRACE_RECORD_BYTES
            + packed_state_bytes(p)
            + drain_bytes(drain_capacity))


def side_budget_bytes(p: SketchParams, impl: str) -> int:
    """Persistent on-chip bytes one side of the recorder must hold:
    the paper accounting, or the packed jnp state when that is larger
    (``impl="batched"`` keeps the packed layout resident)."""
    b = accounting_bytes(p)
    if impl == "batched":
        b = max(b, packed_state_bytes(p))
    return b


# -- reporting ---------------------------------------------------------------

def memory_report(params: SketchParams,
                  comm_params: SketchParams | None = None,
                  impl: str = "ref", *, block: int = 256) -> dict:
    """Full per-chip footprint breakdown for one recorder geometry.
    Pure arithmetic — safe to call from the CLI and from tests without
    touching JAX."""
    comm_params = comm_params or params
    sides = {"comp": params, "comm": comm_params}
    rep: dict = {"impl": impl, "sides": {}}
    for name, p in sides.items():
        rep["sides"][name] = {
            "params": {"d": p.d, "m": p.m, "H": p.H, "L": p.L},
            "accounting_bytes": accounting_bytes(p),
            "stage1_bytes": p.stage1_bytes(),
            "stage2_bytes": p.stage2_bytes(),
            "ref_stage1_nbytes": ref_stage1_nbytes(p),
            "packed_state_bytes": packed_state_bytes(p),
            "pallas_vmem_bytes": pallas_vmem_bytes(
                p, block=block, drain_capacity=block),
            "budget_bytes": side_budget_bytes(p, impl),
        }
    rep["total_budget_bytes"] = sum(
        s["budget_bytes"] for s in rep["sides"].values())
    return rep


def _over_budget_message(rep: dict, budget_kb: float) -> str | None:
    total = rep["total_budget_bytes"]
    if total <= budget_kb * 1024:
        return None
    parts = ", ".join(
        f"{name}: d={s['params']['d']} m={s['params']['m']} "
        f"L={s['params']['L']} → {s['budget_bytes']} B"
        for name, s in rep["sides"].items())
    return (f"sketch geometry needs {total} B "
            f"({total / 1024:.1f} KiB) on-chip for impl="
            f"{rep['impl']!r}, over the {budget_kb:g} KiB budget "
            f"({parts}); shrink d/m/L or raise budget_kb")


# -- construction-time guards ------------------------------------------------

def validate_params(params: SketchParams,
                    comm_params: SketchParams | None = None,
                    impl: str = "ref",
                    budget_kb: float | None = DEFAULT_BUDGET_KB) -> None:
    """Raise :class:`MemoryBudgetError` if the comp+comm sketch geometry
    cannot fit the per-chip ``budget_kb`` KiB budget under ``impl``.
    ``budget_kb=None`` disables the check (benchmark sweeps deliberately
    explore over-budget geometries through the unguarded ``record``)."""
    if budget_kb is None:
        return
    rep = memory_report(params, comm_params, impl)
    msg = _over_budget_message(rep, budget_kb)
    if msg is not None:
        raise MemoryBudgetError(msg)


def validate_config(cfg) -> None:
    """Construction guard for ``SlothConfig``-shaped configs: check
    ``cfg.sketch`` (both sides) against ``cfg.budget_kb`` under
    ``cfg.recorder_impl``.  Duck-typed so ``PodTelemetryConfig`` (same
    three fields) validates through the same door.  Raises
    :class:`MemoryBudgetError`; a config with ``budget_kb=None`` is
    exempt."""
    validate_params(cfg.sketch,
                    impl=getattr(cfg, "recorder_impl", "ref"),
                    budget_kb=getattr(cfg, "budget_kb",
                                      DEFAULT_BUDGET_KB))


# -- CLI pass ----------------------------------------------------------------

def check(root=None, budget_kb: float | None = None) -> list[Finding]:
    """Static memory pass over the repo's shipped geometries: the default
    ``SlothConfig`` and the pod-telemetry config must fit their budgets,
    and the closed forms above must agree with the authoritative
    ``SketchParams`` byte methods (drift in either is a finding).
    ``root`` is accepted for pass-signature uniformity and unused."""
    findings: list[Finding] = []

    def against(label: str, path: str, params, impl, kb) -> None:
        rep = memory_report(params, impl=impl)
        msg = _over_budget_message(rep, kb)
        if msg is not None:
            findings.append(Finding("memory", "over-budget", path, 0,
                                    f"{label}: {msg}"))

    # model drift: the closed forms must restate SketchParams exactly
    for p in (SketchParams(), SketchParams(d=3, m=7, H=2, L=5)):
        if accounting_bytes(p) != p.total_bytes():
            findings.append(Finding(
                "memory", "model-drift", "src/repro/core/sketch.py", 0,
                f"accounting_bytes({p}) = {accounting_bytes(p)} != "
                f"SketchParams.total_bytes() = {p.total_bytes()}"))
        if p.stage2_bytes() != p.L * STAGE2_SLOT_BYTES:
            findings.append(Finding(
                "memory", "model-drift", "src/repro/core/sketch.py", 0,
                f"stage2_bytes({p}) is not L * STAGE2_SLOT_BYTES "
                f"({p.stage2_bytes()} != {p.L * STAGE2_SLOT_BYTES})"))

    from ..core.sloth import SlothConfig
    cfg = SlothConfig()
    kb = budget_kb if budget_kb is not None else cfg.budget_kb
    for impl in ("ref", "batched"):
        against(f"default SlothConfig (impl={impl})",
                "src/repro/core/sloth.py", cfg.sketch, impl, kb)

    try:
        from ..distributed.telemetry import PodTelemetryConfig
    except Exception:   # distributed extras may be absent in slim builds
        pass
    else:
        pod = PodTelemetryConfig()
        pod_kb = budget_kb if budget_kb is not None \
            else getattr(pod, "budget_kb", DEFAULT_BUDGET_KB)
        against("PodTelemetryConfig",
                "src/repro/distributed/telemetry.py", pod.sketch,
                getattr(pod, "recorder_impl", "ref"), pod_kb)
    return findings


def self_test() -> None:
    """Plant a synthetic violation and assert the pass catches it."""
    # clean tree: shipped geometries fit
    assert check() == [], f"clean-tree memory findings: {check()}"
    # synthetic violation: a 64k-bucket Stage-1 blows the default budget
    big = SketchParams(m=65536)
    rep = memory_report(big, impl="batched")
    msg = _over_budget_message(rep, DEFAULT_BUDGET_KB)
    assert msg is not None, "over-budget geometry not flagged"
    try:
        validate_params(big, budget_kb=DEFAULT_BUDGET_KB)
    except MemoryBudgetError:
        pass
    else:
        raise AssertionError("validate_params accepted an over-budget "
                             "geometry")
    # the guard honours budget_kb=None (benchmarks explore big sweeps)
    validate_params(big, budget_kb=None)
    # seeding the CLI pass with a tiny budget must produce findings
    planted = check(budget_kb=1.0)
    assert any(f.rule == "over-budget" for f in planted), \
        "check(budget_kb=1.0) produced no over-budget finding"
