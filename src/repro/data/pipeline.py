"""Deterministic, shardable, checkpointable synthetic-token data pipeline.

Real deployments stream tokenized shards; for a self-contained repo the
stream is a counter-based PRNG (threefry via jax on CPU is slow at scale, so
we use a splitmix64-style integer hash in numpy): batch ``i`` is a pure
function of (seed, i), so any host can materialise any step independently —
which is what makes restart/elastic-reshard trivial: the checkpoint stores
only ``step``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + _GOLDEN).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass
class DataConfig:
    vocab: int
    batch: int          # global batch
    seq: int
    seed: int = 0


class TokenPipeline:
    """Iterator with explicit state=(step,) and host-shard slicing."""

    def __init__(self, cfg: DataConfig, shard: int = 0, n_shards: int = 1,
                 step: int = 0):
        assert cfg.batch % n_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.n_shards = n_shards
        self.step = step

    def batch_at(self, step: int) -> np.ndarray:
        """Global batch for ``step`` (any host can compute any shard)."""
        c = self.cfg
        base = (np.uint64(c.seed) << np.uint64(32)) + np.uint64(step)
        idx = np.arange(c.batch * c.seq, dtype=np.uint64) \
            + base * np.uint64(c.batch * c.seq)
        toks = _splitmix64(idx) % np.uint64(c.vocab)
        return toks.astype(np.int32).reshape(c.batch, c.seq)

    def shard_at(self, step: int) -> np.ndarray:
        b = self.cfg.batch // self.n_shards
        return self.batch_at(step)[self.shard * b:(self.shard + 1) * b]

    def __next__(self) -> np.ndarray:
        out = self.shard_at(self.step)
        self.step += 1
        return out

    # -- checkpointable state ------------------------------------------------
    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    @classmethod
    def restore(cls, cfg: DataConfig, state: dict, shard=0, n_shards=1):
        assert state["seed"] == cfg.seed, "data stream seed changed"
        return cls(cfg, shard, n_shards, step=state["step"])
