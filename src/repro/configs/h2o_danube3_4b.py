"""H2O-Danube3-4B [arXiv:2401.16818 lineage]: llama+mistral mix with
sliding-window attention."""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="h2o-danube-3-4b", n_layers=24, d_model=3840, n_heads=32,
        n_kv_heads=8, d_ff=10240, vocab=32000, window=4096,
        mlp="swiglu", norm="rms", rope_theta=1e4, family="dense")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="h2o-danube-3-4b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, window=16, mlp="swiglu",
        norm="rms", family="dense")


register("h2o-danube-3-4b", full, smoke)
