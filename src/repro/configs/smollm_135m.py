"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M]: small llama-arch model."""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="smollm-135m", n_layers=30, d_model=576, n_heads=9,
        n_kv_heads=3, d_ff=1536, vocab=49152, mlp="swiglu", norm="rms",
        tie_embeddings=True, family="dense")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="smollm-135m-smoke", n_layers=2, d_model=48, n_heads=3,
        n_kv_heads=1, d_ff=96, vocab=256, mlp="swiglu", norm="rms",
        tie_embeddings=True, family="dense")


register("smollm-135m", full, smoke)
