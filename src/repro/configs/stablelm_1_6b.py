"""StableLM-2-1.6B [hf:stabilityai/stablelm-2-1_6b]: MHA (kv=heads),
LayerNorm."""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="stablelm-1.6b", n_layers=24, d_model=2048, n_heads=32,
        n_kv_heads=32, d_ff=5632, vocab=100352, mlp="swiglu", norm="ln",
        family="dense")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="stablelm-1.6b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=160, vocab=256, mlp="swiglu", norm="ln",
        family="dense")


register("stablelm-1.6b", full, smoke)
