"""Mamba2-1.3B [arXiv:2405.21060]: attention-free SSD (state-space duality)."""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="mamba2-1.3b", n_layers=48, d_model=2048, n_heads=0,
        n_kv_heads=0, d_ff=0, vocab=50280, pos="none", mlp="swiglu",
        norm="rms", ssm_state=128, ssm_expand=2, ssm_groups=8,
        ssm_conv=4, ssm_head_dim=64, family="ssm")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="mamba2-1.3b-smoke", n_layers=2, d_model=64, n_heads=0,
        n_kv_heads=0, d_ff=0, vocab=256, pos="none", mlp="swiglu",
        norm="rms", ssm_state=16, ssm_expand=2, ssm_groups=2,
        ssm_conv=4, ssm_head_dim=32, family="ssm")


register("mamba2-1.3b", full, smoke)
