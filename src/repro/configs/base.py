"""Architecture configuration for the assigned LM-family models.

Every assigned architecture is an ``ArchConfig``; the model stack in
``repro.models`` builds (init, train_step, prefill, decode) from it.  Each
arch module also defines a reduced ``smoke()`` config of the same family for
CPU tests.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 → d_model // n_heads

    # block structure
    mlp: str = "swiglu"               # 'swiglu' | 'gelu'
    norm: str = "rms"                 # 'rms' | 'ln'
    pos: str = "rope"                 # 'rope' | 'mrope' | 'learned' | 'none'
    window: int | None = None         # sliding-window attention size
    rope_theta: float = 1e4

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_period: int = 1               # MoE every k-th layer
    capacity_factor: float = 1.25

    # SSM / hybrid
    attn_period: int = 0              # 0 → all attention; k → 1 attn per k
    attn_offset: int = 0              # which layer in the period is attention
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_head_dim: int = 64

    # encoder-decoder
    enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: str = "none"            # 'none' | 'audio_stub' | 'vision_stub'
    n_frames: int = 1500              # frontend stub sequence length

    tie_embeddings: bool = False
    family: str = "dense"             # dense | moe | ssm | hybrid | vlm | audio

    # -- derived -----------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_kind(self, li: int) -> str:
        if self.ssm_state == 0:
            return "attn"
        if self.attn_period == 0:
            return "mamba"
        return ("attn" if li % self.attn_period == self.attn_offset
                else "mamba")

    def is_moe_layer(self, li: int) -> bool:
        return self.n_experts > 0 and li % self.moe_period == \
            (self.moe_period - 1)

    @property
    def n_attn_layers(self) -> int:
        return sum(self.layer_kind(i) == "attn" for i in range(self.n_layers))

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k decode (window/state-bounded attention)."""
        return self.ssm_state > 0 or self.window is not None

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        if self.enc_dec:
            total += self.n_frames * d   # learned positions (stub frontend)
        for li in range(self.n_layers):
            total += 2 * d  # norms
            if self.layer_kind(li) == "attn":
                hq = self.n_heads * self.head_dim
                hk = self.n_kv_heads * self.head_dim
                total += d * (hq + 2 * hk) + hq * d
                if self.enc_dec:   # cross attention
                    total += d * (hq + 2 * hk) + hq * d + d
            else:
                di, n = self.d_inner, self.ssm_state
                total += d * (2 * di + 2 * self.ssm_groups * n
                              + self.ssm_heads)
                total += self.ssm_conv * (di + 2 * self.ssm_groups * n)
                total += di * d + 2 * self.ssm_heads
            if self.is_moe_layer(li):
                total += d * self.n_experts  # router
                total += self.n_experts * 3 * d * ff
            else:
                n_mats = 3 if self.mlp == "swiglu" else 2
                total += n_mats * d * ff
        if self.enc_dec:
            for _ in range(self.n_enc_layers):
                hq = self.n_heads * self.head_dim
                hk = self.n_kv_heads * self.head_dim
                total += 2 * d + d * (hq + 2 * hk) + hq * d + 2 * d * ff
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        inactive = 0
        for li in range(self.n_layers):
            if self.is_moe_layer(li):
                inactive += (self.n_experts - self.top_k) * 3 * d * ff
        return self.param_count() - inactive


_REGISTRY: dict[str, "tuple"] = {}


def register(arch_id: str, full, smoke):
    _REGISTRY[arch_id] = (full, smoke)


def get_config(arch_id: str, smoke: bool = False) -> ArchConfig:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    full, sm = _REGISTRY[arch_id]
    return sm() if smoke else full()


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    if _REGISTRY:
        return
    from . import (dbrx_132b, h2o_danube3_4b, jamba_1_5_large,   # noqa: F401
                   mamba2_1_3b, mixtral_8x7b, qwen2_vl_2b, smollm_135m,
                   stablelm_1_6b, whisper_large_v3, yi_34b)
