"""Jamba-1.5-Large (398B) [arXiv:2403.19887]: Mamba+attention 1:7 interleave
with 16-expert top-2 MoE every other layer.  Attention layers carry no
positional encoding (the Mamba layers provide position)."""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="jamba-1.5-large-398b", n_layers=72, d_model=8192, n_heads=64,
        n_kv_heads=8, d_ff=24576, vocab=65536, pos="none",
        n_experts=16, top_k=2, moe_period=2, mlp="swiglu", norm="rms",
        attn_period=8, attn_offset=4, ssm_state=128, ssm_expand=2,
        ssm_groups=8, ssm_conv=4, ssm_head_dim=64, family="hybrid")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="jamba-1.5-large-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, pos="none", n_experts=4,
        top_k=2, moe_period=2, mlp="swiglu", norm="rms", attn_period=4,
        attn_offset=2, ssm_state=16, ssm_expand=2, ssm_groups=2,
        ssm_conv=4, ssm_head_dim=32, family="hybrid")


register("jamba-1.5-large-398b", full, smoke)
