"""Mixtral-8x7B [arXiv:2401.04088]: 8-expert top-2 MoE with sliding-window
attention."""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x7b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=14336, vocab=32000, window=4096,
        n_experts=8, top_k=2, moe_period=1, mlp="swiglu", norm="rms",
        rope_theta=1e6, family="moe")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, window=16, n_experts=4,
        top_k=2, moe_period=1, mlp="swiglu", norm="rms", family="moe")


register("mixtral-8x7b", full, smoke)
