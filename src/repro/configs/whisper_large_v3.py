"""Whisper-large-v3 [arXiv:2212.04356]: encoder-decoder, conv/mel frontend is
a STUB (input_specs provides precomputed frame embeddings, 1500 frames)."""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="whisper-large-v3", n_layers=32, d_model=1280, n_heads=20,
        n_kv_heads=20, d_ff=5120, vocab=51866, pos="learned", mlp="gelu",
        norm="ln", enc_dec=True, n_enc_layers=32, frontend="audio_stub",
        n_frames=1500, family="audio")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="whisper-large-v3-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, pos="learned", mlp="gelu",
        norm="ln", enc_dec=True, n_enc_layers=2, frontend="audio_stub",
        n_frames=32, family="audio")


register("whisper-large-v3", full, smoke)
