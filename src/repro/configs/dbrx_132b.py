"""DBRX-132B [hf:databricks/dbrx-base]: 16-expert top-4 fine-grained MoE."""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=10752, vocab=100352, n_experts=16, top_k=4,
        moe_period=1, mlp="swiglu", norm="ln", rope_theta=5e5,
        family="moe")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="dbrx-132b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=96, vocab=256, n_experts=4, top_k=2,
        moe_period=1, mlp="swiglu", norm="ln", family="moe")


register("dbrx-132b", full, smoke)
