"""Yi-34B [arXiv:2403.04652]: llama-architecture GQA dense model."""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="yi-34b", n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=20480, vocab=64000, mlp="swiglu", norm="rms",
        rope_theta=5e6, family="dense")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="yi-34b-smoke", n_layers=3, d_model=64, n_heads=8,
        n_kv_heads=2, d_ff=192, vocab=256, mlp="swiglu", norm="rms",
        family="dense")


register("yi-34b", full, smoke)
