"""Qwen2-VL-2B text backbone [arXiv:2409.12191]: M-RoPE, dynamic-resolution
vision frontend is a STUB (input_specs provides patch embeddings)."""
from .base import ArchConfig, register


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-2b", n_layers=28, d_model=1536, n_heads=12,
        n_kv_heads=2, d_ff=8960, vocab=151936, pos="mrope",
        mlp="swiglu", norm="rms", rope_theta=1e6, tie_embeddings=True,
        frontend="vision_stub", family="vlm")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-2b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, pos="mrope", mlp="swiglu",
        norm="rms", tie_embeddings=True, frontend="vision_stub",
        family="vlm")


register("qwen2-vl-2b", full, smoke)
