"""Mitigation-policy protocol, plan type and string-keyed registry.

SLOTH's localisation only pays off when the system acts on it (the
faulty-accelerator reuse argument: degraded chips should keep serving by
steering work around bad resources).  This module mirrors the detector
registry in :mod:`repro.core.detectors` one-for-one: a
:class:`MitigationPolicy` turns a detector :class:`~repro.core.detectors.
Verdict` into a :class:`MitigationPlan` (which cores to stop placing work
on, which links to detour around) and then applies that plan to a
:class:`~repro.core.mapping.MappedGraph`, producing the deployment the
simulator re-runs over the remaining failure window.

Policies are stateless and deterministic: ``plan`` and ``apply`` must be
pure functions of their arguments, because campaign process-pool workers
rebuild policies independently and their mitigated outcomes must stay
bit-identical to the serial executor's.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Protocol, runtime_checkable

from ..core.detectors import Verdict
from ..core.mapping import MappedGraph
from ..core.routing import Topology

__all__ = [
    "MitigationPlan", "MitigationPolicy", "DEFAULT_POLICIES",
    "register_policy", "get_policy", "available_policies",
    "instantiate_policy", "flagged_sites", "work_done_frac",
]

#: Built-in policy names, in campaign/table order.
DEFAULT_POLICIES = ("remap", "reroute", "quarantine", "none")


@dataclasses.dataclass(frozen=True)
class MitigationPlan:
    """What a policy decided to do about one verdict.

    ``acted=False`` means the policy has nothing to act on (not-flagged
    verdict, or no site of a kind this policy handles) — ``apply`` is then
    the identity and the mitigated makespan equals the failed one, so the
    ``none`` control's recovered throughput is *exactly* zero.
    """
    policy: str
    acted: bool
    exclude_cores: tuple[int, ...] = ()   # cores dropped from placement
    avoid_links: tuple[int, ...] = ()     # links detoured around
    reason: str = ""                      # human-readable decision note


@runtime_checkable
class MitigationPolicy(Protocol):
    """A verdict-driven mitigation strategy.

    ``plan(verdict, mapped, mesh, cfg)`` decides the resource edits
    (``mapped`` may be ``None`` for plan-only consumers such as the pod
    telemetry bridge); ``apply(plan, mapped, cfg)`` materialises them into
    a new :class:`MappedGraph` without mutating the input.  Both must be
    deterministic — see the module docstring.
    """

    name: str

    def plan(self, verdict: Verdict, mapped: MappedGraph | None,
             mesh: Topology, cfg=None) -> MitigationPlan:
        ...

    def apply(self, plan: MitigationPlan, mapped: MappedGraph,
              cfg=None) -> MappedGraph:
        ...


# --- registry (mirrors core/detectors.py) --------------------------------

_REGISTRY: dict[str, Callable[[], MitigationPolicy]] = {}
_builtins_loaded = False


def register_policy(name: str, factory: Callable[[], MitigationPolicy], *,
                    overwrite: bool = False) -> None:
    """Register ``factory`` (a zero-arg callable returning a policy) under
    ``name``.  Extension point for user policies; the built-ins are
    pre-registered.  Campaign process-pool workers re-import modules in
    fresh interpreters, so a custom policy must be registered at import
    time of its defining module to be visible under ``executor='process'``.
    """
    key = str(name).lower()
    if not overwrite and key in _REGISTRY:
        raise ValueError(f"mitigation policy {key!r} is already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[key] = factory


def _register_builtin_policy(name: str,
                             factory: Callable[[], MitigationPolicy]) -> None:
    """Built-in registration: first registration wins, so a user's earlier
    ``register_policy(name, ..., overwrite=True)`` override of a built-in
    survives the lazy built-in import."""
    _REGISTRY.setdefault(str(name).lower(), factory)


def _ensure_builtins() -> None:
    global _builtins_loaded
    if not _builtins_loaded:
        from . import policies  # noqa: F401  (registers at import time)
        _builtins_loaded = True


def get_policy(name: str) -> Callable[[], MitigationPolicy]:
    """Resolve a policy factory by registry name (case-insensitive)."""
    _ensure_builtins()
    key = str(name).lower()
    try:
        return _REGISTRY[key]
    except KeyError:
        raise KeyError(
            f"unknown mitigation policy {name!r}; available: "
            f"{available_policies()}") from None


def available_policies() -> tuple[str, ...]:
    """Registered policy names: built-ins first (in ``DEFAULT_POLICIES``
    order), then user registrations in registration order."""
    _ensure_builtins()
    head = [n for n in DEFAULT_POLICIES if n in _REGISTRY]
    tail = [n for n in _REGISTRY if n not in DEFAULT_POLICIES]
    return tuple(head + tail)


def instantiate_policy(name: str) -> MitigationPolicy:
    """Resolve ``name`` and instantiate a policy, enforcing the registry
    contract that the instance's ``.name`` equals its (lowercased)
    registry key — mitigation tables are keyed on ``.name``."""
    key = str(name).lower()
    pol = get_policy(key)()
    if getattr(pol, "name", None) != key:
        raise ValueError(
            f"policy factory registered under {key!r} produced an instance "
            f"named {getattr(pol, 'name', None)!r}; the registry key and "
            f"MitigationPolicy.name must match (lowercase)")
    return pol


# --- verdict / stream helpers --------------------------------------------

def flagged_sites(verdict: Verdict) -> tuple[tuple[str, int], ...]:
    """The (kind, location) sites a verdict implicates, deduplicated in
    evidence order: every entry of ``flagged_resources`` when the detector
    reports per-resource flags (SLOTH), else the top-1 kind/location (the
    baselines leave ``flagged_resources`` empty)."""
    if not getattr(verdict, "flagged", False):
        return ()
    sites = [(str(k), int(loc)) for k, loc, _ in
             (getattr(verdict, "flagged_resources", ()) or ())]
    if not sites and verdict.kind is not None and verdict.location is not None:
        sites = [(str(verdict.kind), int(verdict.location))]
    return tuple(dict.fromkeys(sites))


def work_done_frac(sim, t: float) -> float:
    """FLOPs-weighted fraction of compute finished by stream time ``t``.

    Used to compose detection latency with recovery: a mid-stream
    mitigation at first flag keeps the work already completed and re-runs
    only the remainder on the mitigated deployment.
    """
    flops = sim.comp["flops"]
    total = float(flops.sum())
    if total <= 0.0:
        done = float((sim.comp["t_end"] <= t).mean()) if len(flops) else 1.0
        return min(max(done, 0.0), 1.0)
    done = float(flops[sim.comp["t_end"] <= t].sum()) / total
    return min(max(done, 0.0), 1.0)
