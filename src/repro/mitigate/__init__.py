"""Verdict-driven mitigation: act on detector output, measure recovery.

The detect → mitigate loop (ROADMAP item 6): a detector produces a
:class:`~repro.core.detectors.Verdict`, a registered
:class:`~repro.mitigate.policy.MitigationPolicy` turns it into a
:class:`~repro.mitigate.policy.MitigationPlan` (cores to exclude, links to
detour), and the simulator re-runs the mitigated deployment over the
remaining failure window.  ``run_campaign(mitigation=...)`` judges every
detector × policy cell and :mod:`repro.core.metrics` aggregates
*recovered throughput* — the sharpest end-to-end test of verdict quality,
because acting on a wrong verdict makes performance worse, not better.

Policies register exactly like detectors do: string-keyed factories via
:func:`register_policy`, built-ins pre-registered lazily.
"""

from .policies import (NonePolicy, QuarantinePolicy,  # noqa: F401
                       RemapPolicy, ReroutePolicy)
from .policy import (DEFAULT_POLICIES, MitigationPlan,  # noqa: F401
                     MitigationPolicy, available_policies, flagged_sites,
                     get_policy, instantiate_policy, register_policy,
                     work_done_frac)

__all__ = [
    "MitigationPlan", "MitigationPolicy", "DEFAULT_POLICIES",
    "register_policy", "get_policy", "available_policies",
    "instantiate_policy", "flagged_sites", "work_done_frac",
    "RemapPolicy", "ReroutePolicy", "QuarantinePolicy", "NonePolicy",
]
