"""Built-in mitigation policies: remap / reroute / quarantine / none.

Each policy reads the verdict's implicated sites (``flagged_sites``) and
edits the deployment the way an operator would:

* ``remap`` — re-run the Gemini-style mapper with verdict-flagged *cores*
  excluded from the placement pool.  Link/router sites are out of scope
  for remap (compute placement cannot dodge a slow wire).
* ``reroute`` — detour flows around flagged *links* via
  :class:`~repro.core.routing.DetourTopology`.  When several flagged links
  share one router (≥2 incident) the router itself is presumed slow and
  the policy falls back to remap for it: its core leaves the placement
  pool and *all* its links are detoured.  Core sites likewise fall back
  to remap-style exclusion.
* ``quarantine`` — belt and braces: drop the flagged resource *and* its
  neighbourhood (a core with its 4-neighbours; a link with both endpoint
  cores and every link touching them).
* ``none`` — the experimental control: never acts, so its mitigated
  makespan is the failed makespan and its recovered throughput is
  exactly zero.

All exclusion/avoidance tuples are sorted, so plans are deterministic and
identical across campaign executors.
"""

from __future__ import annotations

import dataclasses

from ..core.detectors import Verdict
from ..core.mapping import MappedGraph, map_graph
from ..core.routing import DetourTopology, Topology
from .policy import (MitigationPlan, _register_builtin_policy, flagged_sites)

__all__ = ["NonePolicy", "RemapPolicy", "ReroutePolicy", "QuarantinePolicy"]

#: flagged incident links at-or-above which a router (not just its links)
#: is presumed slow — a single flagged link touches two routers once each,
#: so the threshold of 2 never fires on an isolated link verdict.
ROUTER_LINK_THRESHOLD = 2


def _not_acted(name: str, reason: str) -> MitigationPlan:
    return MitigationPlan(policy=name, acted=False, reason=reason)


def _cap_exclusion(cores: list[int], n_cores: int) -> tuple[int, ...]:
    """Never exclude the whole mesh: keep at least one core alive by
    truncating the (sorted) exclusion list deterministically."""
    cores = sorted(dict.fromkeys(cores))
    if len(cores) >= n_cores:
        cores = cores[:n_cores - 1]
    return tuple(cores)


def _finish(name: str, mesh: Topology, exclude: list[int],
            avoid: list[int], reason: str) -> MitigationPlan:
    exclude_t = _cap_exclusion(exclude, mesh.n_cores)
    avoid_t = tuple(sorted(dict.fromkeys(int(l) for l in avoid)))
    if not exclude_t and not avoid_t:
        return _not_acted(name, reason or "no actionable site")
    return MitigationPlan(policy=name, acted=True, exclude_cores=exclude_t,
                          avoid_links=avoid_t, reason=reason)


def _apply_edits(plan: MitigationPlan, mapped: MappedGraph) -> MappedGraph:
    """Materialise a plan: wrap the fabric in a DetourTopology when links are
    avoided, re-map when cores are excluded, and leave ``mapped``
    untouched either way."""
    mesh: Topology = mapped.mesh
    if plan.avoid_links:
        mesh = DetourTopology(mapped.mesh, plan.avoid_links)
    if plan.exclude_cores:
        return map_graph(mapped.graph, mesh,
                         exclude_cores=plan.exclude_cores)
    if mesh is mapped.mesh:
        return mapped
    # placement is untouched; only path selection changes
    return dataclasses.replace(mapped, mesh=mesh)


class NonePolicy:
    """Control policy: observes the verdict, does nothing."""

    name = "none"

    def plan(self, verdict: Verdict, mapped: MappedGraph | None,
             mesh: Topology, cfg=None) -> MitigationPlan:
        return _not_acted(self.name, "control policy")

    def apply(self, plan: MitigationPlan, mapped: MappedGraph,
              cfg=None) -> MappedGraph:
        return mapped


class RemapPolicy:
    """Re-map the workload with verdict-flagged cores excluded."""

    name = "remap"

    def plan(self, verdict: Verdict, mapped: MappedGraph | None,
             mesh: Topology, cfg=None) -> MitigationPlan:
        sites = flagged_sites(verdict)
        if not sites:
            return _not_acted(self.name, "verdict not flagged")
        cores = [loc for kind, loc in sites if kind == "core"]
        if not cores:
            return _not_acted(self.name, "no core site to remap away from")
        return _finish(self.name, mesh, cores, [],
                       f"exclude {len(cores)} flagged core(s)")

    def apply(self, plan: MitigationPlan, mapped: MappedGraph,
              cfg=None) -> MappedGraph:
        return _apply_edits(plan, mapped)


class ReroutePolicy:
    """Detour flows around flagged links; fall back to remap for flagged
    cores and for routers implicated by ≥2 flagged incident links."""

    name = "reroute"

    def plan(self, verdict: Verdict, mapped: MappedGraph | None,
             mesh: Topology, cfg=None) -> MitigationPlan:
        sites = flagged_sites(verdict)
        if not sites:
            return _not_acted(self.name, "verdict not flagged")
        link_sites = [loc for kind, loc in sites if kind == "link"]
        core_sites = [loc for kind, loc in sites if kind == "core"]

        incident: dict[int, int] = {}
        for lid in dict.fromkeys(link_sites):
            for end in mesh.links[lid]:
                incident[end] = incident.get(end, 0) + 1
        slow_routers = sorted(c for c, n in incident.items()
                              if n >= ROUTER_LINK_THRESHOLD)

        exclude = list(core_sites)
        avoid = list(link_sites)
        notes = []
        if link_sites:
            notes.append(f"detour {len(dict.fromkeys(link_sites))} link(s)")
        if slow_routers:
            # router fallback: the router's core leaves the placement pool
            # and every one of its links is detoured
            for c in slow_routers:
                exclude.append(c)
                avoid.extend(mesh.links_of_router(c))
            notes.append(f"remap fallback for router(s) {slow_routers}")
        if core_sites:
            notes.append(f"remap fallback for {len(core_sites)} core site(s)")
        return _finish(self.name, mesh, exclude, avoid, "; ".join(notes))

    def apply(self, plan: MitigationPlan, mapped: MappedGraph,
              cfg=None) -> MappedGraph:
        return _apply_edits(plan, mapped)


class QuarantinePolicy:
    """Drop the flagged resource and its neighbourhood."""

    name = "quarantine"

    def plan(self, verdict: Verdict, mapped: MappedGraph | None,
             mesh: Topology, cfg=None) -> MitigationPlan:
        sites = flagged_sites(verdict)
        if not sites:
            return _not_acted(self.name, "verdict not flagged")
        exclude: list[int] = []
        avoid: list[int] = []
        for kind, loc in sites:
            if kind == "core":
                exclude.append(loc)
                exclude.extend(mesh.neighbours(loc))
            elif kind == "link":
                u, v = mesh.links[loc]
                exclude.extend((u, v))
                avoid.extend(mesh.links_of_router(u))
                avoid.extend(mesh.links_of_router(v))
        return _finish(self.name, mesh, exclude, avoid,
                       f"quarantine {len(sites)} site(s) + neighbourhood")

    def apply(self, plan: MitigationPlan, mapped: MappedGraph,
              cfg=None) -> MappedGraph:
        return _apply_edits(plan, mapped)


_register_builtin_policy("remap", RemapPolicy)
_register_builtin_policy("reroute", ReroutePolicy)
_register_builtin_policy("quarantine", QuarantinePolicy)
_register_builtin_policy("none", NonePolicy)
